#!/usr/bin/env python
"""Check that relative markdown links resolve to real files.

Usage: python tools/check_links.py README.md docs/ARCHITECTURE.md ...

Only repo-local file links are checked: http(s)/mailto URLs, pure
anchors, and paths that escape the repository root (e.g. GitHub web
paths like ``../../actions/...`` used by CI badges) are skipped.
Exits 1 listing every broken link.
"""
from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def check_file(md_path: str) -> list[str]:
    broken = []
    base = os.path.dirname(os.path.abspath(md_path))
    with open(md_path) as f:
        text = f.read()
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = os.path.normpath(os.path.join(base, target.split("#")[0]))
        if not path.startswith(REPO_ROOT + os.sep):
            continue  # escapes the repo (e.g. GitHub-web badge paths)
        if not os.path.exists(path):
            broken.append(f"{md_path}: broken link -> {target}")
    return broken


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_links.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    broken = []
    for md in argv:
        broken += check_file(md)
    for b in broken:
        print(b, file=sys.stderr)
    if not broken:
        print(f"ok: all repo-local links in {len(argv)} file(s) resolve")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
