"""Bench-regression gate: diff a fresh BENCH_results.json against the
committed benchmarks/baseline.json.

CI's bench-smoke job runs the benchmark harness at smoke sizes, then this
tool compares every row's ``us_per_call`` to the committed baseline and
fails the job when a metric regressed past its table's tolerance. The
default tolerance is deliberately generous (shared runners are noisy and
the baseline may have been recorded on different silicon): the gate exists
to catch structural regressions — an accidental serial fallback, a
recompile per call, an O(N) -> O(N^2) slip — not single-digit-percent
drift. Tighten per table with ``--table-tolerance`` when a metric is known
to be stable.

Usage:
  python tools/bench_compare.py                         # compare + report
  python tools/bench_compare.py --tolerance 2.0         # global override
  python tools/bench_compare.py --table-tolerance table7=3.0 ...
  python tools/bench_compare.py --update                # rewrite baseline

Rows present in the baseline but missing from a table the fresh run
attempted count as regressions (a renamed/dropped metric must update the
baseline explicitly); tables absent from the fresh run entirely are
skipped, matching run.py's per-table merge semantics. A markdown delta
table is always printed, and appended to $GITHUB_STEP_SUMMARY when set.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(_ROOT, "BENCH_results.json")
BASELINE = os.path.join(_ROOT, "benchmarks", "baseline.json")

#: default allowed slowdown factor: fresh_us <= tol * baseline_us passes
DEFAULT_TOLERANCE = 2.5

#: built-in per-table overrides (CLI --table-tolerance wins): table9's
#: end-to-end serving rows and table10's sub-millisecond instrumentation
#: probes are the noisiest metrics in the suite on shared runners;
#: table11's sweep rows depend on whether the autotune cache answered;
#: table12's end-to-end ingest walls swing with host core count (the
#: engine changes pipeline mode on single-CPU runners)
DEFAULT_TABLE_TOLERANCES = {"table9": 5.0, "table10": 5.0, "table11": 5.0,
                            "table12": 5.0}


def _table_of(name: str) -> str:
    """'table7.get_versions_s2_q32' -> 'table7' (run.py's table key)."""
    return name.split(".")[0]


def _load(path: str) -> dict[str, float]:
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in data.get("results", [])
            if "name" in r and "us_per_call" in r}


def compare(base: dict[str, float], fresh: dict[str, float],
            tolerance: float, table_tol: dict[str, float]):
    """Returns (rows, regressions): rows are markdown cells for every
    baseline metric of an attempted table; regressions the failing names."""
    attempted = {_table_of(n) for n in fresh}
    rows, regressions = [], []
    for name in sorted(base):
        table = _table_of(name)
        if table not in attempted:
            continue
        tol = table_tol.get(table, tolerance)
        b = base[name]
        f = fresh.get(name)
        if f is None:
            rows.append((name, b, None, None, tol, "MISSING"))
            regressions.append(name)
            continue
        ratio = f / b if b > 0 else float("inf")
        ok = ratio <= tol
        rows.append((name, b, f, ratio, tol, "ok" if ok else "REGRESSED"))
        if not ok:
            regressions.append(name)
    for name in sorted(set(fresh) - set(base)):
        rows.append((name, None, fresh[name], None, None, "new"))
    return rows, regressions


def render(rows) -> str:
    out = ["| metric | baseline us | fresh us | ratio | tol | status |",
           "|---|---|---|---|---|---|"]

    def fmt(v, suf=""):
        return "-" if v is None else f"{v:.1f}{suf}"

    for name, b, f, ratio, tol, status in rows:
        out.append(f"| {name} | {fmt(b)} | {fmt(f)} | "
                   f"{fmt(ratio, 'x')} | {fmt(tol, 'x')} | {status} |")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--results", default=RESULTS,
                    help="fresh results json (default: BENCH_results.json)")
    ap.add_argument("--baseline", default=BASELINE,
                    help="committed baseline json")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_COMPARE_TOLERANCE",
                                                 DEFAULT_TOLERANCE)),
                    help="allowed slowdown factor (default %(default)s, "
                    "env BENCH_COMPARE_TOLERANCE)")
    ap.add_argument("--table-tolerance", action="append", default=[],
                    metavar="TABLE=TOL",
                    help="per-table override, e.g. table7=3.0 (repeatable)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the fresh results "
                    "(merging per table, like run.py) instead of comparing")
    args = ap.parse_args(argv)

    table_tol = dict(DEFAULT_TABLE_TOLERANCES)
    for spec in args.table_tolerance:
        table, _, tol = spec.partition("=")
        try:
            table_tol[table] = float(tol)
        except ValueError:
            ap.error(f"bad --table-tolerance {spec!r} (want TABLE=FLOAT)")

    fresh = _load(args.results)
    if args.update:
        old = _load(args.baseline) if os.path.exists(args.baseline) else {}
        attempted = {_table_of(n) for n in fresh}
        merged = {n: v for n, v in old.items()
                  if _table_of(n) not in attempted}
        merged.update(fresh)
        payload = {"results": [{"name": n, "us_per_call": merged[n]}
                               for n in sorted(merged)]}
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"baseline updated: {len(fresh)} rows merged into "
              f"{args.baseline} ({len(merged)} total)")
        return 0

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; run with --update to seed it",
              file=sys.stderr)
        return 2
    base = _load(args.baseline)
    rows, regressions = compare(base, fresh, args.tolerance, table_tol)
    report = render(rows)
    print(report)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write("## Benchmark comparison\n\n" + report + "\n")
    if regressions:
        print(f"\n{len(regressions)} metric(s) regressed past tolerance: "
              + ", ".join(regressions), file=sys.stderr)
        return 1
    print(f"\nall {sum(1 for r in rows if r[5] == 'ok')} compared metrics "
          "within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
