"""Persistence demo: the segmented storage lifecycle (paper §III.B/§IV).

save -> add release -> incremental save -> lazy load -> compact, with the
byte counts printed at each step so the append-only property is visible:
persisting one new release writes O(new cells), not O(history).

Run: PYTHONPATH=src python examples/persistence_demo.py
"""
import os
import tempfile

import numpy as np

from repro.core import segments
from repro.core.store import FieldSchema, VersionedStore


def release(rng, n, width=16):
    return {"profile": rng.integers(0, 1000, (n, width)).astype(np.int32),
            "score": rng.normal(size=(n, 2)).astype(np.float32)}


def main():
    rng = np.random.default_rng(42)
    n = 2000
    keys = [f"UP{i:06d}" for i in range(n)]
    store = VersionedStore("uniprot", [FieldSchema("profile", 16, "int32"),
                                       FieldSchema("score", 2, "float32")])
    for ts in (10, 20, 30, 40):
        store.update(ts, keys, release(rng, n))

    with tempfile.TemporaryDirectory() as root:
        path = os.path.join(root, "uniprot")

        # 1. first save: full rewrite, one base segment per field
        stats = store.save(path)
        print(f"first save:       mode={stats['mode']:<12} "
              f"segments={stats['segments_written']} "
              f"bytes={stats['bytes_written']:,}")

        # 2. add one release, save again: only the new segments hit disk
        store.update(50, keys, release(rng, n))
        stats = store.save(path)
        print(f"incremental save: mode={stats['mode']:<12} "
              f"segments={stats['segments_written']} "
              f"bytes={stats['bytes_written']:,}  "
              f"(vs {stats['disk_bytes']:,} total on disk)")

        # 3. lazy load: the manifest is read, segment files are not —
        # a narrow query materializes only the segments it needs
        reopened = VersionedStore.load(path)          # lazy=True default
        pending = sum(len(c.log._pending) for c in reopened.fields.values())
        view = reopened.get_version(20, fields=["score"])
        pending_after = sum(len(c.log._pending)
                            for c in reopened.fields.values())
        print(f"lazy load:        {pending} segments pending; after one "
              f"narrow query: {pending_after} still unread "
              f"({len(view)} entries materialized)")

        # 4. compact: collapse history <= 30 on disk too — covered
        # segments are replaced by a base segment, newer ones retained
        stats = store.compact(30, path=path)
        print(f"compact(30):      cells_dropped={stats['cells_dropped']:,} "
              f"rewrote={stats['segments_written']} "
              f"retained={stats['segments_retained']} segments")

        # 5. the compacted store still answers every retained version
        reopened = VersionedStore.load(path)
        for ts in (30, 40, 50):
            v = reopened.get_version(ts)
            assert len(v) == n
        print(f"reload:           versions 30/40/50 intact, "
              f"{len(segments.read_segment_index(path, segments.read_manifest(path)))} "
              f"segments on disk")


if __name__ == "__main__":
    main()
