"""End-to-end driver: the Meta-pipe analogue — serve similarity-search
queries against a VERSIONED embedding corpus with incremental updates
(paper §IV + Table IV).

A transformer encoder (models zoo, metapipe config) embeds every corpus
sequence; queries are scored against all of them with an exact
e-value-style normalizer. When the corpus updates, only changed entries are
re-embedded/re-scored and the merge is EXACT — this is the paper's 13x
incremental-reanalysis win with the merge made lossless.

Run: PYTHONPATH=src python examples/incremental_search.py [n_entries]
"""
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.metapipe import ENCODER
from repro.core.search import EmbeddingSearchDB
from repro.core.store import FieldSchema, VersionedStore
from repro.models import build
from repro.models.transformer import FwdOpts, forward_train

N = int(sys.argv[1]) if len(sys.argv) > 1 else 1500
SEQ_W = 32
CHURN = 0.03


def make_encoder():
    bundle = build(ENCODER)
    params = bundle.init(jax.random.key(0))

    @jax.jit
    def fwd(tokens):
        x, _ = forward_train(params, ENCODER, {"tokens": tokens % ENCODER.vocab},
                             FwdOpts(attn_impl="xla", remat="none"))
        return x.mean(axis=1)

    def encode(tokens):
        out, bs = [], 256
        for i in range(0, len(tokens), bs):
            chunk = tokens[i:i + bs]
            pad = bs - len(chunk)
            if pad:
                chunk = np.concatenate(
                    [chunk, np.zeros((pad, chunk.shape[1]), chunk.dtype)])
            out.append(np.asarray(fwd(jnp.asarray(chunk)))[: bs - pad])
        return (np.concatenate(out) if out
                else np.zeros((0, ENCODER.d_model), np.float32))
    return encode


def main():
    rng = np.random.default_rng(0)
    store = VersionedStore("corpus", [FieldSchema("sequence", SEQ_W, "int32")],
                           capacity=N + 64)
    store.update(1, [f"d{i}" for i in range(N)],
                 {"sequence": rng.integers(0, 25, (N, SEQ_W)).astype(np.int32)})

    # release 2: ~3% churn (the monthly-UniProt regime)
    view = store.get_version(1)
    tbl = view.values["sequence"].copy()
    mut = rng.choice(N, int(CHURN * N), replace=False)
    tbl[mut] = rng.integers(0, 25, (len(mut), SEQ_W))
    store.update(2, [k.decode() for k in view.keys], {"sequence": tbl})

    db = EmbeddingSearchDB(store, make_encoder(), seg_size=64)
    queries = rng.integers(0, 25, (8, SEQ_W)).astype(np.int32)
    qids = [f"q{i}".encode() for i in range(8)]

    t0 = time.time()
    db.refresh(1)
    r1 = db.query(qids, queries, ts=1, k=10)
    t_full = time.time() - t0
    print(f"full analysis @v1: {N} entries embedded in {t_full:.1f}s")

    t0 = time.time()
    r2 = db.incremental_query(r1, qids, queries, t_last=1, ts=2)
    t_inc = time.time() - t0
    print(f"incremental @v2: {r2.n_embedded} entries re-embedded in "
          f"{t_inc:.1f}s  (speedup {t_full / max(t_inc, 1e-9):.1f}x wall, "
          f"{N / max(r2.n_embedded, 1):.0f}x work — paper Table IV: 13.6x)")

    # verify against full recompute
    db2 = EmbeddingSearchDB(store, make_encoder(), seg_size=64)
    db2.refresh(2)
    rf = db2.query(qids, queries, ts=2, k=10)
    exact = (np.array_equal(r2.topk_idx, rf.topk_idx) and
             np.allclose(r2.z, rf.z, atol=1e-4))
    print(f"incremental == full recompute: {exact}")
    print("top hit per query:", r2.topk_idx[:, 0].tolist())
    print("e-values:", np.round(r2.evalue()[:, 0], 4).tolist())


if __name__ == "__main__":
    main()
