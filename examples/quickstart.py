"""Quickstart: the GeStore lifecycle in 60 lines (paper §III).

Creates a meta-database from a FASTA release, updates it with a new release
(annotation churn + sequence churn + additions/deletions), then shows the
three retrieval modes: pinned version, incremental, cached.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

import repro.core as core
from repro.core.parsers import FastaParser


def make_release(n, mutate=(), seed=7):
    rng = np.random.default_rng(seed)
    entries = []
    for i in range(n):
        seq = "".join(rng.choice(list("ACDEFGHIKLMNPQRSTVWY"), 40))
        if i in mutate:
            seq = seq[:8] + "WWWWWWWW" + seq[16:]
        entries.append(f">PROT{i:05d} hypothetical protein {i}\n{seq}\n")
    return "".join(entries)


def main():
    registry = core.PluginRegistry()
    registry.register_parser(FastaParser(seq_width=64, desc_width=32))
    registry.register_tool(core.ToolPlugin(
        "blastp",
        core.FileGenerator(parser="fasta",
                           output_fields=["sequence", "length", "desc"],
                           significant_fields=["sequence", "length"]),
        merger=core.BlastEvalueMerger()))

    with tempfile.TemporaryDirectory() as root:
        gs = core.GeStore(root, registry)

        # data-feeder interface: ingest two releases
        info1 = gs.add_release("uniprot", 2014_09, make_release(500),
                               parser_name="fasta", label="2014_09")
        info2 = gs.add_release("uniprot", 2014_10,
                               make_release(515, mutate=range(0, 15)),
                               parser_name="fasta", label="2014_10")
        print(f"release 1: {info1.n_new} new entries")
        print(f"release 2: +{info2.n_new} new, {info2.n_updated} updated, "
              f"-{info2.n_deleted} deleted")

        # workflow-manager interface: pinned full version (reproducibility)
        full = gs.generate_files("blastp", "uniprot", t_version=2014_09)
        print(f"full v2014_09: {full.n_entries} entries -> {full.path}")

        # incremental: only what a BLAST rerun actually needs
        inc = gs.generate_files("blastp", "uniprot", t_version=2014_10,
                                t_last=2014_09)
        print(f"increment: {inc.n_entries} entries "
              f"({inc.n_entries / full.n_entries:.1%} of full; annotation "
              f"churn excluded by significant-field detection)")

        # cache: second request is a filename-keyed hit
        again = gs.generate_files("blastp", "uniprot", t_version=2014_10,
                                  t_last=2014_09)
        print(f"second request: mode={again.mode}")

        # taxon-style filter (paper §IV.C)
        sub = gs.generate_files("blastp", "uniprot", t_version=2014_10,
                                key_filter=r"PROT0000\d")
        print(f"filtered subset: {sub.n_entries} entries")


if __name__ == "__main__":
    main()
