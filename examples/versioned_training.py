"""End-to-end training driver: train an LM on a VERSIONED corpus with
delta-compressed checkpoints, simulate a crash, restart from the last
checkpoint version (the paper's "rerun with a pinned meta-database version"
applied to training state).

Defaults are laptop-scale (CPU container); --arch/--steps scale it up (the
same driver runs any of the 10 assigned architectures via smoke configs,
and full configs on real hardware).

Run: PYTHONPATH=src python examples/versioned_training.py [--steps N]
"""
import argparse
import tempfile


from repro.configs.base import RunConfig, get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.data.versioned_dataset import VersionedCorpus
from repro.train.train_loop import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    # versioned corpus: training pins version ts=1
    corpus = VersionedCorpus()
    docs = {f"doc{i}": f"the versatile meta database number {i} stores "
                       f"versions incrementally " * 4 for i in range(120)}
    corpus.add_release(1, docs)
    cfg = get_smoke_config(args.arch)
    tokens = corpus.token_stream(1) % cfg.vocab
    pipe = TokenPipeline(tokens, DataConfig(seq_len=32, global_batch=4, seed=0))
    print(f"corpus v1: {len(tokens)} tokens; arch={cfg.name}")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        run = RunConfig(learning_rate=2e-3, attn_impl="xla")
        tr = Trainer(cfg, run,
                     TrainerConfig(total_steps=args.steps, warmup_steps=3,
                                   ckpt_every=args.ckpt_every,
                                   ckpt_dir=ckpt_dir))
        hist = tr.run_loop(iter(pipe))
        print(f"trained {len(hist)} steps: loss {hist[0]['loss']:.3f} -> "
              f"{hist[-1]['loss']:.3f}")
        stats = tr.ckpt.stats()
        print(f"checkpoint store: {stats['versions']} versions, "
              f"{stats['cells']} delta cells over {stats['rows']} chunks")

        # simulated crash + restart from the last version
        last = tr.ckpt.steps()[-1]
        tr2 = Trainer(cfg, run,
                      TrainerConfig(total_steps=args.steps + 10,
                                    warmup_steps=3, ckpt_every=0,
                                    ckpt_dir=ckpt_dir))
        tr2.state["params"] = tr.ckpt.restore(last, like=tr2.state["params"])
        tr2.step = last
        hist2 = tr2.run_loop(iter(pipe))
        print(f"restarted at step {last}, continued to {tr2.step}: "
              f"loss {hist2[-1]['loss']:.3f}")

        # incremental corpus release: only changed docs re-tokenized
        docs2 = dict(docs)
        docs2["doc3"] = "completely different text now"
        docs2["doc_new"] = "a brand new document"
        info = corpus.incremental_release(1, 2, docs2)
        print(f"corpus v2: re-tokenized {info.n_entries} of {len(docs2)} docs "
              f"(incremental data pipeline)")


if __name__ == "__main__":
    main()
