"""Batched serving demo: scheduler -> bucketed continuous batching ->
prefill + ring-cache decode, over any assigned architecture's smoke config.

Run: PYTHONPATH=src python examples/serve_demo.py [--arch rwkv6-7b]
"""
import argparse
import time

import numpy as np
import jax

from repro.configs.base import get_smoke_config
from repro.models import build
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.scheduler import Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    engine = ServeEngine(cfg, params,
                         ServeConfig(max_new_tokens=args.max_new,
                                     temperature=0.8, top_k=20))
    sched = Scheduler(engine, max_batch=4)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(3, cfg.vocab, size=int(rng.integers(4, 24)))
        sched.submit(f"req{i:03d}", prompt)
    stats = sched.run_until_drained()
    wall = time.time() - t0

    print(f"arch={cfg.name}: {stats['n_done']} requests in {wall:.1f}s")
    print(f"p50 latency {stats['p50_latency_s']:.2f}s, "
          f"p99 {stats['p99_latency_s']:.2f}s")
    print(f"engine: {engine.stats}")
    for rid in list(sched.done)[:3]:
        print(f"  {rid}: {sched.done[rid].output[:8].tolist()}...")


if __name__ == "__main__":
    main()
