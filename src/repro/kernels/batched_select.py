"""Batched Pallas masked-cumsum: one launch materializes MANY versions.

``masked_cumsum`` (version_select.py) answers one query timestamp per
launch, so materializing N versions of an F-field store costs N*F kernel
launches, each re-streaming the CSR log. Production platforms re-run
analyses against many pinned versions concurrently (the paper's §III.C
workload; OrpheusDB's multi-version checkout), so this kernel computes the
inclusive cumsum of ``ts <= t_q`` for a *vector* of Q query timestamps in a
single launch with grid ``(ts_tile, query)``: each grid cell re-reads one
timestamp tile (already VMEM-resident across the inner query axis) and
emits the intra-tile cumsum for one query. The tiny per-(query, tile)
offset cumsum and the CSR boundary gathers run in XLA, exactly as in the
single-query kernel.

The ts tile is no longer hardcoded: ``launch.tile_for("batched_select")``
resolves it (env override > autotuned winner > default), and callers are
expected to pre-pad the cell axis to a :func:`scan_bucket` power-of-two
bucket so a continuously growing superlog reuses a handful of compiled
executables instead of retracing per ingest (core/store.py does this for
the fused superlog; padding *inside* the jit boundary cannot help, the
trace has already happened by then).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import launch, ref
from ._compat import interpret_default

#: kept as a module attr for backward compatibility (the pre-autotune
#: hardcoded tile); live launches resolve through launch.tile_for.
TILE_C = launch.DEFAULT_TILES["batched_select"]


def scan_bucket(n: int) -> int:
    """Power-of-two cell bucket for the fused scan, floored at the launch
    tile so the bucketed length is always a whole number of tiles."""
    return launch.pow2_bucket(n, floor=tile())


def tile() -> int:
    """The resolved scan tile (env > autotune cache > default)."""
    return launch.tile_for("batched_select")


def _batched_masked_cumsum_kernel(ts_ref, tq_ref, cum_ref, tot_ref):
    t = tq_ref[0]
    m = (ts_ref[:] <= t).astype(jnp.int32)
    c = jnp.cumsum(m)
    cum_ref[0, :] = c
    tot_ref[0, 0] = c[-1]


def batched_masked_cumsum(ts: jax.Array, t_queries: jax.Array, *,
                          interpret: bool | None = None,
                          tile: int | None = None) -> jax.Array:
    """ts: (C,); t_queries: (Q,) -> (Q, C) int32 inclusive cumsum of
    (ts <= t_q) per query. interpret=None: kernel on TPU, jitted ref on CPU;
    True: force kernel (interpret mode off-TPU). ``tile`` overrides the
    resolved launch tile (static; autotune sweeps pass it explicitly)."""
    if tile is None:
        tile = launch.tile_for("batched_select", n=ts.shape[0])
    return _batched_masked_cumsum(ts, t_queries, interpret=interpret,
                                  tile=int(tile))


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def _batched_masked_cumsum(ts, t_queries, *, interpret, tile):
    t_queries = jnp.asarray(t_queries, dtype=ts.dtype)
    if interpret is None:
        if interpret_default():
            return ref.ref_batched_masked_cumsum(ts, t_queries)
        interpret = False
    (c,) = ts.shape
    (q,) = t_queries.shape
    if c == 0 or q == 0:
        return jnp.zeros((q, c), jnp.int32)
    c_pad = launch.round_up_tile(c, tile)
    if c_pad != c:
        # pad above every possible query (queries are clamped below TS_MAX)
        pad = jnp.full((c_pad - c,), jnp.iinfo(ts.dtype).max, ts.dtype)
        ts = jnp.concatenate([ts, pad])
    n_tiles = c_pad // tile
    intra, totals = pl.pallas_call(
        _batched_masked_cumsum_kernel,
        grid=(n_tiles, q),
        in_specs=[
            pl.BlockSpec((tile,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i, j: (j, i)),
            pl.BlockSpec((1, 1), lambda i, j: (j, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, c_pad), jnp.int32),
            jax.ShapeDtypeStruct((q, n_tiles), jnp.int32),
        ],
        interpret=interpret,
    )(ts, t_queries)
    offsets = jnp.concatenate(
        [jnp.zeros((q, 1), jnp.int32), jnp.cumsum(totals, axis=1)[:, :-1]],
        axis=1)
    # broadcast-reshape, not jnp.repeat: adds the per-tile offset without
    # materializing a (q, c_pad) repeat buffer first
    out = (intra.reshape(q, n_tiles, tile)
           + offsets[:, :, None]).reshape(q, c_pad)
    return out[:, :c]


def _stacked_masked_cumsum_kernel(ts_ref, tq_ref, cum_ref, tot_ref):
    t = tq_ref[0]
    m = (ts_ref[0, :] <= t).astype(jnp.int32)
    c = jnp.cumsum(m)
    cum_ref[0, 0, :] = c
    tot_ref[0, 0, 0] = c[-1]


def stacked_masked_cumsum(ts_stack: jax.Array, t_queries: jax.Array, *,
                          interpret: bool | None = None,
                          tile: int | None = None) -> jax.Array:
    """ts_stack: (S, C); t_queries: (Q,) -> (S, Q, C) int32 inclusive
    cumsum of (ts <= t_q) per (shard, query) — the batched kernel with one
    extra grid axis over shards, so S independent fused superlogs scan in
    ONE launch. Pad rows (and ragged tails) with a value above every
    query (int32 max > TS_MAX); padded cells never count."""
    if tile is None:
        tile = launch.tile_for("batched_select", n=ts_stack.shape[-1])
    return _stacked_masked_cumsum(ts_stack, t_queries, interpret=interpret,
                                  tile=int(tile))


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def _stacked_masked_cumsum(ts_stack, t_queries, *, interpret, tile):
    t_queries = jnp.asarray(t_queries, dtype=ts_stack.dtype)
    if interpret is None:
        if interpret_default():
            return ref.ref_stacked_masked_cumsum(ts_stack, t_queries)
        interpret = False
    s, c = ts_stack.shape
    (q,) = t_queries.shape
    if s == 0 or c == 0 or q == 0:
        return jnp.zeros((s, q, c), jnp.int32)
    c_pad = launch.round_up_tile(c, tile)
    if c_pad != c:
        pad = jnp.full((s, c_pad - c), jnp.iinfo(ts_stack.dtype).max,
                       ts_stack.dtype)
        ts_stack = jnp.concatenate([ts_stack, pad], axis=1)
    n_tiles = c_pad // tile
    intra, totals = pl.pallas_call(
        _stacked_masked_cumsum_kernel,
        grid=(s, n_tiles, q),
        in_specs=[
            pl.BlockSpec((1, tile), lambda k, i, j: (k, i)),
            pl.BlockSpec((1,), lambda k, i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tile), lambda k, i, j: (k, j, i)),
            pl.BlockSpec((1, 1, 1), lambda k, i, j: (k, j, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, q, c_pad), jnp.int32),
            jax.ShapeDtypeStruct((s, q, n_tiles), jnp.int32),
        ],
        interpret=interpret,
    )(ts_stack, t_queries)
    offsets = jnp.concatenate(
        [jnp.zeros((s, q, 1), jnp.int32),
         jnp.cumsum(totals, axis=2)[:, :, :-1]], axis=2)
    out = (intra.reshape(s, q, n_tiles, tile)
           + offsets[:, :, :, None]).reshape(s, q, c_pad)
    return out[:, :, :c]


def scan_cache_size() -> int:
    """Number of compiled entries behind the jitted scan wrappers — the
    recompile-stability regression tests probe this to prove epoch rolls
    under continuous ingest stay bounded by the shape-bucket count."""
    n = 0
    for fn in (_batched_masked_cumsum, _stacked_masked_cumsum):
        try:
            n += int(fn._cache_size())
        except (AttributeError, TypeError):  # older/newer jax internals
            return -1
    return n


def _boundary_take(cum: jax.Array, boundaries: jax.Array) -> jax.Array:
    """Sample an (S, Q, C) stacked cumsum at per-shard CSR boundaries
    (S, B) -> (S, Q, B): entry 0 prepended so boundary 0 reads count 0."""
    s, q, _ = cum.shape
    cum0 = jnp.concatenate([jnp.zeros((s, q, 1), jnp.int32), cum], axis=2)
    idx = jnp.broadcast_to(boundaries[:, None, :].astype(jnp.int32),
                           (s, q, boundaries.shape[1]))
    return jnp.take_along_axis(cum0, idx, axis=2)


def stacked_boundary_select(ts_stack, t_queries, boundaries, *, mesh=None,
                            interpret: bool | None = None):
    """Device-parallel batched-select over S stacked fused superlogs.

    ts_stack: (S, Cmax) int32 fused per-shard ts rows padded with int32
    max; t_queries: (Q,) clamped query timestamps; boundaries: (S, Bmax)
    int32 per-shard CSR boundary positions (zero-padded). Returns the
    (S, Q, Bmax) boundary cumsums — the per-shard _SuperLog.boundary_cums
    numbers for every shard from ONE launch.

    With ``mesh`` (a 1-D ("shard",) mesh of exactly S devices) the scan
    runs under shard_map, one shard per device, and the caller should have
    device_put the stacked operands with NamedSharding(mesh, P("shard",
    None)) so no resharding happens on the hot path. Without a mesh the
    same stacked computation runs on whatever device holds the operands —
    still one launch instead of S, byte-identical either way.
    """
    if mesh is None:
        cum = stacked_masked_cumsum(ts_stack, t_queries, interpret=interpret)
        return _boundary_take(cum, jnp.asarray(boundaries))
    return _mesh_boundary_select(mesh, interpret)(
        ts_stack, t_queries, boundaries)


@functools.lru_cache(maxsize=8)
def _mesh_boundary_select(mesh, interpret: bool | None):
    """Compiled shard_map'd boundary select for one mesh, cached so the
    serving hot path never retraces (jit keyed per operand shape)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(ts, qs, bnd):
        cum = stacked_masked_cumsum(ts, qs, interpret=interpret)
        return _boundary_take(cum, bnd)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P("shard", None), P(), P("shard", None)),
        out_specs=P("shard", None, None)))


def batched_version_select(log_vals, log_ts, row_ptr, t_queries, *,
                           interpret: bool | None = None):
    """Segmented last-cell-with-ts<=T selection for Q query timestamps.

    log_vals: (C, W); log_ts: (C,) ascending within each row segment;
    row_ptr: (N+1,); t_queries: (Q,). Returns (out (Q, N, W), found (Q, N)).
    One batched scan replaces Q independent ``version_select`` launches.
    """
    t_queries = jnp.asarray(t_queries)
    (q,) = t_queries.shape
    n = row_ptr.shape[0] - 1
    if log_ts.shape[0] == 0:  # empty log: nothing found anywhere
        return (jnp.zeros((q, n) + log_vals.shape[1:], log_vals.dtype),
                jnp.zeros((q, n), bool))
    cum = batched_masked_cumsum(log_ts, t_queries, interpret=interpret)
    cum0 = jnp.concatenate([jnp.zeros((q, 1), jnp.int32), cum], axis=1)
    lo = row_ptr[:-1]
    hi = row_ptr[1:]
    cnt = cum0[:, hi] - cum0[:, lo]
    found = cnt > 0
    idx = jnp.clip(lo[None, :] + cnt - 1, 0, max(log_ts.shape[0] - 1, 0))
    out = jnp.where(found[..., None], log_vals[idx], 0)
    return out, found
