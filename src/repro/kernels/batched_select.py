"""Batched Pallas masked-cumsum: one launch materializes MANY versions.

``masked_cumsum`` (version_select.py) answers one query timestamp per
launch, so materializing N versions of an F-field store costs N*F kernel
launches, each re-streaming the CSR log. Production platforms re-run
analyses against many pinned versions concurrently (the paper's §III.C
workload; OrpheusDB's multi-version checkout), so this kernel computes the
inclusive cumsum of ``ts <= t_q`` for a *vector* of Q query timestamps in a
single launch with grid ``(ts_tile, query)``: each grid cell re-reads one
timestamp tile (already VMEM-resident across the inner query axis) and
emits the intra-tile cumsum for one query. The tiny per-(query, tile)
offset cumsum and the CSR boundary gathers run in XLA, exactly as in the
single-query kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from ._compat import cdiv, interpret_default

TILE_C = 2048


def _batched_masked_cumsum_kernel(ts_ref, tq_ref, cum_ref, tot_ref):
    t = tq_ref[0]
    m = (ts_ref[:] <= t).astype(jnp.int32)
    c = jnp.cumsum(m)
    cum_ref[0, :] = c
    tot_ref[0, 0] = c[-1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def batched_masked_cumsum(ts: jax.Array, t_queries: jax.Array, *,
                          interpret: bool | None = None) -> jax.Array:
    """ts: (C,); t_queries: (Q,) -> (Q, C) int32 inclusive cumsum of
    (ts <= t_q) per query. interpret=None: kernel on TPU, jitted ref on CPU;
    True: force kernel (interpret mode off-TPU)."""
    t_queries = jnp.asarray(t_queries, dtype=ts.dtype)
    if interpret is None:
        if interpret_default():
            return ref.ref_batched_masked_cumsum(ts, t_queries)
        interpret = False
    (c,) = ts.shape
    (q,) = t_queries.shape
    if c == 0 or q == 0:
        return jnp.zeros((q, c), jnp.int32)
    c_pad = cdiv(c, TILE_C) * TILE_C
    if c_pad != c:
        # pad above every possible query (queries are clamped below TS_MAX)
        pad = jnp.full((c_pad - c,), jnp.iinfo(ts.dtype).max, ts.dtype)
        ts = jnp.concatenate([ts, pad])
    n_tiles = c_pad // TILE_C
    intra, totals = pl.pallas_call(
        _batched_masked_cumsum_kernel,
        grid=(n_tiles, q),
        in_specs=[
            pl.BlockSpec((TILE_C,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE_C), lambda i, j: (j, i)),
            pl.BlockSpec((1, 1), lambda i, j: (j, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, c_pad), jnp.int32),
            jax.ShapeDtypeStruct((q, n_tiles), jnp.int32),
        ],
        interpret=interpret,
    )(ts, t_queries)
    offsets = jnp.concatenate(
        [jnp.zeros((q, 1), jnp.int32), jnp.cumsum(totals, axis=1)[:, :-1]],
        axis=1)
    out = intra + jnp.repeat(offsets, TILE_C, axis=1,
                             total_repeat_length=c_pad)
    return out[:, :c]


def batched_version_select(log_vals, log_ts, row_ptr, t_queries, *,
                           interpret: bool | None = None):
    """Segmented last-cell-with-ts<=T selection for Q query timestamps.

    log_vals: (C, W); log_ts: (C,) ascending within each row segment;
    row_ptr: (N+1,); t_queries: (Q,). Returns (out (Q, N, W), found (Q, N)).
    One batched scan replaces Q independent ``version_select`` launches.
    """
    t_queries = jnp.asarray(t_queries)
    (q,) = t_queries.shape
    n = row_ptr.shape[0] - 1
    if log_ts.shape[0] == 0:  # empty log: nothing found anywhere
        return (jnp.zeros((q, n) + log_vals.shape[1:], log_vals.dtype),
                jnp.zeros((q, n), bool))
    cum = batched_masked_cumsum(log_ts, t_queries, interpret=interpret)
    cum0 = jnp.concatenate([jnp.zeros((q, 1), jnp.int32), cum], axis=1)
    lo = row_ptr[:-1]
    hi = row_ptr[1:]
    cnt = cum0[:, hi] - cum0[:, lo]
    found = cnt > 0
    idx = jnp.clip(lo[None, :] + cnt - 1, 0, max(log_ts.shape[0] - 1, 0))
    out = jnp.where(found[..., None], log_vals[idx], 0)
    return out, found
