"""Pallas row-fingerprint kernel (change-detection hot spot, paper §III.A).

GeStore's update path compares every entry of a new meta-database release
against the stored head version. Byte-comparing 240 GB is memory-bound; we
instead hash each row's significant-field lanes to a 2x32-bit fingerprint and
compare fingerprints. The kernel is a tiled VPU reduction over the lane axis:
each grid step loads a (TILE_N, W) block into VMEM and folds the W int32
lanes into two accumulators with int32 wraparound multiplies.

Roofline: reads N*W*4 bytes, writes N*8 bytes, does ~2*W int32 mul+xor per
row -> arithmetic intensity ~0.5 op/byte: bandwidth-bound, so the tiling goal
is simply full-width VMEM streaming.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from ._compat import cdiv, interpret_default

TILE_N = 512


def _fingerprint_kernel(lanes_ref, out_ref, *, w: int):
    h1 = jnp.full((lanes_ref.shape[0],), ref.FNV1_INIT, dtype=jnp.int32)
    h2 = jnp.full((lanes_ref.shape[0],), ref.FNV2_INIT, dtype=jnp.int32)
    for j in range(w):  # static unroll over lanes (fields are narrow)
        x = lanes_ref[:, j]
        h1 = (h1 ^ x) * ref.FNV1_MUL
        h2 = (h2 * ref.FNV2_MUL) ^ (x + np.int32(j + 1))
    h1 = h1 ^ (h2 << 13)
    h2 = h2 ^ (h1 >> 7)
    out_ref[:, 0] = h1
    out_ref[:, 1] = h2


@functools.partial(jax.jit, static_argnames=("interpret",))
def fingerprint(lanes: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """lanes: (N, W) int32 -> (N, 2) int32 row fingerprints.

    interpret=None: Pallas kernel on TPU, jitted ref oracle on CPU (interpret
    mode is for validation, not production CPU throughput).
    interpret=True: force the kernel body via the Pallas interpreter."""
    if interpret is None:
        if interpret_default():
            return ref.ref_fingerprint(lanes)
        interpret = False
    n, w = lanes.shape
    if n == 0:
        return jnp.zeros((0, 2), jnp.int32)
    n_pad = cdiv(n, TILE_N) * TILE_N
    if n_pad != n:
        lanes = jnp.pad(lanes, ((0, n_pad - n), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_fingerprint_kernel, w=w),
        grid=(n_pad // TILE_N,),
        in_specs=[pl.BlockSpec((TILE_N, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE_N, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 2), jnp.int32),
        interpret=interpret,
    )(lanes)
    return out[:n]
