"""Pallas delta-codec kernels (versioned-cell storage compression, §III.B).

GeStore stores a set of database versions with delta compression (HBase
timestamped cells + Snappy). Our on-disk cell segments store, for each
updated row, the delta against the row's previous value: arithmetic
difference for integer fields and bitwise XOR for float fields (unchanged
exponent/mantissa bytes zero out, which downstream byte-level entropy coding
exploits). Both directions are single-pass streaming VPU kernels; pack
additionally emits the per-tile max |delta| so the host can narrow int32
deltas to int16/int8 segments.

On-disk chain format (used by ``core/segments.py`` segment files): cells
are sorted by (row, ts); within each row's run ("chain") the first cell is
packed against zero (i.e. stored raw) and every later cell against its
predecessor. Chains never cross a segment boundary, so every segment file
is self-contained and can be decoded without any other segment — the
property that makes lazy, per-timestamp-range loading possible.
``chain_pack`` / ``chain_unpack`` are the host-facing wrappers around the
``delta_pack`` / ``delta_unpack`` kernels implementing that format.

8-byte dtypes (int64/float64) cannot ride through the 32-bit jax kernels
directly — with x64 disabled ``jnp.asarray`` silently downcasts them — so
they take a *two-lane* device path: each 8-byte value is split host-side
into little-endian (lo, hi) int32 lanes and ``delta_pack_wide`` /
``delta_unpack_wide`` do exact 64-bit modular subtract/add with an
explicit borrow/carry lane (unsigned compares via the int32 sign-flip
trick). On the CPU backend the host numpy fallback remains the dispatch
default, exactly like every other kernel in the family.

``chain_decode`` is the device-side inverse of the chain format: a
segmented (head-flagged) associative scan that reconstructs cell values
from deltas *on device*, so the fused superlog can stay delta-packed in
HBM and decode inside the gather path (core/store.py) instead of
uploading fully decoded cells.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import launch, ref
from ._compat import interpret_default

#: pre-autotune hardcoded tile, kept for backward compatibility; live
#: launches resolve through launch.tile_for("delta_codec").
TILE_N = launch.DEFAULT_TILES["delta_codec"]

# sign-bit flip constant for unsigned int32 compares; kept a Python int so
# Pallas kernels don't capture a traced array constant
_I32_SIGN = -(2**31)


def _pack_int_kernel(new_ref, old_ref, delta_ref, maxabs_ref):
    d = new_ref[:, :] - old_ref[:, :]
    delta_ref[:, :] = d
    # widen before |.|: the stat ref is int32 and abs(int8 -128) overflows
    maxabs_ref[0] = jnp.max(jnp.abs(d.astype(jnp.int32)))


def _pack_xor_kernel(new_ref, old_ref, delta_ref, nz_ref):
    d = new_ref[:, :] ^ old_ref[:, :]
    delta_ref[:, :] = d
    nz_ref[0] = jnp.sum((d != 0).astype(jnp.int32))


def _unpack_int_kernel(delta_ref, old_ref, new_ref, stat_ref):
    new_ref[:, :] = delta_ref[:, :] + old_ref[:, :]
    stat_ref[0] = 0


def _unpack_xor_kernel(delta_ref, old_ref, new_ref, stat_ref):
    new_ref[:, :] = delta_ref[:, :] ^ old_ref[:, :]
    stat_ref[0] = 0


def _run_2d(kernel, a, b, out_dtypes, *, interpret, tile):
    """The codec family's launch shape, via the shared helper: two (N, W)
    inputs, an (N, W) output and a per-tile stat."""
    w = a.shape[1]
    return launch.tiled_rows(
        kernel, [a, b],
        [((w,), out_dtypes[0], "rows"), ((), out_dtypes[1], "tile")],
        tile=tile, interpret=interpret)


def _as_int_lanes(x: jax.Array) -> tuple[jax.Array, jnp.dtype]:
    if jnp.issubdtype(x.dtype, jnp.floating):
        ib = {4: jnp.int32, 2: jnp.int16}[x.dtype.itemsize]
        return x.view(ib), ib
    return x, x.dtype


def delta_pack(new: jax.Array, old: jax.Array, *,
               interpret: bool | None = None, tile: int | None = None):
    """Pack (new, old) -> (delta, stat). Floats: XOR lanes + nonzero count;
    ints: arithmetic delta + per-tile max|delta| (for narrowing).
    interpret=None: kernel on TPU, jitted ref on CPU; True: force kernel."""
    if tile is None:
        tile = launch.tile_for("delta_codec", n=new.shape[0])
    return _delta_pack(new, old, interpret=interpret, tile=int(tile))


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def _delta_pack(new, old, *, interpret, tile):
    if interpret is None:
        if interpret_default():
            d = ref.ref_delta_pack(new, old)
            di, _ = _as_int_lanes(d)
            stat = (jnp.sum((di != 0).astype(jnp.int32))[None]
                    if jnp.issubdtype(new.dtype, jnp.floating)
                    else jnp.max(jnp.abs(di.astype(jnp.int32)))[None])
            return d, stat
        interpret = False
    is_float = jnp.issubdtype(new.dtype, jnp.floating)
    a, ib = _as_int_lanes(new)
    b, _ = _as_int_lanes(old)
    kernel = _pack_xor_kernel if is_float else _pack_int_kernel
    delta, stat = _run_2d(kernel, a, b, (ib, jnp.int32), interpret=interpret,
                          tile=tile)
    if is_float:
        delta = delta.view(new.dtype)
    return delta, stat


def delta_unpack(delta: jax.Array, old: jax.Array, *,
                 interpret: bool | None = None, tile: int | None = None):
    if tile is None:
        tile = launch.tile_for("delta_codec", n=delta.shape[0])
    return _delta_unpack(delta, old, interpret=interpret, tile=int(tile))


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def _delta_unpack(delta, old, *, interpret, tile):
    if interpret is None:
        if interpret_default():
            return ref.ref_delta_unpack(delta, old)
        interpret = False
    is_float = jnp.issubdtype(delta.dtype, jnp.floating)
    a, ib = _as_int_lanes(delta)
    b, _ = _as_int_lanes(old)
    kernel = _unpack_xor_kernel if is_float else _unpack_int_kernel
    new, _ = _run_2d(kernel, a, b, (ib, jnp.int32), interpret=interpret,
                     tile=tile)
    if is_float:
        new = new.view(delta.dtype)
    return new


# -- two-lane 8-byte device path ----------------------------------------------

def _pack_wide_kernel(alo_ref, ahi_ref, blo_ref, bhi_ref,
                      dlo_ref, dhi_ref, stat_ref):
    """64-bit modular subtract on (lo, hi) int32 lanes: lo borrows into hi
    when unsigned a_lo < b_lo (sign-flip trick — int32 has no uint compare)."""
    alo, ahi = alo_ref[:, :], ahi_ref[:, :]
    blo, bhi = blo_ref[:, :], bhi_ref[:, :]
    borrow = ((alo ^ _I32_SIGN) < (blo ^ _I32_SIGN)).astype(jnp.int32)
    dlo_ref[:, :] = alo - blo
    dhi_ref[:, :] = ahi - bhi - borrow
    stat_ref[0] = 0


def _unpack_wide_kernel(dlo_ref, dhi_ref, olo_ref, ohi_ref,
                        nlo_ref, nhi_ref, stat_ref):
    """64-bit modular add on (lo, hi) lanes: the lo sum wrapped (unsigned
    sum < either addend) iff a carry must propagate into hi."""
    dlo, dhi = dlo_ref[:, :], dhi_ref[:, :]
    olo, ohi = olo_ref[:, :], ohi_ref[:, :]
    lo = dlo + olo
    carry = ((lo ^ _I32_SIGN) < (dlo ^ _I32_SIGN)).astype(jnp.int32)
    nlo_ref[:, :] = lo
    nhi_ref[:, :] = dhi + ohi + carry
    stat_ref[0] = 0


def _xor_wide_kernel(alo_ref, ahi_ref, blo_ref, bhi_ref,
                     dlo_ref, dhi_ref, stat_ref):
    """float64 XOR decomposes lane-wise — same kernel packs and unpacks."""
    dlo_ref[:, :] = alo_ref[:, :] ^ blo_ref[:, :]
    dhi_ref[:, :] = ahi_ref[:, :] ^ bhi_ref[:, :]
    stat_ref[0] = 0


def split_lanes64(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Host: (C, W) int64/float64 -> ((C, W) lo, (C, W) hi) little-endian
    int32 lanes. Explicit LE so lane semantics never depend on host byte
    order (same contract as shard_route.key_lanes)."""
    c, w = x.shape
    lanes = (np.ascontiguousarray(x).view(np.int64).astype("<i8")
             .view("<i4").reshape(c, w, 2))
    return (np.ascontiguousarray(lanes[..., 0]),
            np.ascontiguousarray(lanes[..., 1]))


def join_lanes64(lo: np.ndarray, hi: np.ndarray,
                 dtype: np.dtype) -> np.ndarray:
    """Host: inverse of :func:`split_lanes64`."""
    c, w = lo.shape
    lanes = np.empty((c, w, 2), "<i4")
    lanes[..., 0] = lo
    lanes[..., 1] = hi
    out = lanes.view("<i8").reshape(c, w).astype(np.int64)
    return out.view(dtype) if np.dtype(dtype) != np.int64 else out


@functools.partial(jax.jit, static_argnames=("op", "interpret", "tile"))
def _wide_2lane(alo, ahi, blo, bhi, *, op, interpret, tile):
    kernel = {"sub": _pack_wide_kernel, "add": _unpack_wide_kernel,
              "xor": _xor_wide_kernel}[op]
    w = alo.shape[1]
    lo, hi, _ = launch.tiled_rows(
        kernel, [alo, ahi, blo, bhi],
        [((w,), jnp.int32, "rows"), ((w,), jnp.int32, "rows"),
         ((), jnp.int32, "tile")],
        tile=tile, interpret=interpret)
    return lo, hi


def delta_pack_wide(new: np.ndarray, old: np.ndarray, *,
                    interpret: bool | None = None,
                    tile: int | None = None) -> np.ndarray:
    """8-byte delta pack on device via two int32 lanes (exact 64-bit
    modular arithmetic; XOR lanes for float64). Host in, host out — the
    chain codec is a host-facing path. interpret=None: device kernel on
    TPU, host numpy on CPU; True forces the kernel (tests)."""
    if interpret is None and interpret_default():
        return ref.ref_delta_pack64(new, old)
    if tile is None:
        tile = launch.tile_for("delta_codec", n=new.shape[0])
    op = "xor" if np.issubdtype(new.dtype, np.floating) else "sub"
    alo, ahi = split_lanes64(new)
    blo, bhi = split_lanes64(old)
    lo, hi = _wide_2lane(jnp.asarray(alo), jnp.asarray(ahi),
                         jnp.asarray(blo), jnp.asarray(bhi),
                         op=op, interpret=bool(interpret), tile=int(tile))
    return join_lanes64(np.asarray(lo), np.asarray(hi), new.dtype)


def delta_unpack_wide(delta: np.ndarray, old: np.ndarray, *,
                      interpret: bool | None = None,
                      tile: int | None = None) -> np.ndarray:
    """Inverse of :func:`delta_pack_wide` (64-bit modular add / XOR)."""
    if interpret is None and interpret_default():
        return ref.ref_delta_unpack64(delta, old)
    if tile is None:
        tile = launch.tile_for("delta_codec", n=delta.shape[0])
    op = "xor" if np.issubdtype(delta.dtype, np.floating) else "add"
    dlo, dhi = split_lanes64(delta)
    olo, ohi = split_lanes64(old)
    lo, hi = _wide_2lane(jnp.asarray(dlo), jnp.asarray(dhi),
                         jnp.asarray(olo), jnp.asarray(ohi),
                         op=op, interpret=bool(interpret), tile=int(tile))
    return join_lanes64(np.asarray(lo), np.asarray(hi), delta.dtype)


# -- device-side chain decode (segmented scan) --------------------------------

def chain_decode(deltas: jax.Array, heads: jax.Array, *,
                 xor: bool = False) -> jax.Array:
    """Decode chain deltas ON DEVICE: deltas (C, W) int lanes where the
    first cell of every chain is raw and ``heads`` (C,) flags those cells.
    A segmented inclusive scan (reset at heads) reconstructs values —
    modular int32 addition, so truncating the widened scan back to the
    stored dtype reproduces the host depth-loop byte-for-byte. ``xor=True``
    scans with XOR (float lane chains; XOR is its own inverse).

    This is what lets the fused superlog keep fields delta-packed in HBM
    and decode inside the gather path instead of uploading decoded cells.
    """
    h = jnp.asarray(heads, bool).reshape(-1, 1)
    if xor:
        d = deltas

        def comb(a, b):
            av, af = a
            bv, bf = b
            return jnp.where(bf, bv, av ^ bv), af | bf
    else:
        d = deltas.astype(jnp.int32)

        def comb(a, b):
            av, af = a
            bv, bf = b
            return jnp.where(bf, bv, av + bv), af | bf
    v, _ = jax.lax.associative_scan(comb, (d, h), axis=0)
    return v


def narrow_dtype(maxabs: int, base=jnp.int32):
    """Pick the narrowest int dtype that can hold every delta in a segment."""
    if maxabs < 128:
        return jnp.int8
    if maxabs < 32768:
        return jnp.int16
    if maxabs < 2**31:
        return jnp.int32
    return base


# -- host-facing chain codec (the on-disk segment cell format) ---------------

def _chain_heads(rows: np.ndarray) -> np.ndarray:
    first = np.ones(len(rows), bool)
    first[1:] = rows[1:] != rows[:-1]
    return first


def chain_pack(vals: np.ndarray, rows: np.ndarray) -> tuple[np.ndarray, dict]:
    """Delta-pack a (row, ts)-sorted cell run for on-disk storage.

    Args:
      vals: (C, W) cell values, sorted so equal-row cells are adjacent and
        in ascending ts order within the row ("chains").
      rows: (C,) row index of each cell (defines the chain boundaries).

    Returns:
      (packed, meta): ``packed`` has the same shape as ``vals`` — the first
      cell of each chain raw, later cells as deltas vs their predecessor
      (arithmetic for ints, XOR lanes for floats, via the ``delta_pack``
      kernel). Integer deltas are narrowed to int8/int16 when the whole run
      allows it. ``meta`` records ``mode`` ("raw" for empty input, else
      "delta"), the original ``dtype`` name, and optionally ``narrow``.
    """
    if len(vals) == 0:
        return vals.copy(), {"mode": "raw", "dtype": vals.dtype.name}
    # traffic model: read new + predecessor cells, write the delta;
    # arithmetic: one sub/xor per element (the narrowing stat rides along).
    # logical = the cells themselves; padded adds the pow2 bucket slack the
    # kernel actually streams (8-byte host path: no padding happens)
    n = len(vals)
    n_pad = n if vals.dtype.itemsize == 8 else _codec_bucket(n)
    with launch.measured("delta_codec", nbytes=3 * vals.nbytes,
                         flops=vals.size,
                         padded_nbytes=3 * n_pad * vals.itemsize
                         * (vals.size // n)):
        return _chain_pack_timed(vals, rows)


def _codec_bucket(n: int) -> int:
    """pow2 cell bucket for chain codec launches (floored at the tile so a
    bucket is a whole number of tiles — the original 512 floor)."""
    return launch.pow2_bucket(n, floor=launch.tile_for("delta_codec"))


def _chain_pack_timed(vals: np.ndarray, rows: np.ndarray) -> tuple[np.ndarray, dict]:
    first = _chain_heads(rows)
    prev = np.roll(vals, 1, axis=0)
    prev[first] = 0  # chain heads pack against zero (stored raw)
    if vals.dtype.itemsize == 8:
        if interpret_default():
            # CPU backend: delta on host (the 32-bit jax default would
            # silently downcast int64/float64 through jnp.asarray)
            if np.issubdtype(vals.dtype, np.floating):
                delta = (vals.view(np.int64)
                         ^ prev.view(np.int64)).view(vals.dtype)
            else:
                # two's-complement wraparound; chain_unpack's add inverts it
                # exactly, so overflowing deltas still round-trip
                with np.errstate(over="ignore"):
                    delta = vals - prev
        else:
            # TPU: exact 64-bit modular delta via the two-lane int32 kernel
            delta = delta_pack_wide(vals, prev)
    else:
        # pad the cell count to a power-of-two bucket: every incremental
        # save has a unique cell count, and an unbucketed call would
        # re-trace the jitted kernel per save (zero rows delta to zero, so
        # results and the narrowing stat are unaffected)
        n = len(vals)
        n_pad = _codec_bucket(n)
        if n_pad != n:
            pad = ((0, n_pad - n), (0, 0))
            vals_in = np.pad(vals, pad)
            prev_in = np.pad(prev, pad)
        else:
            vals_in, prev_in = vals, prev
        delta, _stat = delta_pack(jnp.asarray(vals_in), jnp.asarray(prev_in))
        delta = np.asarray(delta)[:n]
    meta = {"mode": "delta", "dtype": vals.dtype.name}
    if np.issubdtype(vals.dtype, np.integer) and vals.dtype.itemsize >= 4:
        # bound via min/max lifted to Python ints — exact even for
        # int64-min, where np.abs silently wraps negative
        if delta.size:
            maxabs = max(-int(delta.min()), int(delta.max()))
        else:
            maxabs = 0
        narrow = narrow_dtype(
            maxabs, base=jnp.int64 if vals.dtype.itemsize == 8 else jnp.int32)
        if np.dtype(narrow) != vals.dtype:
            delta = delta.astype(narrow)
            meta["narrow"] = np.dtype(narrow).name
    return delta, meta


def chain_unpack(packed: np.ndarray, rows: np.ndarray, meta: dict,
                 out_dtype: np.dtype) -> np.ndarray:
    """Invert ``chain_pack``: reconstruct (C, W) cell values.

    Chains are rebuilt one depth level per pass (chains are short — one
    cell per version the row changed in), so the cost is
    O(cells x max_chain_depth / chain_count) vectorized steps.

    Raises:
      KeyError/TypeError: if ``meta`` does not come from ``chain_pack``.
    """
    if meta["mode"] == "raw" or len(packed) == 0:
        return packed.astype(out_dtype)
    # traffic model mirrors chain_pack's: read delta + predecessor,
    # write the reconstruction; one add/xor per element (the host depth
    # loop moves logical bytes only — no pad slack on the unpack side)
    with launch.measured("delta_codec", nbytes=3 * packed.nbytes,
                         flops=packed.size):
        return _chain_unpack_timed(packed, rows, meta, out_dtype)


def _chain_unpack_timed(packed: np.ndarray, rows: np.ndarray, meta: dict,
                        out_dtype: np.dtype) -> np.ndarray:
    stored = np.dtype(meta["dtype"])
    delta = packed.astype(stored) if "narrow" in meta else packed
    out = delta.copy()
    first = _chain_heads(rows)
    starts = np.nonzero(first)[0]
    lens = np.diff(np.append(starts, len(rows)))
    is_float = np.issubdtype(stored, np.floating)
    ib = {8: np.int64, 4: np.int32, 2: np.int16}.get(stored.itemsize, np.int32)
    for depth in range(1, int(lens.max()) if len(lens) else 0):
        idx = starts[lens > depth] + depth
        if is_float:
            out[idx] = (out[idx].view(ib) ^ out[idx - 1].view(ib)).view(out.dtype)
        else:
            out[idx] = out[idx] + out[idx - 1]
    return out.astype(out_dtype)
