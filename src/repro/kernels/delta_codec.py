"""Pallas delta-codec kernels (versioned-cell storage compression, §III.B).

GeStore stores a set of database versions with delta compression (HBase
timestamped cells + Snappy). Our on-disk cell segments store, for each
updated row, the delta against the row's previous value: arithmetic
difference for integer fields and bitwise XOR for float fields (unchanged
exponent/mantissa bytes zero out, which downstream byte-level entropy coding
exploits). Both directions are single-pass streaming VPU kernels; pack
additionally emits the per-tile max |delta| so the host can narrow int32
deltas to int16/int8 segments.

On-disk chain format (used by ``core/segments.py`` segment files): cells
are sorted by (row, ts); within each row's run ("chain") the first cell is
packed against zero (i.e. stored raw) and every later cell against its
predecessor. Chains never cross a segment boundary, so every segment file
is self-contained and can be decoded without any other segment — the
property that makes lazy, per-timestamp-range loading possible.
``chain_pack`` / ``chain_unpack`` are the host-facing wrappers around the
``delta_pack`` / ``delta_unpack`` kernels implementing that format.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.obs import kerneltel

from . import ref
from ._compat import cdiv, interpret_default

TILE_N = 512


def _pack_int_kernel(new_ref, old_ref, delta_ref, maxabs_ref):
    d = new_ref[:, :] - old_ref[:, :]
    delta_ref[:, :] = d
    # widen before |.|: the stat ref is int32 and abs(int8 -128) overflows
    maxabs_ref[0] = jnp.max(jnp.abs(d.astype(jnp.int32)))


def _pack_xor_kernel(new_ref, old_ref, delta_ref, nz_ref):
    d = new_ref[:, :] ^ old_ref[:, :]
    delta_ref[:, :] = d
    nz_ref[0] = jnp.sum((d != 0).astype(jnp.int32))


def _unpack_int_kernel(delta_ref, old_ref, new_ref, stat_ref):
    new_ref[:, :] = delta_ref[:, :] + old_ref[:, :]
    stat_ref[0] = 0


def _unpack_xor_kernel(delta_ref, old_ref, new_ref, stat_ref):
    new_ref[:, :] = delta_ref[:, :] ^ old_ref[:, :]
    stat_ref[0] = 0


def _run_2d(kernel, a, b, out_dtypes, *, interpret):
    n, w = a.shape
    n_pad = cdiv(max(n, 1), TILE_N) * TILE_N
    if n_pad != n:
        a = jnp.pad(a, ((0, n_pad - n), (0, 0)))
        b = jnp.pad(b, ((0, n_pad - n), (0, 0)))
    n_tiles = n_pad // TILE_N
    outs = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((TILE_N, w), lambda i: (i, 0)),
            pl.BlockSpec((TILE_N, w), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_N, w), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, w), out_dtypes[0]),
            jax.ShapeDtypeStruct((n_tiles,), out_dtypes[1]),
        ],
        interpret=interpret,
    )(a, b)
    return outs[0][:n], outs[1]


def _as_int_lanes(x: jax.Array) -> tuple[jax.Array, jnp.dtype]:
    if jnp.issubdtype(x.dtype, jnp.floating):
        ib = {4: jnp.int32, 2: jnp.int16}[x.dtype.itemsize]
        return x.view(ib), ib
    return x, x.dtype


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_pack(new: jax.Array, old: jax.Array, *, interpret: bool | None = None):
    """Pack (new, old) -> (delta, stat). Floats: XOR lanes + nonzero count;
    ints: arithmetic delta + per-tile max|delta| (for narrowing).
    interpret=None: kernel on TPU, jitted ref on CPU; True: force kernel."""
    if interpret is None:
        if interpret_default():
            d = ref.ref_delta_pack(new, old)
            di, _ = _as_int_lanes(d)
            stat = (jnp.sum((di != 0).astype(jnp.int32))[None]
                    if jnp.issubdtype(new.dtype, jnp.floating)
                    else jnp.max(jnp.abs(di.astype(jnp.int32)))[None])
            return d, stat
        interpret = False
    is_float = jnp.issubdtype(new.dtype, jnp.floating)
    a, ib = _as_int_lanes(new)
    b, _ = _as_int_lanes(old)
    kernel = _pack_xor_kernel if is_float else _pack_int_kernel
    delta, stat = _run_2d(kernel, a, b, (ib, jnp.int32), interpret=interpret)
    if is_float:
        delta = delta.view(new.dtype)
    return delta, stat


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_unpack(delta: jax.Array, old: jax.Array, *, interpret: bool | None = None):
    if interpret is None:
        if interpret_default():
            return ref.ref_delta_unpack(delta, old)
        interpret = False
    is_float = jnp.issubdtype(delta.dtype, jnp.floating)
    a, ib = _as_int_lanes(delta)
    b, _ = _as_int_lanes(old)
    kernel = _unpack_xor_kernel if is_float else _unpack_int_kernel
    new, _ = _run_2d(kernel, a, b, (ib, jnp.int32), interpret=interpret)
    if is_float:
        new = new.view(delta.dtype)
    return new


def narrow_dtype(maxabs: int, base=jnp.int32):
    """Pick the narrowest int dtype that can hold every delta in a segment."""
    if maxabs < 128:
        return jnp.int8
    if maxabs < 32768:
        return jnp.int16
    if maxabs < 2**31:
        return jnp.int32
    return base


# -- host-facing chain codec (the on-disk segment cell format) ---------------

def chain_pack(vals: np.ndarray, rows: np.ndarray) -> tuple[np.ndarray, dict]:
    """Delta-pack a (row, ts)-sorted cell run for on-disk storage.

    Args:
      vals: (C, W) cell values, sorted so equal-row cells are adjacent and
        in ascending ts order within the row ("chains").
      rows: (C,) row index of each cell (defines the chain boundaries).

    Returns:
      (packed, meta): ``packed`` has the same shape as ``vals`` — the first
      cell of each chain raw, later cells as deltas vs their predecessor
      (arithmetic for ints, XOR lanes for floats, via the ``delta_pack``
      kernel). Integer deltas are narrowed to int8/int16 when the whole run
      allows it. ``meta`` records ``mode`` ("raw" for empty input, else
      "delta"), the original ``dtype`` name, and optionally ``narrow``.
    """
    if len(vals) == 0:
        return vals.copy(), {"mode": "raw", "dtype": vals.dtype.name}
    # traffic model: read new + predecessor cells, write the delta;
    # arithmetic: one sub/xor per element (the narrowing stat rides along)
    with kerneltel.launch("delta_codec", nbytes=3 * vals.nbytes,
                          flops=vals.size):
        return _chain_pack_timed(vals, rows)


def _chain_pack_timed(vals: np.ndarray, rows: np.ndarray) -> tuple[np.ndarray, dict]:
    first = np.ones(len(rows), bool)
    first[1:] = rows[1:] != rows[:-1]
    prev = np.roll(vals, 1, axis=0)
    prev[first] = 0  # chain heads pack against zero (stored raw)
    if vals.dtype.itemsize == 8:
        # 8-byte dtypes cannot pass through the jax kernels: with x64
        # disabled jnp.asarray silently downcasts int64/float64 to 32 bits,
        # corrupting any value outside the 32-bit range. Delta on host.
        if np.issubdtype(vals.dtype, np.floating):
            delta = (vals.view(np.int64) ^ prev.view(np.int64)).view(vals.dtype)
        else:
            # two's-complement wraparound; chain_unpack's add inverts it
            # exactly, so overflowing deltas still round-trip
            with np.errstate(over="ignore"):
                delta = vals - prev
    else:
        # pad the cell count to a power-of-two bucket: every incremental
        # save has a unique cell count, and an unbucketed call would
        # re-trace the jitted kernel per save (zero rows delta to zero, so
        # results and the narrowing stat are unaffected)
        n = len(vals)
        n_pad = max(512, 1 << (n - 1).bit_length())
        if n_pad != n:
            pad = ((0, n_pad - n), (0, 0))
            vals_in = np.pad(vals, pad)
            prev_in = np.pad(prev, pad)
        else:
            vals_in, prev_in = vals, prev
        delta, _stat = delta_pack(jnp.asarray(vals_in), jnp.asarray(prev_in))
        delta = np.asarray(delta)[:n]
    meta = {"mode": "delta", "dtype": vals.dtype.name}
    if np.issubdtype(vals.dtype, np.integer) and vals.dtype.itemsize >= 4:
        # bound via min/max lifted to Python ints — exact even for
        # int64-min, where np.abs silently wraps negative
        if delta.size:
            maxabs = max(-int(delta.min()), int(delta.max()))
        else:
            maxabs = 0
        narrow = narrow_dtype(
            maxabs, base=jnp.int64 if vals.dtype.itemsize == 8 else jnp.int32)
        if np.dtype(narrow) != vals.dtype:
            delta = delta.astype(narrow)
            meta["narrow"] = np.dtype(narrow).name
    return delta, meta


def chain_unpack(packed: np.ndarray, rows: np.ndarray, meta: dict,
                 out_dtype: np.dtype) -> np.ndarray:
    """Invert ``chain_pack``: reconstruct (C, W) cell values.

    Chains are rebuilt one depth level per pass (chains are short — one
    cell per version the row changed in), so the cost is
    O(cells x max_chain_depth / chain_count) vectorized steps.

    Raises:
      KeyError/TypeError: if ``meta`` does not come from ``chain_pack``.
    """
    if meta["mode"] == "raw" or len(packed) == 0:
        return packed.astype(out_dtype)
    # traffic model mirrors chain_pack's: read delta + predecessor,
    # write the reconstruction; one add/xor per element
    with kerneltel.launch("delta_codec", nbytes=3 * packed.nbytes,
                          flops=packed.size):
        return _chain_unpack_timed(packed, rows, meta, out_dtype)


def _chain_unpack_timed(packed: np.ndarray, rows: np.ndarray, meta: dict,
                        out_dtype: np.dtype) -> np.ndarray:
    stored = np.dtype(meta["dtype"])
    delta = packed.astype(stored) if "narrow" in meta else packed
    out = delta.copy()
    first = np.ones(len(rows), bool)
    first[1:] = rows[1:] != rows[:-1]
    starts = np.nonzero(first)[0]
    lens = np.diff(np.append(starts, len(rows)))
    is_float = np.issubdtype(stored, np.floating)
    ib = {8: np.int64, 4: np.int32, 2: np.int16}.get(stored.itemsize, np.int32)
    for depth in range(1, int(lens.max()) if len(lens) else 0):
        idx = starts[lens > depth] + depth
        if is_float:
            out[idx] = (out[idx].view(ib) ^ out[idx - 1].view(ib)).view(out.dtype)
        else:
            out[idx] = out[idx] + out[idx - 1]
    return out.astype(out_dtype)
