"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function is the semantic ground truth; the Pallas kernels are
validated against these in interpret mode over shape/dtype sweeps
(tests/test_kernels_*.py). The refs are also the CPU fallback path used by
``ops.py`` when a kernel is not profitable at the given size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# fingerprint: 2x32-bit multiplicative (FNV-style) row hashing.
# ---------------------------------------------------------------------------

import numpy as np

FNV1_INIT = np.int32(-2128831035)  # 0x811C9DC5 as int32
FNV1_MUL = np.int32(16777619)
FNV2_INIT = np.int32(-1442509163)  # arbitrary odd second basis
FNV2_MUL = np.int32(374761393)  # prime (from xxHash)


def ref_fingerprint(lanes: jax.Array) -> jax.Array:
    """lanes: (N, W) int32 row lanes -> (N, 2) int32 fingerprints."""
    assert lanes.ndim == 2 and lanes.dtype == jnp.int32
    n, w = lanes.shape
    h1 = jnp.full((n,), FNV1_INIT, dtype=jnp.int32)
    h2 = jnp.full((n,), FNV2_INIT, dtype=jnp.int32)
    for j in range(w):
        x = lanes[:, j]
        h1 = (h1 ^ x) * FNV1_MUL
        h2 = (h2 * FNV2_MUL) ^ (x + np.int32(j + 1))
    # final avalanche-ish mix
    h1 = h1 ^ (h2 << 13)
    h2 = h2 ^ (h1 >> 7)
    return jnp.stack([h1, h2], axis=1)


# ---------------------------------------------------------------------------
# masked_cumsum: tiled cumulative count of (ts <= T); the scan primitive
# behind get_version / get_increment (segmented last-cell-<=T selection).
# ---------------------------------------------------------------------------


def ref_masked_cumsum(ts: jax.Array, t_query) -> jax.Array:
    """ts: (C,) int64/int32 -> (C,) int32 inclusive cumsum of (ts <= T)."""
    m = (ts <= jnp.asarray(t_query, dtype=ts.dtype)).astype(jnp.int32)
    return jnp.cumsum(m, dtype=jnp.int32)


def ref_batched_masked_cumsum(ts: jax.Array, t_queries: jax.Array) -> jax.Array:
    """ts: (C,); t_queries: (Q,) -> (Q, C) int32 inclusive cumsum of
    (ts <= t_q), one row per query."""
    m = (ts[None, :] <= jnp.asarray(t_queries, ts.dtype)[:, None])
    return jnp.cumsum(m.astype(jnp.int32), axis=1, dtype=jnp.int32)


def ref_stacked_masked_cumsum(ts_stack: jax.Array,
                              t_queries: jax.Array) -> jax.Array:
    """ts_stack: (S, C) one padded fused-ts row per shard; t_queries: (Q,)
    -> (S, Q, C) int32 inclusive cumsum of (ts <= t_q) per (shard, query).
    Padding cells must hold a value strictly above every possible query
    (int32 max > TS_MAX) so they never count."""
    m = (ts_stack[:, None, :]
         <= jnp.asarray(t_queries, ts_stack.dtype)[None, :, None])
    return jnp.cumsum(m.astype(jnp.int32), axis=2, dtype=jnp.int32)


def ref_stacked_boundary_select(ts_stack, t_queries, boundaries):
    """Boundary-sampled form of ref_stacked_masked_cumsum: entry
    (s, q, b) is the count of cells with ts <= t_q among the first
    ``boundaries[s, b]`` cells of shard s — exactly the per-shard
    _SuperLog.boundary_cums numbers, computed for every shard in one
    expression. boundaries: (S, B) int32 CSR positions in [0, C]."""
    cum = ref_stacked_masked_cumsum(ts_stack, t_queries)
    s, q, _ = cum.shape
    cum0 = jnp.concatenate([jnp.zeros((s, q, 1), jnp.int32), cum], axis=2)
    idx = jnp.broadcast_to(boundaries[:, None, :].astype(jnp.int32),
                           (s, q, boundaries.shape[1]))
    return jnp.take_along_axis(cum0, idx, axis=2)


def ref_batched_version_select(log_vals, log_ts, row_ptr, t_queries):
    """Q-query generalization of ref_version_select: returns
    (out (Q, N, W), found (Q, N))."""
    t_queries = jnp.asarray(t_queries)
    (q,) = t_queries.shape
    n = row_ptr.shape[0] - 1
    if log_ts.shape[0] == 0:
        return (jnp.zeros((q, n) + log_vals.shape[1:], log_vals.dtype),
                jnp.zeros((q, n), bool))
    cum = ref_batched_masked_cumsum(log_ts, t_queries)
    cum0 = jnp.concatenate([jnp.zeros((q, 1), jnp.int32), cum], axis=1)
    lo = row_ptr[:-1]
    hi = row_ptr[1:]
    cnt = cum0[:, hi] - cum0[:, lo]
    found = cnt > 0
    idx = jnp.clip(lo[None, :] + cnt - 1, 0, max(log_ts.shape[0] - 1, 0))
    out = jnp.where(found[..., None], log_vals[idx], jnp.zeros((), log_vals.dtype))
    return out, found


def ref_version_select(log_vals, log_ts, row_ptr, t_query):
    """Segmented last-cell-with-ts<=T selection over a CSR cell log.

    log_vals: (C, W); log_ts: (C,) ascending within each row segment;
    row_ptr: (N+1,) CSR offsets. Returns (out_vals (N, W), found (N,) bool).
    """
    if log_ts.shape[0] == 0:
        n = row_ptr.shape[0] - 1
        return (jnp.zeros((n,) + log_vals.shape[1:], log_vals.dtype),
                jnp.zeros((n,), bool))
    cum = ref_masked_cumsum(log_ts, t_query)
    cum0 = jnp.concatenate([jnp.zeros((1,), jnp.int32), cum])
    lo = row_ptr[:-1]
    hi = row_ptr[1:]
    cnt = cum0[hi] - cum0[lo]
    found = cnt > 0
    idx = jnp.clip(lo + cnt - 1, 0, max(log_ts.shape[0] - 1, 0))
    out = log_vals[idx]
    out = jnp.where(found[:, None], out, jnp.zeros_like(out))
    return out, found


# ---------------------------------------------------------------------------
# shard_route: stable key -> shard hashing for the sharded store facade.
# ---------------------------------------------------------------------------

# xxHash 32-bit primes, wrapped to int32 (int32 wraparound multiplies produce
# the same bits as uint32 multiplies, and int32 is what the VPU natively runs)
RT_MUL1 = np.int32(-1640531535)   # 0x9E3779B1
RT_MUL2 = np.int32(-2048144777)   # 0x85EBCA77
RT_MUL3 = np.int32(-1028477379)   # 0xC2B2AE3D
RT_MUL4 = np.int32(668265263)     # 0x27D4EB2F


def ref_shard_route(lanes: jax.Array, lengths: jax.Array,
                    n_shards: int) -> jax.Array:
    """lanes: (N, W) int32 little-endian-packed key bytes (zero-padded);
    lengths: (N,) int32 true key byte lengths -> (N,) int32 shard ids in
    [0, n_shards).

    The hash is *width-stable by construction*: a zero lane contributes
    nothing (0 * mul rotated is still 0), so the same key routes to the same
    shard no matter how wide its batch happened to be padded — the property
    that makes the routing usable as a persistent partitioning function.
    Keys whose real bytes end in zeros are disambiguated by folding the byte
    length into the final mix.
    """
    assert lanes.ndim == 2 and lanes.dtype == jnp.int32
    n, w = lanes.shape
    h = jnp.zeros((n,), jnp.int32)
    for j in range(w):  # static unroll: key widths are small (a few lanes)
        t = lanes[:, j] * RT_MUL1
        t = t ^ jax.lax.shift_right_logical(t, 15)
        t = t * RT_MUL2
        r = (j % 31) + 1  # position-dependent rotate, never by 0 or 32
        h = h ^ ((t << r) | jax.lax.shift_right_logical(t, 32 - r))
    h = h ^ (lengths.astype(jnp.int32) * RT_MUL3)
    h = h ^ jax.lax.shift_right_logical(h, 16)
    h = h * RT_MUL4
    h = h ^ jax.lax.shift_right_logical(h, 13)
    return (h & jnp.int32(0x7FFFFFFF)) % jnp.int32(n_shards)


# ---------------------------------------------------------------------------
# delta codec: elementwise version-chain delta packing (sub for ints,
# XOR-of-bits for floats so unchanged mantissa bytes zero out).
# ---------------------------------------------------------------------------


def ref_delta_pack(new: jax.Array, old: jax.Array) -> jax.Array:
    if jnp.issubdtype(new.dtype, jnp.floating):
        ib = jnp.int32 if new.dtype.itemsize == 4 else jnp.int16
        return (new.view(ib) ^ old.view(ib)).view(new.dtype)
    return new - old


def ref_delta_unpack(delta: jax.Array, old: jax.Array) -> jax.Array:
    if jnp.issubdtype(delta.dtype, jnp.floating):
        ib = jnp.int32 if delta.dtype.itemsize == 4 else jnp.int16
        return (delta.view(ib) ^ old.view(ib)).view(delta.dtype)
    return delta + old


def ref_delta_pack64(new: np.ndarray, old: np.ndarray) -> np.ndarray:
    """Host oracle for the two-lane 8-byte pack: exact 64-bit modular
    subtract (or lane XOR for float64) in numpy — the semantic ground
    truth ``delta_pack_wide`` must reproduce lane-by-lane."""
    if np.issubdtype(new.dtype, np.floating):
        return (new.view(np.int64) ^ old.view(np.int64)).view(new.dtype)
    with np.errstate(over="ignore"):
        return new - old


def ref_delta_unpack64(delta: np.ndarray, old: np.ndarray) -> np.ndarray:
    """Host oracle for the two-lane 8-byte unpack (modular add / XOR)."""
    if np.issubdtype(delta.dtype, np.floating):
        return (delta.view(np.int64) ^ old.view(np.int64)).view(delta.dtype)
    with np.errstate(over="ignore"):
        return delta + old


def ref_chain_decode(deltas: np.ndarray, heads: np.ndarray, *,
                     xor: bool = False) -> np.ndarray:
    """Host oracle for the device segmented chain decode: sequential
    prefix op within each head-delimited chain (int path widened to int32
    exactly like the device scan; caller truncates to the stored dtype)."""
    out = (deltas.copy() if xor
           else deltas.astype(np.int32))
    with np.errstate(over="ignore"):
        for i in range(1, len(out)):
            if not heads[i]:
                out[i] = (out[i] ^ out[i - 1]) if xor else out[i] + out[i - 1]
    return out


# ---------------------------------------------------------------------------
# masked_merge: fused (row-mask & field-mask) select + EXISTS/ts stamping.
# ---------------------------------------------------------------------------


def ref_masked_merge(base, upd, row_mask, field_mask, ts_base, ts_new):
    """base/upd: (N, W); row_mask: (N,) bool; field_mask: (W,) bool;
    ts_base: (N,) int64; ts_new: scalar. Returns (merged, ts_out)."""
    sel = row_mask[:, None] & field_mask[None, :]
    merged = jnp.where(sel, upd, base)
    ts_out = jnp.where(row_mask, jnp.asarray(ts_new, ts_base.dtype), ts_base)
    return merged, ts_out


# ---------------------------------------------------------------------------
# flash attention (causal, GQA): oracle is plain softmax attention.
# ---------------------------------------------------------------------------


def ref_attention(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, Sq, H, D); k/v: (B, Sk, K, D) with H % K == 0. f32 math."""
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    g = h // kh
    qf = q.astype(jnp.float32) * (scale if scale is not None else d ** -0.5)
    qf = qf.reshape(b, sq, kh, g, d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    if causal:
        # queries are the LAST sq positions of the sk-long key sequence
        qpos = jnp.arange(sq)[:, None] + (sk - sq)
        kpos = jnp.arange(sk)[None, :]
        mask = qpos >= kpos
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)
