"""Kernel-dispatch compatibility helpers.

TPU is the TARGET for every kernel here; on the CPU backend we validate the
kernel bodies via Pallas interpret mode (the kernel Python executes on CPU).
"""
from __future__ import annotations

import functools

import jax


@functools.lru_cache(None)
def interpret_default() -> bool:
    """Run pallas_call in interpret mode unless we are actually on TPU."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
