"""Device-side ``compact()`` segment rewrite (core/store.py's horizon fold).

Compaction collapses every row's cell history at or below a horizon into
one base cell and splices the surviving tail back in (row, ts) order. The
math used to live entirely in host numpy; the heavy parts — the horizon
keep-mask over the cell timestamps and the (C, W) value-byte rewrite into
the new CSR order — now run on device through the shared launch helper
(kernels/launch.py), under the ``compact_rewrite`` telemetry name:

  * a row-tiled Pallas kernel computes the ``ts > horizon`` keep mask and
    per-tile survivor counts (bandwidth-bound, same launch family as
    shard_route);
  * ONE fused device gather permutes base + surviving cell values into
    the final lexsorted order (the host only handles the small int32
    index vectors: chain heads, lexsort keys, CSR pointer rebuild).

Dispatch matches the rest of the family: device path on TPU, numpy
reference (:func:`ref_compact_rewrite` — the exact pre-device code) on the
CPU backend, ``interpret=True`` forcing the device path through the Pallas
interpreter for byte-equivalence tests. 8-byte value dtypes always take
the host path (a 32-bit jax gather would silently downcast them).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import launch
from ._compat import interpret_default


def _keep_mask_kernel(ts_ref, keep_ref, cnt_ref, *, cutoff: int):
    k = (ts_ref[:] > cutoff).astype(jnp.int32)
    keep_ref[:] = k
    cnt_ref[0] = jnp.sum(k)


@functools.partial(jax.jit, static_argnames=("cutoff", "interpret", "tile"))
def _keep_mask(ts32, *, cutoff, interpret, tile):
    return launch.tiled_rows(
        functools.partial(_keep_mask_kernel, cutoff=cutoff),
        [ts32], [((), jnp.int32, "rows"), ((), jnp.int32, "tile")],
        tile=tile, interpret=interpret)


def ref_compact_rewrite(vals, tss, ptr, base_vals, base_found, before_ts,
                        n_rows):
    """Host oracle: the exact numpy rewrite ``compact()`` always did."""
    keep = tss > before_ts
    rows_all = np.repeat(np.arange(n_rows, dtype=np.int32), np.diff(ptr))
    base_rows = np.nonzero(base_found)[0].astype(np.int32)
    new_rows = np.concatenate([base_rows, rows_all[keep]])
    new_tss = np.concatenate([
        np.full(len(base_rows), before_ts, np.int64), tss[keep]])
    new_vals = np.concatenate([base_vals[base_found], vals[keep]])
    order = np.lexsort((new_tss, new_rows))
    nptr = np.zeros(n_rows + 1, np.int32)
    np.add.at(nptr, new_rows + 1, 1)
    return (new_vals[order], new_tss[order], new_rows[order],
            np.cumsum(nptr).astype(np.int32))


def compact_rewrite(vals, tss, ptr, base_vals, base_found, before_ts,
                    n_rows, *, interpret: bool | None = None,
                    tile: int | None = None):
    """Rewrite one cell log for a compaction at horizon ``before_ts``.

    Args:
      vals: (C, W) cell values sorted by (row, ts).
      tss: (C,) int64 cell timestamps (same order).
      ptr: (n_rows+1,) CSR row pointers.
      base_vals / base_found: ``select_at(n_rows, before_ts)`` output —
        the per-row folded base value at the horizon.
      before_ts: compaction horizon (inclusive).
      n_rows: row count.

    Returns:
      (new_vals, new_tss int64, new_rows int32, new_ptr int32) — the
      compacted log in (row, ts) order, byte-identical across dispatch
      paths (pinned by the equivalence tests).
    """
    c = len(tss)
    w = vals.shape[1] if vals.ndim == 2 else 1
    use_ref = (interpret is None and interpret_default()) \
        or vals.dtype.itemsize == 8 or c == 0
    # traffic model: stream the (C,) ts for the mask (read + int32 mask
    # write) and move every value byte once on each side of the gather;
    # arithmetic: one compare per cell. padded adds the mask tile slack.
    t = launch.tile_for("compact_rewrite", n=c)
    c_pad = launch.round_up_tile(c, t)
    nb = 8 * c + 2 * (vals.nbytes + base_vals.nbytes)
    with launch.measured("compact_rewrite", nbytes=nb, flops=c,
                         padded_nbytes=nb + 8 * (c_pad - c)):
        if use_ref:
            return ref_compact_rewrite(vals, tss, ptr, base_vals,
                                       base_found, before_ts, n_rows)
        return _device_rewrite(vals, tss, ptr, base_vals, base_found,
                               before_ts, n_rows,
                               interpret=bool(interpret), tile=t)


def _device_rewrite(vals, tss, ptr, base_vals, base_found, before_ts,
                    n_rows, *, interpret, tile):
    # stored device timestamps are int32 by convention (core/store.py
    # clamps queries below TS_MAX), so the mask kernel compares in int32
    cutoff = int(min(max(int(before_ts), -(2**31) + 1), 2**31 - 2))
    keep_dev, _cnts = _keep_mask(jnp.asarray(tss.astype(np.int32)),
                                 cutoff=cutoff, interpret=interpret,
                                 tile=tile)
    keep = np.asarray(keep_dev).astype(bool)
    keep_idx = np.nonzero(keep)[0].astype(np.int32)
    rows_all = np.repeat(np.arange(n_rows, dtype=np.int32), np.diff(ptr))
    base_rows = np.nonzero(base_found)[0].astype(np.int32)
    new_rows = np.concatenate([base_rows, rows_all[keep_idx]])
    new_tss = np.concatenate([
        np.full(len(base_rows), before_ts, np.int64), tss[keep_idx]])
    order = np.lexsort((new_tss, new_rows))
    # the value bytes (the heavy part) move in ONE fused device gather:
    # output position -> source row in concat(full base table, old cells)
    cat_pos = np.concatenate([base_rows, n_rows + keep_idx])
    src = jnp.asarray(cat_pos[order].astype(np.int32))
    cat = jnp.concatenate([jnp.asarray(base_vals), jnp.asarray(vals)],
                          axis=0)
    new_vals = np.asarray(jnp.take(cat, src, axis=0))
    nptr = np.zeros(n_rows + 1, np.int32)
    np.add.at(nptr, new_rows + 1, 1)
    return (new_vals, new_tss[order], new_rows[order],
            np.cumsum(nptr).astype(np.int32))
