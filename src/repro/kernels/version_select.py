"""Pallas masked-cumsum kernel: the scan behind get_version / get_increment.

GeStore materializes version T by selecting, for every row's cell chain, the
newest cell with ts <= T (paper §III.C). With the cell log in CSR order
(sorted by (row, ts)), timestamps are ascending inside each row segment, so
the per-row answer index is ``row_ptr[i] + count(ts_segment <= T) - 1`` and
the count is a difference of the GLOBAL inclusive cumsum of the 0/1 mask
(ts <= T) at segment boundaries.

The kernel computes that cumsum hierarchically: pass 1 (this kernel) emits
per-tile intra-cumsum plus per-tile totals; the (tiny) tile-offset cumsum and
the boundary gathers run in XLA. This keeps the hot O(C) pass in a single
streaming Pallas kernel with bounded VMEM, with no reliance on cross-grid
scratch carry semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from ._compat import cdiv, interpret_default

TILE_C = 2048


def _masked_cumsum_kernel(ts_ref, t_ref, cum_ref, tot_ref):
    t = t_ref[0]
    m = (ts_ref[:] <= t).astype(jnp.int32)
    c = jnp.cumsum(m)
    cum_ref[:] = c
    tot_ref[0] = c[-1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_cumsum(ts: jax.Array, t_query, *, interpret: bool | None = None) -> jax.Array:
    """ts: (C,) -> (C,) int32 inclusive cumsum of (ts <= t_query).
    interpret=None: kernel on TPU, jitted ref on CPU; True: force kernel."""
    if interpret is None:
        if interpret_default():
            return ref.ref_masked_cumsum(ts, jnp.asarray(t_query, ts.dtype))
        interpret = False
    (c,) = ts.shape
    if c == 0:
        return jnp.zeros((0,), jnp.int32)
    c_pad = cdiv(c, TILE_C) * TILE_C
    tq = jnp.asarray(t_query, dtype=ts.dtype)
    if c_pad != c:
        # pad with a value > t_query so the padding never counts
        ts = jnp.concatenate(
            [ts, jnp.full((c_pad - c,), tq + jnp.asarray(1, ts.dtype), ts.dtype)])
    n_tiles = c_pad // TILE_C
    intra, totals = pl.pallas_call(
        _masked_cumsum_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((TILE_C,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_C,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.int32),
        ],
        interpret=interpret,
    )(ts, tq[None])
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(totals)[:-1]])
    out = intra + jnp.repeat(offsets, TILE_C, total_repeat_length=c_pad)
    return out[:c]


def version_select(log_vals, log_ts, row_ptr, t_query, *, interpret: bool | None = None):
    """CSR segmented last-cell-with-ts<=T selection (see ref.ref_version_select)."""
    if log_ts.shape[0] == 0:  # empty log: nothing found anywhere
        n = row_ptr.shape[0] - 1
        return (jnp.zeros((n,) + log_vals.shape[1:], log_vals.dtype),
                jnp.zeros((n,), bool))
    cum = masked_cumsum(log_ts, t_query, interpret=interpret)
    cum0 = jnp.concatenate([jnp.zeros((1,), jnp.int32), cum])
    lo = row_ptr[:-1]
    hi = row_ptr[1:]
    cnt = cum0[hi] - cum0[lo]
    found = cnt > 0
    idx = jnp.clip(lo + cnt - 1, 0, max(log_ts.shape[0] - 1, 0))
    out = jnp.where(found[:, None], log_vals[idx], 0)
    return out, found
