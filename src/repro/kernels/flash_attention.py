"""Blocked causal flash attention (Pallas, TPU target) — beyond-paper kernel.

The paper's own hot spots are storage scans; this kernel covers the dominant
compute hot spot of the *framework* serving path (prefill attention), where
materializing (Sq, Sk) logits for 32k contexts is HBM-infeasible.

Design for v5e: grid (B, H, Sq/BQ); each grid step holds one q tile
(BQ, D) and streams kv tiles (BK, D) from a VMEM-resident (Sk, D) block with
an online-softmax carry (m, l, acc) in f32. GQA is folded into the k/v
BlockSpec index_map (q head h reads kv head h // group). MXU alignment:
BQ = BK = 128, D = head_dim (128 for every assigned arch except qwen2-0.5b's
64). VMEM bound: k+v blocks are Sk*D*2*2 bytes -> Sk <= ~48k at D=128 bf16,
which covers the prefill_32k shape; longer contexts use the sequence-sharded
path (see sharding/rules.py) so per-device Sk stays within this bound.
The causal inner loop has a dynamic trip count (no wasted tiles past the
diagonal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import cdiv, interpret_default

BQ = 128
BK = 128
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, sk: int, sq: int, scale: float,
                  bq: int, bk: int):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, d)
    d = q.shape[-1]
    offset = sk - sq  # queries are the last sq positions of the key axis
    qpos = offset + qi * bq + jax.lax.iota(jnp.int32, bq)

    def body(kv, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.ds(kv * bk, bk), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(kv * bk, bk), :].astype(jnp.float32)
        kpos = kv * bk + jax.lax.iota(jnp.int32, bk)
        logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        mask = (qpos[:, None] >= kpos[None, :]) & (kpos[None, :] < sk)
        logits = jnp.where(mask, logits, NEG)
        m_new = jnp.maximum(m, jnp.max(logits, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(mask, jnp.exp(logits - m_new[:, None]), 0.0)
        l = l * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        return m_new, l, acc

    # dynamic causal trip count: kv tiles strictly past the diagonal are skipped
    hi = jnp.minimum((offset + (qi + 1) * bq + bk - 1) // bk, cdiv(sk, bk))
    m0 = jnp.full((bq,), NEG, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "interpret", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, interpret: bool | None = None,
                    bq: int = BQ, bk: int = BK):
    """q: (B, Sq, H, D); k/v: (B, Sk, K, D), H % K == 0 -> (B, Sq, H, D).

    Causal with the queries aligned to the END of the key axis (prefill and
    chunked-prefill both satisfy this).
    """
    assert causal, "only the causal serving path is kernelized"
    if interpret is None:
        if interpret_default():
            from . import ref
            return ref.ref_attention(q, k, v, causal=True)
        interpret = False
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    group = h // kh
    scale = d ** -0.5

    qt = jnp.swapaxes(q, 1, 2)  # (B, H, Sq, D)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    sq_pad = cdiv(sq, bq) * bq
    sk_pad = cdiv(sk, bk) * bk
    if sq_pad != sq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    if sk_pad != sk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_flash_kernel, sk=sk, sq=sq, scale=scale, bq=bq, bk=bk),
        grid=(b, h, sq_pad // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, q_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, sk_pad, d), lambda b_, h_, q_, g=group: (b_, h_ // g, 0, 0)),
            pl.BlockSpec((1, 1, sk_pad, d), lambda b_, h_, q_, g=group: (b_, h_ // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, q_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_pad, d), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out[:, :, :sq], 1, 2)
