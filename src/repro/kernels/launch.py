"""Unified kernel-launch plumbing: tiles, buckets, autotune, telemetry.

Every kernel in the family (``batched_select``, ``shard_route``,
``delta_codec``, ``compact_rewrite``) used to carry its own copy of the
same host-side launch logic — pad the leading axis to a hardcoded tile
multiple, build the grid/BlockSpec boilerplate, pick interpret mode, and
wrap the host-sync site in ``kerneltel``. This module is that plumbing,
written once:

  * **Tile resolution** (:func:`tile_for`): ``GESTORE_TILE_<KERNEL>`` env
    override > autotuned winner from the on-disk cache > built-in default
    (the old hardcoded ``TILE_C``/``TILE_N`` values). Resolution is pure
    host Python and happens *outside* jit, so the tile is a static launch
    parameter.
  * **Power-of-two shape buckets** (:func:`pow2_bucket`): the retrace
    killer. Operand leading dims are padded up to the next power of two so
    a continuously growing superlog (every ingest changes the cell count)
    revisits a small set of static shapes instead of recompiling per
    ingest — the same trick ``chain_pack`` has always used for segment
    cell runs.
  * **Autotune sweep** (:func:`sweep`): explicit, never implicit. The
    serving path only ever *reads* the cache; the sweep runs when
    ``benchmarks/table11_kernels.py`` (or a caller) asks for it, and the
    winning tile per ``(kernel, platform, shape bucket)`` is persisted to
    ``GESTORE_TILE_CACHE`` (default ``~/.cache/gestore/tiles.json``) so it
    runs once per machine. CI uploads the file as an artifact and restores
    it with ``actions/cache`` so repeat runs skip the sweep entirely.
  * **Row-tiled pallas_call builder** (:func:`tiled_rows`): the shared
    1-D-grid launch shape (pad rows to a tile multiple, per-tile row
    blocks plus optional per-tile stat outputs, slice back to the logical
    row count).
  * **Telemetry** (:func:`measured`): the ``kerneltel.launch`` wrap used
    by every host-facing call site, carrying *both* the logical traffic
    model and the padded bytes that actually move (bucket slack must not
    skew roofline fractions — see obs/kerneltel.py).

On the CPU backend the kernels dispatch to their jnp reference oracles, so
tile choice is a no-op there; the sweep still records a winner (cheap) to
keep the cache shape identical across platforms.
"""
from __future__ import annotations

import json
import os
import threading

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import cdiv

#: built-in tiles — exactly the values the kernels hardcoded before the
#: launch helper existed, so behavior without env/cache input is unchanged.
DEFAULT_TILES = {
    "batched_select": 2048,
    "shard_route": 512,
    "delta_codec": 512,
    "compact_rewrite": 512,
}

#: default sweep candidates per kernel (table11 can widen via env).
SWEEP_CANDIDATES = {
    "batched_select": (512, 1024, 2048, 4096),
    "shard_route": (256, 512, 1024, 2048),
    "delta_codec": (256, 512, 1024, 2048),
    "compact_rewrite": (256, 512, 1024, 2048),
}

ENV_PREFIX = "GESTORE_TILE_"
CACHE_ENV = "GESTORE_TILE_CACHE"

_lock = threading.Lock()
#: in-memory mirror of the on-disk winner cache; None = not loaded yet.
_winners: dict[str, int] | None = None


# -- shape buckets ------------------------------------------------------------

def pow2_bucket(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor) (and >= 1): the static-shape
    bucket for a logically ``n``-long axis."""
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()


def round_up_tile(n: int, tile: int) -> int:
    """Pad ``n`` up to a multiple of ``tile`` (at least one tile)."""
    return cdiv(max(int(n), 1), tile) * tile


# -- tile resolution ----------------------------------------------------------

def cache_path() -> str:
    """Location of the on-disk autotune winner cache."""
    p = os.environ.get(CACHE_ENV, "").strip()
    if p:
        return p
    return os.path.join(os.path.expanduser("~"), ".cache", "gestore",
                        "tiles.json")


def _cache_key(kernel: str, bucket: int, platform: str | None = None) -> str:
    plat = platform or jax.default_backend()
    return f"{kernel}/{plat}/b{int(bucket)}"


def _load_winners() -> dict[str, int]:
    global _winners
    with _lock:
        if _winners is None:
            _winners = {}
            try:
                with open(cache_path()) as f:
                    raw = json.load(f)
                _winners = {str(k): int(v) for k, v in raw.items()
                            if isinstance(v, (int, float))}
            except (OSError, ValueError, TypeError):
                pass  # missing or corrupt cache: start empty
        return _winners


def reset_cache() -> None:
    """Drop the in-memory winner mirror (tests / env changes re-read disk)."""
    global _winners
    with _lock:
        _winners = None


def record_winner(kernel: str, bucket: int, tile: int,
                  platform: str | None = None) -> None:
    """Persist an autotuned winner to memory + the on-disk cache (best
    effort: an unwritable cache dir degrades to in-memory only)."""
    winners = _load_winners()
    with _lock:
        winners[_cache_key(kernel, bucket, platform)] = int(tile)
        payload = dict(winners)
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass


def tile_for(kernel: str, n: int | None = None) -> int:
    """Resolve the launch tile for ``kernel`` (leading-axis length ``n``).

    Precedence: ``GESTORE_TILE_<KERNEL>`` env var > autotuned winner for
    this (kernel, platform, pow2 bucket of n) > ``DEFAULT_TILES``. Always
    a plain positive int — callers pass it to jit as a static arg.
    """
    env = os.environ.get(ENV_PREFIX + kernel.upper(), "").strip()
    if env:
        try:
            t = int(env)
            if t > 0:
                return t
        except ValueError:
            pass  # malformed override: fall through to cache/default
    if n is not None:
        w = _load_winners().get(_cache_key(kernel, pow2_bucket(n)))
        if w:
            return w
    return DEFAULT_TILES.get(kernel, 512)


# -- autotune sweep -----------------------------------------------------------

def sweep(kernel: str, bench, *, n: int, candidates=None,
          force: bool = False) -> dict:
    """Time ``bench(tile) -> wall_seconds`` over candidate tiles and persist
    the winner for this (kernel, platform, bucket of n).

    Never called implicitly from a serving path: table11 (or an explicit
    caller) owns the sweep. With a cached winner and ``force=False`` the
    sweep is skipped entirely — that is what makes the CI cache artifact
    worth persisting.

    Returns ``{"tile", "bucket", "cached", "walls"}`` where ``walls`` maps
    tile -> measured seconds (empty when the cache answered).
    """
    bucket = pow2_bucket(n)
    if not force:
        w = _load_winners().get(_cache_key(kernel, bucket))
        if w:
            return {"tile": w, "bucket": bucket, "cached": True, "walls": {}}
    cands = tuple(candidates or SWEEP_CANDIDATES.get(
        kernel, (256, 512, 1024, 2048)))
    walls = {int(t): float(bench(int(t))) for t in cands}
    best = min(walls, key=walls.get)
    record_winner(kernel, bucket, best)
    return {"tile": best, "bucket": bucket, "cached": False, "walls": walls}


# -- shared row-tiled pallas_call plumbing ------------------------------------

def _row_map(ndim: int):
    """Block index map that walks the leading axis and pins the rest."""
    if ndim == 1:
        return lambda i: (i,)
    if ndim == 2:
        return lambda i: (i, 0)
    return lambda i: (i,) + (0,) * (ndim - 1)


def tiled_rows(body, inputs, outs, *, tile: int, interpret: bool):
    """Run ``body`` over a 1-D grid of row tiles — the whole kernel family's
    launch shape in one place.

    Args:
      body: pallas kernel taking input refs then output refs in order.
      inputs: arrays sharing a leading axis N; each is zero-padded along
        axis 0 to a ``tile`` multiple (callers that need a non-zero pad
        value pad before calling, as batched_select does with its
        above-every-query sentinel).
      outs: list of ``(trailing_shape, dtype, kind)``; kind ``"rows"`` is a
        per-row output (block ``(tile, *trailing)``, sliced back to N) and
        ``"tile"`` a per-tile stat (block ``(1, *trailing)``, returned at
        full ``n_tiles`` length).
      tile: static tile size from :func:`tile_for`.
      interpret: pallas interpret flag (resolved by the caller's dispatch).

    Returns the tuple of outputs.
    """
    n = inputs[0].shape[0]
    n_pad = round_up_tile(n, tile)
    if n_pad != n:
        inputs = [jnp.pad(a, ((0, n_pad - n),) + ((0, 0),) * (a.ndim - 1))
                  for a in inputs]
    n_tiles = n_pad // tile
    in_specs = [pl.BlockSpec((tile,) + a.shape[1:], _row_map(a.ndim))
                for a in inputs]
    out_specs, out_shape = [], []
    for trailing, dtype, kind in outs:
        trailing = tuple(trailing)
        lead = tile if kind == "rows" else 1
        rows = n_pad if kind == "rows" else n_tiles
        out_specs.append(pl.BlockSpec((lead,) + trailing,
                                      _row_map(1 + len(trailing))))
        out_shape.append(jax.ShapeDtypeStruct((rows,) + trailing, dtype))
    res = pl.pallas_call(body, grid=(n_tiles,), in_specs=in_specs,
                         out_specs=out_specs, out_shape=out_shape,
                         interpret=interpret)(*inputs)
    return tuple(r[:n] if k == "rows" else r
                 for r, (_t, _d, k) in zip(res, outs))


# -- telemetry ----------------------------------------------------------------

def measured(kernel: str, *, nbytes: float, flops: float,
             padded_nbytes: float | None = None):
    """The kernel family's ``kerneltel.launch`` wrap: logical traffic model
    plus the padded bytes that actually cross HBM (bucket/tile slack)."""
    from repro.obs import kerneltel
    return kerneltel.launch(kernel, nbytes=nbytes, flops=flops,
                            padded_nbytes=padded_nbytes)
