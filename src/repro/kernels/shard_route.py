"""Pallas key->shard routing kernel (sharded-store scatter step).

The sharded meta-database facade (core/shard.py) hash-partitions the entry
keyspace over N independent stores, mirroring the paper's spread of
meta-database rows across HBase region servers (§II.B/§V). Routing must be
a *persistent* function of the key alone — the same key has to land on the
same shard across releases, processes, and batch compositions — so the hash
folds zero-padded little-endian key lanes with a zero-transparent
xor-rotate mix (a padded zero lane contributes nothing) and disambiguates
real trailing zero bytes via the key length. ``ref.ref_shard_route`` is the
semantic ground truth; the kernel is a tiled VPU fold exactly like
fingerprint.py (reads N*W*4 bytes, writes N*4 -> bandwidth-bound).

The gather step of scatter-gather (merging per-shard row selections back
into global row order) is ``merge_shard_rows`` below: per-shard global-row
arrays are each ascending and mutually disjoint, so one argsort over the
concatenation reproduces the unsharded store's row order exactly.
"""
from __future__ import annotations

import functools
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from . import launch, ref
from ._compat import interpret_default

#: pre-autotune hardcoded tile, kept for backward compatibility; live
#: launches resolve through launch.tile_for("shard_route").
TILE_N = launch.DEFAULT_TILES["shard_route"]

#: routing-function version tag, persisted in shard manifests: a store
#: written under one tag must never be extended by a different hash.
ROUTING_VERSION = "xor-rotate-fold-v1"


def _shard_route_kernel(lanes_ref, len_ref, out_ref, *, w: int, n_shards: int):
    h = jnp.zeros((lanes_ref.shape[0],), jnp.int32)
    for j in range(w):  # static unroll over lanes (keys are a few lanes wide)
        t = lanes_ref[:, j] * ref.RT_MUL1
        t = t ^ jax.lax.shift_right_logical(t, 15)
        t = t * ref.RT_MUL2
        r = (j % 31) + 1
        h = h ^ ((t << r) | jax.lax.shift_right_logical(t, 32 - r))
    h = h ^ (len_ref[:] * ref.RT_MUL3)
    h = h ^ jax.lax.shift_right_logical(h, 16)
    h = h * ref.RT_MUL4
    h = h ^ jax.lax.shift_right_logical(h, 13)
    out_ref[:] = (h & jnp.int32(0x7FFFFFFF)) % jnp.int32(n_shards)


def shard_route(lanes: jax.Array, lengths: jax.Array, n_shards: int, *,
                interpret: bool | None = None,
                tile: int | None = None) -> jax.Array:
    """lanes: (N, W) int32; lengths: (N,) int32 -> (N,) int32 shard ids.

    interpret=None: Pallas kernel on TPU, jitted ref oracle on CPU;
    interpret=True: force the kernel body via the Pallas interpreter."""
    if tile is None:
        tile = launch.tile_for("shard_route", n=lanes.shape[0])
    return _shard_route(lanes, lengths, int(n_shards), interpret=interpret,
                        tile=int(tile))


@functools.partial(jax.jit, static_argnames=("n_shards", "interpret", "tile"))
def _shard_route(lanes, lengths, n_shards, *, interpret, tile):
    if interpret is None:
        if interpret_default():
            return ref.ref_shard_route(lanes, lengths, n_shards)
        interpret = False
    n, w = lanes.shape
    if n == 0:
        return jnp.zeros((0,), jnp.int32)
    (out,) = launch.tiled_rows(
        functools.partial(_shard_route_kernel, w=w, n_shards=n_shards),
        [lanes, lengths], [((), jnp.int32, "rows")],
        tile=tile, interpret=interpret)
    return out


# -- host plumbing ------------------------------------------------------------

def key_lanes(keys: Sequence[bytes]) -> tuple[np.ndarray, np.ndarray]:
    """Pack byte keys into (lanes (N, W) int32, lengths (N,) int32): each
    key's bytes little-endian into 4-byte lanes, zero-padded to the batch
    max width (the hash is width-stable, so the batch max is just a packing
    convenience, not part of the route)."""
    n = len(keys)
    lens = np.fromiter((len(k) for k in keys), np.int32, count=n)
    wb = max((int(lens.max(initial=1)) + 3) // 4, 1) * 4
    buf = np.zeros((n, wb), np.uint8)
    for i, k in enumerate(keys):
        buf[i, : len(k)] = np.frombuffer(k, np.uint8)
    # explicit little-endian lane packing: the route (and therefore the
    # persisted partitioning) must not depend on host byte order
    lanes = buf.view("<u4").astype(np.uint32).view(np.int32)
    return lanes, lens


def route_keys(keys: Sequence[bytes], n_shards: int) -> np.ndarray:
    """Stable shard id per key: (N,) host int32 in [0, n_shards)."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if not keys:
        return np.zeros(0, np.int32)
    if n_shards == 1:
        return np.zeros(len(keys), np.int32)
    lanes, lens = key_lanes(keys)
    n, w = lanes.shape
    # traffic model: read (N, W) lanes + (N,) lengths, write (N,) ids;
    # arithmetic: ~8 integer ops per lane in the xor-rotate fold + the
    # 5-op finalizer per key; padded counts the tile-multiple row slack
    n_pad = launch.round_up_tile(n, launch.tile_for("shard_route", n=n))
    with launch.measured("shard_route", nbytes=4 * (n * w + 2 * n),
                         flops=n * (8 * w + 5),
                         padded_nbytes=4 * (n_pad * w + 2 * n_pad)):
        return np.asarray(shard_route(jnp.asarray(lanes), jnp.asarray(lens),
                                      int(n_shards)))


def merge_shard_rows(parts: Sequence[np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
    """Gather step: K per-shard ascending global-row arrays -> (merged rows,
    gather order into their concatenation). Shards partition the row space,
    so one argsort over the concatenation reproduces the exact ascending
    row order the unsharded store would have produced."""
    cat = (np.concatenate(parts) if len(parts)
           else np.zeros(0, np.int64))
    order = np.argsort(cat, kind="stable")
    return cat[order], order
