"""Pallas TPU kernels for GeStore-JAX hot spots.

Storage-layer kernels (the paper's hot spots): fingerprint, version_select,
delta_codec, masked_merge. Framework hot spot (beyond-paper): flash_attention.
Each kernel module pairs with a pure-jnp oracle in ref.py; ops.py exposes the
jit'd public API.
"""
from . import ops  # noqa: F401
from .ops import (  # noqa: F401
    delta_pack, delta_unpack, fingerprint, fingerprint_rows, flash_attention,
    masked_cumsum, masked_merge, narrow_dtype, version_select,
)
