"""Jit'd public wrappers over the Pallas kernels (+ dtype plumbing).

The store layer talks to kernels only through this module, so the
kernel/XLA-fallback decision is centralized here.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import launch, ref
from .batched_select import (batched_masked_cumsum, batched_version_select,
                             scan_bucket, scan_cache_size)
from .compact_rewrite import compact_rewrite
from .delta_codec import (chain_decode, chain_pack, chain_unpack, delta_pack,
                          delta_pack_wide, delta_unpack, delta_unpack_wide,
                          narrow_dtype)
from .fingerprint import fingerprint
from .flash_attention import flash_attention
from .masked_merge import masked_merge
from .shard_route import merge_shard_rows, route_keys, shard_route
from .version_select import masked_cumsum, version_select

__all__ = [
    "fingerprint", "fingerprint_rows", "masked_cumsum", "version_select",
    "batched_masked_cumsum", "batched_version_select",
    "scan_bucket", "scan_cache_size", "compact_rewrite",
    "delta_pack", "delta_unpack", "chain_pack", "chain_unpack",
    "delta_pack_wide", "delta_unpack_wide", "chain_decode",
    "narrow_dtype", "masked_merge", "shard_route", "route_keys",
    "merge_shard_rows", "flash_attention", "to_int_lanes", "launch", "ref",
]


def to_int_lanes(x) -> jax.Array:
    """View any fixed-width row array (N, W) as int32 lanes (N, W') for
    fingerprinting. Sub-4-byte dtypes are zero-extended per element (cheap,
    keeps lane semantics stable under schema evolution)."""
    x = jnp.asarray(x)
    if x.ndim == 1:
        x = x[:, None]
    if x.dtype == jnp.int32:
        return x
    if x.dtype.itemsize == 4:
        return x.view(jnp.int32)
    if x.dtype == jnp.int64:
        lo = (x & 0xFFFFFFFF).astype(jnp.uint32).view(jnp.int32)
        hi = (x >> 32).astype(jnp.int32)
        return jnp.concatenate([lo, hi], axis=1)
    if x.dtype.itemsize == 2:
        return x.view(jnp.int16).astype(jnp.int32)
    if x.dtype.itemsize == 1:
        return x.view(jnp.int8).astype(jnp.int32)
    raise TypeError(f"unsupported lane dtype {x.dtype}")


def fingerprint_rows(x) -> np.ndarray:
    """Fingerprint arbitrary-dtype rows; returns host (N, 2) int32."""
    lanes = to_int_lanes(x)
    return np.asarray(fingerprint(lanes))
