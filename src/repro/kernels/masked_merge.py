"""Pallas fused masked-merge kernel (increment application, paper §III.A/F).

Merging an incremental result back into the head table is a fused
(row-mask AND field-mask) select plus EXISTS/timestamp stamping. Doing this
as one streaming kernel avoids three separate O(N*W) passes (select, exists
update, ts update) over HBM. Row alignment (scatter of the compacted
increment onto the row space) is done once in XLA outside the kernel; the
kernel owns the wide data movement.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from ._compat import cdiv, interpret_default

TILE_N = 512


def _masked_merge_kernel(base_ref, upd_ref, rmask_ref, fmask_ref, tsb_ref, tsn_ref,
                         out_ref, tso_ref):
    rm = rmask_ref[:] != 0
    fm = fmask_ref[:] != 0
    sel = rm[:, None] & fm[None, :]
    out_ref[:, :] = jnp.where(sel, upd_ref[:, :], base_ref[:, :])
    tso_ref[:] = jnp.where(rm, tsn_ref[0], tsb_ref[:])


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_merge(base, upd, row_mask, field_mask, ts_base, ts_new,
                 *, interpret: bool | None = None):
    """base/upd: (N, W) same dtype; row_mask: (N,) bool; field_mask: (W,) bool;
    ts_base: (N,) int64; ts_new: scalar -> (merged (N, W), ts_out (N,))."""
    if interpret is None:
        if interpret_default():
            return ref.ref_masked_merge(base, upd, row_mask, field_mask,
                                        ts_base, ts_new)
        interpret = False
    n, w = base.shape
    n_pad = cdiv(max(n, 1), TILE_N) * TILE_N
    pad = n_pad - n

    def pad0(x):
        return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x

    tsn = jnp.asarray(ts_new, dtype=ts_base.dtype)[None]
    merged, ts_out = pl.pallas_call(
        _masked_merge_kernel,
        grid=(n_pad // TILE_N,),
        in_specs=[
            pl.BlockSpec((TILE_N, w), lambda i: (i, 0)),
            pl.BlockSpec((TILE_N, w), lambda i: (i, 0)),
            pl.BlockSpec((TILE_N,), lambda i: (i,)),
            pl.BlockSpec((w,), lambda i: (0,)),
            pl.BlockSpec((TILE_N,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_N, w), lambda i: (i, 0)),
            pl.BlockSpec((TILE_N,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, w), base.dtype),
            jax.ShapeDtypeStruct((n_pad,), ts_base.dtype),
        ],
        interpret=interpret,
    )(pad0(base), pad0(upd), pad0(row_mask.astype(jnp.int32)),
      field_mask.astype(jnp.int32), pad0(ts_base), tsn)
    return merged[:n], ts_out[:n]
