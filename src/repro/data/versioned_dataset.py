"""Versioned training corpus on the GeStore core (DESIGN.md §2).

Documents live in a VersionedStore (text chunk rows + token rows); a corpus
release update triggers INCREMENTAL re-tokenization: only documents whose
text changed in (t_last, t] are re-encoded — the paper's incremental update
applied to the data pipeline. Training jobs pin a corpus version ts, giving
exact data reproducibility across reruns ("gold standard" requirement).
"""
from __future__ import annotations

import numpy as np

from repro.core.store import FieldSchema, VersionedStore
from .tokenizer import ByteTokenizer

TEXT_W = 1024
TOK_W = 1024


class VersionedCorpus:
    def __init__(self, name: str = "corpus", tokenizer: ByteTokenizer | None = None):
        self.tokenizer = tokenizer or ByteTokenizer()
        self.store = VersionedStore(name, [
            FieldSchema("text", TEXT_W, "int8"),
            FieldSchema("tokens", TOK_W, "int32"),
            FieldSchema("n_tokens", 1, "int32"),
        ])
        self.tokens_encoded_total = 0   # work counter (bench metric)

    def _doc_rows(self, docs: dict[str, str]):
        keys, texts, toks, lens = [], [], [], []
        for k, text in docs.items():
            b = text.encode()[:TEXT_W]
            trow = np.zeros(TEXT_W, np.int8)
            trow[: len(b)] = np.frombuffer(b, np.uint8).astype(np.int8)
            enc = self.tokenizer.encode(text)[:TOK_W]
            krow = np.zeros(TOK_W, np.int32)
            krow[: len(enc)] = enc
            keys.append(k.encode())
            texts.append(trow)
            toks.append(enc := krow)
            lens.append(np.asarray([min(len(self.tokenizer.encode(text)), TOK_W)],
                                   np.int32))
        return keys, {"text": np.stack(texts), "tokens": np.stack(toks),
                      "n_tokens": np.stack(lens)}

    def add_release(self, ts: int, docs: dict[str, str], *,
                    full_release: bool = True):
        """Ingest a corpus release; tokenization happens here (the 'tool')."""
        keys, table = self._doc_rows(docs)
        self.tokens_encoded_total += len(docs)
        return self.store.update(ts, keys, table, full_release=full_release)

    def incremental_release(self, t_last: int, ts: int, docs: dict[str, str]):
        """Only re-tokenize docs whose TEXT changed vs version t_last (change
        detection on the raw field, tokenization only for the increment)."""
        keys = [k.encode() for k in docs]
        texts = []
        for k, text in docs.items():
            b = text.encode()[:TEXT_W]
            row = np.zeros(TEXT_W, np.int8)
            row[: len(b)] = np.frombuffer(b, np.uint8).astype(np.int8)
            texts.append(row)
        texts = np.stack(texts)
        # find which docs actually changed (fingerprint against head)
        from repro.kernels import ops as kops
        fp = kops.fingerprint_rows(texts)
        self.store.rebuild_heads(["text"])  # stale after a lazy load
        col = self.store.fields["text"]
        changed_keys = {}
        for i, k in enumerate(keys):
            row = self.store.key_to_row.get(k, -1)
            if row < 0 or not col.head_has[row] or \
                    not (fp[i] == col.head_fp[row]).all():
                changed_keys[k.decode()] = docs[k.decode()]
        ck, table = self._doc_rows(changed_keys) if changed_keys else \
            ([], {"text": np.zeros((0, TEXT_W), np.int8),
                  "tokens": np.zeros((0, TOK_W), np.int32),
                  "n_tokens": np.zeros((0, 1), np.int32)})
        self.tokens_encoded_total += len(changed_keys)
        # patch update carrying only changed docs; the full release key set
        # drives deletion tombstones (present_keys)
        return self.store.update(ts, ck, table, full_release=False,
                                 present_keys=keys)

    def token_stream(self, ts: int) -> np.ndarray:
        """Concatenated token ids of corpus version ts (packing input)."""
        view = self.store.get_version(ts, fields=["tokens", "n_tokens"])
        parts = [row[:n[0]] for row, n in
                 zip(view.values["tokens"], view.values["n_tokens"])]
        return (np.concatenate(parts) if parts else np.zeros(0, np.int32))
