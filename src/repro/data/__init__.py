"""Data substrate: tokenizer, versioned corpus, deterministic pipeline."""
