"""Byte-level tokenizer with a versioned vocabulary remap.

Deliberately simple (no external deps): tokens are bytes offset by the
number of special tokens. The vocab *version* matters to the GeStore story:
a tokenizer/vocab update is a meta-database update, and the versioned
dataset re-tokenizes only changed documents (data/versioned_dataset.py).
"""
from __future__ import annotations

import numpy as np

PAD, BOS, EOS = 0, 1, 2
N_SPECIAL = 3


class ByteTokenizer:
    def __init__(self, vocab_size: int = 259):
        assert vocab_size >= 256 + N_SPECIAL
        self.vocab_size = vocab_size

    def encode(self, text: str, *, bos: bool = True, eos: bool = True) -> np.ndarray:
        b = np.frombuffer(text.encode("utf-8", "replace"), np.uint8)
        toks = b.astype(np.int32) + N_SPECIAL
        parts = []
        if bos:
            parts.append([BOS])
        parts.append(toks)
        if eos:
            parts.append([EOS])
        return np.concatenate([np.asarray(p, np.int32) for p in parts])

    def decode(self, toks) -> str:
        toks = np.asarray(toks)
        body = toks[(toks >= N_SPECIAL)] - N_SPECIAL
        return body.astype(np.uint8).tobytes().decode("utf-8", "replace")
