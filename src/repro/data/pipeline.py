"""Deterministic sharded batch pipeline.

Packs a token stream into (global_batch, seq_len+1) examples, shuffles with
a seeded permutation per epoch, and yields per-host slices (each host feeds
its local devices; `host_id`/`n_hosts` mirror jax.process_index/count on a
real cluster). Determinism = f(seed, corpus version ts, step), so elastic
restarts resume exactly (ft/elastic.py notes).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1


class TokenPipeline:
    def __init__(self, tokens: np.ndarray, cfg: DataConfig):
        self.cfg = cfg
        ex_len = cfg.seq_len + 1
        n_ex = len(tokens) // ex_len
        assert n_ex >= 1, "corpus smaller than one example"
        self.examples = tokens[: n_ex * ex_len].reshape(n_ex, ex_len)

    def n_steps_per_epoch(self) -> int:
        return max(len(self.examples) // self.cfg.global_batch, 1)

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for global step (any host can compute it)."""
        cfg = self.cfg
        spe = self.n_steps_per_epoch()
        epoch, within = divmod(step, spe)
        rng = np.random.default_rng(cfg.seed + epoch)
        perm = rng.permutation(len(self.examples))
        idx = perm[within * cfg.global_batch:(within + 1) * cfg.global_batch]
        if len(idx) < cfg.global_batch:  # wrap the tail
            idx = np.concatenate([idx, perm[: cfg.global_batch - len(idx)]])
        ex = self.examples[idx]
        # host slice
        per_host = cfg.global_batch // cfg.n_hosts
        lo = cfg.host_id * per_host
        ex = ex[lo: lo + per_host] if cfg.n_hosts > 1 else ex
        return {"tokens": ex[:, :-1].astype(np.int32),
                "labels": ex[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
