"""Optimizers: AdamW and Adafactor (factored second moment).

Spec-level state construction (`state_specs`) mirrors the params' logical
axes so optimizer state inherits FSDP/TP sharding — including the *reduced*
axes of Adafactor's row/column statistics. Adafactor is the default for the
1e12-param MoE configs: its state is O(rows+cols) per matrix, which is what
makes kimi-k2 trainable on v5e-class HBM (see DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec


@dataclasses.dataclass(frozen=True)
class OptHyper:
    name: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.1
    # adafactor
    decay_rate: float = 0.8
    eps2: float = 1e-30
    clip_threshold: float = 1.0
    factored_min: int = 128       # factor matrices with both dims >= this


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _factorable(shape, hyper) -> bool:
    return len(shape) >= 2 and shape[-1] >= hyper.factored_min and \
        shape[-2] >= hyper.factored_min


# ---------------------------------------------------------------------------
# state at the ParamSpec level (drives both init and abstract shardings)
# ---------------------------------------------------------------------------

def state_specs(param_specs, hyper: OptHyper):
    if hyper.name == "adamw":
        zero = lambda s: ParamSpec(s.shape, s.axes, jnp.float32, "zeros")
        return {
            "m": jax.tree_util.tree_map(zero, param_specs, is_leaf=_is_spec),
            "v": jax.tree_util.tree_map(zero, param_specs, is_leaf=_is_spec),
            "step": ParamSpec((), (), jnp.int32, "zeros"),
        }
    assert hyper.name == "adafactor", hyper.name

    def vr(s: ParamSpec):
        if _factorable(s.shape, hyper):
            return ParamSpec(s.shape[:-1], s.axes[:-1], jnp.float32, "zeros")
        return ParamSpec(s.shape, s.axes, jnp.float32, "zeros")

    def vc(s: ParamSpec):
        if _factorable(s.shape, hyper):
            return ParamSpec(s.shape[:-2] + s.shape[-1:],
                             s.axes[:-2] + s.axes[-1:], jnp.float32, "zeros")
        return ParamSpec((1,), (None,), jnp.float32, "zeros")  # unused stub

    return {
        "vr": jax.tree_util.tree_map(vr, param_specs, is_leaf=_is_spec),
        "vc": jax.tree_util.tree_map(vc, param_specs, is_leaf=_is_spec),
        "step": ParamSpec((), (), jnp.int32, "zeros"),
    }


def init_state(params, hyper: OptHyper):
    """Concrete zeros matching state_specs (host-side smoke/examples path)."""
    specs = state_specs(
        jax.tree_util.tree_map(
            lambda p: ParamSpec(p.shape, (None,) * p.ndim, p.dtype), params),
        hyper)
    from repro.models.layers import init_params
    return init_params(specs, jax.random.key(0))


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------

def _adamw_update(hyper, p, g, m, v, step):
    g = g.astype(jnp.float32)
    m = hyper.b1 * m + (1 - hyper.b1) * g
    v = hyper.b2 * v + (1 - hyper.b2) * g * g
    mh = m / (1 - hyper.b1 ** step)
    vh = v / (1 - hyper.b2 ** step)
    upd = mh / (jnp.sqrt(vh) + hyper.eps)
    if p.ndim >= 2:
        upd = upd + hyper.weight_decay * p.astype(jnp.float32)
    return (p - hyper.lr * upd.astype(p.dtype)).astype(p.dtype), m, v


def _adafactor_update(hyper, p, g, vr, vc, step):
    g = g.astype(jnp.float32)
    beta2 = 1.0 - step.astype(jnp.float32) ** (-hyper.decay_rate)
    g2 = g * g + hyper.eps2
    if _factorable(p.shape, hyper):
        vr = beta2 * vr + (1 - beta2) * g2.mean(axis=-1)
        vc = beta2 * vc + (1 - beta2) * g2.mean(axis=-2)
        rfac = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), hyper.eps2)
        pre = jnp.sqrt(rfac)[..., None] * jnp.sqrt(vc)[..., None, :]
        upd = g / jnp.maximum(pre, 1e-30)
    else:
        vr = beta2 * vr + (1 - beta2) * g2
        upd = g * jax.lax.rsqrt(jnp.maximum(vr, hyper.eps2))
    # RMS clipping
    rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-30)
    upd = upd / jnp.maximum(1.0, rms / hyper.clip_threshold)
    if p.ndim >= 2:
        upd = upd + hyper.weight_decay * p.astype(jnp.float32)
    return (p - hyper.lr * upd.astype(p.dtype)).astype(p.dtype), vr, vc


def apply_updates(hyper: OptHyper, params, grads, state, lr_scale=1.0):
    """Returns (new_params, new_state)."""
    step = state["step"] + 1
    h = dataclasses.replace(hyper, lr=hyper.lr * lr_scale)
    if hyper.name == "adamw":
        leaves = jax.tree_util.tree_map(
            lambda p, g, m, v: _adamw_update(h, p, g, m, v, step),
            params, grads, state["m"], state["v"])
        new_p = jax.tree_util.tree_map(lambda t: t[0], leaves,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], leaves,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], leaves,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "step": step}
    leaves = jax.tree_util.tree_map(
        lambda p, g, vr, vc: _adafactor_update(h, p, g, vr, vc, step),
        params, grads, state["vr"], state["vc"])
    new_p = jax.tree_util.tree_map(lambda t: t[0], leaves,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_vr = jax.tree_util.tree_map(lambda t: t[1], leaves,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_vc = jax.tree_util.tree_map(lambda t: t[2], leaves,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"vr": new_vr, "vc": new_vc, "step": step}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype),
                                  grads), gn
