"""Training substrate: optimizers, schedules, loop, gradient compression."""
