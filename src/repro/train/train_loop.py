"""Training loop: jit'd step + schedules + async delta checkpoints +
straggler monitor + (optional) int8 cross-pod gradient compression."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.ft.checkpoint import CheckpointManager
from repro.ft.straggler import StragglerMonitor
from repro.launch.steps import default_hyper, make_train_step
from repro.models import build
from repro.train import grad_compress, schedule
from repro.train.optimizer import init_state


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    warmup_steps: int = 10
    ckpt_every: int = 0            # 0 = no checkpoints
    ckpt_dir: str = "ckpts"
    log_every: int = 10
    host: str = "host0"


class Trainer:
    def __init__(self, cfg: ModelConfig, run: RunConfig, tcfg: TrainerConfig,
                 params=None, seed: int = 0):
        self.cfg, self.run, self.tcfg = cfg, run, tcfg
        self.bundle = build(cfg)
        self.hyper = default_hyper(cfg, run)
        params = params if params is not None else \
            self.bundle.init(jax.random.key(seed))
        self.state = {"params": params,
                      "opt": init_state(params, self.hyper)}
        if run.grad_compress:
            self.state["ef"] = grad_compress.init_error_state(params)
        self.step_fn = jax.jit(self._make_step(), donate_argnums=(0,))
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, async_save=True)
                     if tcfg.ckpt_every else None)
        self.monitor = StragglerMonitor()
        self.history: list[dict] = []
        self.step = 0

    def _make_step(self):
        base = make_train_step(self.cfg, self.run, self.hyper)
        if not self.run.grad_compress:
            return base
        # wrap: compress grads with error feedback before the optimizer.
        # (On a multi-pod mesh the dequantized grads ride the cross-pod
        # reduction; here the quant/dequant pair runs in-line and the EF
        # residual is carried in the state.)
        from repro.train.optimizer import apply_updates, clip_by_global_norm
        from repro.launch.steps import fwd_opts
        bundle, run, hyper = self.bundle, self.run, self.hyper
        opts = fwd_opts(run)

        def step(state, batch):
            params = state["params"]
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: bundle.loss(p, batch, opts), has_aux=True)(params)
            grads, ef = grad_compress.compress_grads(grads, state["ef"])
            grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
            new_params, new_opt = apply_updates(hyper, params, grads,
                                                state["opt"])
            m = dict(metrics)
            m.update(loss=loss, grad_norm=gnorm)
            return {"params": new_params, "opt": new_opt, "ef": ef}, m

        return step

    def lr_at(self, step: int) -> float:
        return float(schedule.warmup_cosine(
            step, peak_lr=self.run.learning_rate,
            warmup_steps=self.tcfg.warmup_steps,
            total_steps=self.tcfg.total_steps))

    def run_loop(self, batches: Iterator[dict],
                 hook: Callable[[int, dict], None] | None = None) -> list[dict]:
        for batch in batches:
            if self.step >= self.tcfg.total_steps:
                break
            t0 = time.time()
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            self.state, metrics = self.step_fn(self.state, jb)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            self.monitor.record(self.tcfg.host, dt)
            self.step += 1
            metrics.update(step=self.step, step_time=dt)
            self.history.append(metrics)
            if self.ckpt and self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(self.step, self.state["params"])
            if hook:
                hook(self.step, metrics)
        if self.ckpt:
            self.ckpt.wait()
        return self.history

    def restore(self, step: int) -> None:
        assert self.ckpt is not None
        self.state["params"] = self.ckpt.restore(step,
                                                 like=self.state["params"])
        self.step = step
