"""Error-feedback int8 gradient compression for the cross-pod reduction.

At 1000+-node scale the `pod` axis crosses DCN, whose bandwidth is ~10-30x
below ICI; compressing the cross-pod gradient all-reduce to int8 cuts that
traffic 4x (vs f32 master grads) with error feedback keeping convergence
(1-bit/8-bit SGD literature). Mechanics:

    q, scale = quantize(g + e)        # per-tensor symmetric int8
    e'       = (g + e) - dequantize(q, scale)   # residual carried forward
    g_hat    = psum(dequantize(q, scale), 'pod') / n_pods

The quantize/dequantize pair runs inside the train step; on a multi-pod
mesh the psum rides the `pod` axis via a shard_map wrapper
(tests/test_distributed.py exercises it on 8 host devices). The EF buffers
live in the train state and are sharded like the gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    g = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, error_state):
    """Returns (decompressed grads as seen by every receiver, new error)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize(corrected)
        deq = dequantize(q, scale)
        return deq, corrected - deq

    pairs = jax.tree_util.tree_map(one, grads, error_state)
    deq = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


def podwise_mean(grads, axis_name: str = "pod"):
    """psum-mean over the cross-pod axis (call under shard_map)."""
    return jax.tree_util.tree_map(
        lambda g: jax.lax.pmean(g, axis_name), grads)
