"""Mini workflow manager (the paper's GePan, §II.B.1 + §IV.C).

A workflow is a DAG of Tools; each Tool is an UNMODIFIED callable from
input file paths to an output string. The manager integrates GeStore the
way the paper's 300-LOC GePan patch does: before a tool runs, file-copy
operations are replaced by `gestore.generate_files` (full version,
increment, or cache hit); after it runs, `gestore.merge_files` folds the
partial output into previous results. Provenance lands in the `runs` table;
users may pin a meta-database version per run (§IV.D) and pass an entry
filter (the taxon use case).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.core.increment import GeStore


@dataclasses.dataclass
class Tool:
    name: str
    fn: Callable[[dict], str]        # {input name -> path or text} -> output text
    inputs: list[str]                # names: either prior tool names or "store:<db>"
    uses_increments: bool = True


@dataclasses.dataclass
class WorkflowResult:
    outputs: dict[str, str]
    mode: str
    wall_s: float
    generated: dict[str, str]        # input name -> generation mode used


class WorkflowManager:
    def __init__(self, gestore: GeStore, tools: list[Tool]):
        self.gs = gestore
        self.tools = {t.name: t for t in tools}
        self.order = self._toposort(tools)
        self.previous_outputs: dict[str, str] = {}

    def _toposort(self, tools: list[Tool]) -> list[str]:
        names = {t.name for t in tools}
        done: list[str] = []
        while len(done) < len(tools):
            progressed = False
            for t in tools:
                if t.name in done:
                    continue
                deps = [i for i in t.inputs if i in names]
                if all(d in done for d in deps):
                    done.append(t.name)
                    progressed = True
            assert progressed, "workflow DAG has a cycle"
        return done

    def ingest_release(self, store_name: str, ts: int, source, *,
                       parser_name: str, label: str = "",
                       full_release: bool = True, shards: int = 1,
                       config=None, pressure_fn=None):
        """Run a streaming release ingest as a journaled workflow step.

        The data-feeder analogue of ``run()``: the ingest goes through
        ``GeStore.add_release_stream`` (chunk-parallel parse, shard-wave
        updates, resumable chunk journal under the GeStore root) and its
        provenance lands in the ``runs`` table — a crashed ingest leaves
        an unfinished run row plus the journal; re-invoking with the same
        arguments records a fresh run that replays journaled chunks and
        finishes the release.

        Returns:
          ``IngestReport`` from ``core.ingest``.
        """
        src_desc = source if isinstance(source, str) else f"<{type(source).__name__}>"
        run_id = f"ingest:{store_name}@{ts}-{time.time_ns()}"
        self.gs.tables.start_run(run_id, f"ingest:{store_name}", [src_desc],
                                 {"ts": int(ts), "label": label,
                                  "parser": parser_name,
                                  "full_release": bool(full_release)})
        rep = self.gs.add_release_stream(
            store_name, ts, source, parser_name=parser_name, label=label,
            full_release=full_release, shards=shards, config=config,
            pressure_fn=pressure_fn)
        self.gs.tables.finish_run(run_id, [
            f"store:{store_name}@{ts}",
            f"entries={rep.n_entries}",
            f"chunks_replayed={rep.chunks_replayed}",
            f"already_committed={rep.already_committed}"])
        return rep

    def run(self, *, db_version: int, last_version: int | None = None,
            key_filter: str | None = None) -> WorkflowResult:
        """last_version=None: full run at db_version (pinned-version use
        case). Otherwise an incremental rerun over (last_version, db_version]
        with per-tool output merging."""
        t0 = time.time()
        outputs: dict[str, str] = {}
        generated: dict[str, str] = {}
        for name in self.order:
            tool = self.tools[name]
            args: dict[str, str] = {}
            ctx: dict = {}
            for inp in tool.inputs:
                if inp.startswith("store:"):
                    db = inp.split(":", 1)[1]
                    t_last = last_version if tool.uses_increments else None
                    gen = self.gs.generate_files(
                        name, db, t_version=db_version, t_last=t_last,
                        key_filter=key_filter)
                    args[inp] = gen.path
                    ctx = gen.context
                    generated[f"{name}/{inp}"] = gen.mode
                else:
                    args[inp] = outputs[inp]
            run_id = f"{name}@{db_version}-{time.time_ns()}"
            self.gs.tables.start_run(run_id, name, list(args.values()),
                                     {"db_version": db_version,
                                      "last": last_version})
            partial = tool.fn(args)
            if last_version is not None and name in self.previous_outputs:
                partial = self.gs.merge_files(
                    name, self.previous_outputs[name], partial, context=ctx)
            outputs[name] = partial
            self.gs.tables.finish_run(run_id, [name])
        self.previous_outputs = dict(outputs)
        return WorkflowResult(outputs=outputs,
                              mode="full" if last_version is None else "incremental",
                              wall_s=time.time() - t0, generated=generated)
