"""Mini workflow manager with GeStore integration (the paper's GePan)."""
