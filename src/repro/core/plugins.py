"""GeStore plugin framework (paper §III.F).

A tool plugin = (file parsers, file generator, output merger). The parser
interface mirrors the paper's six methods: entry delimiters, entry->columns
split, version compare, required-element validation, Put-object generation
(here: (key, field-row dict)), and output formatting. Plugins are small —
the framework owns storage, change detection, generation and merging.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Iterable, Sequence

import numpy as np

from .store import FieldSchema, Increment, VersionView


class FileParser(abc.ABC):
    """One parser per file format (§III.F.1). Subclasses are format-specific;
    everything tool-specific lives in the generator/merger."""

    #: format name (registry key)
    format_name: str = ""

    # (i) regular expressions delimiting an entry in the file
    @abc.abstractmethod
    def entry_pattern(self) -> tuple[str, str]:
        """(start_regex, end_regex) for one entry."""

    # (ii) split an entry into columns
    @abc.abstractmethod
    def split_entry(self, entry: str) -> tuple[bytes, dict[str, np.ndarray]]:
        """entry text -> (row key, field -> fixed-width row)."""

    # schema of the columns this parser emits
    @abc.abstractmethod
    def schema(self) -> list[FieldSchema]:
        ...

    # (iii) compare two versions of an entry (fingerprint equality on fields)
    def compare(self, a: dict[str, np.ndarray], b: dict[str, np.ndarray],
                significant: Sequence[str] | None = None) -> bool:
        names = significant if significant is not None else list(a)
        return all(np.array_equal(a[n], b[n]) for n in names)

    # (iv) check an entry contains every element the tool needs
    def validate(self, row: dict[str, np.ndarray],
                 required: Sequence[str]) -> bool:
        return all(n in row and np.asarray(row[n]).size > 0 for n in required)

    # (v) generate a Put object (key + column dict, HBase Put analogue)
    def to_put(self, entry: str) -> tuple[bytes, dict[str, np.ndarray]]:
        return self.split_entry(entry)

    # (vi) generate output in other formats
    @abc.abstractmethod
    def format_entry(self, key: bytes, row: dict[str, np.ndarray]) -> str:
        """row -> file text (inverse of split_entry up to canonicalization)."""

    # -- framework-provided bulk helpers (plugins get these for free) --------
    def parse_text(self, text: str) -> tuple[list[bytes], dict[str, np.ndarray]]:
        keys, rows = [], []
        for entry in self.iter_entries(text):
            k, r = self.split_entry(entry)
            keys.append(k)
            rows.append(r)
        if not rows:
            return [], {f.name: np.zeros((0, f.width), f.np_dtype)
                        for f in self.schema()}
        table = {name: np.stack([r[name] for r in rows])
                 for name in rows[0]}
        return keys, table

    def iter_entries(self, text: str) -> Iterable[str]:
        import re
        start_re, end_re = self.entry_pattern()
        start = re.compile(start_re, re.M)
        starts = [m.start() for m in start.finditer(text)]
        if not starts:
            return []
        starts.append(len(text))
        return [text[starts[i]:starts[i + 1]] for i in range(len(starts) - 1)]

    def format_view(self, view: VersionView | Increment) -> str:
        out = []
        for i, k in enumerate(view.keys):
            row = {n: v[i] for n, v in view.values.items()}
            out.append(self.format_entry(k, row))
        return "".join(out)


@dataclasses.dataclass
class FileGenerator:
    """Tool-specific input/meta-data file generation (§III.F.2): which parser
    per file, which fields the tool reads, which fields are significant for
    change detection (the BLAST lesson: annotation edits don't change
    alignments)."""
    parser: str                      # format registry key
    output_fields: Sequence[str]     # fields written to the generated file
    significant_fields: Sequence[str]  # fields that trigger an increment
    required_fields: Sequence[str] = ()


class OutputMerger(abc.ABC):
    """Tool-specific incremental-output merge (§III.F.3)."""

    @abc.abstractmethod
    def merge(self, previous: str, partial: str, *, context: dict) -> str:
        """Merge a partial (incremental) tool output into the previous full
        output, fixing aggregate fields (e.g. BLAST e-values).

        Args:
          previous: the full output of the last run against the old
            version.
          partial: the tool's output against the increment only.
          context: `GeneratedInput.context` — changed-key sets
            (``deleted_keys`` / ``updated_keys`` / ``new_keys``), db-size
            fields when applicable, and the tool's params.

        Returns:
          Full output text equivalent to rerunning against the new
          version.
        """


@dataclasses.dataclass
class ToolPlugin:
    """One unmodified tool's plugin bundle (§III.F): its file generator,
    optional output merger, and free-form params. ``params`` is recorded
    into cache descriptors, so two configurations of the same tool never
    share generated files."""
    name: str
    generator: FileGenerator
    merger: OutputMerger | None = None
    #: extra free-form parameters recorded into cache descriptors
    params: dict = dataclasses.field(default_factory=dict)


class PluginRegistry:
    """Registry mapping format names -> parsers and tool names -> plugins.
    ``REGISTRY`` is the module-level default; GeStore takes any instance."""

    def __init__(self):
        self.parsers: dict[str, FileParser] = {}
        self.tools: dict[str, ToolPlugin] = {}

    def register_parser(self, parser: FileParser) -> FileParser:
        """Register (and return) a parser under its ``format_name``.
        Raises AssertionError when the parser has no format name."""
        assert parser.format_name, "parser needs format_name"
        self.parsers[parser.format_name] = parser
        return parser

    def register_tool(self, plugin: ToolPlugin) -> ToolPlugin:
        """Register (and return) a tool plugin under its name."""
        self.tools[plugin.name] = plugin
        return plugin

    def parser_for(self, tool: str) -> FileParser:
        """The parser a registered tool generates files with.
        Raises KeyError for an unknown tool or unregistered format."""
        return self.parsers[self.tools[tool].generator.parser]


REGISTRY = PluginRegistry()
