"""GeStore plugin framework (paper §III.F).

A tool plugin = (file parsers, file generator, output merger). The parser
interface mirrors the paper's six methods: entry delimiters, entry->columns
split, version compare, required-element validation, Put-object generation
(here: (key, field-row dict)), and output formatting. Plugins are small —
the framework owns storage, change detection, generation and merging.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Iterable, Sequence

import numpy as np

from .store import FieldSchema, Increment, VersionView


class FileParser(abc.ABC):
    """One parser per file format (§III.F.1). Subclasses are format-specific;
    everything tool-specific lives in the generator/merger."""

    #: format name (registry key)
    format_name: str = ""

    # (i) regular expressions delimiting an entry in the file
    @abc.abstractmethod
    def entry_pattern(self) -> tuple[str, str]:
        """(start_regex, end_regex) for one entry."""

    # (ii) split an entry into columns
    @abc.abstractmethod
    def split_entry(self, entry: str) -> tuple[bytes, dict[str, np.ndarray]]:
        """entry text -> (row key, field -> fixed-width row)."""

    # schema of the columns this parser emits
    @abc.abstractmethod
    def schema(self) -> list[FieldSchema]:
        ...

    # (iii) compare two versions of an entry (fingerprint equality on fields)
    def compare(self, a: dict[str, np.ndarray], b: dict[str, np.ndarray],
                significant: Sequence[str] | None = None) -> bool:
        names = significant if significant is not None else list(a)
        return all(np.array_equal(a[n], b[n]) for n in names)

    # (iv) check an entry contains every element the tool needs
    def validate(self, row: dict[str, np.ndarray],
                 required: Sequence[str]) -> bool:
        return all(n in row and np.asarray(row[n]).size > 0 for n in required)

    # (v) generate a Put object (key + column dict, HBase Put analogue)
    def to_put(self, entry: str) -> tuple[bytes, dict[str, np.ndarray]]:
        return self.split_entry(entry)

    # (vi) generate output in other formats
    @abc.abstractmethod
    def format_entry(self, key: bytes, row: dict[str, np.ndarray]) -> str:
        """row -> file text (inverse of split_entry up to canonicalization)."""

    # -- framework-provided bulk helpers (plugins get these for free) --------
    def parse_text(self, text: str) -> tuple[list[bytes], dict[str, np.ndarray]]:
        # routed through the streaming path so whole-file and chunked
        # parses share one implementation (byte-identity by construction)
        keys, rows = [], []
        for k, r in self.iter_records([text]):
            keys.append(k)
            rows.append(r)
        if not rows:
            return [], self.empty_table()
        return keys, self.stack_rows(rows)

    def iter_entries(self, text: str) -> Iterable[str]:
        return self.iter_entries_chunks([text])

    def iter_entries_chunks(self, chunks: Iterable[str]) -> Iterable[str]:
        """Split a release streamed as arbitrary text chunks into entries.

        Yields the same entry strings ``iter_entries`` produces on the
        concatenated text, without ever materialising the whole release:
        only the current entry and one partial line are buffered. Start
        regexes are line-anchored (``^...``) and must be decidable within
        a line plus its terminating newline — true of every shipped
        parser. Text before the first entry start is dropped and a
        truncated final record is still yielded, both exactly as in the
        whole-file split.
        """
        for entry, _ in self.iter_entries_with_offsets(chunks):
            yield entry

    def iter_entries_with_offsets(
            self, chunks: Iterable[str],
    ) -> Iterable[tuple[str, int]]:
        """``(entry, end_offset)`` pairs from streamed chunks.

        ``end_offset`` is the absolute character offset one past the
        entry's last character — equivalently, the offset the *next*
        entry starts at. A stream re-opened at that offset parses the
        remaining entries identically (the resumable-ingest seek point;
        character == byte for the ASCII release formats).
        """
        import re
        start_re, _ = self.entry_pattern()
        rx = re.compile(start_re, re.M)
        buf = ""          # from the current entry's start (or stream junk)
        base = 0          # absolute offset of buf[0]
        started = False   # buf[0] is a real entry start
        for chunk in chunks:
            if not chunk:
                continue
            buf += chunk
            # only complete lines are decidable: a start pattern must be
            # resolvable within a line + its newline (the parser contract),
            # so matching stops at the last newline and the partial final
            # line carries over to the next chunk
            cut = buf.rfind("\n") + 1
            if not cut:
                continue
            # C-speed scan; pos=1 skips buf[0] when it is the (already
            # known) current entry's start, and ``^`` still anchors to
            # true line boundaries regardless of pos
            starts = [m.start()
                      for m in rx.finditer(buf, 1 if started else 0, cut)]
            if started:
                starts.insert(0, 0)
            if not starts:
                # no entry yet: everything decidable is droppable prefix
                base += cut
                buf = buf[cut:]
                continue
            for i in range(len(starts) - 1):
                yield buf[starts[i]:starts[i + 1]], base + starts[i + 1]
            base += starts[-1]
            buf = buf[starts[-1]:]
            started = True
        if buf:
            # EOF terminates the final (possibly newline-less) line, so
            # the held-back tail becomes decidable: split any entry
            # starts in it exactly as the whole-file finditer would
            starts = [m.start()
                      for m in rx.finditer(buf, 1 if started else 0)]
            if started:
                starts.insert(0, 0)
            for i in range(len(starts) - 1):
                yield buf[starts[i]:starts[i + 1]], base + starts[i + 1]
            if starts:
                yield buf[starts[-1]:], base + len(buf)

    def iter_records(
            self, chunks: Iterable[str],
    ) -> Iterable[tuple[bytes, dict[str, np.ndarray]]]:
        """(key, field->row) records from streamed text chunks. Block
        formats whose ``split_entry`` is undefined override this."""
        for entry in self.iter_entries_chunks(chunks):
            yield self.split_entry(entry)

    def parse_chunks(
            self, chunks: Iterable[str], batch_entries: int = 512,
    ) -> Iterable[tuple[list[bytes], dict[str, np.ndarray]]]:
        """Stream text chunks into ``(keys, table)`` batches of at most
        ``batch_entries`` rows — the bounded-memory ingest feed."""
        keys: list[bytes] = []
        rows: list[dict[str, np.ndarray]] = []
        for k, r in self.iter_records(chunks):
            keys.append(k)
            rows.append(r)
            if len(keys) >= batch_entries:
                yield keys, self.stack_rows(rows)
                keys, rows = [], []
        if keys:
            yield keys, self.stack_rows(rows)

    def stack_rows(
            self, rows: Sequence[dict[str, np.ndarray]],
    ) -> dict[str, np.ndarray]:
        return {name: np.stack([r[name] for r in rows]) for name in rows[0]}

    def empty_table(self) -> dict[str, np.ndarray]:
        return {f.name: np.zeros((0, f.width), f.np_dtype)
                for f in self.schema()}

    def format_view(self, view: VersionView | Increment) -> str:
        out = []
        for i, k in enumerate(view.keys):
            row = {n: v[i] for n, v in view.values.items()}
            out.append(self.format_entry(k, row))
        return "".join(out)


@dataclasses.dataclass
class FileGenerator:
    """Tool-specific input/meta-data file generation (§III.F.2): which parser
    per file, which fields the tool reads, which fields are significant for
    change detection (the BLAST lesson: annotation edits don't change
    alignments)."""
    parser: str                      # format registry key
    output_fields: Sequence[str]     # fields written to the generated file
    significant_fields: Sequence[str]  # fields that trigger an increment
    required_fields: Sequence[str] = ()


class OutputMerger(abc.ABC):
    """Tool-specific incremental-output merge (§III.F.3)."""

    @abc.abstractmethod
    def merge(self, previous: str, partial: str, *, context: dict) -> str:
        """Merge a partial (incremental) tool output into the previous full
        output, fixing aggregate fields (e.g. BLAST e-values).

        Args:
          previous: the full output of the last run against the old
            version.
          partial: the tool's output against the increment only.
          context: `GeneratedInput.context` — changed-key sets
            (``deleted_keys`` / ``updated_keys`` / ``new_keys``), db-size
            fields when applicable, and the tool's params.

        Returns:
          Full output text equivalent to rerunning against the new
          version.
        """


@dataclasses.dataclass
class ToolPlugin:
    """One unmodified tool's plugin bundle (§III.F): its file generator,
    optional output merger, and free-form params. ``params`` is recorded
    into cache descriptors, so two configurations of the same tool never
    share generated files."""
    name: str
    generator: FileGenerator
    merger: OutputMerger | None = None
    #: extra free-form parameters recorded into cache descriptors
    params: dict = dataclasses.field(default_factory=dict)


class PluginRegistry:
    """Registry mapping format names -> parsers and tool names -> plugins.
    ``REGISTRY`` is the module-level default; GeStore takes any instance."""

    def __init__(self):
        self.parsers: dict[str, FileParser] = {}
        self.tools: dict[str, ToolPlugin] = {}

    def register_parser(self, parser: FileParser) -> FileParser:
        """Register (and return) a parser under its ``format_name``.
        Raises AssertionError when the parser has no format name."""
        assert parser.format_name, "parser needs format_name"
        self.parsers[parser.format_name] = parser
        return parser

    def register_tool(self, plugin: ToolPlugin) -> ToolPlugin:
        """Register (and return) a tool plugin under its name."""
        self.tools[plugin.name] = plugin
        return plugin

    def parser_for(self, tool: str) -> FileParser:
        """The parser a registered tool generates files with.
        Raises KeyError for an unknown tool or unregistered format."""
        return self.parsers[self.tools[tool].generator.parser]


REGISTRY = PluginRegistry()
