"""Materialized meta-database cache (paper §III.E).

GeStore caches generated meta-database files in HDFS because many workflows
share them; the *filename* uniquely identifies content: file id, time range,
entry-selection regex, plugin params, and optionally run/task ids. We keep
that property: the descriptor is a canonical string, the on-disk name embeds
a digest of it, and the `files` system table maps descriptor -> path.
Unbounded by default (paper: "GeStore does not limit the cache size; the
oldest files can be deleted by e.g. a cron job") — `evict()` is that cron
job.
"""
from __future__ import annotations

import hashlib
import os
from typing import Callable

from .tables import SystemTables


def descriptor(file_id: str, t0: int, t1: int, *, filter_expr: str = "",
               plugin: str = "", params: dict | None = None,
               run_id: str = "", task_id: str = "") -> str:
    parts = [file_id, str(t0), str(t1), filter_expr, plugin]
    for k in sorted(params or {}):
        parts.append(f"{k}={params[k]}")
    if run_id:
        parts.append(f"run={run_id}")
    if task_id:
        parts.append(f"task={task_id}")
    return "|".join(parts)


class VersionCache:
    def __init__(self, root: str, tables: SystemTables | None = None, *,
                 max_bytes: int | None = None):
        """Args:
          root: cache directory.
          tables: `files` system table (descriptor -> path index).
          max_bytes: optional byte budget — every ``put`` runs the LRU
            ``evict`` down to it, so serving hosts get a bounded cache
            instead of the paper's unbounded-plus-cron-job model. None
            (default) preserves the paper-faithful unbounded behavior.
            A budget smaller than a single generated file still admits
            the file being written (``put`` returns a live path); it is
            evicted by the next put.
        """
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.tables = tables or SystemTables()
        self.max_bytes = max_bytes
        self.hits = 0
        self.misses = 0

    def _path_for(self, desc: str, suffix: str) -> str:
        digest = hashlib.sha256(desc.encode()).hexdigest()[:24]
        safe = "".join(c if c.isalnum() or c in "._-=" else "_" for c in desc)[:80]
        return os.path.join(self.root, f"{safe}.{digest}{suffix}")

    def get(self, desc: str) -> str | None:
        row = self.tables.lookup_file(desc)
        if row is not None and row.path and os.path.exists(row.path):
            self.hits += 1
            return row.path
        self.misses += 1
        return None

    def put(self, desc: str, writer: Callable[[str], None], *, plugin: str = "",
            suffix: str = ".bin", in_store: bool = True) -> str:
        """Generate-or-return: writer(path) materializes the file on miss."""
        path = self.get(desc)
        if path is not None:
            self.misses -= 1  # get() above counted a hit
            return path
        path = self._path_for(desc, suffix)
        tmp = path + ".tmp"
        writer(tmp)
        os.replace(tmp, path)
        self.tables.record_file(desc, path, plugin, in_store,
                                nbytes=os.path.getsize(path))
        if self.max_bytes is not None:
            self.evict(self.max_bytes, protect=desc)
        return path

    def evict(self, max_bytes: int, *, protect: str | None = None) -> int:
        """Drop least-recently-used generated files until total <= max_bytes.

        Store segment manifests (plugin ``store-segment``, recorded by
        ``GeStore.flush``) are never candidates: generated files are
        regenerable from the store, but the segments ARE the store —
        evicting them would destroy data, not cache. ``protect`` exempts
        one descriptor (the file a ``put`` just returned a live path to).
        """
        rows = sorted((r for r in self.tables.files.values()
                       if r.path and r.plugin != "store-segment"),
                      key=lambda r: r.last_used)
        total = sum(r.bytes for r in rows)
        n = 0
        for r in rows:
            if total <= max_bytes:
                break
            if r.file_id == protect:
                continue
            if os.path.exists(r.path):
                os.remove(r.path)
            total -= r.bytes
            self.tables.drop_file(r.file_id)
            n += 1
        return n
