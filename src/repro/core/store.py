"""VersionedStore: the GeStore meta-database data model (paper §III.B-§III.D).

HBase mapping -> JAX-native columnar MVCC:
  * entries  -> rows (dense int index; byte-string keys via a host dict)
  * parsed fields -> fixed-width numeric columns (one ``_FieldColumn`` each;
    schema evolution = add a column, as in HBase)
  * timestamped cells -> an append-only per-field cell log, consolidated
    lazily to CSR (sorted by (row, ts)) for the ``version_select`` kernel
  * EXISTS column -> a dedicated int8 cell log (tombstones on delete)

The four operations of §III.C: ``create`` (constructor), ``update``,
``get_increment``, ``get_version``. Change detection is fingerprint-based
(kernels/fingerprint.py) so an update touches O(changed) cells, which is what
makes storing many 240 GB-class releases cheap. Heavy scans run on device via
the Pallas kernels; key bookkeeping stays on host (the HBase-master
analogue).

Row-space sharding: every device-side op here is data-parallel over rows or
log cells, so a production deployment shards rows over the mesh ``data``
axis; ``shard_spec()`` exposes the NamedSharding used by the distributed
tests and the dry-run.

Persistence is segmented and append-only (core/segments.py): ``save()``
writes only cells newer than the on-disk manifest's watermark, ``load()``
attaches lazy segment handles that are spliced into a log's CSR only when
a query's timestamp bound reaches them, and ``compact(..., path=...)``
rewrites covered segments into a base segment while retaining the tail.
See the segments module docstring for the on-disk format.

Invalidation contract: ``log_epoch`` is a monotone counter bumped by every
log mutation (update/delete/add_field/compact/load). Any externally cached
materialization derived from this store MUST be keyed on
``(store name, log_epoch)`` — equal epoch for the same store object implies
bit-identical query results, so caches need no other invalidation hook.
The serve-layer plan cache and the tiered memory manager
(serve/gestore_service.py) both rely on this; a store reloaded from disk
after spilling gets its epoch floored above the spilled store's epoch so
the contract survives eviction.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import re
from typing import Callable, Mapping, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.obs import kerneltel
from repro.obs.trace import StageTimer

Timestamp = int

# device-side timestamps are int32 (JAX default int width); host keeps int64.
TS_MAX = 2**31 - 2


class OperationCancelled(RuntimeError):
    """A cooperatively cancelled query (see ``get_versions(cancel=...)``).

    The store is left untouched — cancellation points sit between read-only
    stages, never inside a mutation — so a cancelled query can simply be
    retried."""


def _check_cancel(cancel: Callable[[], bool] | None) -> None:
    """Cooperative cancellation point: queries accept an optional
    ``cancel`` callable and poll it between expensive stages (superlog
    build, batched scan, value gather). The serving front door
    (serve/frontdoor.py) uses this to abandon waves whose every request
    was cancelled or deadline-shed before paying for device work."""
    if cancel is not None and cancel():
        raise OperationCancelled("query cancelled between stages")


# the per-stage latency hook the serving layer aggregates into p50/p99
# histograms, migrated onto the shared observability layer: same additive
# trace-dict contract, now also feeding the active trace span and the
# process-wide stage histograms (core/shard.py uses it via this alias).
_StageTimer = StageTimer


def _checked_cast(name: str, vals, dtype: np.dtype) -> np.ndarray:
    """Cast a table value block to its field dtype, refusing same-kind
    narrowing that would silently corrupt: out-of-range ints and float
    magnitudes that overflow to inf / underflow to zero raise ValueError
    (float mantissa rounding is accepted — the engine is 32-bit)."""
    arr = np.asarray(vals)
    with np.errstate(over="ignore"):  # overflow is checked by value below
        out = np.ascontiguousarray(arr, dtype=dtype)
    if arr.dtype == out.dtype:
        return out
    if np.issubdtype(arr.dtype, np.integer) and np.issubdtype(dtype, np.integer):
        if not np.array_equal(out.astype(arr.dtype), arr):
            raise ValueError(
                f"field {name}: values exceed the {dtype} range")
    elif np.issubdtype(arr.dtype, np.floating) and \
            np.issubdtype(dtype, np.floating):
        bad = ((np.isfinite(arr) & ~np.isfinite(out))
               | ((arr != 0) & (out == 0)))
        if bad.any():
            raise ValueError(
                f"field {name}: magnitudes exceed the {dtype} range")
    return out


def _clamp_ts(t: Timestamp) -> int:
    return int(min(max(int(t), -(2**31) + 1), TS_MAX))


def infer_field_schema(name: str, values) -> "FieldSchema":
    """Schema for a field seen for the first time in an update table.

    np.asarray of plain Python numbers defaults to int64/float64 on 64-bit
    platforms; narrow to the engine's 32-bit lanes when lossless rather
    than tripping add_field's wide-dtype rejection. The sharded facade
    (core/shard.py) calls this on the FULL value block before scattering,
    so every shard adopts the same schema the unsharded store would have —
    per-shard slices must never make independent narrowing decisions.
    """
    arr = np.asarray(values)
    if arr.ndim == 1:
        arr = arr[:, None]
    if arr.dtype == np.int64:
        # bounds check, not abs (abs wraps for int64-min)
        if (arr.size == 0 or (arr.min() >= -(2**31)
                              and arr.max() <= 2**31 - 1)):
            arr = arr.astype(np.int32)
    elif arr.dtype == np.float64:
        with np.errstate(over="ignore"):  # overflow checked below
            a32 = arr.astype(np.float32)
        # mantissa rounding is accepted (the engine is 32-bit); magnitude
        # overflow to inf / underflow to zero is not — those fall through
        # to add_field's loud rejection
        bad = ((np.isfinite(arr) & ~np.isfinite(a32))
               | ((arr != 0) & (a32 == 0)))
        if not bad.any():
            arr = a32
    return FieldSchema(name, arr.shape[1], arr.dtype.name)


@dataclasses.dataclass(frozen=True)
class FieldSchema:
    name: str
    width: int
    dtype: str = "int32"  # numpy dtype name

    @property
    def np_dtype(self) -> np.dtype:
        return np.dtype(self.dtype)


@dataclasses.dataclass
class VersionInfo:
    """Row of the `updates` system table (§III.D)."""
    ts: Timestamp
    label: str
    n_entries: int
    n_new: int
    n_updated: int
    n_deleted: int


@dataclasses.dataclass
class VersionView:
    """A materialized meta-database version (get_version output)."""
    ts: Timestamp
    keys: list[bytes]
    row_idx: np.ndarray  # (K,) int32 store row index
    values: dict[str, np.ndarray]  # field -> (K, W)

    def __len__(self) -> int:
        return len(self.keys)


KIND_NEW, KIND_UPDATED, KIND_DELETED = 0, 1, 2


@dataclasses.dataclass
class Increment:
    """get_increment output: entries changed in (t0, t1]."""
    t0: Timestamp
    t1: Timestamp
    keys: list[bytes]
    row_idx: np.ndarray
    kind: np.ndarray  # (K,) int8 KIND_*
    values: dict[str, np.ndarray]  # values at t1 (zeros for deleted rows)

    def __len__(self) -> int:
        return len(self.keys)


class _CellLog:
    """Append-only timestamped cell log for one column, lazy CSR.

    Three cell sources feed the consolidated CSR: fresh appends
    (``_chunks``), a previously consolidated CSR (``_csr``), and — after a
    lazy load — on-disk segment handles (``_pending``, sorted by ts0).
    Pending segments are materialized only when a caller's timestamp bound
    reaches their range, so opening a 32-release store and querying one
    pinned old version reads only the segments at or below that version.
    """

    def __init__(self, width: int, dtype: np.dtype):
        self.width = width
        self.dtype = dtype
        self._chunks: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self._csr: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None  # vals, ts, order-rows
        self._row_ptr: np.ndarray | None = None
        self._n_rows_at_build = -1
        self._pending: list = []  # unread segments.SegmentHandle, by ts0

    @property
    def n_cells(self) -> int:
        return (sum(len(c[1]) for c in self._chunks)
                + (0 if self._csr is None else len(self._csr[1]))
                + sum(h.n_cells for h in self._pending))

    def append(self, rows: np.ndarray, ts: Timestamp, vals: np.ndarray) -> None:
        if len(rows) == 0:
            return
        assert vals.shape == (len(rows), self.width)
        self._chunks.append((rows.astype(np.int32),
                             np.full(len(rows), ts, np.int64),
                             np.ascontiguousarray(vals, dtype=self.dtype)))
        self._row_ptr = None  # CSR dirty

    # -- lazy on-disk segments ------------------------------------------------
    def attach_segments(self, handles) -> None:
        """Register on-disk segment handles (from a lazy load) without
        reading them."""
        if handles:
            self._pending = sorted(self._pending + list(handles),
                                   key=lambda h: h.ts0)

    def _materialize(self, handle) -> None:
        rows, tss, vals = handle.materialize()
        self._chunks.append((rows.astype(np.int32), tss.astype(np.int64),
                             np.ascontiguousarray(vals, dtype=self.dtype)))
        self._row_ptr = None

    def _ensure(self, through_ts) -> None:
        """Splice every pending segment with ts0 <= through_ts into the log
        (cells strictly above the bound cannot affect a query at it)."""
        if not self._pending:
            return
        keep = []
        for h in self._pending:
            if h.ts0 <= through_ts:
                self._materialize(h)
            else:
                keep.append(h)
        self._pending = keep

    def splice_csr(self, vals: np.ndarray, tss: np.ndarray, rows: np.ndarray,
                   ptr: np.ndarray, n_rows: int) -> None:
        """Install a fully consolidated CSR directly (loader fast path)."""
        self._csr = (vals, tss, rows)
        self._chunks = []
        self._row_ptr = np.asarray(ptr)
        self._n_rows_at_build = n_rows

    def cells_after(self, cutoff: Timestamp):
        """All cells with ts > cutoff as (rows, ts, vals) sorted by
        (row, ts) — the incremental-save extraction. Only pending segments
        that could hold such cells (ts1 > cutoff) are read; for a store
        loaded from ``cutoff``'s own manifest that is none of them, so the
        cost is O(cells appended since the last save)."""
        keep = []
        for h in self._pending:
            if h.ts1 > cutoff:
                self._materialize(h)
            else:
                keep.append(h)
        self._pending = keep
        parts = list(self._chunks)
        if self._csr is not None:
            vals0, tss0, rows0 = self._csr
            parts.insert(0, (rows0, tss0, vals0))
        # mask per part BEFORE concatenating: a consolidated history with
        # nothing past the cutoff contributes one comparison pass, not a
        # full copy + lexsort — incremental save stays O(new cells)
        kept = []
        for rows, tss, vals in parts:
            m = tss > cutoff
            if m.any():
                kept.append((rows[m], tss[m], vals[m]))
        if not kept:
            return (np.zeros(0, np.int32), np.zeros(0, np.int64),
                    np.zeros((0, self.width), self.dtype))
        rows = np.concatenate([c[0] for c in kept])
        tss = np.concatenate([c[1] for c in kept])
        vals = np.concatenate([c[2] for c in kept])
        order = np.lexsort((tss, rows))
        return rows[order], tss[order], vals[order]

    def csr(self, n_rows: int, *, through_ts: Timestamp | None = None):
        """Returns (vals (C,W), ts (C,), row_ptr (n_rows+1,)) sorted by (row, ts).

        ``through_ts`` bounds which pending on-disk segments must be
        spliced in first: the returned CSR is complete for any query at
        t <= through_ts (None = materialize everything).
        """
        self._ensure(np.inf if through_ts is None else through_ts)
        if self._row_ptr is not None and self._n_rows_at_build == n_rows:
            return self._csr[0], self._csr[1], self._row_ptr
        parts = list(self._chunks)  # each: (rows, ts, vals)
        if self._csr is not None:
            vals0, tss0, rows0 = self._csr
            parts.insert(0, (rows0, tss0, vals0))
        rows = (np.concatenate([c[0] for c in parts]) if parts
                else np.zeros(0, np.int32))
        tss = (np.concatenate([c[1] for c in parts]) if parts
               else np.zeros(0, np.int64))
        vals = (np.concatenate([c[2] for c in parts]) if parts
                else np.zeros((0, self.width), self.dtype))
        order = np.lexsort((tss, rows))
        rows, tss, vals = rows[order], tss[order], vals[order]
        ptr = np.zeros(n_rows + 1, np.int32)
        np.add.at(ptr, rows + 1, 1)
        ptr = np.cumsum(ptr).astype(np.int32)
        self._csr = (vals, tss, rows)
        self._chunks = []
        self._row_ptr = ptr
        self._n_rows_at_build = n_rows
        return vals, tss, ptr

    def select_at(self, n_rows: int, t: Timestamp):
        """(vals_at_t (n_rows, W), found (n_rows,)) via the Pallas kernel.
        Only materializes on-disk segments at or below ``t``."""
        vals, tss, ptr = self.csr(n_rows, through_ts=t)
        if len(tss) == 0:
            return (np.zeros((n_rows, self.width), self.dtype),
                    np.zeros(n_rows, bool))
        out, found = kops.version_select(
            jnp.asarray(vals), jnp.asarray(tss.astype(np.int32)),
            jnp.asarray(ptr), _clamp_ts(t))
        return np.asarray(out), np.asarray(found)

    def changed_counts(self, n_rows: int, t0: Timestamp, t1: Timestamp) -> np.ndarray:
        """Per-row number of cells with t0 < ts <= t1 (windowed scan, §III.C)."""
        _, tss, ptr = self.csr(n_rows, through_ts=t1)
        if len(tss) == 0:
            return np.zeros(n_rows, np.int32)
        ts_j = jnp.asarray(tss.astype(np.int32))
        c1 = np.asarray(kops.masked_cumsum(ts_j, _clamp_ts(t1)))
        c0 = np.asarray(kops.masked_cumsum(ts_j, _clamp_ts(t0)))
        cum = np.concatenate([[0], c1 - c0])
        return (cum[ptr[1:]] - cum[ptr[:-1]]).astype(np.int32)


@dataclasses.dataclass
class _SuperLogField:
    """One log's slice of the fused superlog.

    When ``packed_host`` is set the field stays *delta-packed on device*:
    cells are stored as narrowed chain deltas (first cell of every row
    chain raw, flagged by ``heads_host``) and the gather path decodes them
    in-kernel via a segmented scan (kernels/delta_codec.chain_decode) —
    device bytes and cold-reload upload traffic shrink by the narrowing
    factor while gathers stay a single fused device op. ``vals_host``
    remains the decoded host copy (placement and host paths read it)."""
    offset: int                 # first cell of this log in the fused ts array
    b_off: int                  # first entry of this log in the fused boundary array
    n_cells: int
    width: int
    dtype: np.dtype
    ptr: np.ndarray             # (N+1,) log-local CSR offsets (host)
    vals_host: np.ndarray | None  # (C_f, W) consolidated cell values
    device: object = None       # upload target (None = default device)
    packed_host: np.ndarray | None = None  # narrowed chain deltas
    heads_host: np.ndarray | None = None   # (C_f,) chain-head flags
    _vals_dev: object = None
    _packed_dev: object = None
    _heads_dev: object = None

    def _put(self, arr):
        return (jnp.asarray(arr) if self.device is None
                else jax.device_put(arr, self.device))

    def vals_dev(self):
        """Device copy of the cell values, uploaded on first gather — a
        narrow-field query must not pay for the store's wide columns.
        With a pinned ``device`` (shard->device placement) the upload
        lands there, so per-shard gathers run one shard per device."""
        if self._vals_dev is None and self.vals_host is not None:
            self._vals_dev = self._put(self.vals_host)
        return self._vals_dev

    def take_cells(self, idx):
        """ONE fused device gather of cell values at field-local cell
        indices. Delta-packed fields decode on device first (segmented
        scan over the narrowed deltas), so the wide decoded array exists
        only transiently inside the launch — HBM holds the packed copy."""
        idx = jnp.asarray(idx)
        if self.packed_host is None:
            return jnp.take(self.vals_dev(), idx, axis=0)
        if self._packed_dev is None:
            self._packed_dev = self._put(self.packed_host)
            self._heads_dev = self._put(self.heads_host)
        decoded = kops.chain_decode(self._packed_dev, self._heads_dev)
        # int32 scan truncated to the stored dtype == the host depth-loop
        return jnp.take(decoded.astype(self.dtype), idx, axis=0)

    def dev_nbytes(self) -> int:
        n = 0
        for a in (self._vals_dev, self._packed_dev, self._heads_dev):
            if a is not None:
                n += int(a.nbytes)
        return n


def _pack_field(vals: np.ndarray, ptr: np.ndarray):
    """Chain-delta pack one field's consolidated cells for device residency.

    Same chain format as the on-disk segments (kernels/delta_codec): first
    cell of every row chain raw, later cells as wraparound deltas vs their
    predecessor, narrowed when the whole run fits a smaller int. Returns
    (packed, heads) when narrowing actually shrinks device bytes, else
    (None, None) — floats, int8, and incompressible runs stay unpacked.
    Disable globally with ``GESTORE_PACKED_SUPERLOG=0``."""
    dt = vals.dtype
    if not np.issubdtype(dt, np.integer) or not 2 <= dt.itemsize <= 4:
        return None, None
    heads = np.zeros(len(vals), bool)
    heads[ptr[:-1][np.diff(ptr) > 0]] = True
    prev = np.roll(vals, 1, axis=0)
    prev[heads] = 0  # chain heads pack against zero (stored raw)
    with np.errstate(over="ignore"):
        delta = vals - prev
    # min/max as Python ints: exact even at the int32 minimum
    maxabs = (max(-int(delta.min()), int(delta.max())) if delta.size else 0)
    narrow = np.dtype(kops.narrow_dtype(maxabs, base=dt))
    if narrow.itemsize >= dt.itemsize:
        return None, None
    # heads ride along as one byte/cell; only pack when that still wins
    if narrow.itemsize * vals.shape[1] + 1 >= dt.itemsize * vals.shape[1]:
        return None, None
    return delta.astype(narrow), heads


class _SuperLog:
    """Consolidated device-resident CSR over every cell log of a store.

    All field logs plus the EXISTS log are fused into ONE device timestamp
    array with per-field cell offsets, so materializing Q versions costs a
    single batched masked-cumsum launch over the fused array
    (kernels/batched_select.py) instead of Q*F per-field launches that each
    re-upload their log from host. Per-field boundary gathers and value
    gathers are O(boundaries) / O(selected) afterthoughts.

    A snapshot is immutable; ``VersionedStore`` rebuilds it lazily whenever
    the log epoch moves (any append/compact/load).
    """

    EXISTS = "__exists__"

    def __init__(self, store: "VersionedStore"):
        self.n_rows = store.n_rows
        self.epoch = store.log_epoch
        self.device = store.device
        logs: dict[str, _CellLog] = {n: c.log for n, c in store.fields.items()}
        logs[self.EXISTS] = store.exists_log
        ts_parts: list[np.ndarray] = []
        bnd_parts: list[np.ndarray] = []
        self.fields: dict[str, _SuperLogField] = {}
        pack_ok = os.environ.get("GESTORE_PACKED_SUPERLOG", "1") != "0"
        off = b_off = 0
        for name, log in logs.items():
            vals, tss, ptr = log.csr(self.n_rows)
            ptr = np.asarray(ptr)
            f = _SuperLogField(
                offset=off, b_off=b_off, n_cells=len(tss), width=log.width,
                dtype=log.dtype, ptr=ptr,
                vals_host=vals if len(tss) else None, device=self.device)
            if pack_ok and f.vals_host is not None and name != self.EXISTS:
                f.packed_host, f.heads_host = _pack_field(vals, ptr)
            self.fields[name] = f
            ts_parts.append(tss.astype(np.int32))
            bnd_parts.append(off + ptr.astype(np.int64))
            off += len(tss)
            b_off += len(ptr)
        self.n_cells = off
        # fused ts stays host-side until the first scan needs it: the
        # sharded facade's device-parallel path scans a cross-shard stacked
        # copy instead (core/placement.py) and must not pay a second upload
        self.ts_host = np.concatenate(ts_parts) if off else None
        self._ts_dev = None
        # every field's CSR boundaries in fused-cell coordinates: the scan
        # result is only ever read at these positions
        self.boundaries = np.concatenate(bnd_parts)

    @property
    def ts(self):
        """Device copy of the fused ts array, uploaded on first use (to
        the pinned ``device`` when shard placement set one) — padded to a
        power-of-two cell bucket with int32 max (above every clamped
        query, so padded cells never count). Bucketing happens HERE,
        outside any jit boundary: successive ingests that grow the cell
        count land in the same bucket and reuse the compiled scan instead
        of retracing per epoch roll (the table9 serving-latency stall)."""
        if self._ts_dev is None and self.ts_host is not None:
            c = len(self.ts_host)
            c_pad = kops.scan_bucket(c)
            padded = self.ts_host
            if c_pad != c:
                padded = np.concatenate([
                    padded,
                    np.full(c_pad - c, np.iinfo(np.int32).max, np.int32)])
            self._ts_dev = (jnp.asarray(padded)
                            if self.device is None
                            else jax.device_put(padded, self.device))
        return self._ts_dev

    # -- the one batched scan -------------------------------------------------
    def boundary_cums(self, ts_list: Sequence[Timestamp]) -> np.ndarray:
        """(Q, n_boundaries) cumsum of (ts <= t_q) AT every field's CSR
        boundaries: ONE batched kernel launch for all queries and all
        fields, with only the boundary columns crossing device->host
        (O(Q x F x N), not O(Q x total_cells))."""
        qs = np.asarray([_clamp_ts(t) for t in ts_list], np.int32)
        out = np.zeros((len(qs), len(self.boundaries)), np.int32)
        if self.n_cells and len(qs):
            q, c, b = len(qs), self.n_cells, len(self.boundaries)
            # bucket the query and boundary axes like the cell axis (pow2,
            # outside jit): continuous ingest + varying wave widths then
            # revisit a handful of static shapes, so the scan AND the eager
            # boundary take/where below stop recompiling per epoch roll
            q_pad = kops.launch.pow2_bucket(q, floor=8)
            b_pad = kops.launch.pow2_bucket(b, floor=8)
            qs_in = qs if q_pad == q else np.concatenate(
                [qs, np.full(q_pad - q, qs[-1], np.int32)])
            bnd = self.boundaries
            if b_pad != b:  # zero-pad: boundary 0 reads count 0 below
                bnd = np.concatenate([bnd, np.zeros(b_pad - b, np.int64)])
            c_pad = kops.scan_bucket(c)
            # traffic model: read the fused ts once (C*4), write the
            # (Q, C) running cumsum, read+write the (Q, B) boundary
            # columns; arithmetic: one compare + one add per (q, cell).
            # logical uses the real shapes, padded the bucketed ones
            with kerneltel.launch(
                    "batched_select",
                    nbytes=4 * (c + q * c + 2 * q * b),
                    flops=2 * q * c,
                    padded_nbytes=4 * (c_pad + q_pad * c_pad
                                       + 2 * q_pad * b_pad)):
                cum = kops.batched_masked_cumsum(self.ts, jnp.asarray(qs_in))
                at = jnp.take(cum,
                              jnp.asarray(np.maximum(bnd - 1, 0)),
                              axis=1)
                at = jnp.where(jnp.asarray(bnd == 0)[None, :],
                               0, at)
                out = np.asarray(at)[:q, :b]
        return out

    # -- per-field boundary math ----------------------------------------------
    def counts(self, name: str, bcum: np.ndarray) -> np.ndarray:
        """(Q, N) per-row count of cells with ts <= t_q for one field."""
        f = self.fields[name]
        b = bcum[:, f.b_off: f.b_off + len(f.ptr)]
        return b[:, 1:] - b[:, :-1]

    def exists_matrix(self, bcum: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(alive (Q, N), ever (Q, N)) from the EXISTS log."""
        f = self.fields[self.EXISTS]
        cnt = self.counts(self.EXISTS, bcum)
        ever = cnt > 0
        if f.vals_host is None:
            return np.zeros_like(ever), ever
        idx = np.clip(f.ptr[None, :-1] + cnt - 1, 0, f.n_cells - 1)
        v = np.asarray(jnp.take(f.vals_dev()[:, 0], jnp.asarray(idx), axis=0))
        return (v > 0) & ever, ever

    def gather_dispatch(self, name: str, cnts: "Sequence[np.ndarray]",
                        sels: Sequence[np.ndarray]) -> tuple:
        """Launch the fused per-field gather WITHOUT forcing a host sync:
        returns an opaque handle for ``gather_finalize``. The sharded
        facade dispatches every shard's gathers (each on its own device
        under placement) before collecting any, so they overlap."""
        f = self.fields[name]
        lens = [len(s) for s in sels]
        if f.vals_host is None or sum(lens) == 0:
            return (None, lens, None)
        cat_cnt = np.concatenate([c[s] for c, s in zip(cnts, sels)])
        cat_rows = np.concatenate(sels)
        idx = np.clip(f.ptr[cat_rows] + cat_cnt - 1, 0, f.n_cells - 1)
        dev = f.take_cells(idx)  # decodes delta-packed fields on device
        return (dev, lens, cat_cnt)

    def gather_finalize(self, name: str, handle: tuple) -> list[np.ndarray]:
        """Collect a ``gather_dispatch`` result to host, split per query.
        Rows with no cell at the query time come back zeroed (same
        semantics as _CellLog.select_at)."""
        dev, lens, cat_cnt = handle
        f = self.fields[name]
        if dev is None:
            return [np.zeros((l, f.width), f.dtype) for l in lens]
        out = np.array(dev)
        out[cat_cnt <= 0] = 0
        offs = np.cumsum([0] + lens)
        return [out[offs[i]: offs[i + 1]] for i in range(len(lens))]

    def gather_many(self, name: str, cnts: "Sequence[np.ndarray]",
                    sels: Sequence[np.ndarray]) -> list[np.ndarray]:
        """Per-query row selections fused into ONE device gather per field:
        cnts[q] the (N,) per-row counts and sels[q] the selected rows of
        query q (dispatch + finalize in one step)."""
        return self.gather_finalize(name, self.gather_dispatch(name, cnts,
                                                               sels))


class _FieldColumn:
    """Head state + cell log for one field.

    ``head_stale`` marks heads not yet rebuilt after a lazy load; the store
    rebuilds them (one select_at(TS_MAX)) before the first mutation that
    needs change detection, so opening a store stays O(manifest)."""

    def __init__(self, schema: FieldSchema, capacity: int):
        self.schema = schema
        self.log = _CellLog(schema.width, schema.np_dtype)
        self.head_vals = np.zeros((capacity, schema.width), schema.np_dtype)
        self.head_fp = np.zeros((capacity, 2), np.int32)
        self.head_has = np.zeros(capacity, bool)
        self.head_stale = False

    def grow(self, capacity: int) -> None:
        def g(a):
            out = np.zeros((capacity,) + a.shape[1:], a.dtype)
            out[: len(a)] = a
            return out
        self.head_vals = g(self.head_vals)
        self.head_fp = g(self.head_fp)
        self.head_has = g(self.head_has)


class ReleaseSession:
    """Chunked single-release mutation (the streaming-ingest write path).

    ``store.begin_release(ts)`` -> repeated ``apply(keys, table)`` (one
    bounded-memory chunk each) -> ``finish()``. The committed result is
    equivalent to one whole-file ``update(ts, all_keys, all_table)`` over
    the concatenated chunks — identical cells, heads, counts, VersionInfo
    AND content digest — provided keys are unique within the release
    (true of real database releases; a duplicate key repeating identical
    values would be fingerprint-skipped here but double-appended by the
    whole-file path).

    Each ``apply`` validates everything before mutating anything, exactly
    like ``update`` — but the release only commits at ``finish()``: the
    tombstone scan (full releases), the VersionInfo record and the
    digest-chain link all happen there. A session abandoned mid-way
    leaves cells at ``ts`` in the logs with NO version record — in-memory
    state that must be discarded (the ingest journal's resume protocol
    reloads the pre-release store from disk and replays chunks).

    ``present_keys`` patch semantics are not supported — use ``update``.
    """

    def __init__(self, store: "VersionedStore", ts: Timestamp, *,
                 label: str = "", full_release: bool = True):
        if ts <= store.last_ts:
            raise ValueError(
                f"timestamps must be monotonic: {ts} <= {store.last_ts}")
        store._ensure_exists_head()
        self.store = store
        self.ts = int(ts)
        self.label = label
        self.full_release = full_release
        self.n_entries = 0
        self._n_new = 0
        self._n_upd = 0
        self._rows_parts: list[np.ndarray] = []    # rows touched, per chunk
        # digest-chain payload accumulators, assembled at finish() into the
        # exact byte layout update() hashes: per-field blocks in first-seen
        # table order, then appearing rows, then tombstoned rows
        self._field_order: list[str] = []
        self._field_rows: dict[str, list[bytes]] = {}
        self._field_fps: dict[str, list[bytes]] = {}
        self._appear_parts: list[bytes] = []
        self._finished = False

    def apply(self, keys: Sequence[bytes],
              table: Mapping[str, np.ndarray], *,
              _precast: bool = False, _fps=None) -> int:
        """Ingest one chunk of the release; returns the chunk entry count.

        Validation order mirrors ``update``: key encode, schema inference
        for unseen fields, value-checked casts and shape asserts all run
        before the first cell append, so a rejected chunk leaves no
        phantom columns, rows or cells. NOTE: schema inference for a new
        field sees only this chunk's value block — pre-declare fields via
        ``add_field`` (the ingest engine passes the parser schema) when a
        later chunk might need a wider dtype.

        ``_precast``/``_fps`` are the sharded facade's wave fast path:
        the facade already value-cast the full chunk and fingerprinted it
        with ONE kernel launch per field, so the per-shard sub-applies
        skip the cast and slice the shared fingerprints instead of
        launching ``n_shards`` small fingerprint kernels per field."""
        if self._finished:
            raise RuntimeError("release session already finished")
        st = self.store
        keys = [k.encode() if isinstance(k, str) else bytes(k) for k in keys]
        new_fields: dict[str, FieldSchema] = {}
        if not _precast:
            for name in table:
                if name not in st.fields:
                    fs = infer_field_schema(name, table[name])
                    st._validate_new_field(fs)
                    new_fields[name] = fs
        casted: dict[str, np.ndarray] = {}
        for name, vals in table.items():
            if _precast:
                casted[name] = vals
            else:
                fs = new_fields.get(name) or st.fields[name].schema
                vals = _checked_cast(name, vals, fs.np_dtype)
                if vals.ndim == 1:
                    vals = vals[:, None]
                assert vals.shape == (len(keys), fs.width), (
                    f"{name}: {vals.shape} != {(len(keys), fs.width)}")
                casted[name] = vals
            if name not in self._field_rows:
                self._field_order.append(name)
                self._field_rows[name] = []
                self._field_fps[name] = []
        for fs in new_fields.values():
            st.add_field(fs)
        was_known = np.fromiter((k in st.key_to_row for k in keys), bool,
                                count=len(keys))
        rows = st._rows_for_keys(keys, create=True)
        existed = np.zeros(len(keys), bool)
        existed[was_known] = st._exists_head[rows[was_known]]
        is_new = ~existed
        chunk_updated = np.zeros(st.n_rows, bool)
        for name, vals in casted.items():
            col = st.fields[name]
            st._ensure_head(name)
            fp = (_fps[name] if _fps is not None
                  else kops.fingerprint_rows(vals))
            same = (fp == col.head_fp[rows]).all(axis=1) & col.head_has[rows]
            changed = ~same
            if changed.any():
                cr = rows[changed]
                col.log.append(cr, self.ts, vals[changed])
                col.head_vals[cr] = vals[changed]
                col.head_fp[cr] = fp[changed]
                col.head_has[cr] = True
                chunk_updated[cr] |= True
                self._field_rows[name].append(cr.tobytes())
                self._field_fps[name].append(
                    np.ascontiguousarray(fp[changed]).tobytes())
        appearing = rows[is_new]
        if len(appearing):
            st.exists_log.append(appearing, self.ts,
                                 np.ones((len(appearing), 1), np.int8))
            st._exists_head[appearing] = True
            self._appear_parts.append(appearing.tobytes())
        self.n_entries += len(keys)
        self._n_new += int(is_new.sum())
        self._n_upd += int((chunk_updated[rows] & existed).sum())
        self._rows_parts.append(rows)
        st._invalidate_log()  # mid-session queries must not reuse caches
        return len(keys)

    def finish(self) -> VersionInfo:
        """Commit the release: tombstone scan (full releases), version
        record, digest-chain link. Idempotence is the caller's job —
        calling twice raises."""
        if self._finished:
            raise RuntimeError("release session already finished")
        self._finished = True
        st = self.store
        hparts = [str(self.ts).encode(), str(self.n_entries).encode()]
        for name in self._field_order:
            if self._field_rows[name]:
                hparts += [name.encode(), b"".join(self._field_rows[name]),
                           b"".join(self._field_fps[name])]
        if self._appear_parts:
            hparts.append(b"".join(self._appear_parts))
        n_deleted = 0
        if self.full_release:
            mask = np.zeros(st.n_rows, bool)
            for rows in self._rows_parts:
                mask[rows] = True
            gone = np.nonzero(st._exists_head[: st.n_rows] & ~mask)[0]
            if len(gone):
                st.exists_log.append(gone.astype(np.int32), self.ts,
                                     np.zeros((len(gone), 1), np.int8))
                st._exists_head[gone] = False
                n_deleted = len(gone)
                hparts.append(gone.tobytes())
        info = VersionInfo(ts=self.ts, label=self.label or str(self.ts),
                           n_entries=self.n_entries, n_new=self._n_new,
                           n_updated=self._n_upd, n_deleted=n_deleted)
        st.versions.append(info)
        st._chain_digest(b"".join(hparts))
        st._invalidate_log()
        return info


class VersionedStore:
    """One meta-database (one HBase table in the paper).

    Public surface: ``update``/``delete`` ingest releases, ``get_version``/
    ``get_versions`` and ``get_increment``/``get_increments`` materialize,
    ``compact`` collapses old history, ``save``/``load`` persist through the
    segmented on-disk layout (core/segments.py), and ``log_epoch`` is the
    cache-invalidation contract (see module docstring).
    """

    def __init__(self, name: str, schema: Sequence[FieldSchema], capacity: int = 1024):
        self.name = name
        self.schema: dict[str, FieldSchema] = {}
        self.fields: dict[str, _FieldColumn] = {}
        self.capacity = max(capacity, 16)
        self.n_rows = 0
        self.key_to_row: dict[bytes, int] = {}
        self.row_keys: list[bytes] = []
        self.exists_log = _CellLog(1, np.dtype(np.int8))
        self._exists_head = np.zeros(self.capacity, bool)
        self._exists_head_stale = False
        self.versions: list[VersionInfo] = []
        # chained per-release content digests (aligned with `versions`):
        # the incremental-save compatibility check compares these as a
        # prefix, so a same-shaped but different-content history can never
        # be mistaken for "the same store, further along"
        self._version_digests: list[str] = []
        self._history_digest = ""
        self._log_epoch = 0
        self._superlog: _SuperLog | None = None
        # shard->device placement pin (core/placement.py): when set, the
        # fused superlog's device buffers upload to THIS device so
        # per-shard scans and gathers spread across the mesh. None (the
        # default, and every unsharded store) = jax default device.
        # Purely a placement hint — query bytes are identical either way.
        self.device = None
        for fs in schema:
            self.add_field(fs)

    def _chain_digest(self, payload: bytes) -> None:
        d = hashlib.sha256((self._history_digest + "|").encode()
                           + payload).hexdigest()[:16]
        self._history_digest = d
        self._version_digests.append(d)

    def _rechain_digests(self, seed: str) -> None:
        """Rebuild the digest chain deterministically from the current
        versions list (compaction replaces the history prefix; the seed
        carries the pre-compaction content digest forward)."""
        d = seed
        out = []
        for v in self.versions:
            d = hashlib.sha256(
                f"{d}|{dataclasses.asdict(v)}".encode()).hexdigest()[:16]
            out.append(d)
        self._version_digests = out
        self._history_digest = out[-1] if out else seed

    # -- fused superlog lifecycle -------------------------------------------
    @property
    def log_epoch(self) -> int:
        """Monotone counter bumped on every log mutation; (store, log_epoch)
        keys any externally cached materialization plan."""
        return self._log_epoch

    def _invalidate_log(self) -> None:
        self._log_epoch += 1
        self._superlog = None

    def superlog(self) -> _SuperLog:
        """Device-resident consolidated CSR, rebuilt lazily on append."""
        if not self._superlog_fresh():
            self._superlog = _SuperLog(self)
        return self._superlog

    def _superlog_fresh(self) -> bool:
        sl = self._superlog
        return (sl is not None and sl.epoch == self._log_epoch
                and sl.n_rows == self.n_rows)

    def drop_superlog(self) -> None:
        """Release the device-resident fused superlog (device -> host
        demotion, used by the tiered memory manager). Query results are
        unaffected: the next batched query rebuilds it from the host CSR."""
        self._superlog = None

    def has_device_state(self) -> bool:
        """Whether a fused superlog (the device tier) is currently held —
        the tiered memory manager's device->host demotion predicate,
        shared with ShardedStore."""
        return self._superlog is not None

    def nbytes(self) -> dict:
        """Resident-memory accounting: ``{"host": int, "device": int}``.

        host = consolidated CSRs + unconsolidated chunks + head arrays
        (cells still pending on disk count zero — that is the point of the
        lazy load); device = the fused superlog's uploaded buffers."""
        host = self._exists_head.nbytes
        for col in self.fields.values():
            host += col.head_vals.nbytes + col.head_fp.nbytes + col.head_has.nbytes
        for log in [c.log for c in self.fields.values()] + [self.exists_log]:
            if log._csr is not None:
                vals, tss, rows = log._csr
                host += vals.nbytes + tss.nbytes + rows.nbytes
            if log._row_ptr is not None:
                host += log._row_ptr.nbytes
            for rows, tss, vals in log._chunks:
                host += vals.nbytes + tss.nbytes + rows.nbytes
        device = 0
        sl = self._superlog
        if sl is not None:
            if sl._ts_dev is not None:  # lazy: reading .ts would upload
                device += sl._ts_dev.nbytes
            for f in sl.fields.values():
                device += f.dev_nbytes()
        return {"host": host, "device": device}

    # -- head (latest-value) state, rebuilt lazily after load ----------------
    def mark_heads_stale(self) -> None:
        """Defer head rebuilds (loader hook): heads are reconstructed from
        the logs on the first mutation that needs change detection."""
        for col in self.fields.values():
            col.head_stale = True
        self._exists_head_stale = True

    def rebuild_heads(self, fields: Sequence[str] | None = None) -> None:
        """Force stale heads fresh now.

        Queries never need this (they read the logs), but code that reads
        ``head_vals``/``head_fp``/``head_has`` directly MUST call it after
        a lazy ``load()`` — heads are only rebuilt automatically on the
        first mutation. ``fields=None`` rebuilds everything including the
        EXISTS head; a field list rebuilds just those columns."""
        for name in (fields if fields is not None else list(self.fields)):
            self._ensure_head(name)
        if fields is None:
            self._ensure_exists_head()

    def _ensure_head(self, name: str) -> None:
        col = self.fields[name]
        if not col.head_stale:
            return
        hv, found = col.log.select_at(self.n_rows, TS_MAX)
        col.head_vals[: self.n_rows] = hv
        col.head_has[: self.n_rows] = found
        if found.any():
            col.head_fp[np.nonzero(found)[0]] = kops.fingerprint_rows(hv[found])
        col.head_stale = False

    def _ensure_exists_head(self) -> None:
        if not self._exists_head_stale:
            return
        self._exists_head[: self.n_rows] = self.exists_at(TS_MAX)
        self._exists_head_stale = False

    # -- schema evolution (HBase column flexibility, §III.B) ----------------
    def _validate_new_field(self, fs: FieldSchema) -> None:
        """All add_field preconditions, with no mutation — callers that
        register several fields (or validate a whole release up front)
        check everything before changing anything."""
        if fs.name in self.fields:
            raise ValueError(f"field {fs.name} exists")
        if fs.name == "__exists__":
            # reserved: segments.EXISTS_FIELD stores the tombstone log
            # under this sentinel; a user field with the same name would
            # collide with it on disk and misattribute segments at load
            raise ValueError("field name __exists__ is reserved")
        if fs.np_dtype.itemsize > 4:
            # the jax query kernels run 32-bit (x64 disabled): int64/float64
            # cells would be silently downcast during materialization.
            # Refuse loudly; wide values belong in multiple 32-bit lanes.
            raise ValueError(
                f"field {fs.name}: dtype {fs.dtype} is wider than 32 bits, "
                "which the query engine cannot materialize losslessly")

    def add_field(self, fs: FieldSchema) -> None:
        """Add a column (schema evolution). Existing rows read as zeros /
        not-found until a release writes them. Raises ValueError when the
        field already exists."""
        self._validate_new_field(fs)
        self.schema[fs.name] = fs
        self.fields[fs.name] = _FieldColumn(fs, self.capacity)
        self._invalidate_log()

    # -- row allocation ------------------------------------------------------
    def _rows_for_keys(self, keys: Sequence[bytes], create: bool) -> np.ndarray:
        out = np.empty(len(keys), np.int32)
        for i, k in enumerate(keys):
            row = self.key_to_row.get(k, -1)
            if row < 0:
                if not create:
                    raise KeyError(k)
                row = self.n_rows
                self.n_rows += 1
                self.key_to_row[k] = row
                self.row_keys.append(k)
                if self.n_rows > self.capacity:
                    self.capacity *= 2
                    for col in self.fields.values():
                        col.grow(self.capacity)
                    e = np.zeros(self.capacity, bool)
                    e[: len(self._exists_head)] = self._exists_head
                    self._exists_head = e
            out[i] = row
        return out

    @property
    def last_ts(self) -> Timestamp:
        return self.versions[-1].ts if self.versions else -1

    # -- update (§III.C "update") -------------------------------------------
    def update(self, ts: Timestamp, keys: Sequence[bytes],
               table: Mapping[str, np.ndarray], *, label: str = "",
               full_release: bool = True,
               present_keys: Sequence[bytes] | None = None) -> VersionInfo:
        """Ingest a release. ``table``: field -> (M, W) rows aligned with keys.

        full_release=True: keys absent from this release are tombstoned
        (the paper compares consecutive full UniProtKB releases).
        full_release=False: patch semantics, absent keys untouched — unless
        ``present_keys`` lists the full release key set (then rows outside
        it are tombstoned even though only changed rows carry data).

        Args:
          ts: release timestamp, strictly greater than ``last_ts`` (the
            append-only logs and the incremental-save watermark both rely
            on monotonicity).
          keys: entry keys (str or bytes), aligned with ``table`` rows.
          table: field name -> (len(keys), width) values; unknown fields
            trigger schema evolution (a new column is added on the fly).
          label: human-readable release label for the `updates` table.

        Returns:
          VersionInfo with new/updated/deleted counts.

        Raises:
          ValueError: non-monotonic ``ts``.
          AssertionError: a table value block has the wrong shape.
        """
        if ts <= self.last_ts:
            raise ValueError(f"timestamps must be monotonic: {ts} <= {self.last_ts}")
        self._ensure_exists_head()
        # validate EVERYTHING before any mutation — schema registration,
        # row allocation, cell appends: a release rejected on its third
        # field (or an unconvertible key) must leave no phantom columns,
        # rows, or cells behind
        keys = [k.encode() if isinstance(k, str) else bytes(k) for k in keys]
        new_fields: dict[str, FieldSchema] = {}
        for name in table:
            if name not in self.fields:
                # schema evolution on the fly (see infer_field_schema)
                fs = infer_field_schema(name, table[name])
                self._validate_new_field(fs)
                new_fields[name] = fs
        casted: dict[str, np.ndarray] = {}
        for name, vals in table.items():
            fs = new_fields.get(name) or self.fields[name].schema
            vals = _checked_cast(name, vals, fs.np_dtype)
            if vals.ndim == 1:
                vals = vals[:, None]
            assert vals.shape == (len(keys), fs.width), (
                f"{name}: {vals.shape} != {(len(keys), fs.width)}")
            casted[name] = vals
        for fs in new_fields.values():
            self.add_field(fs)
        was_known = np.fromiter((k in self.key_to_row for k in keys), bool,
                                count=len(keys))
        rows = self._rows_for_keys(keys, create=True)
        existed = np.zeros(len(keys), bool)
        existed[was_known] = self._exists_head[rows[was_known]]
        is_new = ~existed

        n_updated_rows = np.zeros(self.n_rows, bool)
        hparts = [str(ts).encode(), str(len(keys)).encode()]
        for name, vals in casted.items():
            col = self.fields[name]
            self._ensure_head(name)
            fp = kops.fingerprint_rows(vals)
            same = (fp == col.head_fp[rows]).all(axis=1) & col.head_has[rows]
            changed = ~same
            if changed.any():
                cr = rows[changed]
                col.log.append(cr, ts, vals[changed])
                col.head_vals[cr] = vals[changed]
                col.head_fp[cr] = fp[changed]
                col.head_has[cr] = True
                n_updated_rows[cr] |= True
                hparts += [name.encode(), cr.tobytes(),
                           np.ascontiguousarray(fp[changed]).tobytes()]

        # EXISTS transitions
        appearing = rows[is_new]
        if len(appearing):
            self.exists_log.append(appearing, ts, np.ones((len(appearing), 1), np.int8))
            self._exists_head[appearing] = True
            hparts.append(appearing.tobytes())
        n_deleted = 0
        if full_release or present_keys is not None:
            mask = np.zeros(self.n_rows, bool)
            mask[rows] = True
            if present_keys is not None:
                for k in present_keys:
                    k = k.encode() if isinstance(k, str) else bytes(k)
                    r = self.key_to_row.get(k, -1)
                    if r >= 0:
                        mask[r] = True
            gone = np.nonzero(self._exists_head[: self.n_rows] & ~mask)[0]
            if len(gone):
                self.exists_log.append(gone.astype(np.int32), ts,
                                       np.zeros((len(gone), 1), np.int8))
                self._exists_head[gone] = False
                n_deleted = len(gone)
                hparts.append(gone.tobytes())

        n_new = int(is_new.sum())
        n_upd = int((n_updated_rows[rows] & existed).sum())
        info = VersionInfo(ts=ts, label=label or str(ts), n_entries=len(keys),
                           n_new=n_new, n_updated=n_upd, n_deleted=n_deleted)
        self.versions.append(info)
        self._chain_digest(b"".join(hparts))
        self._invalidate_log()
        return info

    def begin_release(self, ts: Timestamp, *, label: str = "",
                      full_release: bool = True) -> ReleaseSession:
        """Open a chunked mutation session for ONE release at ``ts`` —
        the streaming twin of ``update`` (see ``ReleaseSession``)."""
        return ReleaseSession(self, ts, label=label,
                              full_release=full_release)

    def delete(self, ts: Timestamp, keys: Sequence[bytes], *, label: str = "") -> VersionInfo:
        """Tombstone ``keys`` at ``ts`` (history below ``ts`` is preserved).

        Args:
          ts: deletion timestamp, strictly greater than ``last_ts``.
          keys: existing entry keys (str or bytes).
          label: release label; defaults to ``delete@<ts>``.

        Returns:
          VersionInfo whose ``n_deleted`` is ``len(keys)``.

        Raises:
          ValueError: non-monotonic ``ts``.
          KeyError: a key was never ingested.
        """
        if ts <= self.last_ts:
            raise ValueError(f"timestamps must be monotonic: {ts} <= {self.last_ts}")
        self._ensure_exists_head()
        keys = [k.encode() if isinstance(k, str) else bytes(k) for k in keys]
        rows = self._rows_for_keys(keys, create=False)
        self.exists_log.append(rows, ts, np.zeros((len(rows), 1), np.int8))
        self._exists_head[rows] = False
        info = VersionInfo(ts, label or f"delete@{ts}", len(keys), 0, 0, len(keys))
        self.versions.append(info)
        self._chain_digest(b"delete|" + str(ts).encode() + rows.tobytes())
        self._invalidate_log()
        return info

    # -- exists at a point in time -------------------------------------------
    def exists_at(self, t: Timestamp) -> np.ndarray:
        """(n_rows,) bool — which rows are alive (not tombstoned) at ``t``."""
        vals, found = self.exists_log.select_at(self.n_rows, t)
        return (vals[:, 0] > 0) & found

    def _filter_sel(self, sel: np.ndarray,
                    key_filter: str | Callable[[bytes], bool] | None) -> np.ndarray:
        if key_filter is None or len(sel) == 0:
            return sel
        if isinstance(key_filter, (str, bytes)):
            pat = re.compile(key_filter.encode()
                             if isinstance(key_filter, str) else key_filter)
            fmask = np.fromiter((pat.search(self.row_keys[r]) is not None
                                 for r in sel), bool, count=len(sel))
        else:
            fmask = np.fromiter((key_filter(self.row_keys[r]) for r in sel),
                                bool, count=len(sel))
        return sel[fmask]

    # -- get_version / get_versions (§III.C) ----------------------------------
    def get_versions(self, ts_list: Sequence[Timestamp], *,
                     fields: Sequence[str] | None = None,
                     key_filter: str | Callable[[bytes], bool] | None = None,
                     include_deleted: bool = False,
                     cancel: Callable[[], bool] | None = None,
                     trace: dict | None = None) -> list[VersionView]:
        """Materialize MANY versions in one batched scan of the fused
        superlog (not len(ts_list) x n_fields kernel launches). Duplicate
        timestamps are materialized once and share the returned VersionView
        object (concurrent users pin few distinct versions).

        A single distinct timestamp against a cold superlog takes the
        per-field select_at path instead: building the whole-store fused
        log for one version of a few fields would upload every field's
        cells (the update-then-read checkpoint/search workloads) — and,
        after a lazy load, would read every on-disk segment rather than
        just the requested fields' ranges.

        Args:
          ts_list: timestamps to materialize (duplicates share one view).
          fields: field subset (default: all).
          key_filter: regex (bytes-matched) or predicate over row keys.
          include_deleted: include tombstoned-but-once-alive rows.
          cancel: optional zero-arg callable polled between stages; when
            it returns True the query raises ``OperationCancelled`` (the
            store is untouched — queries never mutate).
          trace: optional dict accumulating per-stage wall seconds under
            ``"scan"`` (superlog build + batched masked-cumsum + exists
            resolution), ``"gather"`` (fused value gathers) and
            ``"materialize"`` (view assembly). Additive across calls.

        Returns:
          list[VersionView] aligned with ``ts_list``.

        Raises:
          KeyError: an unknown field name.
          OperationCancelled: ``cancel`` fired at a cancellation point.
        """
        fields = list(fields) if fields is not None else list(self.fields)
        ts_list = [int(t) for t in ts_list]
        if not ts_list:
            return []
        _check_cancel(cancel)
        uniq = list(dict.fromkeys(ts_list))
        if len(uniq) == 1 and not self._superlog_fresh():
            v = self._get_version_cold(uniq[0], fields, key_filter,
                                       include_deleted, trace=trace)
            return [v] * len(ts_list)
        with _StageTimer(trace, "scan"):
            sl = self.superlog()
            bcum = sl.boundary_cums(uniq)
            alive, ever = sl.exists_matrix(bcum)
        if include_deleted:
            alive = ever
        _check_cancel(cancel)
        with _StageTimer(trace, "gather"):
            field_cnt = {name: sl.counts(name, bcum) for name in fields}
            sels = [self._filter_sel(np.nonzero(alive[qi])[0], key_filter)
                    for qi in range(len(uniq))]
            vals = {name: sl.gather_many(name, field_cnt[name], sels)
                    for name in fields}
        _check_cancel(cancel)
        with _StageTimer(trace, "materialize"):
            by_t = {}
            for qi, (t, sel) in enumerate(zip(uniq, sels)):
                by_t[t] = VersionView(
                    ts=t, keys=[self.row_keys[r] for r in sel],
                    row_idx=sel.astype(np.int32),
                    values={name: vals[name][qi] for name in fields})
            return [by_t[t] for t in ts_list]

    def get_version(self, t: Timestamp, *, fields: Sequence[str] | None = None,
                    key_filter: str | Callable[[bytes], bool] | None = None,
                    include_deleted: bool = False) -> VersionView:
        return self.get_versions([t], fields=fields, key_filter=key_filter,
                                 include_deleted=include_deleted)[0]

    def _get_version_cold(self, t: Timestamp, fields: list[str],
                          key_filter, include_deleted: bool,
                          trace: dict | None = None) -> VersionView:
        """Single-version materialization over the requested fields' own
        CSR logs (no fused-superlog build)."""
        # "ever existed" = any EXISTS cell with ts <= t; the found flag
        # matches _SuperLog.exists_matrix exactly (a windowed
        # changed_counts(-1, t) would drop cells at negative ts)
        with _StageTimer(trace, "scan"):
            vals, found = self.exists_log.select_at(self.n_rows, t)
            alive = found if include_deleted else (vals[:, 0] > 0) & found
            sel = self._filter_sel(np.nonzero(alive)[0], key_filter)
        with _StageTimer(trace, "gather"):
            values = {}
            for name in fields:
                vals, _found = self.fields[name].log.select_at(self.n_rows, t)
                values[name] = vals[sel]
        with _StageTimer(trace, "materialize"):
            return VersionView(ts=t, keys=[self.row_keys[r] for r in sel],
                               row_idx=sel.astype(np.int32), values=values)

    # -- get_increment / get_increments (§III.C) -------------------------------
    def get_increments(self, pairs: Sequence[tuple[Timestamp, Timestamp]], *,
                       significant_fields: Sequence[str] | None = None,
                       fields: Sequence[str] | None = None) -> list[Increment]:
        """Entries whose significant fields changed in (t0, t1], for many
        (t0, t1) windows at once: one batched scan over the unique window
        endpoints serves every pair. Duplicate windows are computed once
        and share the returned Increment object (as get_versions does).

        Mirrors the paper's tool-specific change detection: a BLAST plugin
        passes significant_fields=["sequence"], so annotation-only updates
        produce an empty increment.

        Args:
          pairs: (t0, t1] windows (duplicates share one Increment).
          significant_fields: fields whose change marks a row updated
            (default: all fields).
          fields: fields materialized into ``values`` (default: all;
            pass ``[]`` for keys/kinds only).

        Returns:
          list[Increment] aligned with ``pairs`` (values at t1, zeroed
          for deleted rows).

        Raises:
          KeyError: an unknown field name.
        """
        sig = (list(significant_fields) if significant_fields is not None
               else list(self.fields))
        out_fields = list(fields) if fields is not None else list(self.fields)
        pairs = [(int(t0), int(t1)) for t0, t1 in pairs]
        if not pairs:
            return []
        upairs = list(dict.fromkeys(pairs))
        if len(upairs) == 1 and not self._superlog_fresh():
            inc = self._get_increment_cold(*upairs[0], sig=sig,
                                           out_fields=out_fields)
            return [inc] * len(pairs)
        uniq = list(dict.fromkeys(t for p in upairs for t in p))
        q_of = {t: i for i, t in enumerate(uniq)}
        sl = self.superlog()
        bcum = sl.boundary_cums(uniq)
        exists, _ever = sl.exists_matrix(bcum)
        cnt = {name: sl.counts(name, bcum)
               for name in dict.fromkeys(sig + out_fields)}
        sels, kinds = [], []
        for t0, t1 in upairs:
            i0, i1 = q_of[t0], q_of[t1]
            changed = np.zeros(self.n_rows, bool)
            for name in sig:
                changed |= (cnt[name][i1] - cnt[name][i0]) > 0
            e0, e1 = exists[i0], exists[i1]
            new = e1 & ~e0
            deleted = e0 & ~e1
            updated = e1 & e0 & changed
            sel = np.nonzero(new | deleted | updated)[0]
            kind = np.zeros(len(sel), np.int8)
            kind[new[sel]] = KIND_NEW
            kind[updated[sel]] = KIND_UPDATED
            kind[deleted[sel]] = KIND_DELETED
            sels.append(sel)
            kinds.append(kind)
        vals = {name: sl.gather_many(name, [cnt[name][q_of[t1]]
                                            for _, t1 in upairs], sels)
                for name in out_fields}
        by_pair = {}
        for qi, ((t0, t1), sel, kind) in enumerate(zip(upairs, sels, kinds)):
            values = {}
            for name in out_fields:
                v = vals[name][qi]
                v[kind == KIND_DELETED] = 0
                values[name] = v
            by_pair[(t0, t1)] = Increment(
                t0=t0, t1=t1, keys=[self.row_keys[r] for r in sel],
                row_idx=sel.astype(np.int32), kind=kind, values=values)
        return [by_pair[p] for p in pairs]

    def get_increment(self, t0: Timestamp, t1: Timestamp, *,
                      significant_fields: Sequence[str] | None = None,
                      fields: Sequence[str] | None = None) -> Increment:
        return self.get_increments([(t0, t1)],
                                   significant_fields=significant_fields,
                                   fields=fields)[0]

    def _get_increment_cold(self, t0: Timestamp, t1: Timestamp, *,
                            sig: list[str], out_fields: list[str]) -> Increment:
        """Single-window increment over the involved fields' own CSR logs
        (no fused-superlog build)."""
        changed = np.zeros(self.n_rows, bool)
        for name in sig:
            changed |= self.fields[name].log.changed_counts(
                self.n_rows, t0, t1) > 0
        e0 = self.exists_at(t0)
        e1 = self.exists_at(t1)
        new = e1 & ~e0
        deleted = e0 & ~e1
        updated = e1 & e0 & changed
        sel = np.nonzero(new | deleted | updated)[0]
        kind = np.zeros(len(sel), np.int8)
        kind[new[sel]] = KIND_NEW
        kind[updated[sel]] = KIND_UPDATED
        kind[deleted[sel]] = KIND_DELETED
        values = {}
        for name in out_fields:
            vals, _ = self.fields[name].log.select_at(self.n_rows, t1)
            v = vals[sel]
            v[kind == KIND_DELETED] = 0
            values[name] = v
        return Increment(t0=t0, t1=t1, keys=[self.row_keys[r] for r in sel],
                         row_idx=sel.astype(np.int32), kind=kind,
                         values=values)

    # -- compaction (production housekeeping; paper §III.E leaves retention
    # to "a cron job" — at fleet scale the cell log needs real compaction) --
    def compact(self, before_ts: Timestamp, *, label: str = "",
                path: str | None = None) -> dict:
        """Collapse every row's cell history with ts <= before_ts into a
        single base cell at before_ts. Versions > before_ts are preserved
        exactly; get_version(t) for t >= before_ts is unchanged (older
        pinned versions are the retention cost, as with any compaction).

        Args:
          before_ts: compaction horizon (inclusive).
          label: label for the synthetic base release in ``versions``.
          path: optional store directory — when given, the on-disk segments
            are rewritten too (covered segments replaced by a base segment,
            segments entirely above ``before_ts`` retained untouched; see
            ``segments.compact_on_disk``).

        Returns:
          dict with ``cells_dropped`` / ``versions_kept`` and, when ``path``
          is given, the on-disk rewrite stats (``segments_written``,
          ``segments_retained``, ``bytes_written``, ...).
        """
        # captured before rechaining: compact_on_disk proves the on-disk
        # manifest is an ancestor of THIS history (not a same-shaped
        # divergent store's) against the pre-compaction chain
        pre_digests = list(self._version_digests)
        dropped = 0
        for col in list(self.fields.values()) + [self.exists_log]:
            vals, tss, ptr = col.csr(self.n_rows) if isinstance(col, _CellLog) \
                else col.log.csr(self.n_rows)
            log = col if isinstance(col, _CellLog) else col.log
            if len(tss) == 0:
                continue
            base_vals, base_found = log.select_at(self.n_rows, before_ts)
            # the horizon mask + value rewrite run on device through the
            # shared launch helper (numpy oracle on the CPU backend);
            # byte-identical either way, pinned by the equivalence tests
            new_vals, new_tss, new_rows, new_ptr = kops.compact_rewrite(
                vals, tss, np.asarray(ptr), base_vals, base_found,
                before_ts, self.n_rows)
            dropped += len(tss) - len(new_tss)
            log._csr = (new_vals, new_tss, new_rows)
            log._chunks = []
            log._row_ptr = new_ptr
            log._n_rows_at_build = self.n_rows
        # collapse the updates-table prefix into one synthetic base release
        kept = [v for v in self.versions if v.ts > before_ts]
        n_base = int(self.exists_at(before_ts).sum())
        base = VersionInfo(ts=before_ts, label=label or f"compact@{before_ts}",
                           n_entries=n_base, n_new=n_base, n_updated=0,
                           n_deleted=0)
        self.versions = [base] + kept
        # the seed carries the pre-compaction content digest forward, so
        # divergent histories stay distinguishable after compaction too
        self._rechain_digests(hashlib.sha256(
            f"compact|{before_ts}|{self._history_digest}".encode())
            .hexdigest()[:16])
        self._invalidate_log()
        stats = {"cells_dropped": dropped, "versions_kept": len(kept) + 1}
        if path is not None:
            from . import segments
            stats.update(segments.compact_on_disk(
                self, path, before_ts, prior_digests=pre_digests))
        return stats

    # -- persistence: segmented, append-only layout (core/segments.py) -------
    def save(self, path: str, *, force_full: bool = False) -> dict:
        """Persist to the segmented on-disk layout at ``path``.

        Incremental when the directory already holds a manifest that is a
        prefix of this store (same name/schema/keys/version history): only
        cells newer than the manifest's ``saved_through_ts`` are written,
        one segment per changed field — bytes written are O(new cells),
        independent of total history size. Anything else (first save,
        post-compaction, divergent history, ``force_full=True``) is a full
        rewrite that also migrates/removes legacy monolithic snapshots.

        Args:
          path: store directory (created if missing).
          force_full: skip the incremental check and rewrite everything.

        Returns:
          dict with ``mode`` ("incremental" | "full"), ``segments_written``,
          ``bytes_written`` (segments + manifest written by THIS call),
          ``raw_bytes`` / ``packed_bytes`` (pre/post chain-packing sizes of
          the written cells), and ``disk_bytes`` (total store footprint).
        """
        from . import segments
        return segments.save_store(self, path, force_full=force_full)

    @classmethod
    def load(cls, path: str, *, lazy: bool = True) -> "VersionedStore":
        """Open a store directory (segmented manifest, or a legacy
        monolithic snapshot for backward compatibility).

        Args:
          path: directory written by ``save`` (or a legacy snapshot).
          lazy: when True (default), segment files are only stat-checked
            (existence + exact size, so torn writes fail fast) and attached
            as pending handles — their cells are read the first time a
            query's timestamp bound reaches them, and head state is rebuilt
            on the first mutation. ``lazy=False`` materializes everything
            eagerly (the old behavior).

        Returns:
          A fully functional VersionedStore.

        Raises:
          FileNotFoundError: no manifest or legacy snapshot at ``path``.
          segments.CorruptSegmentError: a listed segment is missing or
            truncated (lazy) / fails its checksum (on read).
        """
        from . import segments
        return segments.load_store(cls, path, lazy=lazy)

    # -- distribution ---------------------------------------------------------
    def shard_spec(self):
        """Rows (and log cells) shard over the mesh 'data' axis."""
        from jax.sharding import PartitionSpec as P
        return P("data", None)
