"""VersionedStore: the GeStore meta-database data model (paper §III.B-§III.D).

HBase mapping -> JAX-native columnar MVCC:
  * entries  -> rows (dense int index; byte-string keys via a host dict)
  * parsed fields -> fixed-width numeric columns (one ``_FieldColumn`` each;
    schema evolution = add a column, as in HBase)
  * timestamped cells -> an append-only per-field cell log, consolidated
    lazily to CSR (sorted by (row, ts)) for the ``version_select`` kernel
  * EXISTS column -> a dedicated int8 cell log (tombstones on delete)

The four operations of §III.C: ``create`` (constructor), ``update``,
``get_increment``, ``get_version``. Change detection is fingerprint-based
(kernels/fingerprint.py) so an update touches O(changed) cells, which is what
makes storing many 240 GB-class releases cheap. Heavy scans run on device via
the Pallas kernels; key bookkeeping stays on host (the HBase-master
analogue).

Row-space sharding: every device-side op here is data-parallel over rows or
log cells, so a production deployment shards rows over the mesh ``data``
axis; ``shard_spec()`` exposes the NamedSharding used by the distributed
tests and the dry-run.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np
import jax.numpy as jnp

from repro.kernels import ops as kops

Timestamp = int

# device-side timestamps are int32 (JAX default int width); host keeps int64.
TS_MAX = 2**31 - 2


def _clamp_ts(t: Timestamp) -> int:
    return int(min(max(int(t), -(2**31) + 1), TS_MAX))


@dataclasses.dataclass(frozen=True)
class FieldSchema:
    name: str
    width: int
    dtype: str = "int32"  # numpy dtype name

    @property
    def np_dtype(self) -> np.dtype:
        return np.dtype(self.dtype)


@dataclasses.dataclass
class VersionInfo:
    """Row of the `updates` system table (§III.D)."""
    ts: Timestamp
    label: str
    n_entries: int
    n_new: int
    n_updated: int
    n_deleted: int


@dataclasses.dataclass
class VersionView:
    """A materialized meta-database version (get_version output)."""
    ts: Timestamp
    keys: list[bytes]
    row_idx: np.ndarray  # (K,) int32 store row index
    values: dict[str, np.ndarray]  # field -> (K, W)

    def __len__(self) -> int:
        return len(self.keys)


KIND_NEW, KIND_UPDATED, KIND_DELETED = 0, 1, 2


@dataclasses.dataclass
class Increment:
    """get_increment output: entries changed in (t0, t1]."""
    t0: Timestamp
    t1: Timestamp
    keys: list[bytes]
    row_idx: np.ndarray
    kind: np.ndarray  # (K,) int8 KIND_*
    values: dict[str, np.ndarray]  # values at t1 (zeros for deleted rows)

    def __len__(self) -> int:
        return len(self.keys)


class _CellLog:
    """Append-only timestamped cell log for one column, lazy CSR."""

    def __init__(self, width: int, dtype: np.dtype):
        self.width = width
        self.dtype = dtype
        self._chunks: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self._csr: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None  # vals, ts, order-rows
        self._row_ptr: np.ndarray | None = None
        self._n_rows_at_build = -1

    @property
    def n_cells(self) -> int:
        return sum(len(c[1]) for c in self._chunks) + (
            0 if self._csr is None else len(self._csr[1]))

    def append(self, rows: np.ndarray, ts: Timestamp, vals: np.ndarray) -> None:
        if len(rows) == 0:
            return
        assert vals.shape == (len(rows), self.width)
        self._chunks.append((rows.astype(np.int32),
                             np.full(len(rows), ts, np.int64),
                             np.ascontiguousarray(vals, dtype=self.dtype)))
        self._row_ptr = None  # CSR dirty

    def csr(self, n_rows: int):
        """Returns (vals (C,W), ts (C,), row_ptr (n_rows+1,)) sorted by (row, ts)."""
        if self._row_ptr is not None and self._n_rows_at_build == n_rows:
            return self._csr[0], self._csr[1], self._row_ptr
        parts = list(self._chunks)  # each: (rows, ts, vals)
        if self._csr is not None:
            vals0, tss0, rows0 = self._csr
            parts.insert(0, (rows0, tss0, vals0))
        rows = (np.concatenate([c[0] for c in parts]) if parts
                else np.zeros(0, np.int32))
        tss = (np.concatenate([c[1] for c in parts]) if parts
               else np.zeros(0, np.int64))
        vals = (np.concatenate([c[2] for c in parts]) if parts
                else np.zeros((0, self.width), self.dtype))
        order = np.lexsort((tss, rows))
        rows, tss, vals = rows[order], tss[order], vals[order]
        ptr = np.zeros(n_rows + 1, np.int32)
        np.add.at(ptr, rows + 1, 1)
        ptr = np.cumsum(ptr).astype(np.int32)
        self._csr = (vals, tss, rows)
        self._chunks = []
        self._row_ptr = ptr
        self._n_rows_at_build = n_rows
        return vals, tss, ptr

    def select_at(self, n_rows: int, t: Timestamp):
        """(vals_at_t (n_rows, W), found (n_rows,)) via the Pallas kernel."""
        vals, tss, ptr = self.csr(n_rows)
        if len(tss) == 0:
            return (np.zeros((n_rows, self.width), self.dtype),
                    np.zeros(n_rows, bool))
        out, found = kops.version_select(
            jnp.asarray(vals), jnp.asarray(tss.astype(np.int32)),
            jnp.asarray(ptr), _clamp_ts(t))
        return np.asarray(out), np.asarray(found)

    def changed_counts(self, n_rows: int, t0: Timestamp, t1: Timestamp) -> np.ndarray:
        """Per-row number of cells with t0 < ts <= t1 (windowed scan, §III.C)."""
        _, tss, ptr = self.csr(n_rows)
        if len(tss) == 0:
            return np.zeros(n_rows, np.int32)
        ts_j = jnp.asarray(tss.astype(np.int32))
        c1 = np.asarray(kops.masked_cumsum(ts_j, _clamp_ts(t1)))
        c0 = np.asarray(kops.masked_cumsum(ts_j, _clamp_ts(t0)))
        cum = np.concatenate([[0], c1 - c0])
        return (cum[ptr[1:]] - cum[ptr[:-1]]).astype(np.int32)


class _FieldColumn:
    """Head state + cell log for one field."""

    def __init__(self, schema: FieldSchema, capacity: int):
        self.schema = schema
        self.log = _CellLog(schema.width, schema.np_dtype)
        self.head_vals = np.zeros((capacity, schema.width), schema.np_dtype)
        self.head_fp = np.zeros((capacity, 2), np.int32)
        self.head_has = np.zeros(capacity, bool)

    def grow(self, capacity: int) -> None:
        def g(a):
            out = np.zeros((capacity,) + a.shape[1:], a.dtype)
            out[: len(a)] = a
            return out
        self.head_vals = g(self.head_vals)
        self.head_fp = g(self.head_fp)
        self.head_has = g(self.head_has)


class VersionedStore:
    """One meta-database (one HBase table in the paper)."""

    def __init__(self, name: str, schema: Sequence[FieldSchema], capacity: int = 1024):
        self.name = name
        self.schema: dict[str, FieldSchema] = {}
        self.fields: dict[str, _FieldColumn] = {}
        self.capacity = max(capacity, 16)
        self.n_rows = 0
        self.key_to_row: dict[bytes, int] = {}
        self.row_keys: list[bytes] = []
        self.exists_log = _CellLog(1, np.dtype(np.int8))
        self._exists_head = np.zeros(self.capacity, bool)
        self.versions: list[VersionInfo] = []
        for fs in schema:
            self.add_field(fs)

    # -- schema evolution (HBase column flexibility, §III.B) ----------------
    def add_field(self, fs: FieldSchema) -> None:
        if fs.name in self.fields:
            raise ValueError(f"field {fs.name} exists")
        self.schema[fs.name] = fs
        self.fields[fs.name] = _FieldColumn(fs, self.capacity)

    # -- row allocation ------------------------------------------------------
    def _rows_for_keys(self, keys: Sequence[bytes], create: bool) -> np.ndarray:
        out = np.empty(len(keys), np.int32)
        for i, k in enumerate(keys):
            row = self.key_to_row.get(k, -1)
            if row < 0:
                if not create:
                    raise KeyError(k)
                row = self.n_rows
                self.n_rows += 1
                self.key_to_row[k] = row
                self.row_keys.append(k)
                if self.n_rows > self.capacity:
                    self.capacity *= 2
                    for col in self.fields.values():
                        col.grow(self.capacity)
                    e = np.zeros(self.capacity, bool)
                    e[: len(self._exists_head)] = self._exists_head
                    self._exists_head = e
            out[i] = row
        return out

    @property
    def last_ts(self) -> Timestamp:
        return self.versions[-1].ts if self.versions else -1

    # -- update (§III.C "update") -------------------------------------------
    def update(self, ts: Timestamp, keys: Sequence[bytes],
               table: Mapping[str, np.ndarray], *, label: str = "",
               full_release: bool = True,
               present_keys: Sequence[bytes] | None = None) -> VersionInfo:
        """Ingest a release. ``table``: field -> (M, W) rows aligned with keys.

        full_release=True: keys absent from this release are tombstoned
        (the paper compares consecutive full UniProtKB releases).
        full_release=False: patch semantics, absent keys untouched — unless
        ``present_keys`` lists the full release key set (then rows outside
        it are tombstoned even though only changed rows carry data).
        """
        if ts <= self.last_ts:
            raise ValueError(f"timestamps must be monotonic: {ts} <= {self.last_ts}")
        for name in table:
            if name not in self.fields:
                # schema evolution on the fly: infer width/dtype
                arr = np.asarray(table[name])
                self.add_field(FieldSchema(name, arr.shape[1], arr.dtype.name))
        keys = [k.encode() if isinstance(k, str) else bytes(k) for k in keys]
        was_known = np.fromiter((k in self.key_to_row for k in keys), bool,
                                count=len(keys))
        rows = self._rows_for_keys(keys, create=True)
        existed = np.zeros(len(keys), bool)
        existed[was_known] = self._exists_head[rows[was_known]]
        is_new = ~existed

        n_updated_rows = np.zeros(self.n_rows, bool)
        for name, vals in table.items():
            col = self.fields[name]
            vals = np.ascontiguousarray(vals, dtype=col.schema.np_dtype)
            if vals.ndim == 1:
                vals = vals[:, None]
            assert vals.shape == (len(keys), col.schema.width), (
                f"{name}: {vals.shape} != {(len(keys), col.schema.width)}")
            fp = kops.fingerprint_rows(vals)
            same = (fp == col.head_fp[rows]).all(axis=1) & col.head_has[rows]
            changed = ~same
            if changed.any():
                cr = rows[changed]
                col.log.append(cr, ts, vals[changed])
                col.head_vals[cr] = vals[changed]
                col.head_fp[cr] = fp[changed]
                col.head_has[cr] = True
                n_updated_rows[cr] |= True

        # EXISTS transitions
        appearing = rows[is_new]
        if len(appearing):
            self.exists_log.append(appearing, ts, np.ones((len(appearing), 1), np.int8))
            self._exists_head[appearing] = True
        n_deleted = 0
        if full_release or present_keys is not None:
            mask = np.zeros(self.n_rows, bool)
            mask[rows] = True
            if present_keys is not None:
                for k in present_keys:
                    k = k.encode() if isinstance(k, str) else bytes(k)
                    r = self.key_to_row.get(k, -1)
                    if r >= 0:
                        mask[r] = True
            gone = np.nonzero(self._exists_head[: self.n_rows] & ~mask)[0]
            if len(gone):
                self.exists_log.append(gone.astype(np.int32), ts,
                                       np.zeros((len(gone), 1), np.int8))
                self._exists_head[gone] = False
                n_deleted = len(gone)

        n_new = int(is_new.sum())
        n_upd = int((n_updated_rows[rows] & existed).sum())
        info = VersionInfo(ts=ts, label=label or str(ts), n_entries=len(keys),
                           n_new=n_new, n_updated=n_upd, n_deleted=n_deleted)
        self.versions.append(info)
        return info

    def delete(self, ts: Timestamp, keys: Sequence[bytes], *, label: str = "") -> VersionInfo:
        keys = [k.encode() if isinstance(k, str) else bytes(k) for k in keys]
        rows = self._rows_for_keys(keys, create=False)
        self.exists_log.append(rows, ts, np.zeros((len(rows), 1), np.int8))
        self._exists_head[rows] = False
        info = VersionInfo(ts, label or f"delete@{ts}", len(keys), 0, 0, len(keys))
        self.versions.append(info)
        return info

    # -- exists at a point in time -------------------------------------------
    def exists_at(self, t: Timestamp) -> np.ndarray:
        vals, found = self.exists_log.select_at(self.n_rows, t)
        return (vals[:, 0] > 0) & found

    # -- get_version (§III.C) --------------------------------------------------
    def get_version(self, t: Timestamp, *, fields: Sequence[str] | None = None,
                    key_filter: str | Callable[[bytes], bool] | None = None,
                    include_deleted: bool = False) -> VersionView:
        fields = list(fields) if fields is not None else list(self.fields)
        alive = self.exists_at(t)
        if include_deleted:
            ever = self.exists_log.changed_counts(self.n_rows, -1, t) > 0
            alive = ever
        sel = np.nonzero(alive)[0]
        if key_filter is not None:
            if isinstance(key_filter, (str, bytes)):
                pat = re.compile(key_filter.encode()
                                 if isinstance(key_filter, str) else key_filter)
                fmask = np.fromiter((pat.search(self.row_keys[r]) is not None
                                     for r in sel), bool, count=len(sel))
            else:
                fmask = np.fromiter((key_filter(self.row_keys[r]) for r in sel),
                                    bool, count=len(sel))
            sel = sel[fmask]
        values = {}
        for name in fields:
            vals, _found = self.fields[name].log.select_at(self.n_rows, t)
            values[name] = vals[sel]
        return VersionView(ts=t, keys=[self.row_keys[r] for r in sel],
                           row_idx=sel.astype(np.int32), values=values)

    # -- get_increment (§III.C) -------------------------------------------------
    def get_increment(self, t0: Timestamp, t1: Timestamp, *,
                      significant_fields: Sequence[str] | None = None,
                      fields: Sequence[str] | None = None) -> Increment:
        """Entries whose significant fields changed in (t0, t1].

        Mirrors the paper's tool-specific change detection: a BLAST plugin
        passes significant_fields=["sequence"], so annotation-only updates
        produce an empty increment.
        """
        sig = list(significant_fields) if significant_fields is not None else list(self.fields)
        out_fields = list(fields) if fields is not None else list(self.fields)
        changed = np.zeros(self.n_rows, bool)
        for name in sig:
            changed |= self.fields[name].log.changed_counts(self.n_rows, t0, t1) > 0
        e0 = self.exists_at(t0)
        e1 = self.exists_at(t1)
        new = e1 & ~e0
        deleted = e0 & ~e1
        updated = e1 & e0 & changed
        any_rel = new | deleted | updated
        sel = np.nonzero(any_rel)[0]
        kind = np.zeros(len(sel), np.int8)
        kind[new[sel]] = KIND_NEW
        kind[updated[sel]] = KIND_UPDATED
        kind[deleted[sel]] = KIND_DELETED
        values = {}
        for name in out_fields:
            vals, _ = self.fields[name].log.select_at(self.n_rows, t1)
            v = vals[sel]
            v[kind == KIND_DELETED] = 0
            values[name] = v
        return Increment(t0=t0, t1=t1, keys=[self.row_keys[r] for r in sel],
                         row_idx=sel.astype(np.int32), kind=kind, values=values)

    # -- compaction (production housekeeping; paper §III.E leaves retention
    # to "a cron job" — at fleet scale the cell log needs real compaction) --
    def compact(self, before_ts: Timestamp, *, label: str = "") -> dict:
        """Collapse every row's cell history with ts <= before_ts into a
        single base cell at before_ts. Versions > before_ts are preserved
        exactly; get_version(t) for t >= before_ts is unchanged (older
        pinned versions are the retention cost, as with any compaction)."""
        dropped = 0
        for col in list(self.fields.values()) + [self.exists_log]:
            vals, tss, ptr = col.csr(self.n_rows) if isinstance(col, _CellLog) \
                else col.log.csr(self.n_rows)
            log = col if isinstance(col, _CellLog) else col.log
            if len(tss) == 0:
                continue
            base_vals, base_found = log.select_at(self.n_rows, before_ts)
            keep = tss > before_ts
            rows_all = np.repeat(np.arange(self.n_rows, dtype=np.int32),
                                 np.diff(ptr))
            base_rows = np.nonzero(base_found)[0].astype(np.int32)
            new_rows = np.concatenate([base_rows, rows_all[keep]])
            new_tss = np.concatenate([
                np.full(len(base_rows), before_ts, np.int64), tss[keep]])
            new_vals = np.concatenate([base_vals[base_found], vals[keep]])
            dropped += len(tss) - len(new_tss)
            order = np.lexsort((new_tss, new_rows))
            nptr = np.zeros(self.n_rows + 1, np.int32)
            np.add.at(nptr, new_rows + 1, 1)
            log._csr = (new_vals[order], new_tss[order], new_rows[order])
            log._chunks = []
            log._row_ptr = np.cumsum(nptr).astype(np.int32)
            log._n_rows_at_build = self.n_rows
        # collapse the updates-table prefix into one synthetic base release
        kept = [v for v in self.versions if v.ts > before_ts]
        n_base = int(self.exists_at(before_ts).sum())
        base = VersionInfo(ts=before_ts, label=label or f"compact@{before_ts}",
                           n_entries=n_base, n_new=n_base, n_updated=0,
                           n_deleted=0)
        self.versions = [base] + kept
        return {"cells_dropped": dropped, "versions_kept": len(kept) + 1}

    # -- persistence with delta-packed cell segments (§III.B compression) ----
    def save(self, path: str) -> dict:
        os.makedirs(path, exist_ok=True)
        meta = {
            "name": self.name,
            "schema": [dataclasses.asdict(f) for f in self.schema.values()],
            "n_rows": self.n_rows,
            "keys": [k.decode("latin1") for k in self.row_keys],
            "versions": [dataclasses.asdict(v) for v in self.versions],
        }
        arrays: dict[str, np.ndarray] = {}
        stats = {"raw_bytes": 0, "packed_bytes": 0}
        for name, col in self.fields.items():
            vals, tss, ptr = col.log.csr(self.n_rows)
            packed, pmeta = _pack_cells(vals, ptr)
            arrays[f"f:{name}:vals"] = packed
            arrays[f"f:{name}:ts"] = tss
            arrays[f"f:{name}:ptr"] = ptr
            meta.setdefault("pack", {})[name] = pmeta
            stats["raw_bytes"] += vals.nbytes
            stats["packed_bytes"] += packed.nbytes
        ev, ets, eptr = self.exists_log.csr(self.n_rows)
        arrays["exists:vals"], arrays["exists:ts"], arrays["exists:ptr"] = ev, ets, eptr
        np.savez_compressed(os.path.join(path, "cells.npz"), **arrays)
        with open(os.path.join(path, "meta.json"), "w") as f:
            json.dump(meta, f)
        stats["disk_bytes"] = os.path.getsize(os.path.join(path, "cells.npz"))
        return stats

    @classmethod
    def load(cls, path: str) -> "VersionedStore":
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, "cells.npz"))
        st = cls(meta["name"], [FieldSchema(**f) for f in meta["schema"]],
                 capacity=max(16, meta["n_rows"]))
        st.n_rows = meta["n_rows"]
        st.row_keys = [k.encode("latin1") for k in meta["keys"]]
        st.key_to_row = {k: i for i, k in enumerate(st.row_keys)}
        st.versions = [VersionInfo(**v) for v in meta["versions"]]
        for name, col in st.fields.items():
            ptr = data[f"f:{name}:ptr"]
            vals = _unpack_cells(data[f"f:{name}:vals"], ptr,
                                 meta["pack"][name], col.schema)
            tss = data[f"f:{name}:ts"]
            rows = np.repeat(np.arange(st.n_rows, dtype=np.int32), np.diff(ptr))
            col.log._csr = (vals, tss, rows)
            col.log._row_ptr = ptr
            col.log._n_rows_at_build = st.n_rows
            # rebuild head = select at +inf
            hv, found = col.log.select_at(st.n_rows, TS_MAX)
            col.head_vals[: st.n_rows] = hv
            col.head_has[: st.n_rows] = found
            if found.any():
                col.head_fp[np.nonzero(found)[0]] = kops.fingerprint_rows(hv[found])
        eptr = data["exists:ptr"]
        erows = np.repeat(np.arange(st.n_rows, dtype=np.int32), np.diff(eptr))
        st.exists_log._csr = (data["exists:vals"], data["exists:ts"], erows)
        st.exists_log._row_ptr = eptr
        st.exists_log._n_rows_at_build = st.n_rows
        st._exists_head[: st.n_rows] = st.exists_at(TS_MAX)
        return st

    # -- distribution ---------------------------------------------------------
    def shard_spec(self):
        """Rows (and log cells) shard over the mesh 'data' axis."""
        from jax.sharding import PartitionSpec as P
        return P("data", None)


def _pack_cells(vals: np.ndarray, ptr: np.ndarray) -> tuple[np.ndarray, dict]:
    """Delta-pack a CSR cell array: within each row chain, cells after the
    first are stored as deltas vs the previous cell (delta_codec kernel),
    with integer narrowing when the whole segment allows it."""
    if len(vals) == 0:
        return vals, {"mode": "raw", "dtype": vals.dtype.name}
    first_of_row = np.zeros(len(vals), bool)
    first_of_row[ptr[:-1][ptr[:-1] < len(vals)]] = True
    prev = np.roll(vals, 1, axis=0)
    prev[first_of_row] = 0  # first cell packs against zero (raw)
    delta, _stat = kops.delta_pack(jnp.asarray(vals), jnp.asarray(prev))
    delta = np.asarray(delta)
    meta = {"mode": "delta", "dtype": vals.dtype.name}
    if np.issubdtype(vals.dtype, np.integer) and vals.dtype.itemsize >= 4:
        maxabs = int(np.abs(delta).max()) if delta.size else 0
        narrow = kops.narrow_dtype(maxabs)
        if np.dtype(narrow) != vals.dtype:
            delta = delta.astype(narrow)
            meta["narrow"] = np.dtype(narrow).name
    return delta, meta


def _unpack_cells(packed: np.ndarray, ptr: np.ndarray, meta: dict,
                  schema: FieldSchema) -> np.ndarray:
    if meta["mode"] == "raw" or len(packed) == 0:
        return packed.astype(schema.np_dtype)
    delta = packed.astype(meta["dtype"]) if "narrow" in meta else packed
    if np.issubdtype(np.dtype(meta["dtype"]), np.floating):
        delta = delta.view(meta["dtype"]) if delta.dtype != np.dtype(meta["dtype"]) else delta
    # vectorized chain reconstruction: one pass per chain depth (chains are
    # short — one cell per version the row changed in)
    out = delta.copy()
    lens = np.diff(ptr)
    max_depth = int(lens.max()) if len(lens) else 0
    is_float = np.issubdtype(np.dtype(meta["dtype"]), np.floating)
    ib = {4: np.int32, 2: np.int16}.get(np.dtype(meta["dtype"]).itemsize, np.int32)
    for depth in range(1, max_depth):
        rows = np.nonzero(lens > depth)[0]
        idx = ptr[rows] + depth
        if is_float:
            out[idx] = (out[idx].view(ib) ^ out[idx - 1].view(ib)).view(out.dtype)
        else:
            out[idx] = out[idx] + out[idx - 1]
    return out.astype(schema.np_dtype)
