"""GeStore system tables (paper §III.D): `updates`, `runs`, `files`.

The paper keeps these as three HBase tables; here they are lightweight
host-side tables with JSON persistence. `updates` records every ingested
release per store; `runs` records which files each workflow tool execution
read/wrote (provenance); `files` indexes generated/materialized files for
cache lookup and for deciding HBase-vs-HDFS residency.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any


@dataclasses.dataclass
class UpdateRow:
    store: str
    ts: int
    label: str
    n_entries: int
    n_new: int = 0
    n_updated: int = 0
    n_deleted: int = 0


@dataclasses.dataclass
class RunRow:
    run_id: str
    tool: str
    inputs: list[str]
    outputs: list[str]
    params: dict[str, Any]
    wall_start: float
    wall_end: float = 0.0
    status: str = "running"


@dataclasses.dataclass
class FileRow:
    file_id: str        # canonical descriptor (filename-encoded, §III.E)
    path: str           # cache path ("HDFS") or "" if generatable from store
    plugin: str
    in_store: bool      # True: regenerable from HBase; False: unparsed blob
    bytes: int = 0
    created: float = 0.0
    last_used: float = 0.0


class SystemTables:
    def __init__(self, root: str | None = None):
        self.root = root
        self.updates: list[UpdateRow] = []
        self.runs: dict[str, RunRow] = {}
        self.files: dict[str, FileRow] = {}
        if root:
            os.makedirs(root, exist_ok=True)
            self._load()

    # -- updates -------------------------------------------------------------
    def record_update(self, store: str, info) -> None:
        self.updates.append(UpdateRow(store, info.ts, info.label, info.n_entries,
                                      info.n_new, info.n_updated, info.n_deleted))
        self._save()

    def updates_for(self, store: str) -> list[UpdateRow]:
        return [u for u in self.updates if u.store == store]

    # -- runs (provenance) -----------------------------------------------------
    def start_run(self, run_id: str, tool: str, inputs: list[str],
                  params: dict[str, Any] | None = None) -> RunRow:
        row = RunRow(run_id, tool, list(inputs), [], params or {}, time.time())
        self.runs[run_id] = row
        self._save()
        return row

    def finish_run(self, run_id: str, outputs: list[str], status: str = "done") -> None:
        row = self.runs[run_id]
        row.outputs = list(outputs)
        row.wall_end = time.time()
        row.status = status
        self._save()

    # -- files (cache index) ---------------------------------------------------
    def record_file(self, file_id: str, path: str, plugin: str, in_store: bool,
                    nbytes: int = 0) -> None:
        now = time.time()
        self.files[file_id] = FileRow(file_id, path, plugin, in_store, nbytes,
                                      created=now, last_used=now)
        self._save()

    def lookup_file(self, file_id: str) -> FileRow | None:
        row = self.files.get(file_id)
        if row is not None:
            row.last_used = time.time()
        return row

    def drop_file(self, file_id: str) -> None:
        self.files.pop(file_id, None)
        self._save()

    # -- persistence -----------------------------------------------------------
    def _save(self) -> None:
        if not self.root:
            return
        blob = {
            "updates": [dataclasses.asdict(u) for u in self.updates],
            "runs": {k: dataclasses.asdict(v) for k, v in self.runs.items()},
            "files": {k: dataclasses.asdict(v) for k, v in self.files.items()},
        }
        tmp = os.path.join(self.root, "tables.json.tmp")
        with open(tmp, "w") as f:
            json.dump(blob, f)
        os.replace(tmp, os.path.join(self.root, "tables.json"))

    def _load(self) -> None:
        p = os.path.join(self.root, "tables.json")
        if not os.path.exists(p):
            return
        with open(p) as f:
            blob = json.load(f)
        self.updates = [UpdateRow(**u) for u in blob["updates"]]
        self.runs = {k: RunRow(**v) for k, v in blob["runs"].items()}
        self.files = {k: FileRow(**v) for k, v in blob["files"].items()}
