"""Segmented on-disk version storage (paper §III.B/§IV: efficient storage
of many meta-database releases).

The monolithic ``cells.npz`` snapshot the seed shipped rewrote every cell
on each ``save()`` and inflated the full history into RAM on ``load()``.
This module replaces it with an append-only segment layout:

    <root>/MANIFEST.json                  atomic commit point (tmp+replace)
    <root>/SEGMENTS.jsonl                 append-only segment index
    <root>/segments/<field>/<ts0>-<ts1>.npz   immutable, delta-packed

Each segment file holds the cells of ONE field (or the EXISTS log, stored
under the ``__exists__`` sentinel) whose timestamps fall in ``[ts0, ts1]``,
as three arrays: ``rows`` (C,) int32, ``ts`` (C,) int64, and ``vals``
(C, W) chain-packed by ``kernels/delta_codec.chain_pack`` (first cell of a
row chain raw, later cells as deltas, with integer narrowing). Chains never
cross segments, so every segment decodes independently — the property that
makes lazy loading possible.

The segment index (``SEGMENTS.jsonl``, or ``SEGMENTS.<gen>.jsonl`` after
a rewrite) holds one JSON line per segment ({field, path, ts0, ts1,
n_cells, kind, pack, nbytes, sha256}). It is append-only so that an
incremental save writes O(new segments) index bytes, not a rewrite of the
whole O(history) index.

``MANIFEST.json`` is the single commit point and records, besides the
store metadata (name, schema, keys, versions):

    "format":           "gestore-segments-v1"
    "saved_through_ts": highest cell timestamp covered by the committed
                        segments (the incremental-save watermark)
    "segment_index":    filename of the committed index
    "index_gen":        index generation (bumped by full rewrite/compact)
    "segment_count":    committed line count of the index
    "segments_bytes":   committed byte length of the index
    "segments_nbytes":  running total of committed segment file bytes
                        (keeps incremental-save stats O(new segments))

Durability protocol: segment files are written to ``.tmp``, fsynced, then
renamed (the manifest and index generations likewise, with a directory
fsync after the rename, so the commit survives power loss, not just
process crashes);
incremental saves append index lines (after truncating any uncommitted
tail to ``segments_bytes``); full rewrites and compactions write a NEW
index generation instead of touching the committed one; the manifest is
rewritten last, atomically, and only then are superseded files deleted.
A crash at any point therefore leaves the previous manifest — whose
``segments_bytes`` prefix of its own index generation is still intact —
loadable; stray appended lines, unreferenced index generations, and
orphan segment files are simply ignored. ``nbytes`` is checked against
``os.stat`` for every committed segment at load time and ``sha256`` on
first read, so torn or bit-flipped segment writes raise
``CorruptSegmentError`` instead of decoding garbage.

Save modes:
  * incremental — when the on-disk manifest is a *prefix* of the in-memory
    store (same name, schema-compatible, version-ts and key prefix), only
    cells with ts > ``saved_through_ts`` are written: one new segment per
    field that changed. Bytes written are O(new cells), independent of the
    total history size.
  * full rewrite — anything else (first save, post-compaction, divergent
    history). Also migrates legacy monolithic snapshots: the new layout is
    committed first, then stale ``cells.npz``/``meta.json`` are removed.

``compact_on_disk`` mirrors ``VersionedStore.compact`` on disk: covered
segments are replaced by one base segment (+ one gap segment for tail cells
whose original segments straddled the compaction point) while segments
entirely above ``before_ts`` are retained untouched.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.kernels.delta_codec import chain_pack, chain_unpack
from repro.obs import RECORDER, REGISTRY
from repro.obs.trace import StageTimer

if TYPE_CHECKING:  # avoid a circular import; store.py imports us lazily
    from .store import VersionedStore

FORMAT = "gestore-segments-v1"
MANIFEST_NAME = "MANIFEST.json"
SEGMENT_INDEX_NAME = "SEGMENTS.jsonl"
SEGMENT_DIR = "segments"
EXISTS_FIELD = "__exists__"
LEGACY_FILES = ("cells.npz", "meta.json")


class CorruptSegmentError(ValueError):
    """A segment file is missing, truncated, or fails its checksum."""


@dataclasses.dataclass(frozen=True)
class SegmentMeta:
    """One manifest entry describing an immutable on-disk segment."""
    field: str        # column name, or EXISTS_FIELD for the tombstone log
    path: str         # store-root-relative file path
    ts0: int          # min cell timestamp in the file
    ts1: int          # max cell timestamp in the file
    n_cells: int
    kind: str         # "delta" (incremental flush) | "base" (compaction)
    pack: dict        # chain_pack meta: mode/dtype/narrow
    nbytes: int       # exact file size (torn-write detection)
    sha256: str       # file digest (bit-rot detection, checked on read)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "SegmentMeta":
        return cls(**d)


def fs_name(name: str) -> str:
    """Filesystem-safe directory name for a field or store name."""
    return "".join(c if c.isalnum() or c in "._-" else "_" for c in name) or "_"


def store_dir_name(name: str) -> str:
    """Collision-free directory name for a store: when sanitization had to
    change the name, a digest suffix keeps distinct names (e.g. ``a/b`` vs
    ``a_b``) from sharing — and destroying — one directory."""
    safe = fs_name(name)
    if safe == name:
        return safe
    return f"{safe}-{hashlib.sha256(name.encode()).hexdigest()[:8]}"


def _fsync_dir(path: str) -> None:
    """Best-effort fsync of a directory entry after rename/create. Unlike
    data files, some filesystems reject opening or fsyncing directories,
    so failures here are swallowed rather than aborting the save."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


# -- segment file I/O ---------------------------------------------------------

def write_segment(root: str, field: str, rows: np.ndarray, tss: np.ndarray,
                  vals: np.ndarray, *, kind: str = "delta",
                  tag: str = "") -> tuple[SegmentMeta, int]:
    """Chain-pack and atomically write one segment file; returns
    (meta, packed-array bytes before npz compression).

    ``rows``/``tss``/``vals`` must be non-empty and sorted by (row, ts) —
    the order ``_CellLog.cells_after`` and ``csr`` produce. ``tag`` goes
    into the filename: rewrites pass the index generation so their files
    can never overwrite a committed same-range segment of the previous
    generation (which must stay intact until the manifest commit).
    """
    assert len(tss) > 0, "empty segments are never written"
    # store_dir_name, not fs_name: field names that sanitize identically
    # ('a/b' vs 'a_b') must not write into each other's directory
    field_dir = store_dir_name(field)
    seg_dir = os.path.join(root, SEGMENT_DIR, field_dir)
    os.makedirs(seg_dir, exist_ok=True)
    ts0, ts1 = int(tss.min()), int(tss.max())
    packed, pack_meta = chain_pack(np.ascontiguousarray(vals),
                                   np.asarray(rows))
    rel = os.path.join(SEGMENT_DIR, field_dir, f"{ts0}-{ts1}{tag}.npz")
    path = os.path.join(root, rel)
    # serialize in memory so size + sha come from the buffer we wrote —
    # no read-back pass over the file we just created
    bio = io.BytesIO()
    np.savez_compressed(bio, rows=rows.astype(np.int32),
                        ts=tss.astype(np.int64), vals=packed)
    blob = bio.getvalue()
    tmp = path + ".tmp.npz"
    with open(tmp, "wb") as f:
        f.write(blob)
        # tmp+rename alone only survives application crashes; a power
        # failure can leave the renamed file empty unless its data was
        # synced first. fsync errors (e.g. EIO) must abort the save.
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    # sync the whole new directory chain: seg_dir holds the file entry,
    # segments/ holds the (possibly just-created) <field> entry; the root's
    # segments/ entry is made durable by the manifest commit's root fsync
    _fsync_dir(seg_dir)
    _fsync_dir(os.path.join(root, SEGMENT_DIR))
    seg = SegmentMeta(field=field, path=rel, ts0=ts0, ts1=ts1,
                      n_cells=len(tss), kind=kind, pack=pack_meta,
                      nbytes=len(blob),
                      sha256=hashlib.sha256(blob).hexdigest())
    return seg, packed.nbytes


def read_segment(root: str, seg: SegmentMeta, dtype: np.dtype,
                 width: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Verify and decode one segment -> (rows, ts, vals).

    Raises:
      CorruptSegmentError: missing file, size mismatch (torn write), digest
        mismatch (bit rot), or cell-count mismatch vs the manifest.
    """
    path = os.path.join(root, seg.path)
    check_segment_stat(root, seg)
    # one disk read: hash the buffer, then decode it from memory
    with open(path, "rb") as f:
        blob = f.read()
    if hashlib.sha256(blob).hexdigest() != seg.sha256:
        raise CorruptSegmentError(f"segment {seg.path}: sha256 mismatch")
    with np.load(io.BytesIO(blob)) as z:
        rows, tss, packed = z["rows"], z["ts"], z["vals"]
    if len(rows) != seg.n_cells or len(tss) != seg.n_cells:
        raise CorruptSegmentError(
            f"segment {seg.path}: {len(rows)} cells != manifest {seg.n_cells}")
    vals = chain_unpack(packed, rows, seg.pack, np.dtype(dtype))
    return rows, tss, vals.reshape(seg.n_cells, width)


def check_segment_stat(root: str, seg: SegmentMeta) -> None:
    """Cheap existence + exact-size check (run for every segment at load
    time, so a torn write surfaces before any query touches the store)."""
    path = os.path.join(root, seg.path)
    if not os.path.exists(path):
        raise CorruptSegmentError(f"segment {seg.path}: missing")
    n = os.path.getsize(path)
    if n != seg.nbytes:
        raise CorruptSegmentError(
            f"segment {seg.path}: {n} bytes on disk != manifest {seg.nbytes}"
            " (torn write?)")


class SegmentHandle:
    """Lazy reference to one on-disk segment, attached to a ``_CellLog``.

    The log materializes a handle (splices its cells into the CSR) only
    when a query's timestamp bound reaches the segment's range."""

    __slots__ = ("root", "seg", "dtype", "width")

    def __init__(self, root: str, seg: SegmentMeta, dtype: np.dtype, width: int):
        self.root, self.seg, self.dtype, self.width = root, seg, dtype, width

    @property
    def ts0(self) -> int:
        return self.seg.ts0

    @property
    def ts1(self) -> int:
        return self.seg.ts1

    @property
    def n_cells(self) -> int:
        return self.seg.n_cells

    def materialize(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        # instrument the CALLER, not read_segment itself: fault-injection
        # tests replace the module-level read_segment wholesale, and an
        # injected failure must still land in the flight recorder with
        # the active trace id attached
        try:
            with StageTimer(None, "segment_read"):
                return read_segment(self.root, self.seg, self.dtype,
                                    self.width)
        except Exception as e:  # noqa: BLE001 — recorded, then re-raised
            REGISTRY.counter("segments.read_errors").inc()
            RECORDER.record("segment_read_error", path=self.seg.path,
                            root=self.root, error=repr(e))
            raise


# -- manifest I/O -------------------------------------------------------------

def read_manifest(root: str) -> dict | None:
    """Parsed MANIFEST.json, or None when absent/unparseable (callers treat
    both as "no segmented store here")."""
    p = os.path.join(root, MANIFEST_NAME)
    if not os.path.exists(p):
        return None
    try:
        with open(p) as f:
            man = json.load(f)
    except (json.JSONDecodeError, OSError):
        return None
    return man if man.get("format") == FORMAT else None


def write_manifest(root: str, man: dict) -> int:
    """Atomically commit the manifest; returns its byte size."""
    p = os.path.join(root, MANIFEST_NAME)
    tmp = p + ".tmp"
    with open(tmp, "w") as f:
        json.dump(man, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, p)
    _fsync_dir(root)
    return os.path.getsize(p)


def _index_name(man: dict) -> str:
    return man.get("segment_index", SEGMENT_INDEX_NAME)


def read_segment_index(root: str, man: dict) -> list[SegmentMeta]:
    """The committed prefix of the manifest's segment index (exactly
    ``segments_bytes`` bytes / ``segment_count`` lines; anything beyond is
    an uncommitted tail from an interrupted save and is ignored).

    Raises:
      CorruptSegmentError: the committed prefix is shorter than the
        manifest claims or contains invalid JSON.
    """
    count, nbytes = man["segment_count"], man["segments_bytes"]
    if count == 0:
        return []
    p = os.path.join(root, _index_name(man))
    try:
        with open(p, "rb") as f:
            blob = f.read(nbytes)
    except OSError as e:
        raise CorruptSegmentError(f"segment index unreadable: {e}") from e
    if len(blob) < nbytes:
        raise CorruptSegmentError(
            f"segment index truncated: {len(blob)} < committed {nbytes}")
    lines = blob.decode().splitlines()
    if len(lines) != count:
        raise CorruptSegmentError(
            f"segment index has {len(lines)} committed lines, "
            f"manifest says {count}")
    try:
        return [SegmentMeta.from_json(json.loads(ln)) for ln in lines]
    except (json.JSONDecodeError, TypeError) as e:
        raise CorruptSegmentError(f"segment index corrupt: {e}") from e


def _append_segment_index(root: str, man: dict,
                          segs: Sequence[SegmentMeta]) -> int:
    """Append index lines after truncating any uncommitted tail; returns
    the new committed byte length."""
    p = os.path.join(root, _index_name(man))
    committed_bytes = man["segments_bytes"]
    data = "".join(json.dumps(s.to_json()) + "\n" for s in segs)
    with open(p, "ab") as f:
        f.truncate(committed_bytes)
        f.seek(committed_bytes)
        f.write(data.encode())
        f.flush()
        os.fsync(f.fileno())
    return committed_bytes + len(data.encode())


def _next_index_gen(old_man: dict | None) -> int:
    return (old_man.get("index_gen", 0) + 1) if old_man else 0


def _write_new_index_generation(root: str, gen: int,
                                segs: Sequence[SegmentMeta]) -> tuple[str, int]:
    """Write a fresh index generation (full rewrite / compaction) WITHOUT
    touching the committed one — the old manifest stays loadable until the
    new manifest commits. Returns (index name, byte length)."""
    name = SEGMENT_INDEX_NAME if gen == 0 else f"SEGMENTS.{gen}.jsonl"
    p = os.path.join(root, name)
    tmp = p + ".tmp"
    data = "".join(json.dumps(s.to_json()) + "\n" for s in segs)
    with open(tmp, "w") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, p)
    _fsync_dir(root)
    return name, len(data.encode())


def _manifest_payload(store: "VersionedStore", saved_through: int, *,
                      segment_count: int, segments_bytes: int,
                      segment_index: str, index_gen: int,
                      segments_nbytes: int) -> dict:
    return {
        "format": FORMAT,
        "name": store.name,
        "schema": [dataclasses.asdict(f) for f in store.schema.values()],
        "n_rows": store.n_rows,
        "keys": [k.decode("latin1") for k in store.row_keys],
        "versions": [dataclasses.asdict(v) for v in store.versions],
        "saved_through_ts": int(saved_through),
        "segment_index": segment_index,
        "index_gen": index_gen,
        "segment_count": segment_count,
        "segments_bytes": segments_bytes,
        # running total of committed segment FILE bytes: keeps the
        # incremental-save stats O(new segments) instead of re-reading and
        # re-parsing the whole index just to sum nbytes
        "segments_nbytes": int(segments_nbytes),
        "history_digests": list(store._version_digests),
    }


def _compatible(man: dict, store: "VersionedStore", *,
                check_versions: bool = True) -> bool:
    """True when the on-disk manifest is a prefix of the in-memory store,
    i.e. appending segments (instead of rewriting) yields a correct store."""
    if man["name"] != store.name or man["n_rows"] > store.n_rows:
        return False
    for f in man["schema"]:
        fs = store.schema.get(f["name"])
        if fs is None or fs.width != f["width"] or fs.dtype != f["dtype"]:
            return False
    if [k.encode("latin1") for k in man["keys"]] != \
            store.row_keys[: len(man["keys"])]:
        return False
    if check_versions:
        # chained per-release CONTENT digests, not just version metadata:
        # two stores ingesting different data with identical churn shapes
        # still diverge here, so "same shape, different content" histories
        # can never be extended incrementally
        ours = store._version_digests
        theirs = man.get("history_digests", [])
        if (len(theirs) != len(man["versions"])
                or len(theirs) > len(ours)
                or ours[: len(theirs)] != theirs):
            return False
    return True


def _digest_prefix(man: dict, prior_digests: Sequence[str] | None) -> bool:
    """True when the manifest's content-digest chain is a prefix of
    ``prior_digests`` — i.e. the directory's history is an ancestor of the
    given chain, not a same-shaped divergent store's."""
    if prior_digests is None:
        return False
    theirs = man.get("history_digests", [])
    return (len(theirs) <= len(prior_digests)
            and list(prior_digests)[: len(theirs)] == list(theirs))


def _iter_logs(store: "VersionedStore"):
    """(field name, _CellLog, dtype, width) for every log incl. EXISTS."""
    for name, col in store.fields.items():
        yield name, col.log, col.schema.np_dtype, col.schema.width
    yield EXISTS_FIELD, store.exists_log, np.dtype(np.int8), 1


# -- save ---------------------------------------------------------------------

def save_store(store: "VersionedStore", path: str, *,
               force_full: bool = False) -> dict:
    """Segmented save: incremental when the manifest at ``path`` is a prefix
    of this store, full rewrite otherwise. See ``VersionedStore.save``."""
    os.makedirs(path, exist_ok=True)
    man = read_manifest(path)
    if not force_full and man is not None and _compatible(man, store):
        return _save_incremental(store, path, man)
    return _save_full(store, path, old_man=man)


def _seg_stats(segs: Sequence[SegmentMeta], raw: int, packed: int,
               mode: str, manifest_bytes: int, total_seg_bytes: int,
               index_bytes: int, index_written: int) -> dict:
    return {
        "mode": mode,
        "segments_written": len(segs),
        "bytes_written": (sum(s.nbytes for s in segs) + manifest_bytes
                          + index_written),
        "raw_bytes": raw,
        "packed_bytes": packed,
        "manifest_bytes": manifest_bytes,
        "disk_bytes": total_seg_bytes + manifest_bytes + index_bytes,
    }


def _save_incremental(store: "VersionedStore", path: str, man: dict) -> dict:
    cutoff = int(man["saved_through_ts"])
    new_segs: list[SegmentMeta] = []
    raw = packed = 0
    for name, log, dtype, width in _iter_logs(store):
        rows, tss, vals = log.cells_after(cutoff)
        if len(tss) == 0:
            continue
        seg, pbytes = write_segment(path, name, rows, tss, vals)
        new_segs.append(seg)
        raw += vals.nbytes
        packed += pbytes
    idx_bytes = _append_segment_index(path, man, new_segs)
    prior_bytes = man.get("segments_nbytes")
    if prior_bytes is None:  # manifest predates the running total
        prior_bytes = sum(s.nbytes for s in read_segment_index(path, man))
    total_seg_bytes = prior_bytes + sum(s.nbytes for s in new_segs)
    mb = write_manifest(path, _manifest_payload(
        store, max(cutoff, store.last_ts),
        segment_count=man["segment_count"] + len(new_segs),
        segments_bytes=idx_bytes, segment_index=_index_name(man),
        index_gen=man.get("index_gen", 0), segments_nbytes=total_seg_bytes))
    return _seg_stats(new_segs, raw, packed, "incremental", mb,
                      total_seg_bytes, idx_bytes,
                      idx_bytes - man["segments_bytes"])


def _save_full(store: "VersionedStore", path: str, *,
               old_man: dict | None) -> dict:
    # The new layout (segments + a NEW index generation) is written beside
    # the old one; the manifest replacement is the only commit point, so a
    # crash anywhere before it leaves the previous state loadable.
    old_segs: list[SegmentMeta] = []
    if old_man is not None:
        try:
            old_segs = read_segment_index(path, old_man)
        except CorruptSegmentError:
            pass  # rewriting anyway; orphans are cleaned best-effort below
    gen = _next_index_gen(old_man)
    segs: list[SegmentMeta] = []
    raw = packed = 0
    for name, log, dtype, width in _iter_logs(store):
        vals, tss, ptr = log.csr(store.n_rows)
        if len(tss) == 0:
            continue
        rows = np.repeat(np.arange(store.n_rows, dtype=np.int32),
                         np.diff(ptr))
        seg, pbytes = write_segment(path, name, rows, tss, vals, kind="base",
                                    tag=f".g{gen}" if gen else "")
        segs.append(seg)
        raw += vals.nbytes
        packed += pbytes
    idx_name, idx_bytes = _write_new_index_generation(path, gen, segs)
    total_seg_bytes = sum(s.nbytes for s in segs)
    mb = write_manifest(path, _manifest_payload(
        store, store.last_ts, segment_count=len(segs),
        segments_bytes=idx_bytes, segment_index=idx_name, index_gen=gen,
        segments_nbytes=total_seg_bytes))
    # only after the new layout is committed: drop files it doesn't own —
    # legacy monolithic snapshots, the superseded index generation, and
    # segments of the divergent old manifest
    for legacy in LEGACY_FILES:
        p = os.path.join(path, legacy)
        if os.path.exists(p):
            os.remove(p)
    if old_man is not None and _index_name(old_man) != idx_name:
        _remove_quiet(os.path.join(path, _index_name(old_man)))
    keep = {s.path for s in segs}
    for s in old_segs:
        if s.path not in keep:
            _remove_quiet(os.path.join(path, s.path))
    return _seg_stats(segs, raw, packed, "full", mb, total_seg_bytes,
                      idx_bytes, idx_bytes)


def _remove_quiet(path: str) -> None:
    try:
        os.remove(path)
    except OSError:
        pass


# -- load ---------------------------------------------------------------------

def _engine_schema(fields: list[dict]) -> list[dict]:
    """Narrow float64 schema entries to float32 on load: the 32-bit query
    engine always materialized float64 fields at float32 precision, so
    this preserves observable behavior while letting stores persisted
    before the wide-dtype rejection reopen (the next save migrates them to
    float32 on disk via the schema-mismatch full rewrite). int64 has no
    such lossless-in-practice narrowing and stays loudly rejected."""
    return [{**f, "dtype": "float32"} if f.get("dtype") == "float64" else f
            for f in fields]


def load_store(cls, path: str, *, lazy: bool = True) -> "VersionedStore":
    """Open a store directory; see ``VersionedStore.load``."""
    from .store import FieldSchema, VersionInfo  # runtime import (cycle)
    man = read_manifest(path)
    if man is None:
        if os.path.exists(os.path.join(path, "meta.json")):
            return _load_legacy(cls, path)
        raise FileNotFoundError(f"no {MANIFEST_NAME} or legacy meta.json "
                                f"under {path}")
    st = cls(man["name"],
             [FieldSchema(**f) for f in _engine_schema(man["schema"])],
             capacity=max(16, man["n_rows"]))
    st.n_rows = man["n_rows"]
    st.row_keys = [k.encode("latin1") for k in man["keys"]]
    st.key_to_row = {k: i for i, k in enumerate(st.row_keys)}
    st.versions = [VersionInfo(**v) for v in man["versions"]]
    st._version_digests = list(man.get("history_digests", []))
    st._history_digest = (st._version_digests[-1]
                          if st._version_digests else "")
    by_field: dict[str, list[SegmentMeta]] = {}
    for seg in read_segment_index(path, man):
        check_segment_stat(path, seg)  # torn writes surface at open time
        by_field.setdefault(seg.field, []).append(seg)
    for name, log, dtype, width in _iter_logs(st):
        segs = sorted(by_field.pop(name, []), key=lambda s: s.ts0)
        log.attach_segments(
            [SegmentHandle(path, s, dtype, width) for s in segs])
    if by_field:
        raise CorruptSegmentError(
            f"manifest lists segments for unknown fields: {sorted(by_field)}")
    st.mark_heads_stale()
    if not lazy:
        st.rebuild_heads()
    st._invalidate_log()
    return st


# -- on-disk compaction -------------------------------------------------------

def compact_on_disk(store: "VersionedStore", path: str, before_ts: int, *,
                    prior_digests: Sequence[str] | None = None) -> dict:
    """Rewrite the store directory to mirror an in-memory ``compact``:
    per field one "base" segment (collapsed history at ``before_ts``), one
    optional "delta" gap segment (tail cells whose original segments
    straddled the compaction point or were never saved), and every existing
    segment entirely above ``before_ts`` retained untouched.

    Must run AFTER the in-memory compaction (``VersionedStore.compact``
    calls it in that order). Falls back to a full rewrite when the on-disk
    manifest does not belong to this store.

    Args:
      prior_digests: the store's PRE-compaction content-digest chain
        (in-memory compaction rechains the digests, so the post-compact
        store can no longer be compared against the manifest directly).
        The manifest's chain must be a prefix of it — otherwise the
        directory holds a divergent store's data and retaining its tail
        segments would silently splice foreign content; we full-rewrite
        instead. ``None`` (no provenance known) also forces a full rewrite.
    """
    man = read_manifest(path)
    if man is None or not _compatible(man, store, check_versions=False) \
            or not _digest_prefix(man, prior_digests):
        return save_store(store, path, force_full=True)
    retained: dict[str, list[SegmentMeta]] = {}
    covered: list[SegmentMeta] = []
    for seg in read_segment_index(path, man):
        if seg.ts0 > before_ts:
            retained.setdefault(seg.field, []).append(seg)
        else:
            covered.append(seg)
    gen = _next_index_gen(man)
    new_segs: list[SegmentMeta] = []
    raw = packed = 0
    for name, log, dtype, width in _iter_logs(store):
        vals, tss, ptr = log.csr(store.n_rows)  # fully in memory post-compact
        if len(tss) == 0:
            continue
        rows = np.repeat(np.arange(store.n_rows, dtype=np.int32),
                         np.diff(ptr))
        base = tss <= before_ts  # post-compact: exactly the collapsed base
        gap = ~base              # minus whatever retained segments cover
        for seg in retained.get(name, ()):
            gap &= ~((tss >= seg.ts0) & (tss <= seg.ts1))
        for mask, kind in ((base, "base"), (gap, "delta")):
            if mask.any():
                seg, pbytes = write_segment(path, name, rows[mask],
                                            tss[mask], vals[mask], kind=kind,
                                            tag=f".g{gen}")
                new_segs.append(seg)
                raw += vals[mask].nbytes
                packed += pbytes
    all_segs = new_segs + [s for segs in retained.values() for s in segs]
    # commit order mirrors _save_full: new index generation, then the
    # manifest swap, then deletion of superseded files
    idx_name, idx_bytes = _write_new_index_generation(path, gen, all_segs)
    total_seg_bytes = sum(s.nbytes for s in all_segs)
    mb = write_manifest(path, _manifest_payload(
        store, store.last_ts, segment_count=len(all_segs),
        segments_bytes=idx_bytes, segment_index=idx_name, index_gen=gen,
        segments_nbytes=total_seg_bytes))
    if _index_name(man) != idx_name:
        _remove_quiet(os.path.join(path, _index_name(man)))
    keep = {s.path for s in all_segs}
    for seg in covered:
        if seg.path not in keep:
            _remove_quiet(os.path.join(path, seg.path))
    stats = _seg_stats(new_segs, raw, packed, "compact", mb, total_seg_bytes,
                       idx_bytes, idx_bytes)
    stats["segments_retained"] = len(all_segs) - len(new_segs)
    stats["segments_dropped"] = len(covered)
    return stats


# -- legacy monolithic snapshots (pre-segment format) -------------------------

def write_legacy_snapshot(store: "VersionedStore", path: str) -> dict:
    """Write the pre-segment monolithic ``cells.npz`` + ``meta.json``
    snapshot. Kept for migration tests and as the full-rewrite baseline in
    ``benchmarks/table6_storage.py`` — new code should use ``save_store``.
    """
    os.makedirs(path, exist_ok=True)
    meta = {
        "name": store.name,
        "schema": [dataclasses.asdict(f) for f in store.schema.values()],
        "n_rows": store.n_rows,
        "keys": [k.decode("latin1") for k in store.row_keys],
        "versions": [dataclasses.asdict(v) for v in store.versions],
    }
    arrays: dict[str, np.ndarray] = {}
    stats = {"raw_bytes": 0, "packed_bytes": 0}
    for name, col in store.fields.items():
        vals, tss, ptr = col.log.csr(store.n_rows)
        rows = np.repeat(np.arange(store.n_rows, dtype=np.int32),
                         np.diff(ptr))
        packed, pmeta = chain_pack(vals, rows)
        arrays[f"f:{name}:vals"] = packed
        arrays[f"f:{name}:ts"] = tss
        arrays[f"f:{name}:ptr"] = ptr
        meta.setdefault("pack", {})[name] = pmeta
        stats["raw_bytes"] += vals.nbytes
        stats["packed_bytes"] += packed.nbytes
    ev, ets, eptr = store.exists_log.csr(store.n_rows)
    arrays["exists:vals"], arrays["exists:ts"], arrays["exists:ptr"] = \
        ev, ets, eptr
    np.savez_compressed(os.path.join(path, "cells.npz"), **arrays)
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)
    stats["disk_bytes"] = os.path.getsize(os.path.join(path, "cells.npz"))
    stats["bytes_written"] = stats["disk_bytes"] + \
        os.path.getsize(os.path.join(path, "meta.json"))
    stats["mode"] = "legacy-full"
    return stats


def _load_legacy(cls, path: str) -> "VersionedStore":
    """Load a pre-segment monolithic snapshot (eager: inflates everything,
    which is exactly why the segmented layout replaced it)."""
    from .store import FieldSchema, VersionInfo
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "cells.npz"))
    st = cls(meta["name"],
             [FieldSchema(**f) for f in _engine_schema(meta["schema"])],
             capacity=max(16, meta["n_rows"]))
    st.n_rows = meta["n_rows"]
    st.row_keys = [k.encode("latin1") for k in meta["keys"]]
    st.key_to_row = {k: i for i, k in enumerate(st.row_keys)}
    st.versions = [VersionInfo(**v) for v in meta["versions"]]
    for name, col in st.fields.items():
        ptr = data[f"f:{name}:ptr"]
        rows = np.repeat(np.arange(st.n_rows, dtype=np.int32), np.diff(ptr))
        vals = chain_unpack(data[f"f:{name}:vals"], rows,
                            meta["pack"][name], col.schema.np_dtype)
        col.log.splice_csr(vals.reshape(len(rows), col.schema.width),
                           data[f"f:{name}:ts"], rows, ptr, st.n_rows)
    eptr = data["exists:ptr"]
    erows = np.repeat(np.arange(st.n_rows, dtype=np.int32), np.diff(eptr))
    st.exists_log.splice_csr(data["exists:vals"], data["exists:ts"], erows,
                             eptr, st.n_rows)
    # legacy snapshots carry no content digests; seed a deterministic
    # chain so the store saves (full rewrite) and evolves consistently
    st._rechain_digests("legacy")
    st.mark_heads_stale()
    st.rebuild_heads()
    st._invalidate_log()
    return st
