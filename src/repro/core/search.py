"""Neural-BLAST: versioned embedding-similarity search with EXACT
incremental merge (the paper's BLAST workload adapted to the framework).

BLAST scores queries against every database sequence and normalizes by
database size (e-value). The embedding analogue: score = q . e_i / tau over
a versioned corpus; the normalizer Z(q) = logsumexp_i score_i plays the
e-value role — it depends on the WHOLE corpus, so incremental computation
must fix it at merge time.

GeStore trick (paper §III.A): partition corpus rows into segments; the
per-(query, segment) sufficient statistics are (top-k hits, logsumexp
partial). On a corpus update only segments containing changed rows are
re-embedded and re-scored; the merge overwrites those segments' statistics
and recombines: Z = logsumexp over segment partials, global top-k = top-k
over per-segment top-ks. This makes the merge EXACT — including under
DELETIONS (a deleted row only invalidates its own segment's statistics,
which is rescored by construction; the paper §III.A notes deletions are the
hard case for output merging).

The encoder is any JAX fn (tokens (N, L) -> embeddings (N, D)) — e.g. one
of the model-zoo architectures in encoder mode; incremental corpus
RE-EMBEDDING is where the 13x-style application win comes from (embedding
cost dominates, exactly like BLAST alignment cost).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from .store import VersionedStore, KIND_DELETED

Encoder = Callable[[np.ndarray], np.ndarray]  # (N, L) int tokens -> (N, D) f32

NEG = -np.inf


@dataclasses.dataclass
class SearchResult:
    """Mergeable per-(query, segment) sufficient statistics."""
    query_ids: list[bytes]
    k: int
    seg_topk_idx: np.ndarray    # (Q, S, k) corpus rows (-1 empty)
    seg_topk_score: np.ndarray  # (Q, S, k)
    seg_lse: np.ndarray         # (Q, S)
    ts: int

    @property
    def z(self) -> np.ndarray:  # (Q,) full-corpus normalizer
        return _lse(self.seg_lse, axis=1)

    @property
    def topk_idx(self) -> np.ndarray:
        idx, _ = self._global_topk()
        return idx

    @property
    def topk_score(self) -> np.ndarray:
        _, sc = self._global_topk()
        return sc

    def _global_topk(self):
        q, s, k = self.seg_topk_idx.shape
        flat_i = self.seg_topk_idx.reshape(q, s * k)
        flat_s = self.seg_topk_score.reshape(q, s * k)
        order = np.argsort(-flat_s, axis=1, kind="stable")[:, : self.k]
        return (np.take_along_axis(flat_i, order, 1),
                np.take_along_axis(flat_s, order, 1))

    def evalue(self) -> np.ndarray:
        """(Q, k) normalized significance: p = exp(score - Z)."""
        return np.exp(self.topk_score - self.z[:, None])


def _lse(x: np.ndarray, axis: int) -> np.ndarray:
    m = np.max(x, axis=axis, keepdims=True)
    m = np.where(np.isfinite(m), m, 0.0)
    return (m + np.log(np.sum(np.exp(x - m), axis=axis, keepdims=True))).squeeze(axis)


@jax.jit
def _score_block(q_emb: jax.Array, c_emb: jax.Array, tau: float = 1.0):
    return (q_emb @ c_emb.T) / tau


class EmbeddingSearchDB:
    """Segmented, versioned embedding index over a VersionedStore field."""

    def __init__(self, store: VersionedStore, encoder: Encoder, *,
                 token_field: str = "sequence", seg_size: int = 64,
                 tau: float = 4.0):
        self.store = store
        self.encoder = encoder
        self.token_field = token_field
        self.seg_size = seg_size
        self.tau = tau
        self._emb: np.ndarray | None = None
        self._emb_ts: int = -1
        self._embedded_rows = np.zeros(0, bool)
        self.n_embedded_total = 0                # work counter (bench metric)

    # -- corpus embedding (full / incremental) -------------------------------
    def refresh(self, ts: int, *, t_last: int | None = None) -> int:
        """Embed the corpus at version ts; with t_last, only rows whose
        token field changed in (t_last, ts]. Returns rows embedded."""
        n = self.store.n_rows
        if t_last is None or self._emb is None:
            view = self.store.get_version(ts, fields=[self.token_field])
            emb = np.asarray(self.encoder(view.values[self.token_field]))
            d = emb.shape[1] if len(emb) else 1
            self._emb = np.zeros((n, d), np.float32)
            self._embedded_rows = np.zeros(n, bool)
            if len(view):
                self._emb[view.row_idx] = emb
                self._embedded_rows[view.row_idx] = True
            self._emb_ts = ts
            self.n_embedded_total += len(view)
            return len(view)
        inc = self.store.get_increment(t_last, ts,
                                       significant_fields=[self.token_field],
                                       fields=[self.token_field])
        live = inc.kind != KIND_DELETED
        rows = inc.row_idx[live]
        if n > len(self._embedded_rows):          # corpus grew
            grown = np.zeros((n, self._emb.shape[1]), np.float32)
            grown[: len(self._emb)] = self._emb
            self._emb = grown
            g = np.zeros(n, bool)
            g[: len(self._embedded_rows)] = self._embedded_rows
            self._embedded_rows = g
        if len(rows):
            emb = np.asarray(self.encoder(inc.values[self.token_field][live]))
            self._emb[rows] = emb
            self._embedded_rows[rows] = True
        dead = inc.row_idx[inc.kind == KIND_DELETED]
        self._embedded_rows[dead] = False
        self._emb_ts = ts
        self.n_embedded_total += int(live.sum())
        return int(live.sum())

    # -- segments -------------------------------------------------------------
    def n_segments(self) -> int:
        return max(1, -(-self.store.n_rows // self.seg_size))

    def changed_segments(self, t0: int, t1: int) -> np.ndarray:
        inc = self.store.get_increment(t0, t1,
                                       significant_fields=[self.token_field],
                                       fields=[])
        return np.unique(inc.row_idx // self.seg_size)

    # -- query ------------------------------------------------------------------
    def query(self, query_ids: list[bytes], q_tokens: np.ndarray, *, ts: int,
              k: int = 10, segments: np.ndarray | None = None,
              prev: SearchResult | None = None) -> SearchResult:
        """Full search (segments=None) or incremental: score only `segments`
        and merge onto `prev`'s per-segment statistics (exact)."""
        assert ts == self._emb_ts, "call refresh(ts) first"
        q_emb = np.asarray(self.encoder(q_tokens))
        alive = self.store.exists_at(ts) & self._embedded_rows
        n_seg = self.n_segments()
        todo = np.arange(n_seg) if segments is None else np.asarray(segments)
        nq = len(query_ids)

        if prev is None:
            seg_idx = np.full((nq, n_seg, k), -1, np.int64)
            seg_score = np.full((nq, n_seg, k), NEG, np.float32)
            seg_lse = np.full((nq, n_seg), NEG, np.float32)
        else:
            assert prev.k == k, "k must match prev result for merging"
            s_prev = prev.seg_lse.shape[1]
            seg_idx = np.full((nq, n_seg, k), -1, np.int64)
            seg_score = np.full((nq, n_seg, k), NEG, np.float32)
            seg_lse = np.full((nq, n_seg), NEG, np.float32)
            seg_idx[:, :s_prev] = prev.seg_topk_idx
            seg_score[:, :s_prev] = prev.seg_topk_score
            seg_lse[:, :s_prev] = prev.seg_lse

        for seg in todo:
            seg = int(seg)
            lo = seg * self.seg_size
            hi = min(self.store.n_rows, lo + self.seg_size)
            rows = np.arange(lo, hi)[alive[lo:hi]]
            if len(rows) == 0:
                seg_lse[:, seg] = NEG
                seg_idx[:, seg] = -1
                seg_score[:, seg] = NEG
                continue
            s = np.asarray(_score_block(jnp.asarray(q_emb),
                                        jnp.asarray(self._emb[rows]),
                                        self.tau))
            seg_lse[:, seg] = _lse(s, axis=1)
            kk = min(k, s.shape[1])
            part = np.argpartition(-s, kk - 1, axis=1)[:, :kk]
            psc = np.take_along_axis(s, part, 1)
            order = np.argsort(-psc, axis=1, kind="stable")
            seg_idx[:, seg] = -1
            seg_score[:, seg] = NEG
            seg_idx[:, seg, :kk] = rows[np.take_along_axis(part, order, 1)]
            seg_score[:, seg, :kk] = np.take_along_axis(psc, order, 1)

        return SearchResult(query_ids, k, seg_idx, seg_score, seg_lse, ts)

    # -- the end-to-end incremental path (GeStore generate->tool->merge) ------
    def incremental_query(self, prev: SearchResult, query_ids, q_tokens, *,
                          t_last: int, ts: int, k: int | None = None) -> SearchResult:
        k = prev.k if k is None else k
        n_embedded = self.refresh(ts, t_last=t_last)
        segs = self.changed_segments(t_last, ts)
        res = self.query(query_ids, q_tokens, ts=ts, k=k, segments=segs,
                         prev=prev)
        res.n_embedded = n_embedded  # type: ignore[attr-defined]
        return res
