"""Streaming ingest engine: chunked parse -> shard-parallel update waves.

The paper's dominant workload is swallowing each new multi-GB database
release (Tables 1/3 are update-bound), and the pre-existing path held the
whole release — text, keys, and stacked value blocks — in host memory
before a single serial scatter. This engine makes ingest a bounded-memory
pipeline instead:

  stage 1  reader      release text streamed in ``chunk_chars`` pieces
                       (a path, a callable, or any str-chunk iterable)
  stage 2  parse       the streaming entry splitter (plugins.py) cuts
                       records at arbitrary chunk boundaries; entries are
                       split into ``batch_entries``-row batches, optionally
                       fanned over a parse worker pool
  stage 3  queue       a ``queue_depth``-bounded handoff — the memory
                       ceiling, and the overlap point: batch k+1 parses
                       while batch k applies
  stage 4  journal     each batch is journaled (ft/checkpoint.py
                       ``IngestJournal``) before it mutates the store, so
                       a crash mid-release replays parsed chunks instead
                       of re-parsing the file
  stage 5  apply       ``begin_release`` session: the batch is routed by
                       the ``shard_route`` kernel and applied to all
                       shards as one concurrent wave (core/shard.py)

One release timestamp commits atomically at ``finish()``; the journal is
the only mid-release durability (see ``IngestJournal`` for why the
store's own incremental save cannot checkpoint half a release).

Backpressure: when the serving tier's ``TieredStorePool.pressure()``
(or any ``pressure_fn``) exceeds ``max_pressure``, the apply loop waits —
ingest yields to query traffic instead of thrashing the pool.

``synth_uniprot_chunks`` generates arbitrarily large synthetic UniProtKB
releases as a stream (never materialized), for benchmarks and tests.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Iterable, Iterator

import numpy as np

from repro.obs import RECORDER, REGISTRY, get_logger, span

from .plugins import FileParser
from .store import VersionInfo

_LOG = get_logger("ingest")

#: str path | iterable of text chunks | callable(start_offset) -> iterable
Source = "str | Iterable[str] | Callable[[int], Iterable[str]]"


class IngestResumeError(RuntimeError):
    """A journal exists for this release but the store does not match its
    pre-release watermark — the store moved on (or holds a half-applied
    release in memory). Reload the store from its directory, or clear the
    journal to start over."""


@dataclasses.dataclass
class IngestConfig:
    """Streaming-ingest tuning knobs (defaults suit multi-MB releases)."""
    chunk_chars: int = 1 << 20     #: source read size (chars == bytes, ASCII)
    batch_entries: int = 1024      #: entries per parsed batch (= one wave)
    #: bounded parse->apply queue (memory cap); 0 runs stage 2 inline —
    #: also the automatic mode on single-CPU hosts, where a reader thread
    #: buys no overlap, only switch overhead
    queue_depth: int = 4
    parse_workers: int = 0         #: >0: split entries on a thread pool
    manifest_every: int = 1        #: journal-manifest commit cadence (batches)
    max_pressure: float | None = None   #: backpressure threshold
    pressure_poll_s: float = 0.01       #: backpressure poll interval
    max_backpressure_wait_s: float = 30.0  #: liveness cap per wait


@dataclasses.dataclass
class IngestReport:
    """What one ``ingest_release`` call did (see field comments)."""
    ts: int
    label: str
    n_entries: int = 0             #: total entries applied this run
    n_chunks: int = 0              #: batches applied (replayed + parsed)
    chunks_replayed: int = 0       #: batches replayed from the journal
    entries_replayed: int = 0
    entries_parsed: int = 0        #: entries parsed from the source this run
    checkpoint_writes: int = 0
    backpressure_waits: int = 0
    backpressure_wait_s: float = 0.0
    wall_s: float = 0.0
    already_committed: bool = False  #: crash landed after finish(); no-op
    info: VersionInfo | None = None

    @property
    def entries_per_s(self) -> float:
        return self.n_entries / self.wall_s if self.wall_s > 0 else 0.0


# -- source plumbing ---------------------------------------------------------
def read_file_chunks(path: str, chunk_chars: int = 1 << 20,
                     start: int = 0) -> Iterator[str]:
    """Stream a release file as text chunks. Bytes decode latin-1 so one
    char is one byte — journal source offsets are therefore byte offsets
    and a resume can ``seek`` (release flat files are ASCII; non-ASCII
    bytes survive the round trip but keys derived from them would be
    mojibake-encoded)."""
    with open(path, "rb") as f:
        if start:
            f.seek(start)
        while True:
            b = f.read(chunk_chars)
            if not b:
                return
            yield b.decode("latin-1")


def _open_source(source, start: int, chunk_chars: int) -> Iterable[str]:
    if isinstance(source, str):
        return read_file_chunks(source, chunk_chars, start)
    if callable(source):
        return source(start)
    if start:
        raise ValueError(
            "iterable sources cannot seek to a resume offset; pass a file "
            "path or a callable(start) -> chunks")
    return iter(source)


def _seekable(source) -> bool:
    return isinstance(source, str) or callable(source)


# -- store watermark ---------------------------------------------------------
def store_watermark(store) -> dict:
    """Fingerprint of a store's committed state, cheap and stable across
    save/lazy-load cycles: last committed ts, total cell count (resident
    + pending segments), and the content digest chain head (per shard for
    a sharded store). The ingest journal pins this at session start; a
    resume refuses any store whose watermark moved."""
    from .shard import ShardedStore
    if isinstance(store, ShardedStore):
        shards = [store.shard(i) for i in range(store.n_shards)]
        return {"last_ts": int(store.last_ts),
                "digests": [sh._history_digest for sh in shards],
                "n_cells": sum(_n_cells(sh) for sh in shards)}
    return {"last_ts": int(store.last_ts),
            "digests": [store._history_digest],
            "n_cells": _n_cells(store)}


def _n_cells(vs) -> int:
    return (vs.exists_log.n_cells
            + sum(col.log.n_cells for col in vs.fields.values()))


# -- parse pipeline ----------------------------------------------------------
def _cpu_count() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # non-Linux
        return os.cpu_count() or 1


class _BatchAssembler:
    """Accumulates parsed rows straight into preallocated schema-shaped
    arrays — the bounded-memory replacement for the list-of-row-dicts +
    ``stack_rows`` pass of ``parse_text``. Strict about dtypes: rows must
    arrive in the parser's declared dtype (true of every shipped parser;
    the whole-file path would have value-checked the cast instead)."""

    def __init__(self, parser: FileParser, cap: int):
        self._schema = parser.schema()
        self._cap = cap
        self.keys: list[bytes] = []
        self._arrays: dict[str, np.ndarray] | None = None

    def add(self, key: bytes, row: dict) -> bool:
        """Append one record; True when the batch is full."""
        if self._arrays is None:
            self._arrays = {fs.name: np.empty((self._cap, fs.width),
                                              fs.np_dtype)
                            for fs in self._schema}
        i = len(self.keys)
        for name, v in row.items():
            dst = self._arrays.get(name)
            if dst is None or np.asarray(v).dtype != dst.dtype:
                raise TypeError(
                    f"parser emitted field {name!r} outside its declared "
                    "schema dtype — streaming ingest requires rows in the "
                    "exact schema() dtypes")
            dst[i] = v
        self.keys.append(key)
        return len(self.keys) >= self._cap

    def flush(self) -> tuple[list[bytes], dict[str, np.ndarray]]:
        n = len(self.keys)
        keys = self.keys
        table = {name: a[:n] for name, a in (self._arrays or {}).items()}
        self.keys, self._arrays = [], None
        return keys, table


def _split_batch(parser: FileParser, texts: list[str], offs: list):
    asm = _BatchAssembler(parser, len(texts))
    for t in texts:
        k, r = parser.split_entry(t)
        asm.add(k, r)
    keys, table = asm.flush()
    return keys, table, offs[-1] if offs else None


def _batches(parser: FileParser, chunks: Iterable[str], cfg: IngestConfig,
             entry_mode: bool, skip_records: int,
             pool: ThreadPoolExecutor | None):
    """Stage 2: split the chunk stream into ``(payload, end_offset, n)``
    batches, where payload is ``(keys, table)`` — or a Future of
    ``(keys, table, off)`` when a parse worker pool fans out the entry
    splitting."""
    if entry_mode and pool is not None:
        texts: list[str] = []
        offs: list = []
        for entry, off in parser.iter_entries_with_offsets(chunks):
            texts.append(entry)
            offs.append(off)
            if len(texts) >= cfg.batch_entries:
                yield pool.submit(_split_batch, parser, texts, offs), \
                    offs[-1], len(texts)
                texts, offs = [], []
        if texts:
            yield (pool.submit(_split_batch, parser, texts, offs),
                   offs[-1], len(texts))
        return
    asm = _BatchAssembler(parser, cfg.batch_entries)
    if entry_mode:
        last_off = None
        for entry, off in parser.iter_entries_with_offsets(chunks):
            k, r = parser.split_entry(entry)
            last_off = off
            if asm.add(k, r):
                keys, table = asm.flush()
                yield (keys, table), last_off, len(keys)
    else:
        # block formats (stateful iter_records override): sequential
        # record machine, resume by skipping already-applied records
        seen = 0
        for k, r in parser.iter_records(chunks):
            seen += 1
            if seen <= skip_records:
                continue
            if asm.add(k, r):
                keys, table = asm.flush()
                yield (keys, table), None, len(keys)
        last_off = None
    if asm.keys:
        keys, table = asm.flush()
        yield (keys, table), last_off, len(keys)


def _bounded_put(q: queue.Queue, item, stop: threading.Event) -> bool:
    """Put that cannot deadlock against a dead consumer."""
    while not stop.is_set():
        try:
            q.put(item, timeout=0.1)
            return True
        except queue.Full:
            continue
    return False


def _producer(gen, q: queue.Queue, stop: threading.Event) -> None:
    """Pipelined stage-2 wrapper: drain the batch generator into the
    bounded queue from a reader thread. Items: ("batch", payload, off, n),
    then ("done"|"error", payload, None, 0)."""
    try:
        for payload, off, n in gen:
            if not _bounded_put(q, ("batch", payload, off, n), stop):
                return
        _bounded_put(q, ("done", None, None, 0), stop)
    except BaseException as e:  # noqa: BLE001 — forwarded to the consumer
        _bounded_put(q, ("error", e, None, 0), stop)


# -- the engine --------------------------------------------------------------
def ingest_release(store, source, parser: FileParser, ts: int, *,
                   label: str = "", full_release: bool = True,
                   config: IngestConfig | None = None,
                   journal_dir: str | None = None,
                   store_dir: str | None = None,
                   pressure_fn: Callable[[], float] | None = None,
                   on_batch: Callable[[int, int, bool], None] | None = None,
                   ) -> IngestReport:
    """Stream one release into ``store`` (either flavor) at ``ts``.

    Args:
      store: ``VersionedStore`` or ``ShardedStore`` (wave-parallel).
      source: file path (resumable via seek), str-chunk iterable, or
        ``callable(start_offset) -> chunk iterable``.
      parser: the release's ``FileParser``; its schema is pre-declared on
        the store so chunk-local inference never narrows dtypes.
      ts / label / full_release: as ``VersionedStore.update``.
      config: pipeline knobs (``IngestConfig``).
      journal_dir: enables crash-resume — parsed batches journal here
        before applying. Call again with the SAME arguments after a crash
        (store reloaded from ``store_dir``): journaled chunks replay
        without re-parsing, the source resumes at the journaled offset,
        and the finished store is byte-identical to an uninterrupted run.
      store_dir: the store's directory. Saved (incrementally) before the
        first chunk so disk holds the exact pre-release state a resume
        reloads, and again after ``finish()`` — release cells reach disk
        exactly once. The journal is cleared only after that final save.
      pressure_fn: mutation backpressure (e.g. ``pool.pressure``); waves
        wait while it exceeds ``config.max_pressure``.
      on_batch: ``(batch_idx, n_entries, replayed)`` test/progress hook,
        called after each applied batch.

    Returns:
      IngestReport (``already_committed=True`` when a resume found the
      release already finished — crash landed between the final save and
      journal cleanup).

    Raises:
      IngestResumeError: journal/store watermark mismatch.
      ValueError: non-monotonic ``ts`` or a mid-stream validation failure
        (already-applied chunks stay applied; the journal resumes them).
    """
    from repro.ft.checkpoint import IngestJournal

    cfg = config or IngestConfig()
    rep = IngestReport(ts=int(ts), label=label or str(ts))
    t_run = time.perf_counter()
    entry_mode = type(parser).iter_records is FileParser.iter_records
    track_offsets = entry_mode and _seekable(source)

    journal = None
    replay: list[dict] = []
    start_offset = 0
    skip_records = 0
    if journal_dir is not None:
        j = IngestJournal.open(journal_dir)
        if (j is not None and j.meta["ts"] == int(ts)
                and j.meta["store"] == store.name):
            if store.last_ts >= int(ts):
                # the crash landed after finish(): release committed,
                # journal just never got cleaned up
                j.clear()
                rep.already_committed = True
                rep.wall_s = time.perf_counter() - t_run
                return rep
            wm = store_watermark(store)
            if wm != j.meta["watermark"]:
                raise IngestResumeError(
                    f"ingest journal {journal_dir} was written against a "
                    f"different store state (journal {j.meta['watermark']} "
                    f"vs store {wm}); reload the store from its directory "
                    "or clear the journal")
            journal = j
            replay = list(j.chunks)
            off = j.resume_offset()
            if off is None or not track_offsets:
                skip_records = j.entries_applied()
                start_offset = 0
            else:
                start_offset = off
            _LOG.info("ingest resume: %d journaled chunks, offset %s",
                      len(replay), off)
        else:
            if j is not None:
                j.clear()  # stale journal for some other release
            if store_dir is not None:
                store.save(store_dir)  # durable pre-release state
            journal = IngestJournal.begin(
                journal_dir, store=store.name, ts=int(ts), label=label,
                full_release=full_release, watermark=store_watermark(store))

    # pre-declare the parser schema: chunk-local inference must never get
    # to pick a narrower dtype than the whole file would
    for fs in parser.schema():
        if fs.name not in store.fields:
            store.add_field(fs)

    c_chunks = REGISTRY.counter("ingest.chunks_parsed")
    c_entries = REGISTRY.counter("ingest.entries_routed")
    c_ckpt = REGISTRY.counter("ingest.checkpoint_writes")
    c_bp = REGISTRY.counter("ingest.backpressure_waits")
    h_wave = REGISTRY.histogram("ingest.wave_wall")

    def wait_pressure() -> None:
        if pressure_fn is None or cfg.max_pressure is None:
            return
        waited = 0.0
        while (pressure_fn() > cfg.max_pressure
               and waited < cfg.max_backpressure_wait_s):
            if waited == 0.0:
                c_bp.inc()
                rep.backpressure_waits += 1
            time.sleep(cfg.pressure_poll_s)
            waited += cfg.pressure_poll_s
        rep.backpressure_wait_s += waited

    session = store.begin_release(int(ts), label=label,
                                  full_release=full_release)
    with span("ingest", store=store.name, ts=int(ts)) as sp:
        try:
            # -- replay journaled chunks (no re-parse) ----------------------
            for c in replay:
                keys, table = journal.load_chunk(c["idx"])
                wait_pressure()
                t0 = time.perf_counter()
                session.apply(keys, table)
                h_wave.record(time.perf_counter() - t0)
                c_entries.inc(len(keys))
                rep.n_chunks += 1
                rep.chunks_replayed += 1
                rep.n_entries += len(keys)
                rep.entries_replayed += len(keys)
                if on_batch is not None:
                    on_batch(rep.n_chunks - 1, len(keys), True)

            # -- parse + apply the remaining source, pipelined --------------
            chunks = _open_source(source, start_offset, cfg.chunk_chars)
            pool = (ThreadPoolExecutor(
                max_workers=cfg.parse_workers,
                thread_name_prefix="ingest-parse")
                if cfg.parse_workers > 0 and entry_mode else None)
            gen = _batches(parser, chunks, cfg, entry_mode, skip_records,
                           pool)

            def apply_batch(payload, off) -> None:
                if isinstance(payload, Future):
                    keys, table, off = payload.result()
                else:
                    keys, table = payload
                wait_pressure()
                if journal is not None:
                    journal.record_chunk(
                        keys, table, source_offset=off,
                        flush=(rep.n_chunks % cfg.manifest_every == 0))
                    c_ckpt.inc()
                    rep.checkpoint_writes += 1
                t0 = time.perf_counter()
                session.apply(keys, table)
                h_wave.record(time.perf_counter() - t0)
                c_chunks.inc()
                c_entries.inc(len(keys))
                rep.n_chunks += 1
                rep.n_entries += len(keys)
                rep.entries_parsed += len(keys)
                if on_batch is not None:
                    on_batch(rep.n_chunks - 1, len(keys), False)

            try:
                if cfg.queue_depth > 0 and _cpu_count() > 1:
                    q: queue.Queue = queue.Queue(maxsize=cfg.queue_depth)
                    stop = threading.Event()
                    prod = threading.Thread(
                        target=_producer, args=(gen, q, stop),
                        name="ingest-reader", daemon=True)
                    prod.start()
                    try:
                        while True:
                            kind, payload, off, _n = q.get()
                            if kind == "done":
                                break
                            if kind == "error":
                                raise payload
                            apply_batch(payload, off)
                    finally:
                        stop.set()
                        prod.join(timeout=5.0)
                else:
                    # inline mode: no reader thread to overlap with
                    for payload, off, _n in gen:
                        apply_batch(payload, off)
            finally:
                if pool is not None:
                    pool.shutdown(wait=False, cancel_futures=True)
            if journal is not None:
                journal.flush()
            rep.info = session.finish()
        except BaseException as e:  # noqa: BLE001 — abort telemetry, re-raise
            RECORDER.record("ingest_abort", trace=sp.trace_id,
                            store=store.name, ts=int(ts),
                            chunks_applied=rep.n_chunks,
                            entries_applied=rep.n_entries, error=repr(e))
            raise

    if store_dir is not None:
        store.save(store_dir)  # release cells reach disk exactly once
        if journal is not None:
            journal.clear()  # durable => the journal has served its purpose
    rep.wall_s = time.perf_counter() - t_run
    return rep


# -- synthetic UniProtKB releases --------------------------------------------
_AA = "ACDEFGHIKLMNPQRSTVWY"


def synth_uniprot_chunks(n_entries: int, *, seed: int = 0,
                         churn: float = 0.0, seq_len: int = 180,
                         entries_per_chunk: int = 64) -> Iterator[str]:
    """Generate a synthetic UniProtKB ``.dat`` release as a text stream.

    Deterministic in ``seed``; ``churn`` perturbs that fraction of
    entries' sequences (vary it across releases to model real release
    deltas). The stream yields ``entries_per_chunk`` entries per chunk and
    never materializes the release — generating a 10M-entry release costs
    O(chunk) memory. Keys are ``P<i:08d>`` accessions, entries carry the
    ID/AC/DE/OX/SQ lines ``UniProtParser`` reads.
    """
    rng = np.random.RandomState(seed)
    out: list[str] = []
    for i in range(n_entries):
        mutate = churn > 0 and rng.random_sample() < churn
        erng = np.random.RandomState(
            (i * 2654435761 + (seed + 1 if mutate else 0)) % (2**31))
        seq = "".join(_AA[j] for j in erng.randint(0, len(_AA), seq_len))
        taxid = int(erng.randint(1, 99999))
        out.append(
            f"ID   E{i:08d}_SYN        Reviewed;       {seq_len} AA.\n"
            f"AC   P{i:08d};\n"
            f"DE   RecName: Full=Synthetic protein {i};\n"
            f"OS   Synthetica gestorensis.\n"
            f"OX   NCBI_TaxID={taxid};\n"
            f"SQ   SEQUENCE   {seq_len} AA;  00000 MW;  0000000000000000 CRC64;\n"
            + "".join(f"     {seq[j:j + 60]}\n"
                      for j in range(0, seq_len, 60))
            + "//\n")
        if len(out) >= entries_per_chunk:
            yield "".join(out)
            out = []
    if out:
        yield "".join(out)


def write_synth_uniprot(path: str, n_entries: int, *, seed: int = 0,
                        churn: float = 0.0, seq_len: int = 180) -> int:
    """Stream a synthetic release to ``path``; returns its byte size."""
    n = 0
    with open(path, "w") as f:
        for chunk in synth_uniprot_chunks(n_entries, seed=seed, churn=churn,
                                          seq_len=seq_len):
            f.write(chunk)
            n += len(chunk)
    return n
