"""Shard->device placement: device-parallel scatter-gather execution.

The paper's scalability story is many HBase region servers answering in
parallel (§II.B/§V); ``ShardedStore`` (core/shard.py) reproduces the
partitioning, and this module supplies the parallelism. Each shard's fused
superlog is pinned to its own JAX device over a 1-D ``("shard",)`` mesh
(launch/mesh.py), and the per-shard batched-select scans collapse into ONE
``shard_map``-style launch over a cross-shard stacked copy of the fused ts
arrays (kernels/batched_select.stacked_boundary_select) — so batched
``get_versions``/``get_increments`` throughput grows with shard count
instead of paying the serial per-shard Python loop.

Execution modes, planned by :func:`plan_placement`:

  * ``mesh`` — ``len(jax.devices()) >= n_shards``: one shard per device,
    stacked operands laid out with ``NamedSharding(mesh, P("shard",
    None))`` so the scan partitions with zero communication. Value
    materialization then pays ONE fused cross-shard gather per field
    (``take_cells``) instead of one per (shard, field).
  * ``stacked`` — fewer devices than shards but parallelism forced
    (``GESTORE_PARALLEL=1`` or an explicit plan): the same single stacked
    launch and fused gathers on one device. Still amortizes per-shard
    launch overhead; no cross-device parallelism.
  * ``serial`` — the PR-3 behavior (per-shard ``get_versions`` loop).
    This is the graceful fallback whenever the host has fewer devices
    than shards, and the explicit opt-out (``GESTORE_PARALLEL=0``).

Every mode returns byte-identical results: the stacked scan computes the
exact per-shard boundary cumsums the serial path does (pinned by the
equivalence suite across device counts), so the choice is pure placement
and composes with the ``log_epoch`` plan-cache contract unchanged — equal
facade epoch still implies identical bytes no matter which mode answered.

Residency-awareness: a :class:`PlacedSuperLog` is built from whatever
shards are resident (the facade forces residency first, exactly like the
serial path) and is keyed on the tuple of shard epochs. ``TieredStorePool``
shard-by-shard eviction composes cleanly: a spill freezes the shard's
epoch, the lazy reload floors back to it, and an unchanged epoch tuple
means the cached stacked copy is still byte-valid — no restack after a
spill/reload cycle. The facade's ``drop_superlog``/``nbytes`` account for
the stacked device buffers so the device->host eviction tier reclaims them.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import launch as klaunch
from repro.kernels.batched_select import scan_bucket, stacked_boundary_select
from repro.launch.mesh import make_shard_mesh
from repro.obs import kerneltel

from .store import _SuperLog, _clamp_ts

#: env override: "0"/"off"/"serial" forces serial, "1"/"on"/"parallel"
#: forces the stacked launch even with fewer devices than shards.
PARALLEL_ENV = "GESTORE_PARALLEL"

_FORCE_ON = ("1", "on", "parallel", "stacked", "force")
_FORCE_OFF = ("0", "off", "serial")


@dataclasses.dataclass(frozen=True)
class ShardPlacement:
    """One shard->device execution plan (see module docstring for modes)."""
    mode: str                 # "mesh" | "stacked" | "serial"
    devices: tuple = ()       # shard id -> device (mesh mode only)
    mesh: object = None       # 1-D ("shard",) mesh (mesh mode only)

    @property
    def parallel(self) -> bool:
        return self.mode != "serial"

    def device_for(self, shard: int):
        """Pinned device of ``shard``, or None (default device)."""
        return self.devices[shard] if shard < len(self.devices) else None


def plan_placement(n_shards: int, *, devices=None,
                   force: str | None = None) -> ShardPlacement:
    """Plan shard->device placement for an ``n_shards``-way store.

    Args:
      n_shards: shard count of the facade.
      devices: explicit device list (default: ``jax.devices()``).
      force: override the auto decision — any of ``_FORCE_ON`` forces the
        stacked/mesh parallel path, ``_FORCE_OFF`` forces serial; None
        reads the ``GESTORE_PARALLEL`` env var, then auto-plans: mesh when
        the host has at least one device per shard, else serial (the
        graceful fallback the serving tier relies on).
    """
    if force is None:
        force = os.environ.get(PARALLEL_ENV)
    if force is not None:
        force = str(force).strip().lower() or None
    devs = list(devices) if devices is not None else jax.devices()
    if n_shards < 2 or force in _FORCE_OFF:
        return ShardPlacement("serial")
    if len(devs) >= n_shards:
        mesh = make_shard_mesh(n_shards, devs)
        if mesh is not None:
            return ShardPlacement("mesh", tuple(devs[:n_shards]), mesh)
    if force in _FORCE_ON:
        return ShardPlacement("stacked")
    return ShardPlacement("serial")


class PlacedSuperLog:
    """Cross-shard stacked fused-superlog state for one facade epoch.

    Holds (S, Cmax) stacked per-shard fused ts rows (padded with int32
    max, which no clamped query timestamp can reach) and (S, Bmax) stacked
    CSR boundary positions (zero-padded; boundary 0 reads count 0), laid
    out across the shard mesh in ``mesh`` mode. ``boundary_cums`` then
    answers every shard's ``_SuperLog.boundary_cums`` in ONE launch.

    Immutable once built; the facade caches one instance keyed on
    ``epochs`` (the per-shard ``log_epoch`` tuple) and rebuilds whenever
    any shard's epoch moves — the same invalidation contract as the
    per-store superlog, so plan-cache semantics are unchanged.
    """

    def __init__(self, superlogs, placement: ShardPlacement):
        self.epochs = tuple(sl.epoch for sl in superlogs)
        self.mesh = placement.mesh if placement.mode == "mesh" else None
        self.b_widths = [len(sl.boundaries) for sl in superlogs]
        self.n_cells = sum(sl.n_cells for sl in superlogs)
        # per-field fused cross-shard value arrays, uploaded lazily on the
        # first gather of that field (name -> (dev, offs, total, w, dtype));
        # content validity follows from the epoch contract, so rebuild-time
        # callers pass their CURRENT superlog list and never retain ours
        self._fused: dict[str, tuple] = {}
        s = len(superlogs)
        # bucket the stacked cell/boundary axes to powers of two (same
        # trick as the per-store superlog): mid-run epoch rolls under
        # continuous ingest then reuse the compiled stacked scan instead
        # of retracing every time any shard's cell count moves
        cmax = scan_bucket(max((sl.n_cells for sl in superlogs), default=0))
        bmax = klaunch.pow2_bucket(max(self.b_widths, default=0), floor=8)
        ts = np.full((s, cmax), np.iinfo(np.int32).max, np.int32)
        bnd = np.zeros((s, bmax), np.int32)
        for i, sl in enumerate(superlogs):
            if sl.ts_host is not None:
                ts[i, : sl.n_cells] = sl.ts_host
            bnd[i, : self.b_widths[i]] = sl.boundaries.astype(np.int32)
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            sharding = NamedSharding(self.mesh, P("shard", None))
            self._ts = jax.device_put(ts, sharding)
            self._bnd = jax.device_put(bnd, sharding)
        else:
            self._ts = jnp.asarray(ts)
            self._bnd = jnp.asarray(bnd)

    def boundary_cums(self, ts_list) -> list[np.ndarray]:
        """Per-shard (Q, B_s) boundary cumsums for ``ts_list`` — the exact
        numbers each shard's ``_SuperLog.boundary_cums`` would return,
        from one device-parallel stacked launch."""
        qs = np.asarray([_clamp_ts(t) for t in ts_list], np.int32)
        if self.n_cells == 0 or not len(qs):
            return [np.zeros((len(qs), w), np.int32) for w in self.b_widths]
        q = len(qs)
        # bucket the query axis too (repeat the last query; extra columns
        # are sliced off) so wave-width churn cannot retrace the scan
        q_pad = klaunch.pow2_bucket(q, floor=8)
        qs_in = qs if q_pad == q else np.concatenate(
            [qs, np.full(q_pad - q, qs[-1], np.int32)])
        s, cmax = self._ts.shape
        bmax = self._bnd.shape[1]
        # stacked traffic model: logical counts the real per-shard cells
        # and boundaries; padded counts the bucketed (S, Cmax)/(S, Q, Bmax)
        # stacked shapes that actually move
        b_sum = sum(self.b_widths)
        with kerneltel.launch("batched_select",
                              nbytes=4 * (self.n_cells + q * self.n_cells
                                          + 2 * q * b_sum),
                              flops=2 * q * self.n_cells,
                              padded_nbytes=4 * (s * cmax + s * q_pad * cmax
                                                 + 2 * s * q_pad * bmax)):
            out = np.asarray(stacked_boundary_select(
                self._ts, jnp.asarray(qs_in), self._bnd, mesh=self.mesh))
        return [out[i, :q, : w] for i, w in enumerate(self.b_widths)]

    # -- fused cross-shard value gathers --------------------------------------
    def _fused_field(self, name: str, superlogs) -> tuple:
        """Cross-shard concatenation of one field's cell values: a single
        device array with per-shard cell offsets, so a materialization wave
        pays ONE ``take`` per field instead of one per (shard, field). The
        host copies come from the caller's current superlogs (equal epochs
        imply identical cells, so the cached upload stays byte-valid across
        spill/reload); only the device buffer and offsets are cached."""
        ent = self._fused.get(name)
        if ent is None:
            f0 = superlogs[0].fields[name]
            parts, offs, off = [], [], 0
            for sl in superlogs:
                f = sl.fields[name]
                offs.append(off)
                if f.vals_host is not None:
                    parts.append(f.vals_host)
                off += f.n_cells
            dev = None
            if off:
                dev = jnp.asarray(parts[0] if len(parts) == 1
                                  else np.concatenate(parts))
            ent = (dev, offs, off, f0.width, f0.dtype)
            self._fused[name] = ent
        return ent

    def field_offsets(self, name: str, superlogs) -> list[int]:
        """Per-shard cell offset of ``name`` in the fused value array."""
        return self._fused_field(name, superlogs)[1]

    def take_cells(self, name: str, idx: np.ndarray, keep: np.ndarray,
                   lens, superlogs) -> list[np.ndarray]:
        """One fused device gather for a whole wave: ``idx`` holds global
        cell positions (already permuted into every query's final merged
        row order, queries back to back with per-query ``lens``) and
        ``keep`` masks rows whose value must be zeroed (no cell at the
        query time / deleted rows) — the same semantics as
        ``_SuperLog.gather_finalize``, minus the host-side mutation."""
        dev, _offs, total, width, dtype = self._fused_field(name, superlogs)
        if dev is None or len(idx) == 0:
            return [np.zeros((int(n), width), dtype) for n in lens]
        out = np.asarray(jnp.where(
            jnp.asarray(keep)[:, None],
            jnp.take(dev, jnp.asarray(np.clip(idx, 0, total - 1)), axis=0),
            jnp.zeros((), dev.dtype)))
        cum = np.cumsum([0] + list(lens))
        return [out[cum[i]: cum[i + 1]] for i in range(len(lens))]

    def exists_matrices(self, bcums, superlogs) -> list[tuple]:
        """Per-shard ``(alive, ever)`` — ``_SuperLog.exists_matrix`` for
        every shard from ONE fused EXISTS gather instead of S launches."""
        name = _SuperLog.EXISTS
        dev, offs, total, _w, _d = self._fused_field(name, superlogs)
        cnts, evers, idxs = [], [], []
        for s, sl in enumerate(superlogs):
            f = sl.fields[name]
            cnt = sl.counts(name, bcums[s])
            cnts.append(cnt)
            evers.append(cnt > 0)
            idxs.append(offs[s] + np.clip(f.ptr[None, :-1] + cnt - 1, 0,
                                          max(f.n_cells - 1, 0)))
        if dev is None:
            return [(np.zeros_like(e), e) for e in evers]
        idx = np.clip(np.concatenate(idxs, axis=1), 0, total - 1)
        v = np.asarray(jnp.take(dev[:, 0], jnp.asarray(idx), axis=0))
        out, col = [], 0
        for ever in evers:
            n = ever.shape[1]
            out.append((((v[:, col: col + n] > 0) & ever), ever))
            col += n
        return out

    def nbytes(self) -> int:
        """Device bytes held by the stacked scan operands plus the fused
        per-field value uploads (facade accounting)."""
        n = int(self._ts.nbytes + self._bnd.nbytes)
        for dev, *_ in self._fused.values():
            if dev is not None:
                n += int(dev.nbytes)
        return n
