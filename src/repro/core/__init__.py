"""GeStore core: the paper's contribution as a composable library.

Layers: VersionedStore (MVCC columnar storage) -> change detection ->
increment engine (generate/merge around unmodified tools) -> plugins/parsers
-> cache + system tables -> neural-BLAST incremental search.
"""
from .store import (FieldSchema, Increment, VersionedStore, VersionInfo,
                    VersionView, KIND_DELETED, KIND_NEW, KIND_UPDATED, TS_MAX)
from .shard import ShardedStore, open_any_store
from .tables import SystemTables
from .cache import VersionCache, descriptor
from .plugins import (FileGenerator, FileParser, OutputMerger, PluginRegistry,
                      REGISTRY, ToolPlugin)
from .mergers import AppendMerger, BlastEvalueMerger
from .increment import GeneratedInput, GeStore
from .search import EmbeddingSearchDB, SearchResult
from .change import SignificanceProfile, classify

__all__ = [
    "FieldSchema", "Increment", "VersionedStore", "VersionInfo", "VersionView",
    "KIND_DELETED", "KIND_NEW", "KIND_UPDATED", "TS_MAX", "ShardedStore",
    "open_any_store", "SystemTables",
    "VersionCache", "descriptor", "FileGenerator", "FileParser", "OutputMerger",
    "PluginRegistry", "REGISTRY", "ToolPlugin", "AppendMerger",
    "BlastEvalueMerger", "GeneratedInput", "GeStore", "EmbeddingSearchDB",
    "SearchResult", "SignificanceProfile", "classify",
]
