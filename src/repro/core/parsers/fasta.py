"""FASTA parser (entries: `>id description\\nSEQUENCE...`)."""
from __future__ import annotations

import numpy as np

from .._schema_compat import FieldSchema
from ..plugins import FileParser
from ._text import pad_bytes, unpad_bytes


class FastaParser(FileParser):
    format_name = "fasta"

    def __init__(self, seq_width: int = 512, desc_width: int = 128):
        self.seq_width = seq_width
        self.desc_width = desc_width

    def entry_pattern(self):
        return (r"^>", r"(?=^>)|\Z")

    def schema(self):
        return [
            FieldSchema("sequence", self.seq_width, "int8"),
            FieldSchema("length", 1, "int32"),
            FieldSchema("desc", self.desc_width, "int8"),
        ]

    def split_entry(self, entry: str):
        header, _, body = entry.partition("\n")
        header = header.lstrip(">").strip()
        key, _, desc = header.partition(" ")
        seq = "".join(body.split())
        return key.encode(), {
            "sequence": pad_bytes(seq, self.seq_width),
            "length": np.asarray([len(seq)], np.int32),
            "desc": pad_bytes(desc, self.desc_width),
        }

    def format_entry(self, key: bytes, row: dict[str, np.ndarray]) -> str:
        desc = unpad_bytes(row["desc"]).decode()
        seq = unpad_bytes(row["sequence"]).decode()
        header = f">{key.decode()}" + (f" {desc}" if desc else "")
        lines = [seq[i:i + 60] for i in range(0, len(seq), 60)] or [""]
        return header + "\n" + "\n".join(lines) + "\n"
