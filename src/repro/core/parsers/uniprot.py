"""UniProtKB flat-file (.dat) parser.

Entries run from an `ID` line to `//`. We parse the fields Meta-pipe's BLAST
stage cares about plus the frequently-churning annotation block, kept as a
separate column so tool-specific change detection can ignore it (the paper's
central example: most UniProtKB release churn is annotation-only and must
not trigger BLAST increments).
"""
from __future__ import annotations

import numpy as np

from .._schema_compat import FieldSchema
from ..plugins import FileParser
from ._text import pad_bytes, unpad_bytes

#: BLAST-significant fields for UniProtKB (paper §III.A)
BLAST_SIGNIFICANT = ("sequence", "length")


class UniProtParser(FileParser):
    format_name = "uniprot_dat"

    def __init__(self, seq_width: int = 512, annot_width: int = 256):
        self.seq_width = seq_width
        self.annot_width = annot_width

    def entry_pattern(self):
        return (r"^ID\s", r"^//$")

    def schema(self):
        return [
            FieldSchema("sequence", self.seq_width, "int8"),
            FieldSchema("length", 1, "int32"),
            FieldSchema("annotation", self.annot_width, "int8"),
            FieldSchema("taxid", 1, "int32"),
        ]

    def split_entry(self, entry: str):
        key = b""
        seq_lines: list[str] = []
        annot_lines: list[str] = []
        taxid = 0
        in_seq = False
        entry_name = ""
        for line in entry.splitlines():
            tag = line[:2]
            if tag == "ID":
                entry_name = line[2:].split()[0] if line[2:].split() else ""
            elif tag == "AC" and not key:
                key = line[2:].strip().rstrip(";").split(";")[0].strip().encode()
            elif tag in ("DE", "GN", "KW", "OS"):
                annot_lines.append(line[2:].strip())
            elif tag == "OX":
                txt = line[2:].strip()
                if "NCBI_TaxID=" in txt:
                    num = txt.split("NCBI_TaxID=")[1].split(";")[0].split()[0]
                    taxid = int("".join(ch for ch in num if ch.isdigit()) or 0)
            elif tag == "SQ":
                in_seq = True
            elif in_seq and line.startswith("  "):
                seq_lines.append(line.replace(" ", ""))
            elif tag == "//":
                in_seq = False
        if not key:
            key = entry_name.encode()
        seq = "".join(seq_lines)
        return key, {
            "sequence": pad_bytes(seq, self.seq_width),
            "length": np.asarray([len(seq)], np.int32),
            "annotation": pad_bytes(" | ".join(annot_lines), self.annot_width),
            "taxid": np.asarray([taxid], np.int32),
        }

    def format_entry(self, key: bytes, row: dict[str, np.ndarray]) -> str:
        """Emit the FASTA form used to build BLAST databases (the paper's
        `formatdb` input), not the full .dat round trip."""
        seq = unpad_bytes(row["sequence"]).decode()
        lines = [seq[i:i + 60] for i in range(0, len(seq), 60)] or [""]
        return f">{key.decode()}\n" + "\n".join(lines) + "\n"
