"""MetaGeneAnnotator (MGA) output parser.

MGA emits, per input contig, a `# <contig>` header followed by predicted
gene rows: `gene_id start end strand frame complete score ...`. One store
row per predicted gene, keyed contig|gene_id.
"""
from __future__ import annotations

import numpy as np

from .._schema_compat import FieldSchema
from ..plugins import FileParser


class MgaParser(FileParser):
    format_name = "mga"

    def entry_pattern(self):
        return (r"^# ", r"(?=^# )|\Z")

    def schema(self):
        return [
            FieldSchema("coords", 3, "int32"),   # start, end, strand(+1/-1)
            FieldSchema("score", 1, "float32"),
        ]

    def split_entry(self, entry: str):
        # one *contig block*; framework-level parse_text flattens genes
        raise NotImplementedError("use parse_text (block format)")

    def parse_text(self, text: str):
        keys, coords, scores = [], [], []
        contig = ""
        for line in text.splitlines():
            if line.startswith("# gc") or line.startswith("# self"):
                continue  # MGA stats headers
            if line.startswith("#"):
                contig = line[1:].strip().split()[0]
                continue
            cols = line.split()
            if len(cols) < 7:
                continue
            gene_id, start, end, strand = cols[0], int(cols[1]), int(cols[2]), cols[3]
            score = float(cols[6])
            keys.append(f"{contig}|{gene_id}".encode())
            coords.append(np.asarray([start, end, 1 if strand == "+" else -1],
                                     np.int32))
            scores.append(np.asarray([score], np.float32))
        if not keys:
            return [], {"coords": np.zeros((0, 3), np.int32),
                        "score": np.zeros((0, 1), np.float32)}
        return keys, {"coords": np.stack(coords), "score": np.stack(scores)}

    def format_entry(self, key: bytes, row: dict[str, np.ndarray]) -> str:
        contig, gene = key.decode().split("|")
        s, e, st = (int(v) for v in row["coords"])
        return (f"# {contig}\n{gene}\t{s}\t{e}\t{'+' if st > 0 else '-'}\t0\t11"
                f"\t{float(row['score'][0]):.2f}\n")
