"""MetaGeneAnnotator (MGA) output parser.

MGA emits, per input contig, a `# <contig>` header followed by predicted
gene rows: `gene_id start end strand frame complete score ...`. One store
row per predicted gene, keyed contig|gene_id.
"""
from __future__ import annotations

import numpy as np

from .._schema_compat import FieldSchema
from ..plugins import FileParser


class MgaParser(FileParser):
    format_name = "mga"

    def entry_pattern(self):
        return (r"^# ", r"(?=^# )|\Z")

    def schema(self):
        return [
            FieldSchema("coords", 3, "int32"),   # start, end, strand(+1/-1)
            FieldSchema("score", 1, "float32"),
        ]

    def split_entry(self, entry: str):
        # one *contig block*; framework-level iter_records flattens genes
        raise NotImplementedError("use iter_records / parse_text (block format)")

    def iter_records(self, chunks):
        # block format: a line-granular state machine carrying the active
        # contig across chunk boundaries (and across `# gc`/`# self` stats
        # lines, which must not reset it). parse_text rides on this, so
        # chunked and whole-file parses share one code path.
        contig = ""
        tail = ""
        for chunk in chunks:
            if not chunk:
                continue
            parts = (tail + chunk).split("\n")
            tail = parts.pop()
            for line in parts:
                rec, contig = self._line_record(line, contig)
                if rec is not None:
                    yield rec
        if tail:
            rec, contig = self._line_record(tail, contig)
            if rec is not None:
                yield rec

    def _line_record(self, line: str, contig: str):
        """One MGA output line -> (record | None, active contig)."""
        if line.startswith("# gc") or line.startswith("# self"):
            return None, contig  # MGA stats headers
        if line.startswith("#"):
            return None, line[1:].strip().split()[0]
        cols = line.split()
        if len(cols) < 7:
            return None, contig
        gene_id, start, end, strand = cols[0], int(cols[1]), int(cols[2]), cols[3]
        score = float(cols[6])
        key = f"{contig}|{gene_id}".encode()
        row = {"coords": np.asarray([start, end, 1 if strand == "+" else -1],
                                    np.int32),
               "score": np.asarray([score], np.float32)}
        return (key, row), contig

    def format_entry(self, key: bytes, row: dict[str, np.ndarray]) -> str:
        contig, gene = key.decode().split("|")
        s, e, st = (int(v) for v in row["coords"])
        return (f"# {contig}\n{gene}\t{s}\t{e}\t{'+' if st > 0 else '-'}\t0\t11"
                f"\t{float(row['score'][0]):.2f}\n")
