"""Format parsers for the Meta-pipe file formats (paper §IV.B): FASTA,
UniProtKB flat-file, BLAST tabular output, and MGA output. One parser per
format, reused by every tool plugin that touches the format."""
from .fasta import FastaParser
from .uniprot import UniProtParser
from .blast_tab import BlastTabParser
from .mga import MgaParser

__all__ = ["FastaParser", "UniProtParser", "BlastTabParser", "MgaParser"]
