"""Fixed-width text <-> numeric row helpers shared by the format parsers."""
from __future__ import annotations

import numpy as np


def pad_bytes(s: str | bytes, width: int) -> np.ndarray:
    """Encode text into a fixed-width int8 row (zero padded, truncated)."""
    b = s.encode() if isinstance(s, str) else bytes(s)
    out = np.zeros(width, np.int8)
    b = b[:width]
    out[: len(b)] = np.frombuffer(b, np.uint8).astype(np.int8)
    return out


def unpad_bytes(row: np.ndarray) -> bytes:
    b = row.astype(np.uint8).tobytes()
    return b.rstrip(b"\x00")


def f32_row(*vals: float) -> np.ndarray:
    return np.asarray(vals, np.float32)


def i32_row(*vals: int) -> np.ndarray:
    return np.asarray(vals, np.int32)
