"""BLAST tabular (-outfmt 6) parser: 12 columns, one hit per line.

qseqid sseqid pident length mismatch gapopen qstart qend sstart send evalue
bitscore. The e-value column is the aggregate the incremental merger must
fix (paper §III.A / §IV.B): E = K*m*n*exp(-lambda*S) scales linearly with
database size m, so hits computed against an increment or an old release
are rescaled by m_new/m_old at merge time.
"""
from __future__ import annotations

import numpy as np

from .._schema_compat import FieldSchema
from ..plugins import FileParser

_INT_COLS = ["length", "mismatch", "gapopen", "qstart", "qend", "sstart", "send"]


class BlastTabParser(FileParser):
    format_name = "blast_tab"

    def entry_pattern(self):
        return (r"^[^\s#]", r"$")

    def schema(self):
        return [
            FieldSchema("ints", len(_INT_COLS), "int32"),    # 7 int columns
            FieldSchema("pident", 1, "float32"),
            FieldSchema("log10_evalue", 1, "float32"),
            FieldSchema("bitscore", 1, "float32"),
        ]

    def split_entry(self, entry: str):
        cols = entry.strip().split("\t")
        if len(cols) != 12:
            cols = entry.strip().split()
        (qseqid, sseqid, pident, length, mismatch, gapopen, qstart, qend,
         sstart, send, evalue, bitscore) = cols
        key = f"{qseqid}|{sseqid}|{qstart}|{sstart}".encode()
        ev = float(evalue)
        log_ev = np.float32(np.log10(ev)) if ev > 0 else np.float32(-400.0)
        return key, {
            "ints": np.asarray([int(length), int(mismatch), int(gapopen),
                                int(qstart), int(qend), int(sstart), int(send)],
                               np.int32),
            "pident": np.asarray([float(pident)], np.float32),
            "log10_evalue": np.asarray([log_ev], np.float32),
            "bitscore": np.asarray([float(bitscore)], np.float32),
        }

    def format_entry(self, key: bytes, row: dict[str, np.ndarray]) -> str:
        qseqid, sseqid, _q, _s = key.decode().split("|")
        ints = row["ints"].astype(int)
        ev = 10.0 ** float(row["log10_evalue"][0])
        return ("\t".join([
            qseqid, sseqid, f"{float(row['pident'][0]):.3f}",
            *[str(int(v)) for v in ints],
            f"{ev:.2e}", f"{float(row['bitscore'][0]):.1f}",
        ]) + "\n")
