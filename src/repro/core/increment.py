"""Incremental-compute engine (paper §III.A + §III.G).

The workflow-manager-facing interface is two calls (paper: `generateFiles` /
`mergeFiles`): before running a tool, generate its input/meta-database files
(full version or increment, cache-aware); after running it, merge the
partial output into the previous result. The tool itself is UNMODIFIED — it
just reads and writes files.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable

from .cache import VersionCache, descriptor
from .plugins import PluginRegistry, ToolPlugin
from .store import Increment, VersionedStore, KIND_DELETED, KIND_NEW, KIND_UPDATED
from .tables import SystemTables


@dataclasses.dataclass
class GeneratedInput:
    path: str
    mode: str                 # "full" | "increment" | "cached"
    t0: int
    t1: int
    n_entries: int
    context: dict             # merge context (db sizes, deleted/updated keys)


class GeStore:
    """Facade owning stores + cache + system tables + plugin registry."""

    def __init__(self, root: str, registry: PluginRegistry):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.tables = SystemTables(os.path.join(root, "sys"))
        self.cache = VersionCache(os.path.join(root, "cache"), self.tables)
        self.registry = registry
        self.stores: dict[str, VersionedStore] = {}

    # -- data-feeder interface (Fig. 3 left) --------------------------------
    def add_release(self, store_name: str, ts: int, text: str, *,
                    parser_name: str, label: str = "",
                    full_release: bool = True):
        parser = self.registry.parsers[parser_name]
        keys, table = parser.parse_text(text)
        store = self.stores.get(store_name)
        if store is None:
            store = VersionedStore(store_name, parser.schema(),
                                   capacity=max(16, len(keys)))
            self.stores[store_name] = store
        info = store.update(ts, keys, table, label=label,
                            full_release=full_release)
        self.tables.record_update(store_name, info)
        return info

    # -- workflow-manager interface (Fig. 3 right) ---------------------------
    def generate_files(self, tool: str, store_name: str, *, t_version: int,
                       t_last: int | None = None,
                       key_filter: str | None = None,
                       run_id: str = "") -> GeneratedInput:
        """paper `generateFiles`: full version if t_last is None, else the
        increment (t_last, t_version]."""
        plugin = self.registry.tools[tool]
        parser = self.registry.parsers[plugin.generator.parser]
        store = self.stores[store_name]
        mode = "full" if t_last is None else "increment"
        desc = descriptor(store_name, -1 if t_last is None else t_last,
                          t_version, filter_expr=key_filter or "",
                          plugin=tool, params=plugin.params)
        context = self._merge_context(store, plugin, t_last, t_version)

        cached = self.cache.get(desc)
        if cached is not None:
            n = sum(1 for _ in open(cached)) if os.path.exists(cached) else 0
            return GeneratedInput(cached, "cached", t_last or -1, t_version,
                                  n, context)

        if mode == "full":
            view = store.get_version(t_version,
                                     fields=list(plugin.generator.output_fields),
                                     key_filter=key_filter)
            text = parser.format_view(view)
            n_entries = len(view)
        else:
            inc = store.get_increment(
                t_last, t_version,
                significant_fields=list(plugin.generator.significant_fields),
                fields=list(plugin.generator.output_fields))
            live = inc.kind != KIND_DELETED
            sub = Increment(inc.t0, inc.t1,
                            [k for k, m in zip(inc.keys, live) if m],
                            inc.row_idx[live], inc.kind[live],
                            {f: v[live] for f, v in inc.values.items()})
            if key_filter is not None:
                import re
                pat = re.compile(key_filter.encode())
                m = [bool(pat.search(k)) for k in sub.keys]
                import numpy as np
                m = np.asarray(m, bool) if m else np.zeros(0, bool)
                sub = Increment(sub.t0, sub.t1,
                                [k for k, mm in zip(sub.keys, m) if mm],
                                sub.row_idx[m], sub.kind[m],
                                {f: v[m] for f, v in sub.values.items()})
            text = parser.format_view(sub)
            n_entries = len(sub)

        path = self.cache.put(desc, lambda p: open(p, "w").write(text),
                              plugin=tool, suffix=".txt")
        return GeneratedInput(path, mode, t_last or -1, t_version, n_entries,
                              context)

    def merge_files(self, tool: str, previous: str, partial: str, *,
                    context: dict) -> str:
        """paper `mergeFiles`."""
        plugin = self.registry.tools[tool]
        if plugin.merger is None:
            return previous + partial
        return plugin.merger.merge(previous, partial, context=context)

    # -- provenance-recorded tool execution ----------------------------------
    def run_tool(self, tool: str, store_name: str,
                 tool_fn: Callable[[str], str], *, t_version: int,
                 t_last: int | None = None, previous_output: str = "",
                 key_filter: str | None = None) -> tuple[str, GeneratedInput]:
        """Generate inputs -> run the unmodified tool -> merge outputs,
        recording provenance in the `runs` table."""
        run_id = f"{tool}-{store_name}-{t_version}-{time.time_ns()}"
        gen = self.generate_files(tool, store_name, t_version=t_version,
                                  t_last=t_last, key_filter=key_filter,
                                  run_id=run_id)
        self.tables.start_run(run_id, tool, [gen.path],
                              {"t_version": t_version, "t_last": t_last,
                               "mode": gen.mode})
        partial = tool_fn(gen.path)
        if t_last is None:
            merged = partial
        else:
            merged = self.merge_files(tool, previous_output, partial,
                                      context=gen.context)
        self.tables.finish_run(run_id, [])
        return merged, gen

    # -- helpers ---------------------------------------------------------------
    def _merge_context(self, store: VersionedStore, plugin: ToolPlugin,
                       t_last: int | None, t_version: int) -> dict:
        ctx: dict = dict(plugin.params)   # tool knobs (e.g. max_hits_per_query)
        if t_last is None:
            return ctx
        inc = store.get_increment(
            t_last, t_version,
            significant_fields=list(plugin.generator.significant_fields),
            fields=[])
        ctx["deleted_keys"] = [k for k, kd in zip(inc.keys, inc.kind)
                               if kd == KIND_DELETED]
        ctx["updated_keys"] = [k for k, kd in zip(inc.keys, inc.kind)
                               if kd == KIND_UPDATED]
        ctx["new_keys"] = [k for k, kd in zip(inc.keys, inc.kind)
                           if kd == KIND_NEW]
        # database-size context for e-value style corrections
        if "length" in store.fields:
            old = store.get_version(t_last, fields=["length"])
            new = store.get_version(t_version, fields=["length"])
            ctx["db_size_old"] = int(old.values["length"].sum())
            ctx["db_size_new"] = int(new.values["length"].sum())
        return ctx
