"""Incremental-compute engine (paper §III.A + §III.G).

The workflow-manager-facing interface is two calls (paper: `generateFiles` /
`mergeFiles`): before running a tool, generate its input/meta-database files
(full version or increment, cache-aware); after running it, merge the
partial output into the previous result. The tool itself is UNMODIFIED — it
just reads and writes files.

`generate_files_batch` is the multi-version entry point: requests are
grouped per store and materialized through the store's fused-superlog
batched scan (store.get_versions / get_increments), so N concurrent
version materializations cost one scan per store-group instead of N x F
kernel launches. `generate_files` is its single-request wrapper.
"""
from __future__ import annotations

import dataclasses
import os
import re
import time
from typing import Callable, Mapping, Sequence

import numpy as np

from .cache import VersionCache, descriptor
from .plugins import PluginRegistry, ToolPlugin
from .shard import (SHARD_MANIFEST_NAME, ShardedStore, is_sharded_dir,
                    open_any_store)
from .store import (FieldSchema, Increment, VersionedStore, KIND_DELETED,
                    KIND_NEW, KIND_UPDATED)
from .tables import SystemTables


@dataclasses.dataclass
class GeneratedInput:
    path: str
    mode: str                 # "full" | "increment" | "cached"
    t0: int
    t1: int
    n_entries: int
    context: dict             # merge context (db sizes, deleted/updated keys)


def _live_filtered(inc: Increment, key_filter: str | None) -> Increment:
    """Drop tombstoned entries (they are merge context, not file content),
    then apply the entry-selection regex."""
    live = inc.kind != KIND_DELETED
    sub = Increment(inc.t0, inc.t1,
                    [k for k, m in zip(inc.keys, live) if m],
                    inc.row_idx[live], inc.kind[live],
                    {f: v[live] for f, v in inc.values.items()})
    if key_filter is not None:
        pat = re.compile(key_filter.encode())
        m = [bool(pat.search(k)) for k in sub.keys]
        m = np.asarray(m, bool) if m else np.zeros(0, bool)
        sub = Increment(sub.t0, sub.t1,
                        [k for k, mm in zip(sub.keys, m) if mm],
                        sub.row_idx[m], sub.kind[m],
                        {f: v[m] for f, v in sub.values.items()})
    return sub


class GeStore:
    """Facade owning stores + cache + system tables + plugin registry.

    Stores persist under ``<root>/stores/<name>`` in the segmented layout
    (core/segments.py): ``flush()`` saves them incrementally, and the
    constructor reopens every persisted store with a lazy load — so a
    GeStore over hundreds of releases starts in O(manifests), not O(cells).
    """

    def __init__(self, root: str, registry: PluginRegistry, *,
                 autoload: bool = True, cache_max_bytes: int | None = None):
        """Args:
          root: GeStore home (system tables, cache, persisted stores).
          registry: parser/tool plugins.
          autoload: reopen stores previously persisted by ``flush()``
            (lazy — segment files are read only when queries need them).
          cache_max_bytes: byte budget for the generated-file cache —
            every ``cache.put`` LRU-evicts down to it (None = unbounded,
            the paper's cron-job retention model).
        """
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.tables = SystemTables(os.path.join(root, "sys"))
        self.cache = VersionCache(os.path.join(root, "cache"), self.tables,
                                  max_bytes=cache_max_bytes)
        self.registry = registry
        self.stores: dict[str, VersionedStore | ShardedStore] = {}
        self.load_errors: dict[str, Exception] = {}
        self.stores_root = os.path.join(root, "stores")
        os.makedirs(self.stores_root, exist_ok=True)
        if autoload:
            self._open_persisted()

    # -- persistence (segmented store layout) --------------------------------
    def _open_persisted(self) -> None:
        """Autoload every persisted store. A store that fails to load
        (corrupt segments, unsupported schema, ...) is skipped and its
        error recorded in ``load_errors`` (keyed by directory name) — one
        bad directory must not brick access to every other store under the
        root. ``open_store`` retries the load on direct access, surfacing
        the store's actual error."""
        from .segments import MANIFEST_NAME
        for d in sorted(os.listdir(self.stores_root)):
            p = os.path.join(self.stores_root, d)
            if not os.path.isdir(p):
                continue
            if (is_sharded_dir(p)
                    or os.path.exists(os.path.join(p, MANIFEST_NAME))
                    or os.path.exists(os.path.join(p, "meta.json"))):
                try:
                    st = open_any_store(p, lazy=True)
                except Exception as e:  # noqa: BLE001 — recorded, re-raised
                    self.load_errors[d] = e
                    continue
                self.stores[st.name] = st

    def store_path(self, name: str) -> str:
        from .segments import store_dir_name
        return os.path.join(self.stores_root, store_dir_name(name))

    def _persisted(self, name: str) -> bool:
        """Whether a store directory (either flavor) exists for ``name``."""
        from .segments import MANIFEST_NAME
        p = self.store_path(name)
        return (is_sharded_dir(p)
                or os.path.exists(os.path.join(p, MANIFEST_NAME))
                or os.path.exists(os.path.join(p, "meta.json")))

    def open_store(self, name: str) -> VersionedStore | ShardedStore:
        """The named store (sharded or not), transparently reopening it
        (lazy) from ``store_path(name)`` when it is not in memory — e.g.
        after a tiered-memory spill removed it from ``stores``.

        Raises:
          KeyError: the store neither exists in memory nor on disk.
        """
        st = self.stores.get(name)
        if st is None:
            if not self._persisted(name):
                raise KeyError(name)
            st = open_any_store(self.store_path(name), lazy=True)
            self.stores[name] = st
        return st

    def create_store(self, name: str, schema: Sequence[FieldSchema], *,
                     shards: int = 1,
                     capacity: int = 1024) -> VersionedStore | ShardedStore:
        """Create (and register) a new store; ``shards > 1`` makes it a
        hash-partitioned ``ShardedStore`` — transparent to every query and
        persistence path above.

        Raises:
          ValueError: a store with this name already exists (in memory or
            persisted under the root).
        """
        if name in self.stores or self._persisted(name):
            raise ValueError(f"store {name} already exists")
        if shards > 1:
            st = ShardedStore(name, schema, n_shards=shards,
                              capacity=capacity)
        else:
            st = VersionedStore(name, schema, capacity=capacity)
        self.stores[name] = st
        return st

    def flush(self, store_name: str | None = None) -> dict[str, dict]:
        """Persist stores to ``<root>/stores/<name>`` (incremental: only
        segments newer than each manifest's watermark are written).

        Args:
          store_name: one store (reopened from disk if a tiered-memory
            spill removed it from ``stores``), or None for every in-memory
            store (spilled stores were saved by the spill itself, so there
            is nothing of theirs left to flush).

        Returns:
          {store name: save stats} (see ``VersionedStore.save``).

        Raises:
          KeyError: unknown ``store_name``.
        """
        names = [store_name] if store_name is not None else list(self.stores)
        out: dict[str, dict] = {}
        for name in names:
            path = self.store_path(name)
            store = self.open_store(name)
            stats = store.save(path)
            out[name] = stats
            # index the manifest in the `files` table: segment bytes are
            # visible to ops/eviction accounting but never cache-evictable
            from .segments import MANIFEST_NAME
            manifest = (SHARD_MANIFEST_NAME if isinstance(store, ShardedStore)
                        else MANIFEST_NAME)
            self.tables.record_file(f"store-segments|{name}",
                                    os.path.join(path, manifest),
                                    "store-segment", True,
                                    nbytes=stats["disk_bytes"])
        return out

    # -- data-feeder interface (Fig. 3 left) --------------------------------
    def add_release(self, store_name: str, ts: int, text: str, *,
                    parser_name: str, label: str = "",
                    full_release: bool = True, shards: int = 1):
        """Parse and ingest one release into a store (created on first use).

        Args:
          store_name: target store (a new store is created with the
            parser's schema when absent).
          ts: release timestamp (strictly greater than the store's last).
          text: raw release file content for ``parser_name``.
          label: human-readable release label.
          full_release: paper semantics — keys absent from this release
            are tombstoned; False = patch semantics.
          shards: partition count used ONLY when the store is created by
            this call (>1 = hash-partitioned ShardedStore); an existing
            store keeps its own layout.

        Returns:
          VersionInfo with new/updated/deleted counts.

        Raises:
          KeyError: unknown parser. ValueError: non-monotonic ``ts``.
        """
        parser = self.registry.parsers[parser_name]
        keys, table = parser.parse_text(text)
        try:
            store = self.open_store(store_name)  # in memory, or spilled
        except KeyError:
            store = self.create_store(store_name, parser.schema(),
                                      shards=shards,
                                      capacity=max(16, len(keys)))
        info = store.update(ts, keys, table, label=label,
                            full_release=full_release)
        self.tables.record_update(store_name, info)
        return info

    def ingest_journal_path(self, store_name: str) -> str:
        """Sidecar ingest-journal directory for a store (under the root,
        next to — never inside — the store's segment directory)."""
        from .segments import store_dir_name
        return os.path.join(self.root, "ingest", store_dir_name(store_name))

    def add_release_stream(self, store_name: str, ts: int, source, *,
                           parser_name: str, label: str = "",
                           full_release: bool = True, shards: int = 1,
                           config=None, resumable: bool = True,
                           pressure_fn: Callable[[], float] | None = None):
        """Streaming sibling of ``add_release``: ingest a release from a
        file path / chunk iterable / ``callable(start) -> chunks`` without
        ever holding it in host memory, with shard-parallel update waves
        and (by default) a crash-resumable chunk journal under the root.

        After a crash, call again with the same arguments — journaled
        chunks replay and parsing resumes mid-file (core/ingest.py has the
        protocol). The store is flushed to its directory as part of the
        ingest (pre-release and post-commit), so a separate ``flush()`` is
        not needed for durability.

        Args:
          source: release file path (resumable via seek), iterable of text
            chunks, or ``callable(start_offset) -> chunk iterable``.
          config: ``IngestConfig`` pipeline knobs (None = defaults).
          resumable: journal parsed chunks for crash-resume. False skips
            the journal AND the pre/post store saves (purely in-memory
            ingest; call ``flush()`` yourself).
          pressure_fn: mutation backpressure source, e.g. a serving
            ``TieredStorePool.pressure`` (honoured when
            ``config.max_pressure`` is set).

        Returns:
          ``IngestReport`` (``.info`` is the release's VersionInfo;
          ``.already_committed`` when a resume found it already applied).
        """
        from .ingest import ingest_release
        parser = self.registry.parsers[parser_name]
        try:
            store = self.open_store(store_name)
        except KeyError:
            store = self.create_store(store_name, parser.schema(),
                                      shards=shards, capacity=1024)
        rep = ingest_release(
            store, source, parser, ts, label=label,
            full_release=full_release, config=config,
            journal_dir=(self.ingest_journal_path(store_name)
                         if resumable else None),
            store_dir=self.store_path(store_name) if resumable else None,
            pressure_fn=pressure_fn)
        if rep.info is not None:
            self.tables.record_update(store_name, rep.info)
        return rep

    # -- workflow-manager interface (Fig. 3 right) ---------------------------
    def generate_files(self, tool: str, store_name: str, *, t_version: int,
                       t_last: int | None = None,
                       key_filter: str | None = None,
                       run_id: str = "") -> GeneratedInput:
        """paper `generateFiles`: full version if t_last is None, else the
        increment (t_last, t_version]. Thin wrapper over the batched path."""
        return self.generate_files_batch([
            {"tool": tool, "store": store_name, "t_version": t_version,
             "t_last": t_last, "key_filter": key_filter, "run_id": run_id},
        ])[0]

    def generate_files_batch(self, requests: Sequence[Mapping]) -> list[GeneratedInput]:
        """Batched `generateFiles`. Each request is a mapping with keys
        ``tool``, ``store``, ``t_version`` and optional ``t_last`` /
        ``key_filter`` / ``run_id``. Returns GeneratedInputs aligned with
        the input order. All increments of a store group into ONE
        get_increments call; all uncached full versions group into ONE
        get_versions call per (store, fields, filter) — each a single
        batched superlog scan."""
        reqs = []
        cached0: list[str | None] = []
        for raw in requests:
            r = dict(raw)
            plugin = self.registry.tools[r["tool"]]
            parser = self.registry.parsers[plugin.generator.parser]
            store = self.open_store(r["store"])
            t_last = r.get("t_last")
            desc = descriptor(r["store"], -1 if t_last is None else t_last,
                              r["t_version"], filter_expr=r.get("key_filter") or "",
                              plugin=r["tool"], params=plugin.params)
            reqs.append((r, plugin, parser, store, desc))
            cached0.append(self.cache.get(desc))

        # -- increments: always materialized (the merge context needs the
        # changed-key sets even when the generated file is cached), one
        # batched scan per (store, significant, output-fields) group.
        # Cache hits only need keys/kinds, so they group with fields=().
        inc_groups: dict[tuple, list[int]] = {}
        for i, (r, plugin, _, _, _) in enumerate(reqs):
            if r.get("t_last") is not None:
                out = () if cached0[i] is not None else tuple(
                    plugin.generator.output_fields)
                key = (r["store"], tuple(plugin.generator.significant_fields),
                       out)
                inc_groups.setdefault(key, []).append(i)
        incs: dict[int, Increment] = {}
        for (sname, sig, out_fields), idxs in inc_groups.items():
            store = self.open_store(sname)
            pairs = [(reqs[i][0]["t_last"], reqs[i][0]["t_version"])
                     for i in idxs]
            uniq = list(dict.fromkeys(pairs))
            got = dict(zip(uniq, store.get_increments(
                uniq, significant_fields=list(sig), fields=list(out_fields))))
            for i, p in zip(idxs, pairs):
                incs[i] = got[p]

        # -- db-size context (e-value style corrections): batched per store.
        size_ts: dict[str, set] = {}
        for i in incs:
            r, _, _, store, _ = reqs[i]
            if "length" in store.fields:
                size_ts.setdefault(r["store"], set()).update(
                    (r["t_last"], r["t_version"]))
        sizes: dict[tuple[str, int], int] = {}
        for sname, tss in size_ts.items():
            store, tss = self.open_store(sname), sorted(tss)
            for t, view in zip(tss, store.get_versions(tss, fields=["length"])):
                # keyed by store.name: _merge_context reads it back that way
                sizes[(store.name, t)] = int(view.values["length"].sum())

        # -- cache check; collect the uncached full versions per group.
        results: list[GeneratedInput | None] = [None] * len(reqs)
        contexts: list[dict] = [None] * len(reqs)
        full_groups: dict[tuple, list[int]] = {}
        for i, (r, plugin, parser, store, desc) in enumerate(reqs):
            contexts[i] = self._merge_context(store, plugin, r.get("t_last"),
                                              r["t_version"], inc=incs.get(i),
                                              sizes=sizes)
            cached = cached0[i]
            if cached is not None:
                results[i] = _cached_result(cached, r, contexts[i])
            elif r.get("t_last") is None:
                key = (r["store"], tuple(plugin.generator.output_fields),
                       r.get("key_filter"))
                full_groups.setdefault(key, []).append(i)

        # -- batched full-version materialization.
        views: dict[int, object] = {}
        for (sname, out_fields, key_filter), idxs in full_groups.items():
            store = self.open_store(sname)
            tss = [reqs[i][0]["t_version"] for i in idxs]
            uniq = list(dict.fromkeys(tss))
            got = dict(zip(uniq, store.get_versions(
                uniq, fields=list(out_fields), key_filter=key_filter)))
            for i, t in zip(idxs, tss):
                views[i] = got[t]

        # -- format + cache-put everything still pending.
        for i, (r, plugin, parser, store, desc) in enumerate(reqs):
            if results[i] is not None:
                continue
            cached = self.cache.get(desc)
            if cached is not None:  # a duplicate earlier in this batch wrote it
                results[i] = _cached_result(cached, r, contexts[i])
                continue
            if r.get("t_last") is None:
                view = views[i]
                text, n_entries, mode = parser.format_view(view), len(view), "full"
            else:
                sub = _live_filtered(incs[i], r.get("key_filter"))
                text, n_entries, mode = parser.format_view(sub), len(sub), "increment"
            path = self.cache.put(desc, lambda p, text=text: _write_text(p, text),
                                  plugin=r["tool"], suffix=".txt")
            results[i] = GeneratedInput(path, mode, _t0(r), r["t_version"],
                                        n_entries, contexts[i])
        return results

    def merge_files(self, tool: str, previous: str, partial: str, *,
                    context: dict) -> str:
        """paper `mergeFiles`: merge a partial (incremental) tool output
        into the previous full output via the tool's OutputMerger.

        Args:
          tool: registered tool name; previous/partial: tool output text;
          context: the GeneratedInput.context of the incremental run
            (changed-key sets, db sizes).

        Returns:
          The merged full output (plain concatenation when the tool has
          no merger).

        Raises:
          KeyError: unknown tool.
        """
        plugin = self.registry.tools[tool]
        if plugin.merger is None:
            return previous + partial
        return plugin.merger.merge(previous, partial, context=context)

    # -- provenance-recorded tool execution ----------------------------------
    def run_tool(self, tool: str, store_name: str,
                 tool_fn: Callable[[str], str], *, t_version: int,
                 t_last: int | None = None, previous_output: str = "",
                 key_filter: str | None = None) -> tuple[str, GeneratedInput]:
        """Generate inputs -> run the unmodified tool -> merge outputs,
        recording provenance in the `runs` table."""
        run_id = f"{tool}-{store_name}-{t_version}-{time.time_ns()}"
        gen = self.generate_files(tool, store_name, t_version=t_version,
                                  t_last=t_last, key_filter=key_filter,
                                  run_id=run_id)
        self.tables.start_run(run_id, tool, [gen.path],
                              {"t_version": t_version, "t_last": t_last,
                               "mode": gen.mode})
        partial = tool_fn(gen.path)
        if t_last is None:
            merged = partial
        else:
            merged = self.merge_files(tool, previous_output, partial,
                                      context=gen.context)
        self.tables.finish_run(run_id, [])
        return merged, gen

    # -- helpers ---------------------------------------------------------------
    def _merge_context(self, store: VersionedStore, plugin: ToolPlugin,
                       t_last: int | None, t_version: int, *,
                       inc: Increment | None,
                       sizes: Mapping[tuple[str, int], int] | None = None) -> dict:
        ctx: dict = dict(plugin.params)   # tool knobs (e.g. max_hits_per_query)
        if t_last is None:
            return ctx
        if inc is None:  # direct callers outside the batch path
            inc = store.get_increment(
                t_last, t_version,
                significant_fields=list(plugin.generator.significant_fields),
                fields=[])
        ctx["deleted_keys"] = [k for k, kd in zip(inc.keys, inc.kind)
                               if kd == KIND_DELETED]
        ctx["updated_keys"] = [k for k, kd in zip(inc.keys, inc.kind)
                               if kd == KIND_UPDATED]
        ctx["new_keys"] = [k for k, kd in zip(inc.keys, inc.kind)
                           if kd == KIND_NEW]
        # database-size context for e-value style corrections
        if "length" in store.fields:
            sizes = sizes or {}
            for label, t in (("db_size_old", t_last), ("db_size_new", t_version)):
                val = sizes.get((store.name, t))
                if val is None:
                    val = int(store.get_version(t, fields=["length"])
                              .values["length"].sum())
                ctx[label] = val
        return ctx


def _t0(r: Mapping) -> int:
    """Increment start for a request; full versions report -1 (a t_last of
    0 is a valid timestamp and must not collapse to -1)."""
    return -1 if r.get("t_last") is None else r["t_last"]


def _cached_result(path: str, r: Mapping, context: dict) -> GeneratedInput:
    with open(path) as f:
        n = sum(1 for _ in f)
    return GeneratedInput(path, "cached", _t0(r), r["t_version"], n, context)


def _write_text(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
