"""Output mergers (paper §III.F.3, §IV.B).

The BLAST merger is the paper's worked example: e-values are normalized by
total database size, so results computed against an increment (or against an
older release) carry wrong e-values. Merge = rescale both sides to the new
database size, drop hits whose subject was deleted, union, and keep the best
hits per query. E = K*m*n*exp(-lambda*S) -> E' = E * m_new/m_old, i.e.
log10 E' = log10 E + log10(m_new/m_old) (cf. Turcu et al., the paper's [23]).
"""
from __future__ import annotations

from collections import defaultdict

from .plugins import OutputMerger
from .parsers.blast_tab import BlastTabParser


class AppendMerger(OutputMerger):
    """For tools whose outputs are record-local (e.g. MGA gene calls):
    incremental output rows simply replace/extend previous rows."""

    def merge(self, previous: str, partial: str, *, context: dict) -> str:
        deleted = set(context.get("deleted_keys", ()))
        updated_first = {ln.split("\t", 1)[0].split("|", 1)[0]
                         for ln in partial.splitlines()
                         if ln and not ln.startswith("#")}
        keep = []
        for ln in previous.splitlines():
            if not ln or ln.startswith("#"):
                continue
            rec = ln.split("\t", 1)[0].split("|", 1)[0]
            if rec in deleted or rec in updated_first:
                continue
            keep.append(ln)
        out = keep + [ln for ln in partial.splitlines()
                      if ln and not ln.startswith("#")]
        return "\n".join(out) + ("\n" if out else "")


class BlastEvalueMerger(OutputMerger):
    """Merge incremental BLAST tabular output with previous results.

    context:
      db_size_old / db_size_new: total residues in old/new database
      deleted_keys: subject ids removed from the database
      updated_keys: subject ids recomputed in the increment (old hits against
        them are stale and dropped; the partial output has the fresh hits)
      max_hits_per_query: keep best-k per query after merge
    """

    def __init__(self):
        self.parser = BlastTabParser()

    def merge(self, previous: str, partial: str, *, context: dict) -> str:
        import math
        m_old = float(context["db_size_old"])
        m_new = float(context["db_size_new"])
        scale = math.log10(m_new / m_old) if m_old > 0 else 0.0
        deleted = {k.decode() if isinstance(k, bytes) else k
                   for k in context.get("deleted_keys", ())}
        updated = {k.decode() if isinstance(k, bytes) else k
                   for k in context.get("updated_keys", ())}
        max_hits = int(context.get("max_hits_per_query", 25))

        per_query: dict[str, list[tuple[float, str]]] = defaultdict(list)

        def add_lines(text: str, rescale: float):
            for ln in text.splitlines():
                if not ln.strip() or ln.startswith("#"):
                    continue
                cols = ln.split("\t")
                q, s, ev = cols[0], cols[1], float(cols[10])
                if rescale and s in (deleted | updated):
                    continue  # stale hit: subject changed or removed
                log_ev = (math.log10(ev) if ev > 0 else -400.0) + \
                    (scale if rescale else 0.0)
                cols[10] = f"{10 ** log_ev:.2e}"
                per_query[q].append((log_ev, "\t".join(cols)))

        add_lines(previous, rescale=True)   # old hits -> rescale e-values
        add_lines(partial, rescale=False)   # fresh hits already at m_new
        out_lines = []
        for q in sorted(per_query):
            hits = sorted(per_query[q], key=lambda t: t[0])[:max_hits]
            out_lines.extend(h[1] for h in hits)
        return "\n".join(out_lines) + ("\n" if out_lines else "")
