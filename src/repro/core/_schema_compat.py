"""Re-export of FieldSchema for parser modules (avoids a circular import of
the full store module at parser-definition time)."""
from .store import FieldSchema  # noqa: F401
