"""Sharded meta-database engine: hash-partitioned stores with
scatter-gather materialization (paper §II.B/§V).

The paper scales GeStore by spreading meta-database rows across HBase
region servers so version generation parallelizes with the data. This
module is that scale-out axis for the JAX-native engine: a ``ShardedStore``
facade hash-partitions the entry keyspace over N independent
``VersionedStore`` shards while preserving the full store API, so every
layer above (increment engine, serving, tiered memory) runs unchanged.

Design invariants:

  * **Stable routing** — ``kernels/shard_route.route_keys`` maps a key to
    its shard as a pure function of the key bytes (width-stable hash, see
    that module). The routing version is pinned in the shard manifest; a
    store written under one hash is never extended by another.
  * **Global row order** — the facade allocates global row ids in first-seen
    key order, exactly as an unsharded store would, and every scatter-gather
    query merges per-shard selections back into that order
    (``merge_shard_rows``). Sharded and unsharded stores therefore return
    *byte-identical* ``get_versions`` / ``get_increments`` results for the
    same history — the property the equivalence tests pin down.
  * **Aligned histories** — every release touches every shard (a shard with
    no keys in a full release still tombstones its vanished rows), so all
    shards share the facade's timestamp sequence and per-shard incremental
    save watermarks advance together.
  * **Per-shard persistence** — ``save`` writes one segmented store
    directory per shard (each incremental on its own) under a single
    ``SHARD_MANIFEST.json`` commit point holding the global key order.
    Like the unsharded ``MANIFEST.json``, the shard manifest rewrites the
    key list and version history on every save — segment bytes are O(new
    cells) but the manifest is O(keys); an append-only key index (like
    SEGMENTS.jsonl) is the known next step for very large keyspaces.
  * **Partial residency** — individual shards can be spilled to disk
    (``spill_shard``) and are transparently (lazily) reloaded on next
    access; ``log_epoch`` is the sum of shard epochs plus a floorable base,
    so the serve-layer plan-cache contract (equal epoch => identical bytes)
    survives per-shard spills exactly as it does whole-store ones.
  * **Device-parallel execution is pure placement** — under a parallel
    ``core/placement.py`` plan the per-shard fused-superlog scans collapse
    into ONE stacked launch (one shard per device on a ``("shard",)``
    mesh), but the math per shard is exactly the serial loop's, so
    serial/stacked/mesh modes return byte-identical results across any
    device count — the equivalence suite pins this.
"""
from __future__ import annotations

import dataclasses
import json
import os
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.kernels import ops as kops
from repro.kernels.shard_route import (ROUTING_VERSION, merge_shard_rows,
                                       route_keys)

from . import store as store_mod
from .placement import PlacedSuperLog, ShardPlacement, plan_placement
from .store import (KIND_DELETED, KIND_UPDATED, FieldSchema,
                    Increment, Timestamp, VersionInfo, VersionView,
                    VersionedStore, _checked_cast, infer_field_schema)

SHARD_FORMAT = "gestore-shards-v1"
SHARD_MANIFEST_NAME = "SHARD_MANIFEST.json"


def shard_dir(path: str, i: int) -> str:
    """Directory of shard ``i`` under a sharded store directory."""
    return os.path.join(path, f"shard-{i:05d}")


def read_shard_manifest(root: str) -> dict | None:
    """Parsed SHARD_MANIFEST.json, or None when absent/unparseable."""
    p = os.path.join(root, SHARD_MANIFEST_NAME)
    if not os.path.exists(p):
        return None
    try:
        with open(p) as f:
            man = json.load(f)
    except (json.JSONDecodeError, OSError):
        return None
    return man if man.get("format") == SHARD_FORMAT else None


def _write_shard_manifest(root: str, man: dict) -> int:
    """Atomically commit the shard manifest; returns its byte size."""
    from .segments import _fsync_dir
    p = os.path.join(root, SHARD_MANIFEST_NAME)
    tmp = p + ".tmp"
    with open(tmp, "w") as f:
        json.dump(man, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, p)
    _fsync_dir(root)
    return os.path.getsize(p)


def is_sharded_dir(path: str) -> bool:
    return os.path.exists(os.path.join(path, SHARD_MANIFEST_NAME))


def open_any_store(path: str, *, lazy: bool = True):
    """Open a store directory regardless of flavor: a ShardedStore when a
    shard manifest is present, otherwise a plain VersionedStore."""
    if is_sharded_dir(path):
        return ShardedStore.load(path, lazy=lazy)
    return VersionedStore.load(path, lazy=lazy)


def _as_bytes(keys: Sequence) -> list[bytes]:
    return [k.encode() if isinstance(k, str) else bytes(k) for k in keys]


class ShardedStore:
    """Hash-partitioned meta-database over N independent VersionedStores.

    Drop-in for ``VersionedStore`` everywhere the engine touches stores:
    ``update``/``delete`` scatter a release across shards, ``get_versions``/
    ``get_increments`` fan a batched query out to per-shard fused-superlog
    scans and gather the results key-stably, ``save``/``load``/``compact``
    persist one segmented directory per shard under a shard manifest, and
    ``nbytes``/``drop_superlog``/``log_epoch``/``spill_shard`` plug into the
    tiered memory manager with per-shard granularity.
    """

    def __init__(self, name: str, schema: Sequence[FieldSchema], *,
                 n_shards: int = 4, capacity: int = 1024):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.name = name
        self.n_shards = int(n_shards)
        self.schema: dict[str, FieldSchema] = {}
        self.versions: list[VersionInfo] = []
        self.row_keys: list[bytes] = []
        self.key_to_row: dict[bytes, int] = {}
        self._shard_of: list[int] = []            # global row -> shard id
        self._global_rows: list[list[int]] = [[] for _ in range(n_shards)]
        self._global_rows_np: list[np.ndarray | None] = [None] * n_shards
        per_shard_cap = max(16, capacity // n_shards)
        self._shards: list[VersionedStore | None] = [
            VersionedStore(self._shard_name(i), schema,
                           capacity=per_shard_cap)
            for i in range(n_shards)]
        self._spilled_epochs: dict[int, int] = {}  # shard id -> epoch at spill
        self._disk_bytes: dict[int, int] = {}      # shard id -> last save size
        self._dir: str | None = None               # set by save()/load()
        self._epoch_base = 0
        self._saved_epoch: int | None = None       # log_epoch at last save()
        # device-parallel execution (core/placement.py): planned lazily on
        # first query; the cross-shard stacked superlog is cached keyed on
        # the per-shard epoch tuple (so it survives spill/reload cycles,
        # which freeze and floor the epoch without changing content)
        self._placement: ShardPlacement | None = None
        self._placed: PlacedSuperLog | None = None
        for fs in schema:
            self.schema[fs.name] = fs

    def _shard_name(self, i: int) -> str:
        return f"{self.name}#shard{i:05d}"

    # -- epoch contract (mirrors VersionedStore.log_epoch) --------------------
    @property
    def log_epoch(self) -> int:
        """Monotone over every mutation of any shard: the sum of shard
        epochs (spilled shards contribute their epoch at spill time — the
        on-disk content is frozen, so the contribution is too) plus a
        base the tiered pool can floor after whole-store spills."""
        total = self._epoch_base
        for i, sh in enumerate(self._shards):
            total += (self._spilled_epochs[i] if sh is None
                      else sh.log_epoch)
        return total

    @property
    def _log_epoch(self) -> int:  # TieredStorePool floors through this name
        return self.log_epoch

    @_log_epoch.setter
    def _log_epoch(self, value: int) -> None:
        self._epoch_base += int(value) - self.log_epoch

    # -- shard residency ------------------------------------------------------
    def shard(self, i: int) -> VersionedStore:
        """Shard ``i``, transparently (lazily) reloading it if spilled."""
        sh = self._shards[i]
        if sh is None:
            if self._dir is None:
                raise RuntimeError(
                    f"shard {i} of {self.name} is spilled but the store has "
                    "no directory to reload it from")
            sh = VersionedStore.load(shard_dir(self._dir, i), lazy=True)
            # identical content => the pre-spill epoch is still correct;
            # flooring keeps the facade's epoch sum from moving backwards
            sh._log_epoch = max(sh._log_epoch, self._spilled_epochs[i])
            self._spilled_epochs.pop(i, None)
            self._shards[i] = sh
        return sh

    def resident_shard_ids(self) -> list[int]:
        return [i for i, sh in enumerate(self._shards) if sh is not None]

    def spill_shard(self, i: int | None = None, *,
                    root: str | None = None) -> int | None:
        """Spill one resident shard to disk and drop it from memory;
        returns the resident bytes freed, or None when no shard was
        resident to spill. ``root`` overrides (and becomes) the store
        directory.

        The spill commits through a whole-store incremental ``save()``
        (cells each shard already flushed are not rewritten), NOT a lone
        per-shard save: the shard manifest must stay consistent with every
        shard directory, or a crash after the spill would leave a
        previously-durable store unloadable (shards holding keys the
        stale manifest never heard of)."""
        if root is not None and root != self._dir:
            # retargeting: the saved-epoch watermark belongs to the OLD
            # directory — the new one has nothing yet
            self._saved_epoch = None
        if self._dir is None and root is None:
            raise RuntimeError(
                f"cannot spill shards of {self.name}: no store directory "
                "(save the store or pass root=)")
        target = root if root is not None else self._dir
        ids = self.resident_shard_ids() if i is None else [i]
        for sid in ids:
            sh = self._shards[sid]
            if sh is None:
                continue
            if self.log_epoch != self._saved_epoch:  # nothing new: skip the
                self.save(target)                    # save, drop straight away
            freed = sum(sh.nbytes().values())
            self._spilled_epochs[sid] = sh.log_epoch
            self._shards[sid] = None
            return freed
        return None

    def has_device_state(self) -> bool:
        return (self._placed is not None
                or any(sh is not None and sh._superlog is not None
                       for sh in self._shards))

    def drop_superlog(self) -> None:
        """Release every shard's device-resident superlog AND the
        cross-shard stacked copy (device -> host demotion)."""
        self._placed = None
        for sh in self._shards:
            if sh is not None:
                sh.drop_superlog()

    def nbytes(self) -> dict:
        """Resident-memory accounting summed over resident shards (spilled
        shards count zero — their cells live on disk). The device tier
        includes the stacked cross-shard superlog, so the tiered pool's
        device->host demotion reclaims it too."""
        out = {"host": 0, "device": 0}
        for sh in self._shards:
            if sh is not None:
                nb = sh.nbytes()
                out["host"] += nb["host"]
                out["device"] += nb["device"]
        if self._placed is not None:
            out["device"] += self._placed.nbytes()
        return out

    # -- shard->device placement (core/placement.py) --------------------------
    @property
    def placement(self) -> ShardPlacement:
        """Shard->device execution plan, auto-planned on first use (mesh
        when the host has a device per shard, else serial; see
        ``plan_placement``). Assign to override — the serving pool pins
        one per store so every replica plans identically."""
        if self._placement is None:
            self._placement = plan_placement(self.n_shards)
        return self._placement

    @placement.setter
    def placement(self, value: ShardPlacement) -> None:
        self._placement = value
        self._placed = None

    def _placed_superlog(self) -> tuple[PlacedSuperLog, list]:
        """(stacked cross-shard superlog, per-shard superlogs), forcing
        residency and (re)pinning each shard to its placed device first.
        Cached on the per-shard epoch tuple: spill/reload cycles freeze
        and floor epochs without changing content, so an equal tuple means
        the stacked device copy is still byte-valid."""
        pl = self.placement
        shards = [self.shard(s) for s in range(self.n_shards)]
        for s, sh in enumerate(shards):
            dev = pl.device_for(s)
            sh.device = dev
            if sh._superlog is not None and sh._superlog.device is not dev:
                sh._superlog = None  # repin: epoch unchanged => same bytes
        sls = [sh.superlog() for sh in shards]
        epochs = tuple(sl.epoch for sl in sls)
        if self._placed is None or self._placed.epochs != epochs:
            self._placed = PlacedSuperLog(sls, pl)
        return self._placed, sls

    def _use_parallel(self, n_queries: int) -> bool:
        """Route this query through the device-parallel stacked path?
        Serial when the placement says so, and for a single distinct
        timestamp against any cold shard — that is the per-field
        ``select_at`` path whose lazy segment reads the stacked build
        would defeat (mirrors ``VersionedStore.get_versions``)."""
        if not self.placement.parallel:
            return False
        if n_queries == 1 and not all(
                sh is not None and sh._superlog_fresh()
                for sh in self._shards):
            return False
        return True

    # -- API parity helpers ---------------------------------------------------
    @property
    def fields(self) -> Mapping[str, FieldSchema]:
        """Field-name mapping (API parity with VersionedStore.fields for
        membership tests and default field lists)."""
        return self.schema

    @property
    def last_ts(self) -> Timestamp:
        return self.versions[-1].ts if self.versions else -1

    def _monotonic_floor(self) -> Timestamp:
        """Strictest monotonicity bound: the facade's own last_ts OR any
        resident shard's. They only diverge after a crash between shard
        saves and the facade-manifest commit (shards then reload "ahead"
        of the facade history) — refusing the colliding timestamp up
        front beats a mid-scatter shard-level ValueError."""
        last = self.last_ts
        for sh in self._shards:
            if sh is not None and sh.last_ts > last:
                last = sh.last_ts
        return last

    @property
    def n_rows(self) -> int:
        return len(self.row_keys)

    def add_field(self, fs: FieldSchema) -> None:
        """Schema evolution, applied to every shard. Shard residency is
        forced first and the first shard's add_field performs all
        validation, so no failure can leave shards with diverged schemas."""
        if fs.name in self.schema:
            raise ValueError(f"field {fs.name} exists")
        shards = [self.shard(i) for i in range(self.n_shards)]
        for sh in shards:
            sh.add_field(fs)
        self.schema[fs.name] = fs

    # -- routing --------------------------------------------------------------
    def _route(self, keys: Sequence[bytes]) -> np.ndarray:
        return route_keys(keys, self.n_shards)

    def _prepare_mutation(self, field_names: Sequence[str]) -> list[VersionedStore]:
        """Force every shard resident and pre-read every on-disk segment
        the coming mutation will touch (heads of the named fields + the
        EXISTS head). Failed reloads and corrupt segments therefore raise
        BEFORE any shard mutates — a failure between shard k-1 and k would
        desync the facade's global row order from the shards' local ones
        for good."""
        shards = [self.shard(s) for s in range(self.n_shards)]
        for sh in shards:
            sh.rebuild_heads([n for n in field_names if n in sh.fields])
            sh._ensure_exists_head()
        return shards

    def _alloc_rows(self, keys: Sequence[bytes], sid: np.ndarray) -> None:
        """Allocate global rows for unseen keys in first-seen order (the
        same order an unsharded store's _rows_for_keys would)."""
        for k, s in zip(keys, sid):
            if k not in self.key_to_row:
                row = len(self.row_keys)
                self.key_to_row[k] = row
                self.row_keys.append(k)
                self._shard_of.append(int(s))
                self._global_rows[int(s)].append(row)
                self._global_rows_np[int(s)] = None

    def _shard_rows(self, s: int) -> np.ndarray:
        """(n_local,) int64 map from shard-local row id to global row id."""
        arr = self._global_rows_np[s]
        if arr is None:
            arr = np.asarray(self._global_rows[s], np.int64)
            self._global_rows_np[s] = arr
        return arr

    # -- update / delete (§III.C, scattered) ----------------------------------
    def update(self, ts: Timestamp, keys: Sequence[bytes],
               table: Mapping[str, np.ndarray], *, label: str = "",
               full_release: bool = True,
               present_keys: Sequence[bytes] | None = None) -> VersionInfo:
        """Scatter one release across all shards. Semantics and returned
        counts match ``VersionedStore.update`` exactly; every shard is
        updated (a key-less shard in a full release still tombstones its
        vanished rows), so shard histories stay timestamp-aligned."""
        # everything fallible runs BEFORE any shard (or facade schema)
        # mutates — a failure between shard k-1 and k would desync the
        # facade's global row order from the shards' local ones for good:
        #   1. shard residency + segment reads; residency FIRST so the
        #      monotonicity floor sees crash-skewed spilled shards too
        self._prepare_mutation(list(table))
        floor = self._monotonic_floor()
        if ts <= floor:
            raise ValueError(
                f"timestamps must be monotonic: {ts} <= {floor}")
        keys = _as_bytes(keys)  # unconvertible keys raise before any mutation
        #   2. schema inference + validation, decided ONCE on the full
        #      value blocks so every shard adopts the dtype the unsharded
        #      store would have
        new_fields: dict[str, FieldSchema] = {}
        for name in table:
            if name not in self.schema:
                fs = infer_field_schema(name, table[name])
                self._shards[0]._validate_new_field(fs)
                new_fields[name] = fs
        #   3. value-checked casts + shape checks on the full blocks
        arrays = {}
        for name, v in table.items():
            fs = new_fields.get(name) or self.schema[name]
            arrays[name] = _checked_cast(name, np.asarray(v), fs.np_dtype)
            shaped = (arrays[name] if arrays[name].ndim > 1
                      else arrays[name][:, None])
            want = (len(keys), fs.width)
            assert shaped.shape == want, f"{name}: {shaped.shape} != {want}"
        #   4. only now register the new columns (facade + every shard)
        for fs in new_fields.values():
            self.add_field(fs)
        sid = self._route(keys)
        self._alloc_rows(keys, sid)
        present_by_shard: list[list[bytes] | None] = [None] * self.n_shards
        if present_keys is not None:
            pk = _as_bytes(present_keys)
            psid = self._route(pk)
            present_by_shard = [[] for _ in range(self.n_shards)]
            for k, s in zip(pk, psid):
                present_by_shard[s].append(k)
        n_new = n_upd = n_del = 0
        for s in range(self.n_shards):
            m = sid == s
            skeys = [k for k, mm in zip(keys, m) if mm]
            stable = {name: arr[m] for name, arr in arrays.items()}
            info = self.shard(s).update(
                ts, skeys, stable, label=label, full_release=full_release,
                present_keys=present_by_shard[s])
            n_new += info.n_new
            n_upd += info.n_updated
            n_del += info.n_deleted
        info = VersionInfo(ts=ts, label=label or str(ts),
                           n_entries=len(keys), n_new=n_new, n_updated=n_upd,
                           n_deleted=n_del)
        self.versions.append(info)
        return info

    def begin_release(self, ts: Timestamp, *, label: str = "",
                      full_release: bool = True,
                      parallel: bool | None = None) -> "ShardedReleaseSession":
        """Open a chunked wave-parallel mutation session for ONE release
        (see ``ShardedReleaseSession``). ``parallel=None`` applies shard
        sub-chunks concurrently whenever the store has more than one
        shard AND the host has more than one CPU; pass False to force the
        serial loop (the equivalence tests' reference mode), True to
        force threaded waves."""
        return ShardedReleaseSession(self, ts, label=label,
                                     full_release=full_release,
                                     parallel=parallel)

    def delete(self, ts: Timestamp, keys: Sequence[bytes], *,
               label: str = "") -> VersionInfo:
        """Tombstone ``keys`` at ``ts`` across their shards. Unknown keys
        raise KeyError before any shard mutates."""
        self._prepare_mutation([])  # residency first: the floor must see
        floor = self._monotonic_floor()  # crash-skewed spilled shards too
        if ts <= floor:
            raise ValueError(
                f"timestamps must be monotonic: {ts} <= {floor}")
        keys = _as_bytes(keys)
        for k in keys:
            if k not in self.key_to_row:
                raise KeyError(k)
        sid = np.asarray([self._shard_of[self.key_to_row[k]] for k in keys],
                         np.int32)
        for s in range(self.n_shards):
            skeys = [k for k, ss in zip(keys, sid) if ss == s]
            self.shard(s).delete(ts, skeys, label=label)
        info = VersionInfo(ts, label or f"delete@{ts}", len(keys), 0, 0,
                           len(keys))
        self.versions.append(info)
        return info

    # -- scatter-gather materialization ---------------------------------------
    def get_versions(self, ts_list: Sequence[Timestamp], *,
                     fields: Sequence[str] | None = None,
                     key_filter: str | Callable[[bytes], bool] | None = None,
                     include_deleted: bool = False,
                     cancel: Callable[[], bool] | None = None,
                     trace: dict | None = None) -> list[VersionView]:
        """Batched get_versions, fanned out to every shard's fused-superlog
        scan and merged back into global (unsharded) row order. Duplicate
        timestamps share one merged view, as in ``VersionedStore``.

        Under a parallel placement the per-shard scans collapse into ONE
        device-parallel stacked launch (``_get_versions_parallel``) —
        byte-identical results, the serial loop below is the fallback.

        ``cancel``/``trace`` follow the ``VersionedStore.get_versions``
        contract: cancellation is polled between per-shard (or stacked)
        stages, and stage seconds accumulate under the same keys."""
        fields = list(fields) if fields is not None else list(self.schema)
        ts_list = [int(t) for t in ts_list]
        if not ts_list:
            return []
        store_mod._check_cancel(cancel)
        uniq = list(dict.fromkeys(ts_list))
        if self._use_parallel(len(uniq)):
            by_t = dict(zip(uniq, self._get_versions_parallel(
                uniq, fields, key_filter, include_deleted,
                cancel=cancel, trace=trace)))
            return [by_t[t] for t in ts_list]
        per_shard = []
        for s in range(self.n_shards):
            store_mod._check_cancel(cancel)
            per_shard.append(self.shard(s).get_versions(
                uniq, fields=fields, key_filter=key_filter,
                include_deleted=include_deleted, cancel=cancel, trace=trace))
        with store_mod._StageTimer(trace, "materialize"):
            by_t: dict[int, VersionView] = {}
            for qi, t in enumerate(uniq):
                views = [per_shard[s][qi] for s in range(self.n_shards)]
                rows, order = merge_shard_rows(
                    [self._shard_rows(s)[v.row_idx]
                     for s, v in enumerate(views)])
                values = {
                    name: np.concatenate([v.values[name]
                                          for v in views])[order]
                    for name in fields}
                by_t[t] = VersionView(
                    ts=t, keys=[self.row_keys[r] for r in rows],
                    row_idx=rows.astype(np.int32), values=values)
            return [by_t[t] for t in ts_list]

    def get_version(self, t: Timestamp, *,
                    fields: Sequence[str] | None = None,
                    key_filter: str | Callable[[bytes], bool] | None = None,
                    include_deleted: bool = False) -> VersionView:
        return self.get_versions([t], fields=fields, key_filter=key_filter,
                                 include_deleted=include_deleted)[0]

    def _get_versions_parallel(self, uniq, fields, key_filter,
                               include_deleted, cancel=None,
                               trace=None) -> list[VersionView]:
        """MERGED views for the unique timestamps, one per ``uniq`` entry,
        from ONE stacked launch: the cross-shard ``PlacedSuperLog`` answers
        every shard's boundary cumsums together (one shard per device under
        a mesh placement), exists resolution is one fused EXISTS gather,
        and each field's values come from one fused cross-shard ``take``
        with the gather indices already permuted into the final merged row
        order — no per-shard intermediate views, no re-concatenation. The
        math per element is exactly ``VersionedStore.get_versions`` + the
        facade merge — byte-identical to the serial loop."""
        with store_mod._StageTimer(trace, "scan"):
            placed, sls = self._placed_superlog()
            nq, ns = len(uniq), self.n_shards
            bcums = placed.boundary_cums(uniq)
            ex = placed.exists_matrices(bcums, sls)
        store_mod._check_cancel(cancel)
        # per-shard flat selections over ALL queries (row-major (qi, row)
        # nonzero order == the per-query loop order the serial path uses)
        sel_cat, qi_cat = [], []
        for s in range(ns):
            mat = ex[s][1] if include_deleted else ex[s][0]
            if key_filter is None:
                qis, rr = np.nonzero(mat)
            else:
                parts = [self._shards[s]._filter_sel(
                    np.nonzero(mat[qi])[0], key_filter) for qi in range(nq)]
                rr = (np.concatenate(parts) if parts
                      else np.zeros(0, np.int64))
                qis = np.repeat(np.arange(nq), [len(p) for p in parts])
            sel_cat.append(rr)
            qi_cat.append(qis)
        # global merge of the whole wave in one stable sort: shards
        # partition the row space, so within a query (qi, global_row) keys
        # are unique and lexsort reproduces merge_shard_rows exactly
        big_qi = np.concatenate(qi_cat)
        big_g = np.concatenate(
            [self._shard_rows(s)[sel_cat[s]] for s in range(ns)])
        perm = np.lexsort((big_g, big_qi))
        rows_all = big_g[perm]
        lens_q = np.bincount(big_qi, minlength=nq)
        rows_q = np.split(rows_all, np.cumsum(lens_q)[:-1])
        values_q: list[dict] = [{} for _ in range(nq)]
        store_mod._check_cancel(cancel)
        with store_mod._StageTimer(trace, "gather"):
            for name in fields:
                offs = placed.field_offsets(name, sls)
                iparts, kparts = [], []
                for s in range(ns):
                    f = sls[s].fields[name]
                    c = sls[s].counts(name, bcums[s])[qi_cat[s], sel_cat[s]]
                    iparts.append(offs[s] + np.clip(
                        f.ptr[sel_cat[s]] + c - 1, 0, max(f.n_cells - 1, 0)))
                    kparts.append(c > 0)
                for qi, v in enumerate(placed.take_cells(
                        name, np.concatenate(iparts)[perm],
                        np.concatenate(kparts)[perm], lens_q, sls)):
                    values_q[qi][name] = v
        with store_mod._StageTimer(trace, "materialize"):
            return [VersionView(ts=t,
                                keys=[self.row_keys[r] for r in rows_q[qi]],
                                row_idx=rows_q[qi].astype(np.int32),
                                values=values_q[qi])
                    for qi, t in enumerate(uniq)]

    def get_increments(self, pairs: Sequence[tuple[Timestamp, Timestamp]], *,
                       significant_fields: Sequence[str] | None = None,
                       fields: Sequence[str] | None = None) -> list[Increment]:
        """Batched get_increments, scatter-gathered like get_versions."""
        sig = (list(significant_fields) if significant_fields is not None
               else list(self.schema))
        out_fields = list(fields) if fields is not None else list(self.schema)
        pairs = [(int(t0), int(t1)) for t0, t1 in pairs]
        if not pairs:
            return []
        upairs = list(dict.fromkeys(pairs))
        if self._use_parallel(len(upairs)):
            by_p = dict(zip(upairs, self._get_increments_parallel(
                upairs, sig, out_fields)))
            return [by_p[p] for p in pairs]
        per_shard = [self.shard(s).get_increments(
            upairs, significant_fields=sig, fields=out_fields)
            for s in range(self.n_shards)]
        by_pair: dict[tuple[int, int], Increment] = {}
        for qi, (t0, t1) in enumerate(upairs):
            incs = [per_shard[s][qi] for s in range(self.n_shards)]
            rows, order = merge_shard_rows(
                [self._shard_rows(s)[inc.row_idx]
                 for s, inc in enumerate(incs)])
            kind = np.concatenate([inc.kind for inc in incs])[order]
            values = {
                name: np.concatenate([inc.values[name] for inc in incs])[order]
                for name in out_fields}
            by_pair[(t0, t1)] = Increment(
                t0=t0, t1=t1, keys=[self.row_keys[r] for r in rows],
                row_idx=rows.astype(np.int32), kind=kind, values=values)
        return [by_pair[p] for p in pairs]

    def get_increment(self, t0: Timestamp, t1: Timestamp, *,
                      significant_fields: Sequence[str] | None = None,
                      fields: Sequence[str] | None = None) -> Increment:
        return self.get_increments(
            [(t0, t1)], significant_fields=significant_fields,
            fields=fields)[0]

    def _get_increments_parallel(self, upairs, sig,
                                 out_fields) -> list[Increment]:
        """MERGED increments for the unique windows from ONE stacked launch
        over the unique endpoints — the device-parallel twin of the serial
        per-shard ``get_increments`` loop + facade merge (same math, same
        bytes). Change detection stays on host (tiny count diffs); value
        materialization is one fused cross-shard ``take`` per field with
        deleted-row zeroing folded into the gather mask."""
        uniq = list(dict.fromkeys(t for p in upairs for t in p))
        q_of = {t: i for i, t in enumerate(uniq)}
        placed, sls = self._placed_superlog()
        np_ct, ns = len(upairs), self.n_shards
        bcums = placed.boundary_cums(uniq)
        ex = placed.exists_matrices(bcums, sls)
        names = list(dict.fromkeys(sig + out_fields))
        cnt = [{name: sls[s].counts(name, bcums[s]) for name in names}
               for s in range(ns)]
        i0_arr = np.asarray([q_of[t0] for t0, _ in upairs], np.intp)
        i1_arr = np.asarray([q_of[t1] for _, t1 in upairs], np.intp)
        # per-shard flat (pair, row) selections + kinds, all pairs at once
        # ((pi, row) nonzero order == the serial per-pair loop order)
        sel_cat, pi_cat, kind_cat = [], [], []
        for s in range(ns):
            exists = ex[s][0]
            changed = np.zeros((np_ct, self._shards[s].n_rows), bool)
            for name in sig:
                changed |= (cnt[s][name][i1_arr] - cnt[s][name][i0_arr]) > 0
            e0, e1 = exists[i0_arr], exists[i1_arr]
            new = e1 & ~e0
            deleted = e0 & ~e1
            updated = e1 & e0 & changed
            pis, rr = np.nonzero(new | deleted | updated)
            kind = np.zeros(len(rr), np.int8)  # zeros == KIND_NEW
            kind[updated[pis, rr]] = KIND_UPDATED
            kind[deleted[pis, rr]] = KIND_DELETED
            sel_cat.append(rr)
            pi_cat.append(pis)
            kind_cat.append(kind)
        # one stable sort merges every pair's rows (see _get_versions_parallel)
        big_pi = np.concatenate(pi_cat)
        big_g = np.concatenate(
            [self._shard_rows(s)[sel_cat[s]] for s in range(ns)])
        perm = np.lexsort((big_g, big_pi))
        rows_all = big_g[perm]
        kind_all = np.concatenate(kind_cat)[perm]
        lens_q = np.bincount(big_pi, minlength=np_ct)
        cuts = np.cumsum(lens_q)[:-1]
        rows_q = np.split(rows_all, cuts)
        kind_q = np.split(kind_all, cuts)
        not_deleted = kind_all != KIND_DELETED
        values_q: list[dict] = [{} for _ in upairs]
        for name in out_fields:
            offs = placed.field_offsets(name, sls)
            iparts, kparts = [], []
            for s in range(ns):
                f = sls[s].fields[name]
                c = cnt[s][name][i1_arr[pi_cat[s]], sel_cat[s]]
                iparts.append(offs[s] + np.clip(
                    f.ptr[sel_cat[s]] + c - 1, 0, max(f.n_cells - 1, 0)))
                kparts.append(c > 0)
            for qi, v in enumerate(placed.take_cells(
                    name, np.concatenate(iparts)[perm],
                    np.concatenate(kparts)[perm] & not_deleted,
                    lens_q, sls)):
                values_q[qi][name] = v
        return [Increment(t0=t0, t1=t1,
                          keys=[self.row_keys[r] for r in rows_q[qi]],
                          row_idx=rows_q[qi].astype(np.int32),
                          kind=kind_q[qi], values=values_q[qi])
                for qi, (t0, t1) in enumerate(upairs)]

    # -- compaction -----------------------------------------------------------
    def compact(self, before_ts: Timestamp, *, label: str = "",
                path: str | None = None) -> dict:
        """Compact every shard at ``before_ts`` (on disk too when ``path``
        is given) and collapse the facade's version prefix the same way
        ``VersionedStore.compact`` does."""
        stats = {"cells_dropped": 0}
        agg: dict[str, int] = {}
        for s in range(self.n_shards):
            st = self.shard(s).compact(
                before_ts, label=label,
                path=shard_dir(path, s) if path is not None else None)
            stats["cells_dropped"] += st.pop("cells_dropped")
            st.pop("versions_kept", None)
            for k, v in st.items():
                if isinstance(v, (int, float)):
                    agg[k] = agg.get(k, 0) + v
            if path is not None:
                self._disk_bytes[s] = st.get("disk_bytes",
                                             self._disk_bytes.get(s, 0))
        kept = [v for v in self.versions if v.ts > before_ts]
        n_base = sum(self.shard(s).versions[0].n_entries
                     for s in range(self.n_shards))
        base = VersionInfo(ts=before_ts,
                           label=label or f"compact@{before_ts}",
                           n_entries=n_base, n_new=n_base, n_updated=0,
                           n_deleted=0)
        self.versions = [base] + kept
        stats["versions_kept"] = len(kept) + 1
        stats.update(agg)
        if path is not None:
            self._dir = path
            stats["manifest_bytes"] = stats.get("manifest_bytes", 0) + \
                _write_shard_manifest(path, self._manifest_payload())
        return stats

    # -- persistence ----------------------------------------------------------
    def _manifest_payload(self) -> dict:
        return {
            "format": SHARD_FORMAT,
            "name": self.name,
            "n_shards": self.n_shards,
            "routing": ROUTING_VERSION,
            "schema": [dataclasses.asdict(f) for f in self.schema.values()],
            "keys": [k.decode("latin1") for k in self.row_keys],
            "versions": [dataclasses.asdict(v) for v in self.versions],
            "shard_dirs": [f"shard-{i:05d}" for i in range(self.n_shards)],
        }

    def save(self, path: str, *, force_full: bool = False) -> dict:
        """Persist every resident shard (each incremental against its own
        manifest watermark) plus the shard manifest as the commit point.
        Spilled shards were saved by the spill itself and are skipped.

        Returns aggregate stats in the ``VersionedStore.save`` shape, with
        ``mode`` = "incremental" when every written shard appended,
        "full" when every one rewrote, otherwise "mixed"."""
        os.makedirs(path, exist_ok=True)
        if path != self._dir:
            # saving to a NEW directory: spilled shards live only in the
            # old one — reload them (lazy) so every shard directory gets
            # written here, or the new manifest would reference shard dirs
            # that do not exist
            for sid in range(self.n_shards):
                if self._shards[sid] is None:
                    self.shard(sid)
        self._dir = path
        modes: list[str] = []
        agg = {"segments_written": 0, "bytes_written": 0, "raw_bytes": 0,
               "packed_bytes": 0, "disk_bytes": 0}
        for i, sh in enumerate(self._shards):
            if sh is None:  # frozen on disk since its spill-save
                agg["disk_bytes"] += self._disk_bytes.get(i, 0)
                continue
            st = sh.save(shard_dir(path, i), force_full=force_full)
            self._disk_bytes[i] = st["disk_bytes"]
            modes.append(st["mode"])
            for k in ("segments_written", "bytes_written", "raw_bytes",
                      "packed_bytes", "disk_bytes"):
                agg[k] += st[k]
        mb = _write_shard_manifest(path, self._manifest_payload())
        agg["bytes_written"] += mb
        agg["disk_bytes"] += mb
        agg["manifest_bytes"] = mb
        agg["mode"] = (modes[0] if modes and len(set(modes)) == 1
                       else "mixed" if modes else "incremental")
        agg["n_shards"] = self.n_shards
        self._saved_epoch = self.log_epoch
        return agg

    @classmethod
    def load(cls, path: str, *, lazy: bool = True) -> "ShardedStore":
        """Open a sharded store directory: the shard manifest supplies the
        global key order and version history; each shard directory opens
        with the plain (lazy) segmented loader.

        Torn-save recovery: ``save()`` commits the shard directories first
        and the shard manifest last, so a crash in between leaves shards
        holding keys the facade manifest never heard of. Those keys are
        adopted (appended in (shard, local-row) order — the original
        cross-shard interleave of the torn release is unrecoverable, any
        deterministic order serves), so the previously durable store stays
        loadable and the torn release's committed cells stay reachable.

        Raises:
          FileNotFoundError: no shard manifest at ``path``.
          ValueError: the manifest was written under a different routing
            function (extending it would mis-route keys), or lists keys no
            shard holds (real divergence — the reverse of a torn save,
            which the commit order makes impossible).
        """
        man = read_shard_manifest(path)
        if man is None:
            raise FileNotFoundError(
                f"no {SHARD_MANIFEST_NAME} under {path}")
        if man.get("routing") != ROUTING_VERSION:
            raise ValueError(
                f"sharded store {path} uses routing "
                f"{man.get('routing')!r}; this build implements "
                f"{ROUTING_VERSION!r}")
        schema = [FieldSchema(**f) for f in man["schema"]]
        # capacity=16: the constructor's fresh shards are placeholders
        # replaced by the loaded ones on the next line
        obj = cls(man["name"], [], n_shards=man["n_shards"], capacity=16)
        obj._shards = [VersionedStore.load(shard_dir(path, i), lazy=lazy)
                       for i in range(obj.n_shards)]
        # adopt the shards' (possibly load-narrowed) schema dtypes
        loaded = obj._shards[0].schema
        obj.schema = {fs.name: loaded.get(fs.name, fs) for fs in schema}
        obj.row_keys = [k.encode("latin1") for k in man["keys"]]
        obj.key_to_row = {k: i for i, k in enumerate(obj.row_keys)}
        obj.versions = [VersionInfo(**v) for v in man["versions"]]
        obj._shard_of = [-1] * len(obj.row_keys)
        adopted = 0
        for s, sh in enumerate(obj._shards):
            rows = []
            for k in sh.row_keys:
                g = obj.key_to_row.get(k)
                if g is None:
                    # torn-save recovery (see docstring): adopt the key
                    g = len(obj.row_keys)
                    obj.key_to_row[k] = g
                    obj.row_keys.append(k)
                    obj._shard_of.append(s)
                    adopted += 1
                rows.append(g)
                obj._shard_of[g] = s
            obj._global_rows[s] = rows
        if any(s < 0 for s in obj._shard_of):
            missing = [obj.row_keys[i] for i, s in enumerate(obj._shard_of)
                       if s < 0][:3]
            raise ValueError(
                f"shard manifest of {path} lists keys no shard holds "
                f"(e.g. {missing})")
        obj._dir = path
        # a recovered (adopted-keys) facade does NOT match the on-disk
        # manifest — leave it save-dirty so the next spill/flush commits it
        obj._saved_epoch = None if adopted else obj.log_epoch
        return obj


class ShardedReleaseSession:
    """Chunked wave-parallel mutation of a ShardedStore for ONE release.

    The streaming twin of ``ShardedStore.update``: every ``apply(keys,
    table)`` routes the chunk with the ``shard_route`` kernel, allocates
    global rows in first-seen order (identical to the whole-file order for
    unique-key releases), then applies the per-shard sub-chunks as one
    concurrent *wave* — each shard's ``ReleaseSession.apply`` runs on its
    own single-thread executor, closing the serial-scatter edge PR 4 left
    open. Shards partition the row space, so wave workers never share
    mutable state, and a shard's executor serializes ITS sub-applies in
    wave order — which lets ``apply`` return as soon as the wave is
    dispatched: routing + fingerprinting chunk k+1 overlaps the shard
    workers still applying chunk k. A worker failure surfaces on the next
    ``apply`` (or at ``finish()``), which is the right boundary: a
    mid-release session is discard-only anyway (the ingest journal owns
    crash recovery).

    ``finish()`` commits every shard's release (tombstone scans run
    per shard over its own touched rows), then appends the single facade
    VersionInfo — one atomically-validated release timestamp, exactly as
    the whole-file path. The committed store is byte-identical to a
    whole-file ``update`` of the concatenated chunks (cells, heads,
    counts, per-shard digest chains) for unique-key releases.
    """

    def __init__(self, store: ShardedStore, ts: Timestamp, *,
                 label: str = "", full_release: bool = True,
                 parallel: bool | None = None):
        #   residency FIRST so the monotonicity floor sees crash-skewed
        #   spilled shards too (mirrors update())
        shards = store._prepare_mutation([])
        floor = store._monotonic_floor()
        if ts <= floor:
            raise ValueError(
                f"timestamps must be monotonic: {ts} <= {floor}")
        self.store = store
        self.ts = int(ts)
        self.label = label
        self.full_release = full_release
        self.n_entries = 0
        self._sessions = [
            sh.begin_release(ts, label=label, full_release=full_release)
            for sh in shards]
        if parallel is None:
            from .ingest import _cpu_count
            # threaded waves only pay when there is a core to run them on
            parallel = store.n_shards > 1 and _cpu_count() > 1
        self._parallel = bool(parallel)
        # one single-thread executor PER SHARD: cross-shard parallel,
        # in-order per shard (required for byte-identical digest chains)
        self._execs = ([ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"ingest-{store.name}-s{s}")
            for s in range(store.n_shards)] if self._parallel else None)
        self._futs: list = []
        self._finished = False

    def _drain(self, *, wait: bool) -> None:
        """Surface worker failures; with ``wait`` also barrier the waves."""
        pending = []
        for f in self._futs:
            if wait or f.done():
                f.result()  # re-raises the worker's exception
            else:
                pending.append(f)
        self._futs = pending

    def apply(self, keys: Sequence[bytes],
              table: Mapping[str, np.ndarray]) -> int:
        """Route one chunk and apply its per-shard sub-chunks as one
        concurrent wave; returns the chunk entry count. Facade-level
        validation runs before any shard mutates (chunks already applied
        stay applied — the ingest journal owns crash recovery)."""
        if self._finished:
            raise RuntimeError("release session already finished")
        self._drain(wait=False)  # propagate any earlier wave's failure
        st = self.store
        keys = _as_bytes(keys)
        new_fields: dict[str, FieldSchema] = {}
        for name in table:
            if name not in st.schema:
                # chunk-local inference (see ReleaseSession.apply NOTE);
                # the ingest engine pre-declares the parser schema instead
                fs = infer_field_schema(name, table[name])
                st.shard(0)._validate_new_field(fs)
                new_fields[name] = fs
        arrays = {}
        for name, v in table.items():
            fs = new_fields.get(name) or st.schema[name]
            arr = _checked_cast(name, np.asarray(v), fs.np_dtype)
            arrays[name] = arr if arr.ndim > 1 else arr[:, None]
            want = (len(keys), fs.width)
            assert arrays[name].shape == want, (
                f"{name}: {arrays[name].shape} != {want}")
        if new_fields:
            self._drain(wait=True)  # shard dicts mutate: barrier the waves
            for fs in new_fields.values():
                st.add_field(fs)
        sid = st._route(keys)
        st._alloc_rows(keys, sid)
        # fingerprint the whole chunk ONCE per field: one kernel launch
        # each instead of n_shards small ones inside the sub-applies (the
        # dominant per-wave fixed cost); shards slice the shared result
        fps = {name: kops.fingerprint_rows(arr)
               for name, arr in arrays.items()}
        names = list(table)
        for s in range(st.n_shards):
            m = sid == s
            if not m.any():
                continue  # empty sub-chunk: nothing to apply, digest-neutral
            skeys = [k for k, mm in zip(keys, m) if mm]
            stable = {name: arr[m] for name, arr in arrays.items()}
            sfps = {name: fp[m] for name, fp in fps.items()}
            sh, sess = st.shard(s), self._sessions[s]

            def work(sh=sh, sess=sess, skeys=skeys, stable=stable,
                     sfps=sfps):
                # pre-read this shard's on-disk segments (corrupt segments
                # raise here, before the shard mutates), then apply
                sh.rebuild_heads([n for n in names if n in sh.fields])
                sess.apply(skeys, stable, _precast=True, _fps=sfps)

            if self._execs is not None:
                self._futs.append(self._execs[s].submit(work))
            else:
                work()
        self.n_entries += len(keys)
        return len(keys)

    def finish(self) -> VersionInfo:
        """Barrier the in-flight waves, commit every shard's release
        (concurrently under a parallel session — tombstone scans are
        per-shard too) and append the single facade version record."""
        if self._finished:
            raise RuntimeError("release session already finished")
        self._finished = True
        try:
            self._drain(wait=True)
            if self._execs is not None:
                futs = [ex.submit(sess.finish)
                        for ex, sess in zip(self._execs, self._sessions)]
                infos = [f.result() for f in futs]
            else:
                infos = [sess.finish() for sess in self._sessions]
        finally:
            self.close()
        info = VersionInfo(ts=self.ts, label=self.label or str(self.ts),
                           n_entries=self.n_entries,
                           n_new=sum(i.n_new for i in infos),
                           n_updated=sum(i.n_updated for i in infos),
                           n_deleted=sum(i.n_deleted for i in infos))
        self.store.versions.append(info)
        return info

    def close(self) -> None:
        """Release the wave executors (idempotent; finish() calls it)."""
        if self._execs is not None:
            for ex in self._execs:
                ex.shutdown(wait=True)
            self._execs = None
