"""Tool-specific change detection helpers (paper §III.A).

The store's fingerprint comparison is field-granular; this module adds the
tool view: a SignificanceProfile names the fields whose changes matter to a
given tool, and classify() maps an Increment to per-kind key lists for merge
contexts. Coarse-grained (whole-file) detection is the degenerate profile
covering every field.
"""
from __future__ import annotations

import dataclasses

from .store import Increment, KIND_DELETED, KIND_NEW, KIND_UPDATED


@dataclasses.dataclass(frozen=True)
class SignificanceProfile:
    tool: str
    fields: tuple[str, ...]          # significant fields
    handles_deletes: bool = True     # must deletions be propagated to merge?


def classify(inc: Increment) -> dict[str, list[bytes]]:
    out = {"new": [], "updated": [], "deleted": []}
    for key, kind in zip(inc.keys, inc.kind):
        if kind == KIND_NEW:
            out["new"].append(key)
        elif kind == KIND_UPDATED:
            out["updated"].append(key)
        elif kind == KIND_DELETED:
            out["deleted"].append(key)
    return out


# canonical profiles for the Meta-pipe tools (paper §IV.B)
BLASTP = SignificanceProfile("blastp", ("sequence", "length"))
MGA = SignificanceProfile("mga", ("sequence", "length"), handles_deletes=True)
