"""GQA attention: specs + train/prefill/decode paths.

Two softmax-attention implementations, selected by RunConfig.attn_impl:

* ``xla``: full (Sq, Sk) logits einsum — best for short train sequences where
  XLA fuses mask+softmax; memory O(S^2).
* ``chunked``: the flash-attention algorithm expressed in XLA (lax.scan over
  KV chunks with an online-softmax carry) — memory O(S * chunk); the
  compile-anywhere twin of kernels/flash_attention.py (which is the Pallas
  TPU version of the same loop, used on real TPU serving). Wrapped in
  jax.checkpoint so the backward pass recomputes chunks instead of saving
  scan carries.

Decode writes new KV into a ring slot (pos % S_max) and attends over the
full cache with a validity mask; the cache seq axis may be sharded over the
``model`` mesh axis (sequence-sharded decode) — the softmax reductions over
the sharded axis become mesh all-reduces under GSPMD.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import ParamSpec, apply_rope

NEG = -1e30


def attn_spec(cfg, *, cross: bool = False) -> dict:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = ParamSpec((k, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = ParamSpec((k, hd), ("kv_heads", "head_dim"), init="zeros")
    return s


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, K, hd)
    v: jax.Array


def _qkv(p: dict, x: jax.Array, cfg, xkv: jax.Array | None = None):
    xkv = x if xkv is None else xkv
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", xkv, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", xkv, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def _xla_attention(q, k, v, *, causal: bool, q_offset, kv_valid=None):
    """Full-logits attention. q: (B,Sq,H,D), k/v: (B,Sk,K,D)."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = q.astype(jnp.float32).reshape(b, sq, kh, g, d) * (d ** -0.5)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    kpos = jnp.arange(k.shape[1])
    mask = None
    if causal:
        qpos = jnp.arange(sq) + q_offset
        mask = qpos[:, None] >= kpos[None, :]
    if kv_valid is not None:
        vmask = kv_valid[None, :] if kv_valid.ndim == 1 else kv_valid
        mask = vmask if mask is None else (mask & vmask)
    if mask is not None:
        while mask.ndim < 5:
            mask = mask[None]
        logits = jnp.where(mask, logits, NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


@functools.partial(jax.checkpoint, static_argnums=(3, 5, 6, 7))
def _chunked_attention(q, k, v, causal: bool, q_offset, chunk: int,
                       unroll: bool = False, compact_logits: bool = False):
    """Flash algorithm in XLA: scan over KV chunks, online softmax carry.

    compact_logits=True (no-grad serving prefill): the (Sq, chunk) logit and
    probability intermediates stay bf16 while the online-softmax statistics
    (m, l, acc) stay f32 — halves the dominant HBM term of 32k prefill
    (§Perf iter 5). On real TPU the Pallas kernel (kernels/flash_attention)
    keeps them in VMEM entirely; this is the XLA-visible approximation.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kh = k.shape[2]
    g = h // kh
    chunk = min(chunk, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ldt = jnp.bfloat16 if compact_logits else jnp.float32
    qf = q.astype(ldt).reshape(b, sq, kh, g, d) * jnp.asarray(d ** -0.5, ldt)
    qpos = jnp.arange(sq) + q_offset
    ks = k.reshape(b, n_chunks, chunk, kh, d).swapaxes(0, 1)
    vs = v.reshape(b, n_chunks, chunk, kh, d).swapaxes(0, 1)

    def body(carry, ckv):
        m, l, acc = carry
        kc, vc, ci = ckv
        kpos = ci * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bqkgd,bckd->bkgqc", qf, kc.astype(ldt),
                            preferred_element_type=ldt)
        mask = kpos[None, :] < sk
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        logits = jnp.where(mask[None, None, None], logits,
                           jnp.asarray(NEG, ldt))
        m_new = jnp.maximum(m, logits.max(-1).astype(jnp.float32))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None].astype(ldt))
        p = jnp.where(mask[None, None, None], p, jnp.asarray(0.0, ldt))
        l = l * alpha + p.sum(-1, dtype=jnp.float32)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, vc.astype(ldt),
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, kh, g, sq), NEG, jnp.float32)
    l0 = jnp.zeros((b, kh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kh, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (ks, vs, jnp.arange(n_chunks)),
                                  unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


def run_attention(q, k, v, *, causal: bool, q_offset=0, impl: str = "xla",
                  chunk: int = 1024, kv_valid=None, unroll: bool = False,
                  compact_logits: bool = False):
    if impl == "chunked" and kv_valid is None:
        return _chunked_attention(q, k, v, causal, q_offset, chunk, unroll,
                                  compact_logits)
    return _xla_attention(q, k, v, causal=causal, q_offset=q_offset,
                          kv_valid=kv_valid)


# ---------------------------------------------------------------------------
# block-level entry points
# ---------------------------------------------------------------------------

def _wants_seq_parallel(cfg) -> bool:
    """True when the head count cannot shard the model axis (qwen2-0.5b's 14
    heads, qwen1.5-4b's 20): attention weights replicate, so without further
    action every model peer computes the FULL attention (16x redundant
    FLOPs, the useful=0.10 pathology in EXPERIMENTS.md SPerf iter 7). The
    fix: shard the QUERY sequence over `model` inside the attention block —
    each peer handles S/16 query rows; k/v (small for GQA) are gathered."""
    from repro.sharding.rules import current_mesh
    mesh = current_mesh()
    if mesh is None or "model" not in (mesh.axis_names or ()):
        return False
    return cfg.n_heads % mesh.shape["model"] != 0


def attention_train(p, x, cfg, *, positions, impl="xla", chunk=1024,
                    causal=True, use_rope=True, xkv=None, unroll=False):
    seq_par = _wants_seq_parallel(cfg)
    if seq_par:
        from repro.sharding.rules import constrain
        x = constrain(x, ("batch", "seq_sp", None))
    q, k, v = _qkv(p, x, cfg, xkv=xkv)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    if seq_par:
        from repro.sharding.rules import constrain
        q = constrain(q, ("batch", "seq_sp", None, None))
        k = constrain(k, ("batch", None, None, None))
        v = constrain(v, ("batch", None, None, None))
    out = run_attention(q, k, v, causal=causal, impl=impl, chunk=chunk,
                        unroll=unroll)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    if seq_par:
        from repro.sharding.rules import constrain
        out = constrain(out, ("batch", None, None))
    return out


def attention_prefill(p, x, cfg, *, positions, impl="chunked", chunk=1024,
                      use_rope=True, unroll=False):
    """Returns (out, KVCache over the S prefill positions)."""
    q, k, v = _qkv(p, x, cfg)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    out = run_attention(q, k, v, causal=True, impl=impl, chunk=chunk,
                        unroll=unroll, compact_logits=True)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    return out, KVCache(k=k, v=v)


def attention_decode(p, x, cfg, cache: KVCache, *, pos, cache_len,
                     positions=None, use_rope=True):
    """One-token decode. x: (B, 1, d); cache: (B, S_max, K, hd) ring.

    pos: scalar int32 position of the new token (ring slot = pos % S_max);
    cache_len: scalar count of valid cached positions (== S_max when full).
    """
    b, _, _ = x.shape
    s_max = cache.k.shape[1]
    q, k, v = _qkv(p, x, cfg)
    if use_rope:
        rp = positions if positions is not None else \
            jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
        q = apply_rope(q, rp, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, rp, cfg.rope_theta, cfg.mrope_sections)
    slot = jnp.asarray(pos % s_max, jnp.int32)
    nk = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), slot, 1)
    nv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), slot, 1)
    n_valid = jnp.minimum(cache_len + 1, s_max)
    kv_valid = jnp.arange(s_max) < n_valid
    out = run_attention(q, nk.astype(x.dtype), nv.astype(x.dtype),
                        causal=False, kv_valid=kv_valid)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    return out, KVCache(k=nk, v=nv)
