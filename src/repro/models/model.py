"""Unified model API: build any assigned architecture from its ModelConfig.

    bundle = build(cfg)
    params = bundle.init(key)
    loss, metrics = bundle.loss(params, batch, opts)
    logits, state = bundle.prefill(params, batch, opts)
    logits, state = bundle.decode(params, token, state)

`batch_specs(cfg, shape)` yields the ShapeDtypeStructs for every model input
of an assigned (arch x shape) cell — the dry-run and the serving engine both
build their abstract inputs from it (modality frontends are stubs: VLM/audio
cells feed precomputed patch/frame embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from . import transformer as tf
from . import whisper as wh
from .layers import init_params
from .transformer import FwdOpts


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    spec: Any
    loss: Callable
    prefill: Callable
    decode: Callable

    def init(self, key) -> Any:
        return init_params(self.spec, key)

    def abstract_params(self):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), self.spec,
            is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"))


def build(cfg: ModelConfig) -> ModelBundle:
    if cfg.family == "encdec":
        return ModelBundle(
            cfg=cfg, spec=wh.whisper_spec(cfg),
            loss=lambda p, b, opts=None: wh.whisper_loss_fn(p, cfg, b, opts),
            prefill=lambda p, b, opts=None, pad_to=None: wh.whisper_prefill(
                p, cfg, b, opts, pad_to=pad_to),
            decode=lambda p, t, s: wh.whisper_decode_step(p, cfg, t, s))
    return ModelBundle(
        cfg=cfg, spec=tf.model_spec(cfg),
        loss=lambda p, b, opts=None: tf.loss_fn(p, cfg, b, opts or FwdOpts()),
        prefill=lambda p, b, opts=None, pad_to=None: tf.prefill(
            p, cfg, b, opts or FwdOpts(attn_impl="chunked"), pad_to=pad_to),
        decode=lambda p, t, s, positions=None: tf.decode_step(p, cfg, t, s,
                                                              positions))


# ---------------------------------------------------------------------------
# input specs per (arch x shape) cell
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    b = shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    if shape.mode == "train":
        out: dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.family == "encdec":
            out["enc_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
            out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
            return out
        if cfg.input_mode == "embeddings":
            out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.mrope_sections is not None:
            out["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
        return out
    if shape.mode == "prefill":
        out = {}
        if cfg.family == "encdec":
            out["enc_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
            out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            return out
        if cfg.input_mode == "embeddings":
            out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.mrope_sections is not None:
            out["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
        return out
    # decode: one new token against an s-long cache/state
    out = {"token": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.input_mode == "embeddings":
        out = {"token": jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)}
    if cfg.mrope_sections is not None:
        out["positions"] = jax.ShapeDtypeStruct((3, b, 1), i32)
    return out


def abstract_decode_state(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for the decode-mode cache/state inputs."""
    b, s = shape.global_batch, shape.seq_len
    as_sds = lambda t: jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    if cfg.family == "encdec":
        kshape = (cfg.n_layers, b, s, cfg.n_kv_heads, cfg.hd)
        cshape = (cfg.n_layers, b, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd)
        from .attention import KVCache
        return wh.WhisperState(
            self_caches=KVCache(k=jax.ShapeDtypeStruct(kshape, jnp.bfloat16),
                                v=jax.ShapeDtypeStruct(kshape, jnp.bfloat16)),
            cross_k=jax.ShapeDtypeStruct(cshape, jnp.bfloat16),
            cross_v=jax.ShapeDtypeStruct(cshape, jnp.bfloat16),
            pos=jax.ShapeDtypeStruct((), jnp.int32),
            cache_len=jax.ShapeDtypeStruct((), jnp.int32))
    caches = jax.eval_shape(lambda: tf.init_caches(cfg, b, s))
    return tf.DecodeState(caches=caches,
                          pos=jax.ShapeDtypeStruct((), jnp.int32),
                          cache_len=jax.ShapeDtypeStruct((), jnp.int32))
