"""Param-spec machinery + shared layers (norms, RoPE/M-RoPE, MLP).

No flax: parameters are plain pytrees of arrays. Every leaf is declared by a
ParamSpec carrying its logical sharding axes, so the same spec tree drives
(a) real initialization for smoke tests/examples and (b) abstract
ShapeDtypeStruct+NamedSharding construction for the 512-device dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]      # logical axis names per dim
    dtype: Any = jnp.float32
    init: str = "normal"              # normal | zeros | ones | embed | small
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_param(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / np.sqrt(max(fan_in, 1))
    if spec.init == "embed":
        std = spec.scale * 0.02
    if spec.init == "small":
        std = spec.scale * 0.01
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)


def _path_key(root: jax.Array, path) -> jax.Array:
    k = root
    for p in path:
        name = getattr(p, "key", getattr(p, "idx", p))
        k = jax.random.fold_in(k, hash(str(name)) % (2**31 - 1))
    return k


def init_params(specs, key: jax.Array):
    """Deterministic per-path initialization of a ParamSpec tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, s: init_param(s, _path_key(key, path)), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Prepend a stacking dim (scan-over-layers parameter layout)."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.dtype,
                            s.init, s.scale),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_spec(cfg) -> dict:
    if cfg.norm == "layernorm_np":     # OLMo: non-parametric LN
        return {}
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
                "bias": ParamSpec((cfg.d_model,), ("embed",), init="zeros")}
    return {"scale": ParamSpec((cfg.d_model,), ("embed",), init="ones")}


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (y * p["scale"]).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               sections: tuple[int, ...] | None = None) -> jax.Array:
    """x: (B, S, H, D). positions: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (qwen2-vl): the D/2 frequency slots are split into
    (temporal, height, width) sections, each rotated by its own position id.
    """
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))            # (D/2,)
    if sections is None:
        angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    else:
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) position ids"
        parts = []
        off = 0
        for i, sec in enumerate(sections):
            f = freqs[off:off + sec]
            parts.append(positions[i][..., None].astype(jnp.float32) * f)
            off += sec
        assert off == freqs.shape[0], "mrope sections must cover head_dim/2"
        angles = jnp.concatenate(parts, axis=-1)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU or plain)
# ---------------------------------------------------------------------------

def mlp_spec(cfg, d_ff: int | None = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    s = {"wi": ParamSpec((cfg.d_model, d_ff), ("embed", "mlp")),
         "wo": ParamSpec((d_ff, cfg.d_model), ("mlp", "embed"))}
    if cfg.mlp_gated:
        s["wg"] = ParamSpec((cfg.d_model, d_ff), ("embed", "mlp"))
    return s


def apply_mlp(p: dict, x: jax.Array, cfg) -> jax.Array:
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = x @ p["wi"].astype(x.dtype)
    if cfg.mlp_gated:
        h = act(x @ p["wg"].astype(x.dtype)) * h
    else:
        h = act(h)
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def embed_spec(cfg) -> dict:
    # embed_tbl (not "embed"): the table's d_model dim must NOT be FSDP-
    # sharded — contracting a data-sharded dim against data-sharded batch
    # activations makes GSPMD emit full (B, S, V) logits all-reduces.
    # vocab@model gives clean vocab-sharded logits instead.
    s = {"tok": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed_tbl"),
                          init="embed")}
    if not cfg.tie_embeddings:
        s["head"] = ParamSpec((cfg.d_model, cfg.vocab), ("embed_tbl", "vocab"))
    return s


def apply_embed(p: dict, tokens: jax.Array, cfg) -> jax.Array:
    return p["tok"].astype(jnp.dtype(cfg.dtype))[tokens]


def apply_head(p: dict, x: jax.Array, cfg) -> jax.Array:
    w = p["head"] if "head" in p else p["tok"].T
    return x @ w.astype(x.dtype)
