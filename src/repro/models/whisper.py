"""Whisper-style encoder-decoder backbone (audio frontend stubbed per spec:
input_specs() provides precomputed log-mel *frame embeddings* (B, S_enc, d);
the conv1d downsampler is outside scope). Sinusoidal positions on both sides
(deviation from learned decoder positions noted in DESIGN.md), pre-LN
blocks, GELU MLP, MHA with QKV bias, no RoPE.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import attention as attn
from .layers import (apply_embed, apply_head, apply_mlp, apply_norm,
                     embed_spec, mlp_spec, norm_spec, stack_specs)


def _sinusoid(s: int, d: int) -> jax.Array:
    """Computed with jnp so it lowers as ops, not a giant HLO literal."""
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = 1.0 / (10000 ** (dim / max(d // 2 - 1, 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def enc_block_spec(cfg) -> dict:
    return {"norm1": norm_spec(cfg), "attn": attn.attn_spec(cfg),
            "norm2": norm_spec(cfg), "mlp": mlp_spec(cfg)}


def dec_block_spec(cfg) -> dict:
    return {"norm1": norm_spec(cfg), "self_attn": attn.attn_spec(cfg),
            "norm_c": norm_spec(cfg), "cross_attn": attn.attn_spec(cfg),
            "norm2": norm_spec(cfg), "mlp": mlp_spec(cfg)}


def whisper_spec(cfg) -> dict:
    return {
        "embed": embed_spec(cfg),
        "enc_blocks": stack_specs(enc_block_spec(cfg), cfg.encoder_layers),
        "enc_final": norm_spec(cfg),
        "dec_blocks": stack_specs(dec_block_spec(cfg), cfg.n_layers),
        "final_norm": norm_spec(cfg),
    }


def _maybe_scan(body, init, xs, unroll: bool):
    from .transformer import _maybe_scan as ms
    return ms(body, init, xs, unroll)


def encode(params, cfg, frames: jax.Array, unroll: bool = False) -> jax.Array:
    """frames: (B, S_enc, d) stub embeddings -> (B, S_enc, d)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + jnp.asarray(_sinusoid(x.shape[1], cfg.d_model)).astype(x.dtype)

    def body(x, bp):
        h = apply_norm(bp["norm1"], x, cfg.norm)
        q, k, v = attn._qkv(bp["attn"], h, cfg)
        y = attn.run_attention(q, k, v, causal=False, impl="xla")
        y = jnp.einsum("bshe,hed->bsd", y, bp["attn"]["wo"].astype(x.dtype))
        x = x + y
        x = x + apply_mlp(bp["mlp"], apply_norm(bp["norm2"], x, cfg.norm), cfg)
        return x, None

    x, _ = _maybe_scan(body, x, params["enc_blocks"], unroll)
    return apply_norm(params["enc_final"], x, cfg.norm)


class WhisperState(NamedTuple):
    self_caches: attn.KVCache    # (L, B, S_max, K, hd) ring caches
    cross_k: jax.Array           # (L, B, S_enc, K, hd) fixed per request
    cross_v: jax.Array
    pos: jax.Array
    cache_len: jax.Array


def _dec_sublayers(bp, x, cfg, positions, enc_out=None, cross_kv=None,
                   self_mode="train", cache=None, pos=None, cache_len=None,
                   attn_impl="xla", chunk=1024, unroll=False):
    """One decoder block; returns (x, new self cache, (ck, cv))."""
    h = apply_norm(bp["norm1"], x, cfg.norm)
    new_cache = None
    if self_mode == "train":
        y = attn.attention_train(bp["self_attn"], h, cfg, positions=positions,
                                 use_rope=False, impl=attn_impl, chunk=chunk,
                                 unroll=unroll)
    elif self_mode == "prefill":
        y, new_cache = attn.attention_prefill(bp["self_attn"], h, cfg,
                                              positions=positions,
                                              use_rope=False, impl=attn_impl,
                                              chunk=chunk, unroll=unroll)
    else:
        y, new_cache = attn.attention_decode(bp["self_attn"], h, cfg, cache,
                                             pos=pos, cache_len=cache_len,
                                             use_rope=False)
    x = x + y
    h = apply_norm(bp["norm_c"], x, cfg.norm)
    if cross_kv is None:
        q, ck, cv = attn._qkv(bp["cross_attn"], h, cfg, xkv=enc_out)
    else:
        ck, cv = cross_kv
        q, _, _ = attn._qkv(bp["cross_attn"], h, cfg, xkv=h[:, :1] * 0)
    y = attn.run_attention(q, ck.astype(x.dtype), cv.astype(x.dtype),
                           causal=False, impl="xla")
    y = jnp.einsum("bshe,hed->bsd", y, bp["cross_attn"]["wo"].astype(x.dtype))
    x = x + y
    x = x + apply_mlp(bp["mlp"], apply_norm(bp["norm2"], x, cfg.norm), cfg)
    return x, new_cache, (ck, cv)


def whisper_loss_fn(params, cfg, batch, opts=None, z_coef: float = 1e-4):
    """batch: enc_embeds (B, S_enc, d), tokens (B, S), labels (B, S)."""
    unroll = bool(getattr(opts, "unroll", False))
    enc_out = encode(params, cfg, batch["enc_embeds"], unroll=unroll)
    x = apply_embed(params["embed"], batch["tokens"], cfg)
    b, s = x.shape[:2]
    x = x + jnp.asarray(_sinusoid(s, cfg.d_model)).astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    impl = getattr(opts, "attn_impl", "xla") if opts is not None else "xla"
    chunk = getattr(opts, "attn_chunk", 1024) if opts is not None else 1024

    def body(x, bp):
        x, _, _ = _dec_sublayers(bp, x, cfg, positions, enc_out=enc_out,
                                 attn_impl=impl, chunk=chunk, unroll=unroll)
        return x, None

    x, _ = _maybe_scan(body, x, params["dec_blocks"], unroll)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = apply_head(params["embed"], x, cfg).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = ((lse - ll) * mask).sum() / denom
    zl = z_coef * ((lse * mask) ** 2).sum() / denom
    return ce + zl, {"ce": ce, "z_loss": zl,
                     "moe_aux": jnp.zeros((), jnp.float32),
                     "tokens": mask.sum()}


def whisper_prefill(params, cfg, batch, opts=None, pad_to: int | None = None):
    """Encode audio + run decoder prompt; returns (logits, WhisperState)."""
    unroll = bool(getattr(opts, "unroll", False))
    enc_out = encode(params, cfg, batch["enc_embeds"], unroll=unroll)
    x = apply_embed(params["embed"], batch["tokens"], cfg)
    b, s = x.shape[:2]
    x = x + jnp.asarray(_sinusoid(s, cfg.d_model)).astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    impl = getattr(opts, "attn_impl", "chunked") if opts is not None else "chunked"

    def body(x, bp):
        x, cache, ckv = _dec_sublayers(bp, x, cfg, positions, enc_out=enc_out,
                                       self_mode="prefill", attn_impl=impl,
                                       unroll=unroll)
        return x, (cache, ckv)

    x, (caches, ckvs) = _maybe_scan(body, x, params["dec_blocks"], unroll)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = apply_head(params["embed"], x[:, -1:, :], cfg)
    if pad_to is not None and pad_to > s:
        pad = pad_to - s
        caches = attn.KVCache(
            k=jnp.pad(caches.k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            v=jnp.pad(caches.v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))))
    state = WhisperState(self_caches=caches, cross_k=ckvs[0], cross_v=ckvs[1],
                         pos=jnp.asarray(s, jnp.int32),
                         cache_len=jnp.asarray(s, jnp.int32))
    return logits.astype(jnp.float32), state


def whisper_decode_step(params, cfg, token, state: WhisperState):
    x = apply_embed(params["embed"], token, cfg)
    s_max = state.self_caches.k.shape[2]
    # sinusoidal row at the absolute position, computed directly (no table)
    d = cfg.d_model
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    inv = 1.0 / (10000 ** (dim / max(d // 2 - 1, 1)))
    ang = state.pos.astype(jnp.float32) * inv
    row = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
    x = x + row.astype(x.dtype)[None, None, :]

    def body(x, scanned):
        bp, cache, ck, cv = scanned
        x, new_cache, _ = _dec_sublayers(
            bp, x, cfg, None, cross_kv=(ck, cv), self_mode="decode",
            cache=cache, pos=state.pos, cache_len=state.cache_len)
        return x, new_cache

    x, new_caches = _maybe_scan(
        body, x, (params["dec_blocks"], state.self_caches,
                  state.cross_k, state.cross_v), unroll=False)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = apply_head(params["embed"], x, cfg)
    new_state = WhisperState(self_caches=new_caches, cross_k=state.cross_k,
                             cross_v=state.cross_v, pos=state.pos + 1,
                             cache_len=jnp.minimum(state.cache_len + 1, s_max))
    return logits.astype(jnp.float32), new_state
