"""Decoder-only LM assembly for dense / MoE / hybrid / SSM / VLM families.

Every layer = mixer (attention | mamba | rwkv_time) + ff (mlp | moe |
rwkv_channel). Layers are grouped into the architecture's repeating pattern
(dense: period 1; jamba: period 8 = 7 mamba + 1 attn with MoE on alternate
layers) and the pattern scans over groups with stacked parameters —
compile time and HLO size are O(pattern), not O(depth).

Three entry points per the assigned shape modes: loss_fn (train),
prefill (build caches + last-token logits), decode_step (one token).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .layers import (apply_embed, apply_head, apply_mlp, apply_norm,
                     embed_spec, init_params, mlp_spec, norm_spec, stack_specs)


# ---------------------------------------------------------------------------
# layer pattern
# ---------------------------------------------------------------------------

def layer_kinds(cfg) -> list[tuple[str, str]]:
    kinds = []
    for l in range(cfg.n_layers):
        if cfg.rwkv:
            kinds.append(("rwkv", "rwkv_ff"))
            continue
        mixer = "attn" if cfg.is_attn_layer(l) else "mamba"
        ff = "moe" if cfg.is_moe_layer(l) else "mlp"
        kinds.append((mixer, ff))
    return kinds


def pattern(cfg) -> tuple[int, int]:
    """(period, n_groups): smallest repeating prefix of layer_kinds."""
    kinds = layer_kinds(cfg)
    n = len(kinds)
    for p in range(1, n + 1):
        if n % p == 0 and kinds == kinds[:p] * (n // p):
            return p, n // p
    return n, 1


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def sublayer_spec(cfg, kind: tuple[str, str]) -> dict:
    mixer, ff = kind
    s: dict[str, Any] = {"norm1": norm_spec(cfg), "norm2": norm_spec(cfg)}
    if mixer == "attn":
        s["attn"] = attn.attn_spec(cfg)
    elif mixer == "mamba":
        s["mamba"] = ssm_mod.ssm_spec(cfg)
    else:
        s["rwkv_t"] = rwkv_mod.rwkv_time_spec(cfg)
    if ff == "mlp":
        s["mlp"] = mlp_spec(cfg)
    elif ff == "moe":
        s["moe"] = moe_mod.moe_spec(cfg)
    else:
        s["rwkv_c"] = rwkv_mod.rwkv_channel_spec(cfg)
    return s


def model_spec(cfg) -> dict:
    p, n_groups = pattern(cfg)
    kinds = layer_kinds(cfg)[:p]
    blocks = [stack_specs(sublayer_spec(cfg, k), n_groups) for k in kinds]
    return {"embed": embed_spec(cfg), "blocks": blocks,
            "final_norm": norm_spec(cfg)}


def init_model(cfg, key) -> dict:
    return init_params(model_spec(cfg), key)


# ---------------------------------------------------------------------------
# caches (decode/prefill state per sublayer, stacked over scan groups)
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    caches: list        # per sublayer-in-pattern: KVCache | SSMState | rwkv tuple
    pos: jax.Array      # scalar int32: absolute position of next token
    cache_len: jax.Array  # scalar int32: number of valid cached positions


def init_caches(cfg, batch: int, s_max: int, dtype=jnp.bfloat16) -> list:
    p, g = pattern(cfg)
    kinds = layer_kinds(cfg)[:p]
    caches = []
    d = cfg.d_model
    for mixer, _ff in kinds:
        if mixer == "attn":
            shape = (g, batch, s_max, cfg.n_kv_heads, cfg.hd)
            caches.append(attn.KVCache(k=jnp.zeros(shape, dtype),
                                       v=jnp.zeros(shape, dtype)))
        elif mixer == "mamba":
            di = cfg.ssm_expand * d
            caches.append(ssm_mod.SSMState(
                h=jnp.zeros((g, batch, di, cfg.ssm_state), jnp.float32),
                conv=jnp.zeros((g, batch, cfg.ssm_conv - 1, di), dtype)))
        else:
            h = cfg.n_heads
            dk = d // h
            caches.append(rwkv_mod.RWKVState(
                s=jnp.zeros((g, batch, h, dk, dk), jnp.float32),
                shift_t=jnp.zeros((g, batch, d), dtype),
                shift_c=jnp.zeros((g, batch, d), dtype)))
    return caches


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FwdOpts:
    attn_impl: str = "xla"
    attn_chunk: int = 1024
    remat: str = "nothing_saveable"
    unroll: bool = False   # unroll the group scan (dry-run cost measurement)


def _maybe_scan(body, init, xs, unroll: bool):
    """lax.scan, or an unrolled python loop (used by the dry-run to recover
    per-layer costs: XLA cost_analysis counts a while body only once)."""
    if not unroll:
        return jax.lax.scan(body, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _remat_policy(name: str):
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    if name == "none":
        return None
    return jax.checkpoint_policies.nothing_saveable


def _apply_sublayer_train(p, x, cfg, kind, positions, opts: FwdOpts):
    mixer, ff = kind
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x, cfg.norm)
    if mixer == "attn":
        y = attn.attention_train(p["attn"], h, cfg, positions=positions,
                                 impl=opts.attn_impl, chunk=opts.attn_chunk,
                                 unroll=opts.unroll)
    elif mixer == "mamba":
        y, _ = ssm_mod.mamba_forward(p["mamba"], h, cfg, unroll=opts.unroll)
    else:
        y, _ = rwkv_mod.rwkv_time_mix(p["rwkv_t"], h, cfg, unroll=opts.unroll)
    x = x + y
    h = apply_norm(p["norm2"], x, cfg.norm)
    if ff == "mlp":
        y = apply_mlp(p["mlp"], h, cfg)
    elif ff == "moe":
        y, aux = moe_mod.apply_moe_sharded(p["moe"], h, cfg)
    else:
        y, _ = rwkv_mod.rwkv_channel_mix(p["rwkv_c"], h, cfg)
    return x + y, aux


def forward_train(params, cfg, batch, opts: FwdOpts = FwdOpts()):
    """batch: tokens (B,S) or embeds (B,S,d); optional positions.
    Returns hidden states (B, S, d) and accumulated moe aux loss."""
    if cfg.input_mode == "embeddings":
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = apply_embed(params["embed"], batch["tokens"], cfg)
    b, s = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    p, n_groups = pattern(cfg)
    kinds = layer_kinds(cfg)[:p]

    def group_body(carry, group_params):
        x, aux = carry
        for i, kind in enumerate(kinds):
            x, a = _apply_sublayer_train(group_params[i], x, cfg, kind,
                                         positions, opts)
            aux = aux + a
        return (x, aux), None

    body = group_body
    policy = _remat_policy(opts.remat)
    if policy is not None:
        body = jax.checkpoint(group_body, policy=policy)
    (x, aux), _ = _maybe_scan(body, (x, jnp.zeros((), jnp.float32)),
                              params["blocks"], opts.unroll)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, aux


def loss_fn(params, cfg, batch, opts: FwdOpts = FwdOpts(), z_coef: float = 1e-4,
            aux_coef: float | None = None):
    """Causal-LM cross entropy with z-loss; labels = batch['labels'] (B,S),
    -100 entries masked."""
    x, aux = forward_train(params, cfg, batch, opts)
    logits = apply_head(params["embed"], x, cfg).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    nll = (lse - ll) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = nll.sum() / denom
    zl = z_coef * ((lse * mask) ** 2).sum() / denom
    ac = cfg.router_aux_coef if aux_coef is None else aux_coef
    loss = ce + zl + ac * aux
    return loss, {"ce": ce, "z_loss": zl, "moe_aux": aux,
                  "tokens": mask.sum()}


# -- prefill -----------------------------------------------------------------

def prefill(params, cfg, batch, opts: FwdOpts = FwdOpts(attn_impl="chunked"),
            pad_to: int | None = None):
    """Full forward over the prompt; returns (last-token logits, DecodeState).

    pad_to: reserve KV-cache capacity for decode (defaults to the prompt
    length: the decode ring then overwrites the oldest slot, i.e. the
    decode_32k "cache at capacity" regime)."""
    if cfg.input_mode == "embeddings":
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = apply_embed(params["embed"], batch["tokens"], cfg)
    b, s = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    p, n_groups = pattern(cfg)
    kinds = layer_kinds(cfg)[:p]

    def group_body(x, group_params):
        caches = []
        for i, (mixer, ff) in enumerate(kinds):
            gp = group_params[i]
            h = apply_norm(gp["norm1"], x, cfg.norm)
            if mixer == "attn":
                y, c = attn.attention_prefill(gp["attn"], h, cfg,
                                              positions=positions,
                                              impl=opts.attn_impl,
                                              chunk=opts.attn_chunk,
                                              unroll=opts.unroll)
            elif mixer == "mamba":
                y, c = ssm_mod.mamba_forward(gp["mamba"], h, cfg,
                                             unroll=opts.unroll)
            else:
                y, (s_wkv, shift) = rwkv_mod.rwkv_time_mix(gp["rwkv_t"], h, cfg,
                                                           unroll=opts.unroll)
                c = None  # completed below with channel shift
            x = x + y
            h = apply_norm(gp["norm2"], x, cfg.norm)
            if ff == "mlp":
                y = apply_mlp(gp["mlp"], h, cfg)
            elif ff == "moe":
                y, _ = moe_mod.apply_moe_sharded(gp["moe"], h, cfg)
            else:
                y, shift_c = rwkv_mod.rwkv_channel_mix(gp["rwkv_c"], h, cfg)
                c = rwkv_mod.RWKVState(s=s_wkv, shift_t=shift.astype(x.dtype),
                                       shift_c=shift_c.astype(x.dtype))
            x = x + y
            caches.append(c)
        return x, tuple(caches)

    x, caches = _maybe_scan(group_body, x, params["blocks"], opts.unroll)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = apply_head(params["embed"], x[:, -1:, :], cfg)
    caches = list(caches)
    if pad_to is not None and pad_to > s:
        pad = pad_to - s
        caches = [attn.KVCache(k=jnp.pad(c.k, ((0, 0), (0, 0), (0, pad),
                                               (0, 0), (0, 0))),
                               v=jnp.pad(c.v, ((0, 0), (0, 0), (0, pad),
                                               (0, 0), (0, 0))))
                  if isinstance(c, attn.KVCache) else c for c in caches]
    state = DecodeState(caches=caches,
                        pos=jnp.asarray(s, jnp.int32),
                        cache_len=jnp.asarray(s, jnp.int32))
    return logits.astype(jnp.float32), state


# -- decode ------------------------------------------------------------------

def decode_step(params, cfg, token_or_embed, state: DecodeState,
                positions=None, opts: FwdOpts | None = None):
    """One token for the whole stack. token: (B, 1) int32 (or (B,1,d) embeds).
    Returns (logits (B,1,V), new DecodeState)."""
    if cfg.input_mode == "embeddings" and token_or_embed.ndim == 3:
        x = token_or_embed.astype(jnp.dtype(cfg.dtype))
    else:
        x = apply_embed(params["embed"], token_or_embed, cfg)
    p, n_groups = pattern(cfg)
    kinds = layer_kinds(cfg)[:p]

    def group_body(carry, scanned):
        x = carry
        group_params, caches = scanned
        new_caches = []
        for i, (mixer, ff) in enumerate(kinds):
            gp = group_params[i]
            c = caches[i]
            h = apply_norm(gp["norm1"], x, cfg.norm)
            if mixer == "attn":
                y, nc = attn.attention_decode(
                    gp["attn"], h, cfg, c, pos=state.pos,
                    cache_len=state.cache_len, positions=positions)
            elif mixer == "mamba":
                y, nc = ssm_mod.mamba_decode(gp["mamba"], h, cfg, c)
            else:
                y, (s_wkv, shift) = rwkv_mod.rwkv_time_mix(
                    gp["rwkv_t"], h, cfg,
                    state=rwkv_mod.RWKVState(c.s, c.shift_t, c.shift_c))
                nc = None
            x = x + y
            h = apply_norm(gp["norm2"], x, cfg.norm)
            if ff == "mlp":
                y = apply_mlp(gp["mlp"], h, cfg)
            elif ff == "moe":
                y, _ = moe_mod.apply_moe_sharded(gp["moe"], h, cfg, no_drop=True)
            else:
                y, shift_c = rwkv_mod.rwkv_channel_mix(
                    gp["rwkv_c"], h, cfg,
                    state=rwkv_mod.RWKVState(c.s, c.shift_t, c.shift_c))
                nc = rwkv_mod.RWKVState(s=s_wkv, shift_t=shift.astype(x.dtype),
                                        shift_c=shift_c.astype(x.dtype))
            x = x + y
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_caches = _maybe_scan(group_body, x,
                                (params["blocks"], tuple(state.caches)),
                                bool(opts and opts.unroll))
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = apply_head(params["embed"], x, cfg)
    new_state = DecodeState(caches=list(new_caches), pos=state.pos + 1,
                            cache_len=jnp.minimum(state.cache_len + 1,
                                                  _cache_smax(state)))
    return logits.astype(jnp.float32), new_state


def _cache_smax(state: DecodeState):
    for c in state.caches:
        if isinstance(c, attn.KVCache):
            return c.k.shape[2]
    return jnp.asarray(2**30, jnp.int32)
