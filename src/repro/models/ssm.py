"""Mamba (selective SSM) block for the Jamba hybrid architecture.

TPU adaptation: the CUDA selective-scan kernel is a sequential recurrence
over time with the hidden state in registers. On TPU we use the chunked
formulation: split the sequence into chunks of CHUNK tokens; within a chunk
  h_t = exp(cum_t) * (h_0 + sum_{j<=t} exp(-cum_j) * b_j),
  cum_t = cumsum of log-decays (<= 0),
realized as an exact jax.lax.associative_scan over the affine maps
h -> a*h + b (a = exp(dt*A) in (0,1], so products never overflow); chunks
chain through a lax.scan carry, bounding the scan intermediates to
O(B * CHUNK * d_inner * N) instead of O(B * L * d_inner * N). Validated
against the sequential oracle in tests/test_ssm.py.

The d_inner axis carries the ``ssm_inner`` logical axis (-> model mesh axis),
so the (B, L, d_inner, N) chunk intermediates shard over TP.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import ParamSpec

CHUNK = 128



def ssm_spec(cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    r = max(1, d // 16)
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((cfg.ssm_conv, di), (None, "ssm_inner"),
                            init="normal", scale=0.5),
        "conv_b": ParamSpec((di,), ("ssm_inner",), init="zeros"),
        "x_proj": ParamSpec((di, r + 2 * n), ("ssm_inner", None)),
        "dt_proj": ParamSpec((r, di), (None, "ssm_inner")),
        "dt_bias": ParamSpec((di,), ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec((di, n), ("ssm_inner", None), init="zeros"),
        "d_skip": ParamSpec((di,), ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed")),
    }


class SSMState(NamedTuple):
    h: jax.Array         # (B, d_inner, N)
    conv: jax.Array      # (B, conv_w - 1, d_inner) trailing inputs


def init_ssm_state(cfg, batch: int, dtype=jnp.float32) -> SSMState:
    di = cfg.ssm_expand * cfg.d_model
    return SSMState(
        h=jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype))


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 history: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv via shifted adds. x: (B, L, di); w: (cw, di)."""
    cw = w.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)
    out = b.astype(x.dtype)
    l = x.shape[1]
    for i in range(cw):
        out = out + xp[:, i:i + l, :] * w[i].astype(x.dtype)
    return out


def _a_matrix(p) -> jax.Array:
    # A = -exp(a_log) - 1: strictly negative (a_log init zeros -> A = -2)
    return -(jnp.exp(p["a_log"].astype(jnp.float32)) + 1.0)


def _dt_bc(p, xc: jax.Array, cfg):
    """xc: (..., di) conv+silu output -> (dt (...,di), B (...,N), C (...,N))."""
    r = max(1, cfg.d_model // 16)
    n = cfg.ssm_state
    dbl = xc @ p["x_proj"].astype(xc.dtype)
    dt_low, bc, cc = jnp.split(dbl, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_low.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return dt, bc.astype(jnp.float32), cc.astype(jnp.float32)


def ssm_scan_chunked(dt, bmat, cmat, x1, a, h0, *, chunk: int = CHUNK,
                     unroll: bool = False):
    """dt: (B,L,di); bmat/cmat: (B,L,N); x1: (B,L,di); a: (di,N);
    h0: (B,di,N). Returns (y (B,L,di) f32, h_final)."""
    b, l, di = dt.shape
    n = a.shape[1]
    if unroll:
        # measurement mode: every chunk is unrolled into the HLO for exact
        # cost accounting — cap the chunk COUNT so compile stays tractable
        chunk = max(chunk, -(-l // 4))
    chunk = min(chunk, l)
    nc = -(-l // chunk)
    pad = nc * chunk - l
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        x1 = jnp.pad(x1, ((0, 0), (0, pad), (0, 0)))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    def per_chunk(h, args):
        dtc, bc, cc, xc = args                       # (B,c,di) / (B,c,N)
        la = dtc[..., None] * a                      # (B,c,di,N) log decay <= 0
        binp = dtc[..., None] * bc[:, :, None, :] * xc[..., None].astype(jnp.float32)
        # exact within-chunk prefix composition of h -> a*h + b maps
        aa, bb = jax.lax.associative_scan(combine, (jnp.exp(la), binp), axis=1)
        h_t = aa * h[:, None] + bb                   # (B,c,di,N)
        y = jnp.einsum("bcn,bcdn->bcd", cc, h_t)
        return h_t[:, -1], y

    dts = dt.reshape(b, nc, chunk, di).swapaxes(0, 1)
    bs = bmat.reshape(b, nc, chunk, n).swapaxes(0, 1)
    cs = cmat.reshape(b, nc, chunk, n).swapaxes(0, 1)
    xs = x1.reshape(b, nc, chunk, di).swapaxes(0, 1)
    h_fin, ys = jax.lax.scan(per_chunk, h0.astype(jnp.float32),
                             (dts, bs, cs, xs), unroll=nc if unroll else 1)
    y = ys.swapaxes(0, 1).reshape(b, nc * chunk, di)[:, :l]
    return y, h_fin


def ssm_scan_sequential(dt, bmat, cmat, x1, a, h0):
    """Oracle: plain per-token recurrence (tests + decode reference)."""
    def step(h, args):
        dtt, bt, ct, xt = args
        da = jnp.exp(dtt[..., None] * a)
        h = da * h + dtt[..., None] * bt[:, None, :] * xt[..., None]
        y = jnp.einsum("bn,bdn->bd", ct, h)
        return h, y
    xs = (dt.swapaxes(0, 1), bmat.swapaxes(0, 1), cmat.swapaxes(0, 1),
          x1.astype(jnp.float32).swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), h


def mamba_forward(p, x, cfg, *, state: SSMState | None = None,
                  chunked: bool = True, unroll: bool = False):
    """x: (B, L, d) -> (out (B, L, d), final SSMState)."""
    di = cfg.ssm_expand * cfg.d_model
    xz = x @ p["in_proj"].astype(x.dtype)
    x1, z = jnp.split(xz, 2, axis=-1)
    hist = state.conv if state is not None else None
    xc = jax.nn.silu(_causal_conv(x1, p["conv_w"], p["conv_b"], hist))
    dt, bmat, cmat = _dt_bc(p, xc, cfg)
    a = _a_matrix(p)
    h0 = state.h if state is not None else \
        jnp.zeros((x.shape[0], di, cfg.ssm_state), jnp.float32)
    if chunked:
        y, h_fin = ssm_scan_chunked(dt, bmat, cmat, xc, a, h0, unroll=unroll)
    else:
        y, h_fin = ssm_scan_sequential(dt, bmat, cmat, xc, a, h0)
    y = y + p["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"].astype(x.dtype)
    cw = cfg.ssm_conv
    hist0 = (state.conv if state is not None else
             jnp.zeros((x.shape[0], cw - 1, di), x1.dtype))
    tail = jnp.concatenate([hist0, x1], axis=1)[:, -(cw - 1):, :]
    return out, SSMState(h=h_fin, conv=tail)


def mamba_decode(p, x, cfg, state: SSMState):
    """One-token step. x: (B, 1, d)."""
    out, new_state = mamba_forward(p, x, cfg, state=state, chunked=False)
    return out, new_state
