"""Model zoo: the 10 assigned architectures as composable pure-JAX modules."""
from .model import ModelBundle, abstract_decode_state, batch_specs, build
from .transformer import FwdOpts

__all__ = ["ModelBundle", "abstract_decode_state", "batch_specs", "build",
           "FwdOpts"]
