"""RWKV6 ("Finch") block: data-dependent per-channel decay, attention-free.

Recurrence per head (state S: (Dk, Dv) matrix):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(w_raw_t)) data-dependent per (head, Dk) channel.

TPU adaptation mirrors ssm.py: the CUDA WKV kernel's sequential loop becomes
chunk-wise processing — an exact associative_scan over the affine state maps
within a CHUNK, chained by a lax.scan carry across chunks (decays are in
(0,1), so scan products cannot overflow). The head axis carries the
``heads`` logical axis so chunk intermediates (B, c, H, Dk, Dv) shard over
the model mesh axis. Sequential oracle kept for tests + decode.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import ParamSpec

CHUNK = 32
LORA = 32
LORA_W = 64


def rwkv_time_spec(cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dk = d // h
    return {
        "mu_x": ParamSpec((d,), ("embed",), init="zeros"),
        "mu": ParamSpec((5, d), (None, "embed"), init="zeros"),       # w,k,v,r,g
        "lora1": ParamSpec((d, 5 * LORA), ("embed", None), init="small"),
        "lora2": ParamSpec((5, LORA, d), (None, None, "embed"), init="small"),
        "w0": ParamSpec((d,), ("embed",), init="zeros"),
        "wlora1": ParamSpec((d, LORA_W), ("embed", None), init="small"),
        "wlora2": ParamSpec((LORA_W, d), (None, "embed"), init="small"),
        "bonus": ParamSpec((h, dk), ("heads", "head_dim"), init="zeros"),
        "wr": ParamSpec((d, d), ("embed", "heads_flat")),
        "wk": ParamSpec((d, d), ("embed", "heads_flat")),
        "wv": ParamSpec((d, d), ("embed", "heads_flat")),
        "wg": ParamSpec((d, d), ("embed", "heads_flat")),
        "wo": ParamSpec((d, d), ("heads_flat", "embed")),
        "ln_x_scale": ParamSpec((d,), ("embed",), init="ones"),
        "ln_x_bias": ParamSpec((d,), ("embed",), init="zeros"),
    }


def rwkv_channel_spec(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamSpec((d,), ("embed",), init="zeros"),
        "mu_r": ParamSpec((d,), ("embed",), init="zeros"),
        "wk": ParamSpec((d, f), ("embed", "mlp")),
        "wv": ParamSpec((f, d), ("mlp", "embed")),
        "wr": ParamSpec((d, d), ("embed", "embed_out")),
    }


class RWKVState(NamedTuple):
    s: jax.Array         # (B, H, Dk, Dv) wkv matrix state
    shift_t: jax.Array   # (B, d) prev token input to time-mix
    shift_c: jax.Array   # (B, d) prev token input to channel-mix


def init_rwkv_state(cfg, batch: int, dtype=jnp.float32) -> RWKVState:
    d, h = cfg.d_model, cfg.n_heads
    dk = d // h
    return RWKVState(
        s=jnp.zeros((batch, h, dk, dk), jnp.float32),
        shift_t=jnp.zeros((batch, d), dtype),
        shift_c=jnp.zeros((batch, d), dtype))


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """xx_t = x_{t-1} (zero / carried state at t=0). x: (B, L, d)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None, :].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(p, x, xx):
    """RWKV6 data-dependent lerp -> (x_w, x_k, x_v, x_r, x_g)."""
    dx = xx - x
    z = x + dx * p["mu_x"].astype(x.dtype)
    lo = jnp.tanh(z @ p["lora1"].astype(x.dtype))           # (B,L,5*LORA)
    lo = lo.reshape(*lo.shape[:-1], 5, LORA)
    mix = jnp.einsum("blsr,srd->bsld", lo, p["lora2"].astype(x.dtype))
    # mix: (B,5,L,d); branch b: x + dx*(mu[b] + mix[:,b])
    outs = []
    for b in range(5):
        outs.append(x + dx * (p["mu"][b].astype(x.dtype) + mix[:, b]))
    return outs


def _decay(p, x_w):
    """w_t in (0,1): exp(-exp(w0 + lora(x_w))). Returns log w (<= 0), f32."""
    raw = p["w0"].astype(jnp.float32) + \
        (jnp.tanh(x_w @ p["wlora1"].astype(x_w.dtype)).astype(jnp.float32)
         @ p["wlora2"].astype(jnp.float32))
    return -jnp.exp(jnp.clip(raw, -8.0, 4.0))


def wkv_sequential(r, k, v, logw, u, s0):
    """Oracle. r/k/v: (B,L,H,Dk); logw: (B,L,H,Dk); u: (H,Dk); s0: (B,H,Dk,Dv)."""
    def step(s, args):
        rt, kt, vt, lwt = args  # (B,H,Dk)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,Dk,Dv)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[..., None] * kv)
        s = jnp.exp(lwt)[..., None] * s + kv
        return s, out
    xs = tuple(a.swapaxes(0, 1) for a in (r, k, v, logw))
    s, outs = jax.lax.scan(step, s0, xs)
    return outs.swapaxes(0, 1), s


def wkv_chunked(r, k, v, logw, u, s0, *, chunk: int = CHUNK,
                unroll: bool = False):
    """Exact chunked WKV via associative_scan (see module docstring)."""
    b, l, h, dk = r.shape
    if unroll:
        chunk = max(chunk, -(-l // 4))  # see ssm.py: bounded unroll count
    chunk = min(chunk, l)
    nc = -(-l // chunk)
    pad = nc * chunk - l
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = z(r), z(k), z(v), z(logw)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2[..., None] * b1 + b2

    def per_chunk(s, args):
        rc, kc, vc, lwc = args                            # (B,c,H,Dk)
        kv = kc[..., :, None] * vc[..., None, :]          # (B,c,H,Dk,Dv)
        w = jnp.exp(lwc)                                  # decay applied BEFORE add
        # state after t: S_t = diag(w_t) S_{t-1} + kv_t
        aa, bb = jax.lax.associative_scan(combine, (w, kv), axis=1)
        s_t = aa[..., None] * s[:, None] + bb             # (B,c,H,Dk,Dv)
        s_prev = jnp.concatenate([s[:, None], s_t[:, :-1]], axis=1)
        out = jnp.einsum("bchk,bchkv->bchv", rc,
                         s_prev + u[..., None] * kv)
        return s_t[:, -1], out

    xs = tuple(a.reshape(b, nc, chunk, h, dk).swapaxes(0, 1)
               for a in (r, k, v, logw))
    s, outs = jax.lax.scan(per_chunk, s0, xs, unroll=nc if unroll else 1)
    out = outs.swapaxes(0, 1).reshape(b, nc * chunk, h, dk)[:, :l]
    return out, s


def rwkv_time_mix(p, x, cfg, *, state: RWKVState | None = None,
                  chunked: bool = True, unroll: bool = False):
    """x: (B, L, d) -> (out, (new wkv state, new shift))."""
    b, l, d = x.shape
    h = cfg.n_heads
    dk = d // h
    xx = _token_shift(x, None if state is None else state.shift_t)
    x_w, x_k, x_v, x_r, x_g = _ddlerp(p, x, xx)
    logw = _decay(p, x_w).reshape(b, l, h, dk)
    r = (x_r @ p["wr"].astype(x.dtype)).reshape(b, l, h, dk)
    k = (x_k @ p["wk"].astype(x.dtype)).reshape(b, l, h, dk)
    v = (x_v @ p["wv"].astype(x.dtype)).reshape(b, l, h, dk)
    g = jax.nn.silu(x_g @ p["wg"].astype(x.dtype))
    s0 = (jnp.zeros((b, h, dk, dk), jnp.float32) if state is None
          else state.s)
    u = p["bonus"].astype(jnp.float32)
    if chunked:
        out, s = wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), logw, u, s0,
                             unroll=unroll)
    else:
        out, s = wkv_sequential(r.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32), logw, u, s0)
    out = out.reshape(b, l, d)
    # per-head group norm (ln_x)
    out = out.reshape(b, l, h, dk)
    mu = out.mean(-1, keepdims=True)
    var = ((out - mu) ** 2).mean(-1, keepdims=True)
    out = ((out - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(b, l, d)
    out = out * p["ln_x_scale"].astype(jnp.float32) + p["ln_x_bias"].astype(jnp.float32)
    out = (out.astype(x.dtype) * g) @ p["wo"].astype(x.dtype)
    return out, (s, x[:, -1, :])


def rwkv_channel_mix(p, x, cfg, *, state: RWKVState | None = None):
    xx = _token_shift(x, None if state is None else state.shift_c)
    xk = x + (xx - x) * p["mu_k"].astype(x.dtype)
    xr = x + (xx - x) * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) * (k @ p["wv"].astype(x.dtype))
    return out, x[:, -1, :]
