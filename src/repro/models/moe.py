"""Mixture-of-Experts with static-shape capacity dispatch (EP-shardable).

Dispatch computes each (token, choice)'s position within its expert's
capacity buffer with two stable argsorts (rank within expert group); tokens
past capacity are dropped (Switch/GLaM semantics, capacity_factor controls
the drop rate). The (E*C, d) dispatch buffer keeps every shape static,
scatters/gathers are XLA ops, and the expert dimension carries the
``expert`` logical axis so EP falls out of the sharding rules (kimi: 384
experts / 16-way model axis = 24 experts per device; grok's 8 experts don't
divide the axis so the rules fall back to expert-FFN tensor parallelism).
Sharding constraints pin token-major tensors to DP and the capacity buffer
to EP; see EXPERIMENTS.md §Perf for the before/after roofline terms.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import ParamSpec


def moe_spec(cfg) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    s = {
        "router": ParamSpec((d, e), ("embed", "expert_in"), init="small"),
        "wi": ParamSpec((e, d, f), ("expert", "embed", "expert_mlp")),
        "wo": ParamSpec((e, f, d), ("expert", "expert_mlp", "embed")),
    }
    if cfg.mlp_gated:
        s["wg"] = ParamSpec((e, d, f), ("expert", "embed", "expert_mlp"))
    return s


def apply_moe(p: dict, x: jax.Array, cfg, *,
              no_drop: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    no_drop=True sizes capacity at the worst case (T*k) so no token is ever
    dropped — required on serving decode steps where T is tiny."""
    from repro.sharding.rules import constrain  # lazy: avoids import cycle
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate, idx = jax.lax.top_k(probs, k)                          # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean((jax.nn.one_hot(idx[:, 0], e)), axis=0)
    aux = e * jnp.sum(me * ce)

    cap = t * k if no_drop else int(max(1, round(t * k / e * cfg.capacity_factor)))
    # position-within-expert via two sorts (NOT a (T*k, E) one-hot cumsum:
    # that lowers to a reduce-window XLA costs as ~O(T*k*E) extra flops and
    # dominated the kimi-k2 compute term ~200x — see EXPERIMENTS.md §Perf).
    # Stable sort by expert id groups assignments; rank - group_start is the
    # arrival-order position, identical semantics to the cumsum scheme.
    eid = idx.reshape(-1)                                        # (T*k,)
    order = jnp.argsort(eid, stable=True)
    inv = jnp.argsort(order, stable=True)                        # rank of i
    counts = jnp.bincount(eid, length=e)                         # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = inv - starts[eid]                                      # (T*k,)
    keep = pos < cap
    slot = jnp.where(keep, eid * cap + pos, e * cap)             # overflow slot

    # dispatch buffer: (E*cap + pad, d); row e*cap is the drop/overflow bin,
    # padding keeps dim0 shardable. Sharding constraints pin the dataflow:
    # token-major tensors ride DP, the capacity buffer rides EP (model axis)
    # so dispatch/combine lower to all-to-alls instead of replication (the
    # grok/kimi collective-term pathology, EXPERIMENTS.md §Perf iter 2).
    xrep = jnp.repeat(xf, k, axis=0)                             # (T*k, d)
    xrep = constrain(xrep, ("batch", "act_embed"))
    pad = (-(e * cap + 1)) % 256 + 1
    buf = jnp.zeros((e * cap + pad, d), x.dtype).at[slot].set(xrep)
    hbuf = constrain(buf[: e * cap].reshape(e, cap, d),
                     ("expert", "exp_cap", "act_embed"))

    h = jnp.einsum("ecd,edf->ecf", hbuf, p["wi"].astype(x.dtype))
    if cfg.mlp_gated:
        g = jnp.einsum("ecd,edf->ecf", hbuf, p["wg"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    ybuf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))
    ybuf = constrain(ybuf, ("expert", "exp_cap", "act_embed"))
    ybuf = jnp.concatenate([ybuf.reshape(e * cap, d),
                            jnp.zeros((pad, d), x.dtype)], axis=0)

    y = ybuf[slot] * (gate.reshape(-1) * keep)[:, None].astype(x.dtype)
    y = constrain(y, ("batch", "act_embed"))
    y = y.reshape(t, k, d).sum(axis=1)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# shard_map expert parallelism: expert-LOCAL dispatch
# ---------------------------------------------------------------------------
# GSPMD cannot shard a data-dependent scatter: the pjit dispatch above gets
# "involuntarily rematerialized" into per-device full all-gathers of the
# (T*k, d) token buffer (~240 GB/layer/device for kimi-k2 — EXPERIMENTS.md
# SPerf iter 2/3). Under shard_map the scatter is provably local: tokens
# stay on their DP shard, every model-axis peer routes the SAME replicated
# activations to the experts (EP mode) or expert-FFN slice (TP mode) it
# owns, and the only combine collective is one psum over `model` of the
# (T_local, d) outputs — the information-theoretic floor for capacity-based
# MoE without token re-layout.

def _moe_local(xf, router, wi, wg, wo, cfg, *, e_lo: int, e_local: int,
               cap: int, axis: str | None, act):
    """Per-device body. xf: (T_loc, d) replicated over `model`; weights are
    this peer's expert slice. Computes this peer's contribution; caller
    psums over `model`."""
    t, d = xf.shape
    k, e = cfg.top_k, cfg.n_experts
    logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e), axis=0)
    aux = e * jnp.sum(me * ce)

    eid = idx.reshape(-1)
    mine = (eid >= e_lo) & (eid < e_lo + e_local)
    eid_m = jnp.where(mine, eid - e_lo, e_local)       # sentinel bucket
    order = jnp.argsort(eid_m, stable=True)
    inv = jnp.argsort(order, stable=True)
    counts = jnp.bincount(eid_m, length=e_local + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = inv - starts[eid_m]
    keep = mine & (pos < cap)
    slot = jnp.where(keep, eid_m * cap + pos, e_local * cap)

    xrep = jnp.repeat(xf, k, axis=0)
    buf = jnp.zeros((e_local * cap + 1, d), xf.dtype).at[slot].set(xrep)
    hbuf = buf[: e_local * cap].reshape(e_local, cap, d)
    h = jnp.einsum("ecd,edf->ecf", hbuf, wi.astype(xf.dtype))
    if wg is not None:
        h = act(jnp.einsum("ecd,edf->ecf", hbuf, wg.astype(xf.dtype))) * h
    else:
        h = act(h)
    ybuf = jnp.einsum("ecf,efd->ecd", h, wo.astype(xf.dtype))
    ybuf = jnp.concatenate([ybuf.reshape(e_local * cap, d),
                            jnp.zeros((1, d), xf.dtype)], axis=0)
    y = ybuf[slot] * (gate.reshape(-1) * keep)[:, None].astype(xf.dtype)
    y = y.reshape(t, k, d).sum(axis=1)
    if axis is not None:
        y = jax.lax.psum(y, axis)
        aux = jax.lax.pmean(aux, axis)
    return y, aux


def apply_moe_sharded(p: dict, x: jax.Array, cfg, *, no_drop: bool = False):
    """EP/TP MoE via shard_map when a mesh context is active; falls back to
    apply_moe otherwise. EP mode: each `model` peer owns E/n experts.
    TP mode (E not divisible, e.g. grok's 8 on a 16-way axis): each peer
    owns a d_ff_expert/n slice of EVERY expert; the same combine psum also
    completes the partial contraction."""
    import math
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.sharding.rules import current_mesh
    mesh = current_mesh()
    if mesh is None or "model" not in (mesh.axis_names or ()):
        return apply_moe(p, x, cfg, no_drop=no_drop)
    n_model = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = math.prod(mesh.shape[a] for a in dp_axes)
    b, s, d = x.shape
    e, k, f = cfg.n_experts, cfg.top_k, cfg.d_ff_expert
    ep_mode = e % n_model == 0 and e >= n_model
    tp_mode = (not ep_mode) and f % n_model == 0
    if b % dp != 0 or not (ep_mode or tp_mode):
        return apply_moe(p, x, cfg, no_drop=no_drop)

    t_loc = (b // dp) * s
    cap = (t_loc * k if no_drop else
           int(max(1, round(t_loc * k / e * cfg.capacity_factor))))
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    bspec = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    e_local = e // n_model if ep_mode else e
    w_spec = P("model", None, None) if ep_mode else P(None, None, "model")
    wo_spec = P("model", None, None) if ep_mode else P(None, "model", None)

    def body(x_loc, router, wi, wg, wo):
        xf = x_loc.reshape(-1, d)
        e_lo = (jax.lax.axis_index("model") * e_local) if ep_mode else 0
        y, aux = _moe_local(xf, router, wi,
                            (wg if cfg.mlp_gated else None), wo, cfg,
                            e_lo=e_lo, e_local=e_local, cap=cap,
                            axis="model", act=act)
        for a in dp_axes:
            aux = jax.lax.pmean(aux, a)
        return y.reshape(x_loc.shape), aux

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None), w_spec,
                  (w_spec if cfg.mlp_gated else P()), wo_spec),
        out_specs=(P(bspec, None, None), P()))
    # NOTE (§Perf iter 4, REFUTED): casting weights to bf16 before the
    # shard_map boundary was hypothesized to halve gather traffic; measured
    # +6.5% collective instead — the dominant term is the f32 gradient
    # all-reduce over `data`, which the pre-cast cannot touch.
    return fn(x, p["router"], p["wi"],
              (p["wg"] if cfg.mlp_gated else jnp.zeros((), x.dtype)), p["wo"])
