"""Serving: batched engine, sampling, bucketed scheduler, the GeStore
version-materialization service (gestore_service.py) with its tiered
store-memory manager, and the multi-tenant front door (frontdoor.py)
with admission control and backpressure."""
from .frontdoor import (AdmissionError, DeadlineExceeded, FrontDoor,
                        FrontDoorConfig, Overloaded, QueueFull)
from .gestore_service import GeStoreService, TieredStorePool, VersionRequest

__all__ = [
    "AdmissionError", "DeadlineExceeded", "FrontDoor", "FrontDoorConfig",
    "GeStoreService", "Overloaded", "QueueFull", "TieredStorePool",
    "VersionRequest",
]
