"""Serving: batched engine, sampling, bucketed scheduler."""
