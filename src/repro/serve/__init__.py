"""Serving: batched engine, sampling, bucketed scheduler, and the GeStore
version-materialization service (gestore_service.py)."""
from .gestore_service import GeStoreService, VersionRequest

__all__ = ["GeStoreService", "VersionRequest"]
