"""Serving: batched engine, sampling, bucketed scheduler, and the GeStore
version-materialization service (gestore_service.py) with its tiered
store-memory manager."""
from .gestore_service import GeStoreService, TieredStorePool, VersionRequest

__all__ = ["GeStoreService", "TieredStorePool", "VersionRequest"]
