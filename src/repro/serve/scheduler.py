"""Request scheduler: bucketed continuous batching over the ServeEngine.

Requests arrive asynchronously; the scheduler packs them into shape buckets
(seq padded to powers of two) so the jit cache stays small, dispatches full
(or timed-out) buckets to the engine, and tracks per-request latency. This
is the piece a 1000-node serving fleet scales horizontally; per-host state
is just the queue.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict

import numpy as np

from .engine import ServeEngine


@dataclasses.dataclass
class Request:
    rid: str
    tokens: np.ndarray
    t_submit: float = 0.0
    t_done: float = 0.0
    output: np.ndarray | None = None


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class Scheduler:
    def __init__(self, engine: ServeEngine, *, max_batch: int = 8,
                 max_wait_s: float = 0.0):
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.queues: dict[int, list[Request]] = defaultdict(list)
        self.done: dict[str, Request] = {}

    def submit(self, rid: str, tokens: np.ndarray) -> None:
        req = Request(rid, np.asarray(tokens, np.int32), t_submit=time.time())
        self.queues[_bucket(len(req.tokens))].append(req)

    def _flush_bucket(self, bucket: int) -> None:
        reqs = self.queues[bucket][: self.max_batch]
        self.queues[bucket] = self.queues[bucket][self.max_batch:]
        if not reqs:
            return
        batch = np.full((len(reqs), bucket), self.engine.scfg.pad_id, np.int32)
        for i, r in enumerate(reqs):
            batch[i, : len(r.tokens)] = r.tokens
        outs = self.engine.generate(batch)
        now = time.time()
        for i, r in enumerate(reqs):
            r.output = outs[i]
            r.t_done = now
            self.done[r.rid] = r

    def run_until_drained(self) -> dict:
        while any(self.queues.values()):
            for bucket in sorted(self.queues):
                while self.queues[bucket]:
                    self._flush_bucket(bucket)
        lats = [r.t_done - r.t_submit for r in self.done.values()]
        return {"n_done": len(self.done),
                "p50_latency_s": float(np.percentile(lats, 50)) if lats else 0.0,
                "p99_latency_s": float(np.percentile(lats, 99)) if lats else 0.0}
