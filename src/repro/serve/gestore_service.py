"""GeStore version-materialization service (the serving face of §III.C).

Production platforms re-run analyses against many pinned meta-database
versions concurrently (the paper's motivating workload; OrpheusDB's
multi-version checkout makes the same case for relational data). This
service accepts concurrent get_version-style requests, groups them by store
into timestamp batches, and serves each batch through the store's fused
superlog (core/store._SuperLog + kernels/batched_select.py) — Q versions
cost one batched scan, not Q x F kernel launches.

Materialized views are memoized in an LRU *plan cache* keyed on
``(store, log_epoch)``: a store mutation bumps its epoch, so stale plans
age out naturally without explicit invalidation hooks. Per-host state is
just the queue + cache; a fleet scales this horizontally exactly like
serve/scheduler.py does for token serving.

Tiered memory management: a host serving hundreds of stores cannot keep
every superlog device-resident, nor every cell log in host RAM. When the
service is given a memory budget it wraps its stores in a
``TieredStorePool`` that tracks per-store resident bytes
(``VersionedStore.nbytes()``) and demotes the coldest stores one tier at a
time — device -> host (drop the fused superlog) then host -> disk
(segmented ``save()`` + drop the store object). A spilled store is
transparently reopened with a lazy ``load()`` on next access, and its
``log_epoch`` is floored above the spilled epoch so plan-cache entries from
before the spill can never alias a post-spill mutation.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from collections import OrderedDict
from concurrent.futures import Future
from typing import Iterator, Mapping, Sequence

from repro.core.store import VersionedStore, VersionView
from repro.obs import RECORDER, REGISTRY


@dataclasses.dataclass(frozen=True)
class VersionRequest:
    """One version-materialization request."""
    store: str
    ts: int
    fields: tuple | None = None
    key_filter: str | None = None
    include_deleted: bool = False

    def plan_key(self) -> tuple:
        return (self.ts, self.fields, self.key_filter, self.include_deleted)

    def group_key(self) -> tuple:
        """Requests sharing a group materialize in one get_versions call."""
        return (self.store, self.fields, self.key_filter, self.include_deleted)


class TieredStorePool:
    """Mapping-like store pool enforcing a resident-memory budget.

    Tracks per-store resident bytes and evicts the least-recently-used
    stores tier by tier until the total fits ``budget_bytes``:

      1. device -> host: drop the fused superlog (cheap; the next batched
         query rebuilds it from the host CSR).
      2. host -> disk: segmented ``save()`` to ``spill_root/<store>`` and
         drop the in-memory store. The next ``pool[name]`` reopens it with
         a lazy load, so only the segments a query touches are re-read.
         Sharded stores (core/shard.py) take this tier one shard at a
         time: the facade stays admitted with partial residency and only
         leaves the pool when every shard is already on disk.

    The pool operates on the LIVE backing dict when given one (including a
    GeStore facade's ``stores`` dict): spilling removes the entry from
    that dict too, so the memory is actually reclaimable and other holders
    of the dict see the store disappear instead of mutating an orphan.
    With a GeStore facade, spills go to ``GeStore.store_path(name)`` — the
    same directory ``flush()``/``open_store()`` use — so the facade and
    the pool always agree on where a spilled store lives.

    Epoch safety: before spilling (or replacing via ``add``), the store's
    ``log_epoch`` is recorded and the next store served under that name is
    floored above it, so any cache keyed on ``(store, log_epoch)`` (e.g.
    the service plan cache) can never confuse old content with new.
    """

    def __init__(self, stores, *, budget_bytes: int | None = None,
                 spill_root: str | None = None,
                 shard_placement=None):
        """Args:
          stores: a GeStore facade or {name: VersionedStore} mapping. A
            dict (or a facade's dict) is shared live; other mappings are
            snapshotted.
          budget_bytes: total resident (host+device) byte budget enforced
            by ``enforce()``; None disables eviction.
          spill_root: directory for host->disk spills; None limits
            eviction to the device->host tier unless a GeStore facade
            supplies its own store paths.
          shard_placement: shard->device execution policy pinned onto
            every sharded store the pool serves (admitted now, ``add``-ed
            later, or reloaded after a spill — reloads must not silently
            re-plan). A ``core.placement.ShardPlacement``, or a force
            string ("parallel"/"serial") planned per store's shard count;
            None leaves stores to auto-plan (see ``plan_placement``).
        """
        self._facade = stores if hasattr(stores, "store_path") else None
        backing = getattr(stores, "stores", stores)
        self._stores: dict[str, VersionedStore] = (
            backing if isinstance(backing, dict) else dict(backing))
        self.budget_bytes = budget_bytes
        self.spill_root = spill_root
        self.shard_placement = shard_placement
        for st in self._stores.values():
            self._apply_placement(st)
        self._spilled: dict[str, str] = {}        # name -> save path
        self._epoch_floor: dict[str, int] = {}
        self._lru: OrderedDict[str, None] = OrderedDict(
            (n, None) for n in self._stores)
        self.stats = {"demotions": 0, "spills": 0, "shard_spills": 0,
                      "reloads": 0}
        # decayed disk-churn score feeding pressure() — see that docstring
        self._thrash = 0.0

    def _spill_path(self, name: str) -> str | None:
        if self._facade is not None:
            return self._facade.store_path(name)
        if self.spill_root is not None:
            # store_dir_name, not fs_name: names that sanitize identically
            # ('a/b' vs 'a_b') must not spill over each other's directory
            return os.path.join(self.spill_root, _store_dir_name(name))
        return None

    def _apply_floor(self, name: str, st: VersionedStore) -> VersionedStore:
        floor = self._epoch_floor.get(name, 0)
        if st._log_epoch < floor:
            st._log_epoch = floor
        return st

    def _apply_placement(self, st) -> None:
        """Pin the pool's shard->device policy onto a sharded store (plain
        stores have no placement and pass through untouched)."""
        sp = self.shard_placement
        if sp is None or not hasattr(st, "placement"):
            return
        if isinstance(sp, str):
            from repro.core.placement import plan_placement
            sp = plan_placement(st.n_shards, force=sp)
        st.placement = sp

    # -- mapping interface ----------------------------------------------------
    def __getitem__(self, name: str) -> VersionedStore:
        st = self._stores.get(name)
        if st is None:
            path = self._spilled.get(name)
            if path is None:
                raise KeyError(name)
            # load first, forget the spill record only on success: a failed
            # reload (e.g. CorruptSegmentError) must keep surfacing instead
            # of decaying into a KeyError on the next access. open_any_store
            # dispatches on the directory flavor, so sharded stores round-
            # trip through spills too.
            from repro.core.shard import open_any_store
            st = self._apply_floor(name, open_any_store(path, lazy=True))
            self._apply_placement(st)
            del self._spilled[name]
            self._stores[name] = st
            self.stats["reloads"] += 1
            self._thrash += 1.0
            REGISTRY.counter("pool.reloads").inc()
            RECORDER.record("pool_reload", store=name, path=path)
        elif name in self._spilled:
            # someone else (e.g. GeStore.open_store) reloaded it into the
            # shared dict first; adopt it and keep the epoch guarantee
            del self._spilled[name]
            self._apply_floor(name, st)
        self._lru[name] = None
        self._lru.move_to_end(name)
        return st

    def __contains__(self, name: object) -> bool:
        return name in self._stores or name in self._spilled

    def __iter__(self) -> Iterator[str]:
        yield from {**dict.fromkeys(self._stores),
                    **dict.fromkeys(self._spilled)}

    def __len__(self) -> int:
        return len(self._stores) + len(self._spilled)

    def keys(self):
        return list(self)

    def add(self, name: str, store: VersionedStore) -> None:
        """Register a store created after pool construction. Replacing an
        existing (or spilled) name advances the epoch floor past the old
        store, so plan-cache entries for it can never serve the new one."""
        old = self._stores.get(name)
        if old is not None:
            self._epoch_floor[name] = max(self._epoch_floor.get(name, 0),
                                          old.log_epoch + 1)
        self._stores[name] = self._apply_floor(name, store)
        self._apply_placement(store)
        self._spilled.pop(name, None)
        self._lru[name] = None

    # -- accounting + eviction ------------------------------------------------
    def resident_bytes(self) -> int:
        """Total host+device bytes of every in-memory store."""
        return sum(sum(st.nbytes().values()) for st in self._stores.values())

    #: pressure() = thrash / PRESSURE_SCALE, thrash halving per enforce():
    #: a pool re-spilling what it just reloaded (2 events/cycle) converges
    #: on thrash 4.0 => pressure 1.0, the canonical "thrashing" level.
    PRESSURE_DECAY = 0.5
    PRESSURE_SCALE = 4.0

    def pressure(self) -> float:
        """Backpressure signal for the serving layer, in [0, inf).

        A decayed count of disk-tier churn events (whole-store spills,
        shard spills, and lazy reloads; device->host demotions are cheap
        and excluded): each event adds 1, and every ``enforce()`` cycle
        halves the accumulated score before adding its own events. The
        score is therefore deterministic — a function of the event
        sequence, not of wall time — which the seeded scheduling tests
        rely on. Calibration: 0 = calm (a pool comfortably within budget
        decays to 0 geometrically); >= 1.0 = thrashing (the steady state
        of a pool that reloads a store every wave only to spill it again).
        The front door (serve/frontdoor.py) degrades reads to serial at
        ``serial_pressure`` and sheds new reads at ``shed_pressure``."""
        return self._thrash / self.PRESSURE_SCALE

    def enforce(self) -> int:
        """Evict coldest-first until within budget; returns evictions
        performed (a demotion, a shard spill, and a whole-store spill each
        count one). Resident bytes are computed once and maintained
        incrementally, so one call is one walk over the pool, not
        O(stores) walks.

        Sharded stores (anything exposing ``spill_shard``) evict with
        per-shard granularity: shards spill to disk one at a time (the
        facade stays admitted with partial residency, reloading spilled
        shards lazily on the next query), and only when every shard is
        out does the facade itself leave the pool like a plain store."""
        if self.budget_bytes is None:
            return 0
        self._thrash *= self.PRESSURE_DECAY
        per_store = {name: sum(st.nbytes().values())
                     for name, st in self._stores.items()}
        total = sum(per_store.values())
        n = 0

        def recount(name, st):
            nonlocal total
            now = sum(st.nbytes().values())
            total -= per_store[name] - now
            per_store[name] = now

        # coldest first; stores never served via the pool come last
        order = list(self._lru) + [m for m in self._stores
                                   if m not in self._lru]
        for name in order:
            if total <= self.budget_bytes:
                break
            st = self._stores.get(name)
            if st is None:
                continue
            if st.has_device_state():               # tier 1: device -> host
                st.drop_superlog()
                self.stats["demotions"] += 1
                REGISTRY.counter("pool.demotions").inc()
                n += 1
                recount(name, st)
                if total <= self.budget_bytes:
                    break
            path = self._spill_path(name)
            if path is None:
                continue
            if hasattr(st, "spill_shard"):          # tier 2a: shard by shard
                while (total > self.budget_bytes
                       and st.spill_shard(root=path) is not None):
                    self.stats["shard_spills"] += 1
                    self._thrash += 1.0
                    REGISTRY.counter("pool.shard_spills").inc()
                    RECORDER.record("pool_shard_spill", store=name,
                                    path=path)
                    n += 1
                    recount(name, st)
                if st.resident_shard_ids():
                    continue  # partial residency: the facade stays admitted
                # every shard on disk: fall through and drop the facade too
                # — its key index is unaccounted host memory (save() below
                # costs one manifest re-commit at most)
            st.save(path)                           # tier 2: host -> disk
            self._epoch_floor[name] = st.log_epoch + 1
            self._spilled[name] = path
            del self._stores[name]
            self._lru.pop(name, None)
            total -= per_store.pop(name, 0)
            self.stats["spills"] += 1
            self._thrash += 1.0
            REGISTRY.counter("pool.spills").inc()
            RECORDER.record("pool_spill", store=name, path=path)
            n += 1
        return n


def _store_dir_name(name: str) -> str:
    from repro.core.segments import store_dir_name
    return store_dir_name(name)


class GeStoreService:
    """Concurrent batched version materialization over a set of stores.

    ``submit`` is thread-safe and returns a Future; ``flush`` drains the
    queue, batching per store. ``materialize`` is the synchronous
    convenience wrapper. Served views are memoized and shared across
    clients, so their arrays are read-only — copy before mutating.

    With ``memory_budget_bytes`` (and optionally ``spill_root``) set, the
    stores are wrapped in a ``TieredStorePool`` and the budget is enforced
    after every flush — cold stores demote device -> host -> disk and
    reload lazily from their segments on the next request for them.
    """

    def __init__(self, stores, *, max_batch: int = 64,
                 plan_cache_size: int = 16, max_views_per_plan: int = 256,
                 memory_budget_bytes: int | None = None,
                 spill_root: str | None = None,
                 shard_placement=None):
        """Args:
          stores: a GeStore facade, {name: VersionedStore} mapping, or an
            existing TieredStorePool.
          max_batch: max distinct timestamps per get_versions call.
          plan_cache_size: LRU capacity in (store, log_epoch) plans.
          max_views_per_plan: LRU capacity of views within one plan.
          memory_budget_bytes / spill_root: tiered-memory knobs (see
            TieredStorePool); both None = no eviction (seed behavior).
          shard_placement: shard->device policy for sharded stores (see
            TieredStorePool; a ShardPlacement or "parallel"/"serial").
            Builds a pool even without a memory budget so the policy
            sticks across adds and spill reloads.
        """
        backing = getattr(stores, "stores", stores)
        if isinstance(backing, TieredStorePool):
            self.pool: TieredStorePool | None = backing
        elif (memory_budget_bytes is not None or spill_root is not None
              or shard_placement is not None):
            # pass the original object: a GeStore facade carries the spill
            # paths its own flush()/open_store() use
            self.pool = TieredStorePool(stores,
                                        budget_bytes=memory_budget_bytes,
                                        spill_root=spill_root,
                                        shard_placement=shard_placement)
        else:
            self.pool = None
        # explicit None check: the pool defines __len__, so an empty pool is
        # falsy and `self.pool or backing` would silently bypass it
        self._stores: Mapping[str, VersionedStore] = (
            backing if self.pool is None else self.pool)
        self.max_batch = max_batch
        self.plan_cache_size = plan_cache_size
        self.max_views_per_plan = max_views_per_plan
        self._lock = threading.Lock()          # guards the pending queue
        self._flush_lock = threading.Lock()    # serializes plan cache + stats
        self._pending: list[tuple[VersionRequest, Future]] = []
        # (store, log_epoch) -> {plan_key: VersionView}, LRU over the epochs
        self._plans: OrderedDict[tuple, dict] = OrderedDict()
        self.stats = {"requests": 0, "batches": 0, "plan_hits": 0,
                      "plan_misses": 0}

    # -- request intake -------------------------------------------------------
    def submit(self, store: str, ts: int, *, fields: Sequence[str] | None = None,
               key_filter: str | None = None,
               include_deleted: bool = False) -> "Future[VersionView]":
        """Enqueue one version-materialization request (thread-safe).

        Args:
          store: store name; ts: version timestamp; fields/key_filter/
            include_deleted: forwarded to ``VersionedStore.get_versions``.

        Returns:
          A Future resolved by a later ``flush()`` with a shared, read-only
          VersionView (copy before mutating). The Future carries
          ``KeyError`` for an unknown store and any store-level error.
        """
        req = VersionRequest(store, int(ts),
                             tuple(fields) if fields is not None else None,
                             key_filter, include_deleted)
        fut: Future = Future()
        with self._lock:
            self._pending.append((req, fut))
            self.stats["requests"] += 1
        return fut

    def materialize(self, requests: Sequence[VersionRequest]) -> list[VersionView]:
        """Synchronous convenience: submit every request, flush once, and
        return the views aligned with ``requests``. Raises whatever the
        underlying store raised for the failing request, if any."""
        futs = [self.submit(r.store, r.ts, fields=r.fields,
                            key_filter=r.key_filter,
                            include_deleted=r.include_deleted)
                for r in requests]
        self.flush()
        return [f.result() for f in futs]

    # -- plan cache -----------------------------------------------------------
    def _plan(self, store_name: str) -> OrderedDict:
        store = self._stores[store_name]
        key = (store_name, store.log_epoch)
        plan = self._plans.get(key)
        if plan is None:
            plan = self._plans[key] = OrderedDict()
        self._plans.move_to_end(key)
        while len(self._plans) > self.plan_cache_size:
            self._plans.popitem(last=False)
        return plan

    # -- batched service loop -------------------------------------------------
    def flush(self) -> int:
        """Serve every pending request; returns the number served.
        Concurrent flushes each drain their own slice of the queue and
        serialize on the plan cache (it is an unsynchronized OrderedDict)."""
        with self._lock:
            pending, self._pending = self._pending, []
        if not pending:
            return 0
        with self._flush_lock:
            return self._serve(pending)

    def serve_wave(self, items: list[tuple[VersionRequest, Future]], *,
                   cancel=None, trace: dict | None = None,
                   enforce_pool: bool = True) -> int:
        """Serve a pre-assembled wave, bypassing the submit queue — the
        front door's dispatch entry point (serve/frontdoor.py): it owns
        wave composition (per-tenant fairness, priority, deadlines) and
        this method owns execution (plan cache, batched scans, tiered
        budget). ``cancel``/``trace`` follow the
        ``VersionedStore.get_versions`` contract; ``enforce_pool=False``
        skips budget enforcement for callers that enforce once per pump
        cycle instead of per wave. Thread-safe (serializes with flush)."""
        with self._flush_lock:
            return self._serve(items, cancel=cancel, trace=trace,
                               enforce_pool=enforce_pool)

    def store(self, name: str):
        """The live store for ``name`` through the tiered pool (reloading
        a spilled store lazily) — the mutation path the front door uses.
        Raises KeyError for an unknown store."""
        return self._stores[name]

    def pool_pressure(self) -> float:
        """The tiered pool's backpressure signal (0.0 without a pool)."""
        return 0.0 if self.pool is None else self.pool.pressure()

    def enforce_pool(self) -> int:
        """Enforce the tiered budget now (0 evictions without a pool)."""
        return 0 if self.pool is None else self.pool.enforce()

    def _serve(self, pending: list[tuple[VersionRequest, Future]], *,
               cancel=None, trace: dict | None = None,
               enforce_pool: bool = True) -> int:
        groups: dict[tuple, list[tuple[VersionRequest, Future]]] = {}
        for req, fut in pending:
            groups.setdefault(req.group_key(), []).append((req, fut))
        for (store_name, fields, key_filter, include_deleted), items in groups.items():
            try:
                store = self._stores[store_name]
                plan = self._plan(store_name)
                todo = []  # deduped uncached plan keys, insertion-ordered
                for req, _ in items:
                    pk = req.plan_key()
                    if pk in plan or pk in todo:  # in-flight dup = a hit too
                        self.stats["plan_hits"] += 1
                    else:
                        todo.append(pk)
                        self.stats["plan_misses"] += 1
                for chunk in (todo[i:i + self.max_batch]
                              for i in range(0, len(todo), self.max_batch)):
                    views = store.get_versions(
                        [pk[0] for pk in chunk],
                        fields=list(fields) if fields is not None else None,
                        key_filter=key_filter,
                        include_deleted=include_deleted,
                        cancel=cancel, trace=trace)
                    self.stats["batches"] += 1
                    for view in views:
                        # memoized views are shared across clients: freeze
                        # them so in-place edits fail loudly instead of
                        # corrupting every later cache hit
                        for arr in view.values.values():
                            arr.setflags(write=False)
                        view.row_idx.setflags(write=False)
                    plan.update(zip(chunk, views))
                for req, fut in items:
                    pk = req.plan_key()
                    plan.move_to_end(pk)
                    view = plan[pk]
                    if fut.set_running_or_notify_cancel():  # skip cancelled
                        fut.set_result(view)
                # bound memory within one long-lived epoch too
                while len(plan) > self.max_views_per_plan:
                    plan.popitem(last=False)
            except Exception as e:
                REGISTRY.counter("service.wave_errors").inc()
                RECORDER.record("wave_error", store=store_name,
                                error=repr(e), requests=len(items))
                for _, fut in items:
                    if not fut.done() and fut.set_running_or_notify_cancel():
                        fut.set_exception(e)
        if enforce_pool and self.pool is not None:
            self.pool.enforce()
        return len(pending)
