"""GeStore version-materialization service (the serving face of §III.C).

Production platforms re-run analyses against many pinned meta-database
versions concurrently (the paper's motivating workload; OrpheusDB's
multi-version checkout makes the same case for relational data). This
service accepts concurrent get_version-style requests, groups them by store
into timestamp batches, and serves each batch through the store's fused
superlog (core/store._SuperLog + kernels/batched_select.py) — Q versions
cost one batched scan, not Q x F kernel launches.

Materialized views are memoized in an LRU *plan cache* keyed on
``(store, log_epoch)``: a store mutation bumps its epoch, so stale plans
age out naturally without explicit invalidation hooks. Per-host state is
just the queue + cache; a fleet scales this horizontally exactly like
serve/scheduler.py does for token serving.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from concurrent.futures import Future
from typing import Mapping, Sequence

from repro.core.store import VersionedStore, VersionView


@dataclasses.dataclass(frozen=True)
class VersionRequest:
    """One version-materialization request."""
    store: str
    ts: int
    fields: tuple | None = None
    key_filter: str | None = None
    include_deleted: bool = False

    def plan_key(self) -> tuple:
        return (self.ts, self.fields, self.key_filter, self.include_deleted)

    def group_key(self) -> tuple:
        """Requests sharing a group materialize in one get_versions call."""
        return (self.store, self.fields, self.key_filter, self.include_deleted)


class GeStoreService:
    """Concurrent batched version materialization over a set of stores.

    ``submit`` is thread-safe and returns a Future; ``flush`` drains the
    queue, batching per store. ``materialize`` is the synchronous
    convenience wrapper. Served views are memoized and shared across
    clients, so their arrays are read-only — copy before mutating.
    """

    def __init__(self, stores, *, max_batch: int = 64,
                 plan_cache_size: int = 16, max_views_per_plan: int = 256):
        # accept a GeStore facade, or any {name: VersionedStore} mapping
        self._stores: Mapping[str, VersionedStore] = getattr(
            stores, "stores", stores)
        self.max_batch = max_batch
        self.plan_cache_size = plan_cache_size
        self.max_views_per_plan = max_views_per_plan
        self._lock = threading.Lock()          # guards the pending queue
        self._flush_lock = threading.Lock()    # serializes plan cache + stats
        self._pending: list[tuple[VersionRequest, Future]] = []
        # (store, log_epoch) -> {plan_key: VersionView}, LRU over the epochs
        self._plans: OrderedDict[tuple, dict] = OrderedDict()
        self.stats = {"requests": 0, "batches": 0, "plan_hits": 0,
                      "plan_misses": 0}

    # -- request intake -------------------------------------------------------
    def submit(self, store: str, ts: int, *, fields: Sequence[str] | None = None,
               key_filter: str | None = None,
               include_deleted: bool = False) -> "Future[VersionView]":
        req = VersionRequest(store, int(ts),
                             tuple(fields) if fields is not None else None,
                             key_filter, include_deleted)
        fut: Future = Future()
        with self._lock:
            self._pending.append((req, fut))
            self.stats["requests"] += 1
        return fut

    def materialize(self, requests: Sequence[VersionRequest]) -> list[VersionView]:
        futs = [self.submit(r.store, r.ts, fields=r.fields,
                            key_filter=r.key_filter,
                            include_deleted=r.include_deleted)
                for r in requests]
        self.flush()
        return [f.result() for f in futs]

    # -- plan cache -----------------------------------------------------------
    def _plan(self, store_name: str) -> OrderedDict:
        store = self._stores[store_name]
        key = (store_name, store.log_epoch)
        plan = self._plans.get(key)
        if plan is None:
            plan = self._plans[key] = OrderedDict()
        self._plans.move_to_end(key)
        while len(self._plans) > self.plan_cache_size:
            self._plans.popitem(last=False)
        return plan

    # -- batched service loop -------------------------------------------------
    def flush(self) -> int:
        """Serve every pending request; returns the number served.
        Concurrent flushes each drain their own slice of the queue and
        serialize on the plan cache (it is an unsynchronized OrderedDict)."""
        with self._lock:
            pending, self._pending = self._pending, []
        if not pending:
            return 0
        with self._flush_lock:
            return self._serve(pending)

    def _serve(self, pending: list[tuple[VersionRequest, Future]]) -> int:
        groups: dict[tuple, list[tuple[VersionRequest, Future]]] = {}
        for req, fut in pending:
            groups.setdefault(req.group_key(), []).append((req, fut))
        for (store_name, fields, key_filter, include_deleted), items in groups.items():
            try:
                store = self._stores[store_name]
                plan = self._plan(store_name)
                todo = []  # deduped uncached plan keys, insertion-ordered
                for req, _ in items:
                    pk = req.plan_key()
                    if pk in plan or pk in todo:  # in-flight dup = a hit too
                        self.stats["plan_hits"] += 1
                    else:
                        todo.append(pk)
                        self.stats["plan_misses"] += 1
                for chunk in (todo[i:i + self.max_batch]
                              for i in range(0, len(todo), self.max_batch)):
                    views = store.get_versions(
                        [pk[0] for pk in chunk],
                        fields=list(fields) if fields is not None else None,
                        key_filter=key_filter,
                        include_deleted=include_deleted)
                    self.stats["batches"] += 1
                    for view in views:
                        # memoized views are shared across clients: freeze
                        # them so in-place edits fail loudly instead of
                        # corrupting every later cache hit
                        for arr in view.values.values():
                            arr.setflags(write=False)
                        view.row_idx.setflags(write=False)
                    plan.update(zip(chunk, views))
                for req, fut in items:
                    pk = req.plan_key()
                    plan.move_to_end(pk)
                    view = plan[pk]
                    if fut.set_running_or_notify_cancel():  # skip cancelled
                        fut.set_result(view)
                # bound memory within one long-lived epoch too
                while len(plan) > self.max_views_per_plan:
                    plan.popitem(last=False)
            except Exception as e:
                for _, fut in items:
                    if not fut.done() and fut.set_running_or_notify_cancel():
                        fut.set_exception(e)
        return len(pending)
