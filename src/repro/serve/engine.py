"""Batched serving engine: prefill + decode with jit'd steps.

Request flow mirrors production continuous batching at the granularity this
substrate needs: requests are grouped into fixed-shape batches (padding to
the bucket), prefilled once (building ring KV caches with decode headroom),
then decoded step-by-step with per-request EOS masking; finished rows keep
decoding into padding but are masked out of the results (slot reuse across
bucket boundaries is the scheduler's job, serve/scheduler.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import build
from repro.models.transformer import FwdOpts
from .sampling import sample


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    eos_id: int = -1               # -1: never stop early
    pad_id: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig | None = None,
                 run: RunConfig | None = None):
        # fresh default per engine: a shared ServeConfig() instance would
        # leak one caller's knob tweaks into every other engine
        self.cfg, self.scfg = cfg, scfg if scfg is not None else ServeConfig()
        self.params = params
        self.bundle = build(cfg)
        run = run or RunConfig()
        opts = FwdOpts(attn_impl=run.attn_impl, attn_chunk=run.attn_chunk)
        self._prefill = jax.jit(
            lambda p, b, pad: self.bundle.prefill(p, b, opts, pad_to=pad),
            static_argnums=(2,))
        self._decode = jax.jit(self.bundle.decode)
        self.stats = {"requests": 0, "prefill_tokens": 0, "decode_tokens": 0}

    def generate(self, prompts: np.ndarray, *, seed: int = 0) -> np.ndarray:
        """prompts: (B, S) int32 (left-aligned, pad with pad_id). Returns
        (B, max_new_tokens) generated ids (pad after EOS)."""
        b, s = prompts.shape
        pad_to = s + self.scfg.max_new_tokens
        logits, state = self._prefill(self.params, {"tokens": jnp.asarray(prompts)},
                                      pad_to)
        self.stats["requests"] += b
        self.stats["prefill_tokens"] += b * s
        key = jax.random.key(seed)
        out = np.full((b, self.scfg.max_new_tokens), self.scfg.pad_id, np.int32)
        done = np.zeros(b, bool)
        tok = None
        for t in range(self.scfg.max_new_tokens):
            key, sub = jax.random.split(key)
            tok = sample(logits, sub, temperature=self.scfg.temperature,
                         top_k=self.scfg.top_k)
            ids = np.asarray(tok)[:, 0]
            ids = np.where(done, self.scfg.pad_id, ids)
            out[:, t] = ids
            done |= (ids == self.scfg.eos_id)
            self.stats["decode_tokens"] += int((~done).sum())
            if done.all():
                break
            logits, state = self._decode(self.params, jnp.asarray(ids[:, None]),
                                         state)
        return out
