"""Multi-tenant serving front door: admission, fairness, backpressure.

The paper's platform serves meta-database versions to many concurrent
analysis jobs; OrpheusDB makes the same case for relational data — bolt-on
versioning behind a normal database interface that heavy concurrent
clients hit without knowing about it. ``GeStoreService`` gave us batched
execution and a plan cache, but nothing that looks like the door a
million users walk through: no per-tenant fairness, no admission control,
no deadline story, no backpressure when the tiered pool is thrashing.
This module is that door.

Request lifecycle::

    submit ──admission──▶ per-tenant queue ──schedule──▶ wave ──▶ dispatch
              (reject)      (priority/deadline)  (batch + riders)    │
                                                                     ▼
                                              GeStoreService.serve_wave
                                              (plan cache, fused scans)

**Admission control** (every rejection is one of these, and nothing else
is ever rejected — the property tests pin this):

  1. ``QueueFull`` — the tenant's queue already holds
     ``max_queue_per_tenant`` requests at submit time. Raised
     synchronously from ``submit*``.
  2. ``Overloaded`` — a *read* submitted while the tiered pool's
     ``pressure()`` is at or above ``shed_pressure`` (mutations are never
     pressure-shed: dropping an ingest loses data, dropping a read loses
     a retry). Raised synchronously from ``submit``.
  3. ``DeadlineExceeded`` — the request's deadline had passed when the
     scheduler considered it for dispatch. Delivered asynchronously
     through the request's future.

**Scheduling.** Tenants are served round-robin (the fairness bound: while
a tenant has pending work, every other tenant initiates at most one wave
before it runs — no starvation). Within a tenant, requests order by
``(-priority, deadline, seq)``: higher priority first, earlier deadline
breaks ties, submission order breaks those. Mutations dispatch alone and
in queue order; reads batch into waves.

**Batching.** A read wave groups compatible ``get_versions`` requests —
same ``(store, fields, key_filter, include_deleted)`` — first from the
initiating tenant's queue, then *riders* from other tenants, up to
``max_wave``. The wave dispatches through ``GeStoreService.serve_wave``,
which batches per ``(store, log_epoch)`` in its plan cache, so one fused
superlog scan answers the whole wave. "Up to batching" is the one relaxation
of priority order: a low-priority request may resolve early by riding a
compatible higher-priority wave (it never *delays* anyone — riders add
zero scans).

**Backpressure.** The tiered pool's ``pressure()`` (a deterministic
decayed spill/reload churn score, see ``TieredStorePool.pressure``) feeds
two thresholds: at ``serial_pressure`` read waves degrade to a single
request (the cold single-ts path avoids building whole-store superlogs
that would immediately be evicted again), and at ``shed_pressure`` new
reads are rejected at the door. Every dispatched wave carries a
cooperative-cancellation token, so a wave whose every request was
cancelled or shed aborts between stages instead of paying for device work
(``core.store.OperationCancelled``).

**Observability.** Every admitted request is minted a ``trace_id``
(``repro.obs.trace.new_trace_id``) at submit; the wave it dispatches in
runs inside a ``span()`` carrying that id, so stage timings and failure
events all the way down to segment I/O land in the flight recorder
under the request's trace. Per-stage wall times (queue, batch-form,
scan, gather, materialize, exec, total) aggregate into bounded
``repro.obs`` histograms — owned by a per-door ``MetricsRegistry`` so
two doors in one process never alias — surfaced as p50/p99 by
``stats()``, which ``benchmarks/table9_serving.py`` writes into
``BENCH_results.json``. Rejections (queue-full, pressure, deadline) are
counted per tenant and recorded as ``admission_reject`` flight-recorder
events.

Determinism for tests: with an injected ``clock`` and a caller-driven
``pump()`` (no background thread), scheduling is a pure function of the
submission sequence — the seeded stress/property suites rely on this.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import threading
import time
from collections import defaultdict
from concurrent.futures import Future
from typing import Callable, Mapping, Sequence

from repro.obs import RECORDER
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.trace import new_trace_id, span

from .gestore_service import GeStoreService, VersionRequest

READ = "get_versions"
MUTATIONS = ("update", "delete", "compact")
STAGES = ("queue", "batch", "scan", "gather", "materialize", "exec", "total")


class AdmissionError(RuntimeError):
    """A request the front door refused; ``reason`` names the policy."""
    reason = "admission"


class QueueFull(AdmissionError):
    """The tenant's bounded queue was full at submit time."""
    reason = "queue_full"


class Overloaded(AdmissionError):
    """A read arrived while pool pressure was at/above ``shed_pressure``."""
    reason = "pressure"


class DeadlineExceeded(AdmissionError):
    """The deadline passed before the scheduler could dispatch the
    request (delivered via the future, not raised at submit)."""
    reason = "deadline"


@dataclasses.dataclass
class FrontDoorConfig:
    """Front-door policy knobs.

    Attributes:
      max_queue_per_tenant: admission bound per tenant queue (QueueFull
        beyond it).
      max_wave: max requests batched into one read wave (initiator +
        riders).
      serial_pressure: pool pressure at/above which read waves degrade to
        a single request.
      shed_pressure: pool pressure at/above which new reads are rejected
        (``Overloaded``). Mutations are never pressure-shed.
      default_priority: priority assigned when ``submit*`` gets none.
      clock: monotonic-seconds source for deadlines/latency; injectable
        so scheduling tests are deterministic.
      hist_cap: per-stage histogram ring capacity (memory bound).
    """
    max_queue_per_tenant: int = 64
    max_wave: int = 32
    serial_pressure: float = 0.5
    shed_pressure: float = 1.5
    default_priority: int = 0
    clock: Callable[[], float] = time.monotonic
    hist_cap: int = 8192


@dataclasses.dataclass
class Ticket:
    """One admitted request: queue entry + trace context + future."""
    seq: int
    tenant: str
    store: str
    kind: str                      # READ or one of MUTATIONS
    priority: int
    deadline: float | None         # absolute clock() time; None = never
    future: Future
    t_submit: float
    req: VersionRequest | None = None    # reads only
    payload: dict | None = None          # mutations only
    wave: int = -1                       # dispatch wave index
    rider: bool = False                  # batched into another's wave
    trace_id: str = ""                   # minted at admission

    def sort_key(self) -> tuple:
        return (-self.priority,
                self.deadline if self.deadline is not None else math.inf,
                self.seq)

    def group_key(self) -> tuple | None:
        return self.req.group_key() if self.req is not None else None


class FrontDoor:
    """The serving front door over a ``GeStoreService``.

    Drive it either caller-pumped (deterministic: ``pump()`` dispatches
    waves until idle) or with a background dispatcher thread
    (``start()``/``stop()``). Mutations execute on the dispatcher, so all
    store access is serialized through it — per-store mutation order is
    the per-tenant queue order, and a read submitted after a mutation's
    future resolved always observes that mutation (read-your-writes).

    Cross-tenant writes to one store are not ordered by the front door;
    the store's own timestamp-monotonicity guard makes such races loud
    (the losing update's future carries ``ValueError``) rather than
    corrupting — give each store a single writer tenant.
    """

    def __init__(self, stores, *, config: FrontDoorConfig | None = None,
                 **service_kwargs):
        """Args:
          stores: an existing ``GeStoreService``, or anything its
            constructor accepts (GeStore facade, name->store mapping,
            TieredStorePool).
          config: policy knobs (``FrontDoorConfig``).
          service_kwargs: forwarded to ``GeStoreService`` when ``stores``
            is not already one (e.g. ``memory_budget_bytes``,
            ``spill_root``, ``shard_placement``).
        """
        self.config = config or FrontDoorConfig()
        self.service = (stores if isinstance(stores, GeStoreService)
                        else GeStoreService(stores, **service_kwargs))
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._queues: dict[str, list[Ticket]] = {}
        self._rr: list[str] = []      # tenant cycle, first-submit order
        self._rr_pos = 0
        self._seq = 0
        self._wave_no = 0
        self._dispatch_lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._stopping = False
        #: per-door registry: two doors in one process must not alias
        #: latency histograms (stats()["latency"]["total"]["n"] counts
        #: THIS door's requests only)
        self.metrics = MetricsRegistry()
        self._hists = {s: self.metrics.histogram(f"latency.{s}",
                                                 self.config.hist_cap)
                       for s in STAGES}
        self._tenant_hist: dict[str, Histogram] = {}
        self.counters = {
            "admitted": 0, "completed": 0, "failed": 0, "cancelled": 0,
            "rejected_queue_full": 0, "rejected_pressure": 0,
            "shed_deadline": 0, "waves": 0, "read_waves": 0,
            "mutation_waves": 0, "riders": 0, "serial_degrades": 0,
        }
        self.per_tenant: dict[str, dict] = defaultdict(
            lambda: {"admitted": 0, "completed": 0, "failed": 0,
                     "shed_deadline": 0, "rejected_queue_full": 0,
                     "rejected_pressure": 0})
        #: dispatch journal (one dict per wave) — the fairness/priority
        #: tests audit it; bounded by hist_cap like the histograms
        self.dispatch_log: list[dict] = []

    # -- intake / admission ---------------------------------------------------
    def submit(self, tenant: str, store: str, ts: int, *,
               fields: Sequence[str] | None = None,
               key_filter: str | None = None,
               include_deleted: bool = False,
               priority: int | None = None,
               timeout: float | None = None) -> "Future":
        """Admit one get_versions request (thread-safe).

        Args:
          tenant: workgroup identity (fairness + queue accounting unit).
          store/ts/fields/key_filter/include_deleted: forwarded to
            ``VersionedStore.get_versions`` via the service plan cache.
          priority: higher dispatches earlier within the tenant
            (default ``config.default_priority``).
          timeout: seconds from now to the deadline; a request still
            queued past it is shed with ``DeadlineExceeded`` (None =
            no deadline).

        Returns:
          Future resolving to a shared read-only ``VersionView``.

        Raises:
          QueueFull: the tenant queue is at ``max_queue_per_tenant``.
          Overloaded: pool pressure >= ``shed_pressure``.
        """
        if self.service.pool_pressure() >= self.config.shed_pressure:
            with self._lock:
                self.counters["rejected_pressure"] += 1
                self.per_tenant[tenant]["rejected_pressure"] += 1
            RECORDER.record("admission_reject", reason="pressure",
                            tenant=tenant, store=store,
                            pressure=self.service.pool_pressure())
            raise Overloaded(
                f"pool pressure {self.service.pool_pressure():.2f} >= "
                f"shed_pressure {self.config.shed_pressure}")
        req = VersionRequest(store, int(ts),
                             tuple(fields) if fields is not None else None,
                             key_filter, include_deleted)
        return self._admit(tenant, store, READ, priority, timeout, req=req)

    def submit_update(self, tenant: str, store: str, ts: int,
                      keys: Sequence, table: Mapping, *, label: str = "",
                      full_release: bool = True,
                      present_keys: Sequence | None = None,
                      priority: int | None = None,
                      timeout: float | None = None) -> "Future":
        """Admit a release ingest (``VersionedStore.update``); the future
        resolves to its ``VersionInfo``. Never pressure-shed. Raises
        QueueFull like ``submit``."""
        payload = dict(ts=int(ts), keys=keys, table=table, label=label,
                       full_release=full_release, present_keys=present_keys)
        return self._admit(tenant, store, "update", priority, timeout,
                           payload=payload)

    def submit_delete(self, tenant: str, store: str, ts: int,
                      keys: Sequence, *, label: str = "",
                      priority: int | None = None,
                      timeout: float | None = None) -> "Future":
        """Admit a tombstone release (``VersionedStore.delete``)."""
        payload = dict(ts=int(ts), keys=keys, label=label)
        return self._admit(tenant, store, "delete", priority, timeout,
                           payload=payload)

    def submit_compact(self, tenant: str, store: str, before_ts: int, *,
                       label: str = "", path: str | None = None,
                       priority: int | None = None,
                       timeout: float | None = None) -> "Future":
        """Admit a compaction (``VersionedStore.compact``); the future
        resolves to its stats dict."""
        payload = dict(before_ts=int(before_ts), label=label, path=path)
        return self._admit(tenant, store, "compact", priority, timeout,
                           payload=payload)

    def _admit(self, tenant, store, kind, priority, timeout, *,
               req=None, payload=None) -> Future:
        cfg = self.config
        now = cfg.clock()
        fut: Future = Future()
        with self._work:
            q = self._queues.get(tenant)
            if q is None:
                q = self._queues[tenant] = []
                self._rr.append(tenant)
                self._tenant_hist[tenant] = self.metrics.histogram(
                    f"tenant.{tenant}", cfg.hist_cap)
            if len(q) >= cfg.max_queue_per_tenant:
                self.counters["rejected_queue_full"] += 1
                self.per_tenant[tenant]["rejected_queue_full"] += 1
                RECORDER.record("admission_reject", reason="queue_full",
                                tenant=tenant, store=store, queued=len(q))
                raise QueueFull(
                    f"tenant {tenant!r}: {len(q)} queued >= "
                    f"max_queue_per_tenant {cfg.max_queue_per_tenant}")
            self._seq += 1
            t = Ticket(seq=self._seq, tenant=tenant, store=store, kind=kind,
                       priority=(cfg.default_priority if priority is None
                                 else int(priority)),
                       deadline=None if timeout is None else now + timeout,
                       future=fut, t_submit=now, req=req, payload=payload,
                       trace_id=new_trace_id("req"))
            bisect.insort(q, t, key=Ticket.sort_key)
            self.counters["admitted"] += 1
            self.per_tenant[tenant]["admitted"] += 1
            self._work.notify_all()
        return fut

    # -- scheduling -----------------------------------------------------------
    def _shed(self, t: Ticket) -> None:
        self.counters["shed_deadline"] += 1
        self.per_tenant[t.tenant]["shed_deadline"] += 1
        RECORDER.record("admission_reject", reason="deadline",
                        tenant=t.tenant, store=t.store, trace=t.trace_id)
        if t.future.set_running_or_notify_cancel():
            t.future.set_exception(DeadlineExceeded(
                f"deadline passed before dispatch (tenant {t.tenant!r}, "
                f"store {t.store!r})"))

    def _purge_expired_locked(self, q: list[Ticket], now: float) -> None:
        live = [t for t in q if t.deadline is None or t.deadline >= now]
        if len(live) != len(q):
            for t in q:
                if t.deadline is not None and t.deadline < now:
                    self._shed(t)
            q[:] = live

    def _form_wave_locked(self) -> list[Ticket] | None:
        """Pick the next wave under the scheduling policy (caller holds
        the lock): round-robin to the next tenant with live work, take its
        queue head, and — for reads — batch compatible requests from its
        own queue then riders from the other tenants'."""
        cfg = self.config
        now = cfg.clock()
        n_tenants = len(self._rr)
        head = None
        for _ in range(n_tenants):
            tenant = self._rr[self._rr_pos % n_tenants]
            self._rr_pos = (self._rr_pos + 1) % max(n_tenants, 1)
            q = self._queues[tenant]
            self._purge_expired_locked(q, now)
            if q:
                head = q.pop(0)
                break
        if head is None:
            return None
        head.wave = self._wave_no
        wave = [head]
        degraded = False
        if head.kind == READ:
            pressure = self.service.pool_pressure()
            if pressure >= cfg.serial_pressure:
                degraded = True
                self.counters["serial_degrades"] += 1
            else:
                gk = head.group_key()
                # same-tenant first, then riders in rr order: compatible
                # requests resolve with zero extra scans
                order = [head.tenant] + [t for t in self._rr
                                         if t != head.tenant]
                for tenant in order:
                    if len(wave) >= cfg.max_wave:
                        break
                    q = self._queues[tenant]
                    taken = []
                    for t in q:
                        if len(wave) + len(taken) >= cfg.max_wave:
                            break
                        if t.kind == READ and t.group_key() == gk:
                            if t.deadline is not None and t.deadline < now:
                                continue   # purged below with the rest
                            taken.append(t)
                    for t in taken:
                        q.remove(t)
                        t.rider = t.tenant != head.tenant
                        t.wave = self._wave_no
                        wave.append(t)
                        if t.rider:
                            self.counters["riders"] += 1
        self._wave_no += 1
        self.counters["waves"] += 1
        self.counters["read_waves" if head.kind == READ
                      else "mutation_waves"] += 1
        for t in wave:
            self._hists["queue"].record(now - t.t_submit)
        self.dispatch_log.append({
            "wave": head.wave, "tenant": head.tenant, "store": head.store,
            "kind": head.kind, "initiator": head.seq,
            "members": [t.seq for t in wave],
            "riders": [t.seq for t in wave if t.rider],
            "degraded": degraded, "pressure": self.service.pool_pressure(),
            "trace": head.trace_id,
        })
        del self.dispatch_log[:-cfg.hist_cap]
        return wave

    # -- dispatch -------------------------------------------------------------
    def _dispatch_once(self) -> bool:
        """Form and execute one wave; False when every queue is idle."""
        with self._dispatch_lock:
            t0 = time.perf_counter()
            with self._lock:
                wave = self._form_wave_locked()
            if wave is None:
                return False
            self._hists["batch"].record(time.perf_counter() - t0)
            if wave[0].kind == READ:
                self._execute_read_wave(wave)
            else:
                self._execute_mutation(wave[0])
            return True

    def _execute_read_wave(self, wave: list[Ticket]) -> None:
        futs = [t.future for t in wave]

        def cancelled() -> bool:
            return all(f.cancelled() for f in futs)

        items = [(t.req, t.future) for t in wave]
        head = wave[0]
        trace: dict[str, float] = {}
        t0 = time.perf_counter()
        # the wave runs under the initiator's trace id: stage timings and
        # any segment-read failure below land on this span in the recorder
        with span("read_wave", trace_id=head.trace_id, wave=head.wave,
                  tenant=head.tenant, store=head.store, members=len(wave)):
            self.service.serve_wave(items, cancel=cancelled, trace=trace)
        self._finish(wave, trace, time.perf_counter() - t0)

    def _execute_mutation(self, t: Ticket) -> None:
        t0 = time.perf_counter()
        if t.future.set_running_or_notify_cancel():
            try:
                with span("mutation", trace_id=t.trace_id, op=t.kind,
                          tenant=t.tenant, store=t.store):
                    store = self.service.store(t.store)
                    p = dict(t.payload)
                    if t.kind == "update":
                        out = store.update(p.pop("ts"), p.pop("keys"),
                                           p.pop("table"), **p)
                    elif t.kind == "delete":
                        out = store.delete(p.pop("ts"), p.pop("keys"), **p)
                    else:   # compact
                        out = store.compact(p.pop("before_ts"), **p)
                t.future.set_result(out)
            except Exception as e:  # noqa: BLE001 — delivered via future
                RECORDER.record("mutation_error", store=t.store,
                                op=t.kind, tenant=t.tenant,
                                trace=t.trace_id, error=repr(e))
                t.future.set_exception(e)
        self.service.enforce_pool()   # mutations grow stores: honor budget
        self._finish([t], {}, time.perf_counter() - t0)

    def _finish(self, wave: list[Ticket], trace: dict, exec_s: float) -> None:
        now = self.config.clock()
        with self._lock:
            for stage, secs in trace.items():
                self._hists[stage].record(secs)
            self._hists["exec"].record(exec_s)
            for t in wave:
                total = now - t.t_submit
                self._hists["total"].record(total)
                self._tenant_hist[t.tenant].record(total)
                f = t.future
                if f.cancelled():
                    self.counters["cancelled"] += 1
                elif f.done() and f.exception() is not None:
                    self.counters["failed"] += 1
                    self.per_tenant[t.tenant]["failed"] += 1
                else:
                    self.counters["completed"] += 1
                    self.per_tenant[t.tenant]["completed"] += 1

    # -- drive ----------------------------------------------------------------
    def pump(self, max_waves: int | None = None) -> int:
        """Dispatch waves on the calling thread until idle (or
        ``max_waves``); returns waves dispatched. The deterministic test
        entry point, and a valid way to run the door without a thread."""
        n = 0
        while max_waves is None or n < max_waves:
            if not self._dispatch_once():
                break
            n += 1
        return n

    def start(self) -> "FrontDoor":
        """Spawn the background dispatcher thread (idempotent)."""
        with self._lock:
            if self._thread is not None:
                return self
            self._stopping = False
            self._thread = threading.Thread(target=self._run,
                                            name="frontdoor-dispatch",
                                            daemon=True)
            self._thread.start()
        return self

    def _run(self) -> None:
        while True:
            with self._work:
                while not self._stopping and not any(
                        self._queues.values()):
                    # timed wait: queued deadlines must be shed even when
                    # no new submit ever notifies again
                    self._work.wait(0.05)
                if self._stopping:
                    return
            self.pump()

    def stop(self, *, drain: bool = True) -> None:
        """Stop the dispatcher thread; ``drain`` pumps remaining queued
        work on the calling thread first."""
        with self._work:
            self._stopping = True
            self._work.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if drain:
            self.pump()

    def __enter__(self) -> "FrontDoor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- observability --------------------------------------------------------
    def queued(self, tenant: str | None = None) -> int:
        """Requests currently queued (one tenant, or all)."""
        with self._lock:
            if tenant is not None:
                return len(self._queues.get(tenant, ()))
            return sum(len(q) for q in self._queues.values())

    def stats(self) -> dict:
        """Point-in-time snapshot: counters, per-stage p50/p99 latency
        histograms, pool pressure, and per-tenant totals."""
        with self._lock:
            out = {
                "counters": dict(self.counters),
                "latency": {s: h.snapshot() for s, h in self._hists.items()},
                "pool_pressure": self.service.pool_pressure(),
                "queued": {t: len(q) for t, q in self._queues.items()},
                "per_tenant": {
                    t: {**c, **self._tenant_hist[t].snapshot()}
                    for t, c in self.per_tenant.items()},
                "service": dict(self.service.stats),
            }
            if self.service.pool is not None:
                out["pool"] = dict(self.service.pool.stats)
            return out
