"""whisper-medium [audio]: enc-dec, 24L+24L d_model=1024 16H (MHA)
d_ff=4096 vocab=51865; conv frontend STUBBED (input_specs provides 1500
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=51865, head_dim=64,
    qkv_bias=True, norm="layernorm", act="gelu", mlp_gated=False,
    encoder_layers=24, encoder_seq=1500)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256, head_dim=16, qkv_bias=True,
    norm="layernorm", act="gelu", mlp_gated=False, encoder_layers=2,
    encoder_seq=30)
