"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE (t/h/w sections), dynamic-resolution vision frontend
STUBBED (input_specs provides precomputed patch embeddings + 3D position
ids). [arXiv:2409.12191; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm", n_layers=80, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=29568, vocab=152064, head_dim=128, qkv_bias=True,
    rope_theta=1e6, mrope_sections=(16, 24, 24), input_mode="embeddings",
    norm="rmsnorm")

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, head_dim=16, qkv_bias=True,
    mrope_sections=(2, 3, 3), input_mode="embeddings", norm="rmsnorm")
