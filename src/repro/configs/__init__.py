"""Assigned-architecture configs (exact published dims) + paper workload."""
from .base import (ARCH_IDS, SHAPES, ModelConfig, RunConfig, ShapeConfig,
                   get_config, get_smoke_config, shapes_for)

__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "RunConfig", "ShapeConfig",
           "get_config", "get_smoke_config", "shapes_for"]
