"""The paper's own workload config: Meta-pipe incremental analysis with a
small encoder for neural-BLAST corpus embedding (examples/incremental_search
and benchmarks/table4)."""
from .base import ModelConfig

# compact encoder used to embed corpus/query sequences
ENCODER = ModelConfig(
    name="metapipe-encoder", family="dense", n_layers=4, d_model=256,
    n_heads=8, n_kv_heads=8, d_ff=1024, vocab=512, head_dim=32,
    norm="rmsnorm", tie_embeddings=True)

SMOKE = ENCODER
CONFIG = ENCODER
