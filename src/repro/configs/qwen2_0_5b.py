"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936, QKV bias. [arXiv:2407.10671; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense", n_layers=24, d_model=896, n_heads=14,
    n_kv_heads=2, d_ff=4864, vocab=151936, head_dim=64, qkv_bias=True,
    rope_theta=1e6, norm="rmsnorm", tie_embeddings=True)

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke", family="dense", n_layers=2, d_model=56,
    n_heads=7, n_kv_heads=1, d_ff=96, vocab=256, head_dim=8, qkv_bias=True,
    tie_embeddings=True)
