"""olmo-1b [dense]: 16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304,
NON-PARAMETRIC LayerNorm (no scale/bias). [arXiv:2402.00838; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense", n_layers=16, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=8192, vocab=50304, head_dim=128,
    norm="layernorm_np", tie_embeddings=True)

SMOKE = ModelConfig(
    name="olmo-1b-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256, head_dim=16, norm="layernorm_np",
    tie_embeddings=True)
