"""qwen1.5-4b [dense]: 40L d_model=2560 20H (kv=20, MHA) d_ff=6912
vocab=151936, QKV bias. [hf:Qwen/Qwen1.5-4B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense", n_layers=40, d_model=2560, n_heads=20,
    n_kv_heads=20, d_ff=6912, vocab=151936, head_dim=128, qkv_bias=True,
    rope_theta=5e6, norm="rmsnorm")

SMOKE = ModelConfig(
    name="qwen1.5-4b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=96, vocab=256, head_dim=16, qkv_bias=True)
