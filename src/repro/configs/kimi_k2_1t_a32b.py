"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert)
vocab=163840, MoE 384 experts top-8. Trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]. Adafactor recommended (see launch/train.py):
AdamW fp32 m+v for 1.03e12 params does not fit 256 chips."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, d_ff=2048, vocab=163840, head_dim=112,
    n_experts=384, top_k=8, d_ff_expert=2048, capacity_factor=1.25,
    rope_theta=5e4, norm="rmsnorm")

SMOKE = ModelConfig(
    name="kimi-k2-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=64, vocab=256, head_dim=16,
    n_experts=8, top_k=4, d_ff_expert=64, capacity_factor=8.0, norm="rmsnorm")
