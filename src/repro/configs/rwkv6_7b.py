"""rwkv6-7b [ssm]: 32L d_model=4096 (attn-free, 64 heads of 64) d_ff=14336
vocab=65536 — Finch, data-dependent decay. [arXiv:2404.05892; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm", n_layers=32, d_model=4096, n_heads=64,
    n_kv_heads=64, d_ff=14336, vocab=65536, head_dim=64, rwkv=True,
    norm="layernorm")

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256, head_dim=16, rwkv=True,
    norm="layernorm")
