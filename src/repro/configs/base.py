"""Config system: ModelConfig (architecture), ShapeConfig (assigned input
shapes), RunConfig (parallelism/optimizer/runtime). One module per assigned
architecture lives next to this file; `get_config(arch_id)` resolves them.
"""
from __future__ import annotations

import dataclasses
import importlib


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1              # layer l is MoE iff l % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] | None = None   # qwen2-vl M-RoPE
    # --- block structure ---
    norm: str = "rmsnorm"           # rmsnorm | layernorm | layernorm_np (OLMo)
    act: str = "silu"
    mlp_gated: bool = True
    tie_embeddings: bool = False
    # --- hybrid / ssm ---
    attn_every: int = 1             # layer l is attention iff l % attn_every == attn_offset
    attn_offset: int = 0            # (else Mamba); rwkv=True overrides all layers
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    rwkv: bool = False
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0            # precomputed frame embeddings (stub frontend)
    # --- modality stub: tokens | embeddings (vlm/audio backbones) ---
    input_mode: str = "tokens"
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def is_moe_layer(self, l: int) -> bool:
        return self.n_experts > 0 and l % self.moe_every == self.moe_offset

    def is_attn_layer(self, l: int) -> bool:
        if self.rwkv:
            return False
        return l % self.attn_every == self.attn_offset

    @property
    def supports_long_context(self) -> bool:
        """True if decode state is sub-quadratic in context (SSM/hybrid)."""
        return self.rwkv or self.attn_every > 1

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND rooflines."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = emb
        enc_layers = self.encoder_layers
        for l in range(self.n_layers + enc_layers):
            is_enc = l >= self.n_layers
            if self.rwkv:
                # time-mix: r,k,v,g,o (5 d^2) + small loras/decay; channel-mix
                total += 5 * d * d + (2 * d * self.d_ff + d * d) + 6 * 32 * 2 * d
                continue
            # mixer
            if is_enc or self.is_attn_layer(l):
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o
                if is_enc:
                    total += q + kv + o  # decoder cross-attn mirrors per enc layer
            else:  # mamba
                di = self.ssm_expand * d
                total += 2 * d * di + di * d + di * (2 * self.ssm_state) \
                    + di * self.ssm_conv + di  # in/out proj, B/C, conv, dt
                total += max(1, d // 16) * (d + di)
            # ffn
            fmul = 3 if self.mlp_gated else 2
            if not is_enc and self.is_moe_layer(l):
                total += self.n_experts * fmul * d * self.d_ff_expert
                total += d * self.n_experts  # router
            else:
                total += fmul * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        fmul = 3 if self.mlp_gated else 2
        n_moe_layers = sum(1 for l in range(self.n_layers) if self.is_moe_layer(l))
        expert_params = n_moe_layers * self.n_experts * fmul * self.d_model * self.d_ff_expert
        active_expert = n_moe_layers * self.top_k * fmul * self.d_model * self.d_ff_expert
        return full - expert_params + active_expert


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str          # train | prefill | decode


#: the assigned input-shape set (same four for every LM arch)
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Parallelism + runtime knobs (launcher-owned, not architecture-owned)."""
    optimizer: str = "adamw"         # adamw | adafactor
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    microbatch: int = 0              # 0 = no gradient accumulation
    remat: str = "nothing_saveable"  # nothing_saveable | dots | none
    attn_impl: str = "chunked"       # xla | chunked (flash algorithm in XLA)
    attn_chunk: int = 8192           # minimizes (S/c)*acc_rw + S*c*logit traffic at 32k
    grad_compress: bool = False      # int8 error-feedback cross-pod reduction
    z_loss: float = 1e-4
    scan_layers: bool = True
    unroll: bool = False             # dry-run cost measurement mode


ARCH_IDS = [
    "grok-1-314b", "kimi-k2-1t-a32b", "llama3.2-1b", "qwen2-0.5b",
    "qwen1.5-4b", "olmo-1b", "qwen2-vl-72b", "whisper-medium",
    "jamba-v0.1-52b", "rwkv6-7b",
]


def _module_for(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_for(arch_id)}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_module_for(arch_id)}")
    return mod.SMOKE


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """The assigned shape cells for an architecture (with documented skips)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.supports_long_context:
        out.append(SHAPES["long_500k"])
    return out
