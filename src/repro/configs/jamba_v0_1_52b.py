"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba+attention 1:7 interleave (1 attn per 8 layers), MoE 16
experts top-2 on every other layer. [arXiv:2403.19887; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536, head_dim=128,
    n_experts=16, top_k=2, d_ff_expert=14336, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=4, ssm_state=16, ssm_conv=4, ssm_expand=2,
    norm="rmsnorm")

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid", n_layers=8, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    n_experts=4, top_k=2, d_ff_expert=128, moe_every=2, moe_offset=1, capacity_factor=8.0,
    attn_every=8, attn_offset=4, ssm_state=8, ssm_conv=4, ssm_expand=2)
