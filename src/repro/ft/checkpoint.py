"""Delta-compressed versioned checkpointing — the paper's technique applied
to training state (DESIGN.md §2): a checkpoint is a *meta-database release*.

Each parameter/optimizer leaf is chunked into fixed-width rows of a
VersionedStore; saving step T is `store.update(ts=T, ...)` — fingerprint
change detection stores only chunks that actually changed, and float chunks
delta-XOR against their previous version on disk (kernels/delta_codec).
Restoring any step is `get_version(T)` — the paper's "run with a specific
meta-database version" requirement, for free.

Async mode: the device->host gather runs on the caller thread, the store
update + disk write on a background thread (off the step critical path).

``IngestJournal`` reuses the same durability discipline for the streaming
ingest engine (core/ingest.py): parsed release chunks are journaled to a
sidecar directory with an atomically-rewritten manifest, so a crash
mid-release resumes by replaying journaled chunks over the pre-release
store instead of re-parsing the whole file.
"""
from __future__ import annotations

import io
import json
import os
import threading
from typing import Any

import numpy as np
import jax

from repro.core.store import FieldSchema, VersionedStore

CHUNK_W = 2048

JOURNAL_FORMAT = "gestore-ingest-journal-v1"
JOURNAL_NAME = "JOURNAL.json"


def _fsync_write(path: str, data: bytes) -> None:
    """Write ``data`` atomically (tmp + fsync + rename + dir fsync)."""
    from repro.core.segments import _fsync_dir
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path))


class IngestJournal:
    """Durable chunk journal for one in-flight streaming release.

    Layout under ``root``: ``JOURNAL.json`` (the manifest — release
    identity, a store *digest watermark* captured at session start, and
    the applied-chunk list with source offsets) plus one
    ``chunk-NNNNN.npz`` of parsed rows per applied chunk. The chunk file
    is fsynced BEFORE the manifest lists it, so every chunk the manifest
    names is replayable. The watermark (history digest + last committed
    ts + total cell count) pins the exact pre-release store state the
    journal's chunks apply over: a resume against a store that moved on
    — or one dirtied by a half-applied release — refuses instead of
    corrupting.

    The journal is *sidecar* state: release cells only reach the store
    directory once, at the post-``finish()`` save. Journaling partially
    applied cells through the store's own incremental save is unsound —
    all of one release's cells share a timestamp, so a second mid-release
    save would re-extract (duplicate) the cells of the first.
    """

    def __init__(self, root: str, meta: dict):
        self.root = root
        self.meta = meta

    # -- lifecycle -----------------------------------------------------------
    @classmethod
    def begin(cls, root: str, *, store: str, ts: int, label: str,
              full_release: bool, watermark: dict) -> "IngestJournal":
        """Start a fresh journal (clearing any stale one at ``root``)."""
        j = cls(root, {"format": JOURNAL_FORMAT, "store": store,
                       "ts": int(ts), "label": label,
                       "full_release": bool(full_release),
                       "watermark": watermark, "chunks": []})
        if os.path.isdir(root):
            j.clear()
        os.makedirs(root, exist_ok=True)
        j._write_manifest()
        return j

    @classmethod
    def open(cls, root: str) -> "IngestJournal | None":
        """The journal at ``root``, or None when absent/unreadable."""
        p = os.path.join(root, JOURNAL_NAME)
        if not os.path.exists(p):
            return None
        try:
            with open(p) as f:
                meta = json.load(f)
        except (json.JSONDecodeError, OSError):
            return None
        if meta.get("format") != JOURNAL_FORMAT:
            return None
        return cls(root, meta)

    def clear(self) -> None:
        """Delete the journal (manifest first, so a crash mid-clear can
        never leave a manifest naming deleted chunk files)."""
        p = os.path.join(self.root, JOURNAL_NAME)
        if os.path.exists(p):
            os.remove(p)
        if os.path.isdir(self.root):
            for name in os.listdir(self.root):
                if name.startswith("chunk-") and name.endswith(".npz"):
                    os.remove(os.path.join(self.root, name))

    # -- chunks --------------------------------------------------------------
    @property
    def chunks(self) -> list[dict]:
        return self.meta["chunks"]

    def _chunk_path(self, idx: int) -> str:
        return os.path.join(self.root, f"chunk-{idx:05d}.npz")

    def record_chunk(self, keys: list[bytes], table: dict, *,
                     source_offset: int | None, flush: bool = True) -> int:
        """Durably append one parsed chunk; returns its index. The npz
        commits before the manifest references it. ``flush=False`` defers
        the manifest rewrite (call ``flush()``); a crash in between
        re-parses the deferred chunks from their source offsets — the npz
        bytes are durable either way, the manifest just doesn't name them
        yet."""
        idx = len(self.chunks)
        buf = io.BytesIO()
        np.savez(buf, __keys__=np.array(keys, dtype="S"),
                 **{f"f_{n}": v for n, v in table.items()})
        _fsync_write(self._chunk_path(idx), buf.getvalue())
        self.chunks.append({"idx": idx, "n_entries": len(keys),
                            "source_offset": source_offset})
        if flush:
            self._write_manifest()
        return idx

    def flush(self) -> None:
        """Commit the manifest naming every recorded chunk."""
        self._write_manifest()

    def load_chunk(self, idx: int) -> tuple[list[bytes], dict]:
        with np.load(self._chunk_path(idx)) as z:
            keys = [bytes(k) for k in z["__keys__"]]
            table = {n[2:]: z[n] for n in z.files if n.startswith("f_")}
        return keys, table

    def entries_applied(self) -> int:
        return sum(c["n_entries"] for c in self.chunks)

    def resume_offset(self) -> int | None:
        """Source offset parsing resumes from, or None when the parser
        journaled no offsets (block formats resume by record skip)."""
        if not self.chunks:
            return 0
        off = self.chunks[-1]["source_offset"]
        return None if off is None else int(off)

    def _write_manifest(self) -> None:
        _fsync_write(os.path.join(self.root, JOURNAL_NAME),
                     json.dumps(self.meta).encode())


def _leaf_rows(path: str, arr: np.ndarray):
    """Flatten a leaf into (keys, (N, CHUNK_W) f32 rows, pad)."""
    flat = np.asarray(arr, np.float32).reshape(-1)
    pad = (-len(flat)) % CHUNK_W
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    rows = flat.reshape(-1, CHUNK_W)
    keys = [f"{path}#{i}".encode() for i in range(len(rows))]
    return keys, rows, pad


class CheckpointManager:
    def __init__(self, root: str, *, async_save: bool = True,
                 keep_every: int = 1):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.store = VersionedStore("ckpt", [FieldSchema("w", CHUNK_W, "float32")])
        self.meta: dict[str, Any] = {"leaves": {}, "steps": []}
        self.async_save = async_save
        self._worker: threading.Thread | None = None
        self._load_existing()

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state) -> dict:
        """Record `state` (pytree of arrays) as version ts=step."""
        self.wait()
        flat, treedef = jax.tree_util.tree_flatten_with_path(state)
        host = [(jax.tree_util.keystr(p), np.asarray(x)) for p, x in flat]

        def work():
            keys: list[bytes] = []
            rows: list[np.ndarray] = []
            for path, arr in host:
                k, r, _pad = _leaf_rows(path, arr)
                keys.extend(k)
                rows.append(r)
                self.meta["leaves"][path] = {
                    "shape": list(arr.shape), "dtype": str(arr.dtype)}
            table = {"w": np.concatenate(rows) if rows else
                     np.zeros((0, CHUNK_W), np.float32)}
            info = self.store.update(step, keys, table, label=f"step{step}")
            self.meta["steps"].append(step)
            self._persist()
            self._last_info = info

        if self.async_save:
            self._worker = threading.Thread(target=work, daemon=True)
            self._worker.start()
            return {"async": True, "step": step}
        work()
        return {"async": False, "step": step,
                "changed": self._last_info.n_updated + self._last_info.n_new}

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    # -- restore ----------------------------------------------------------------
    def steps(self) -> list[int]:
        self.wait()
        return sorted(self.meta["steps"])

    def restore(self, step: int, like=None, mesh=None, shardings=None):
        """Rebuild the pytree at version `step`. With mesh+shardings, leaves
        are device_put with the given shardings — restoring onto a DIFFERENT
        mesh shape than the one that saved is the elastic-resharding path
        (chunks are mesh-agnostic host rows)."""
        self.wait()
        view = self.store.get_version(step, fields=["w"])
        by_key = dict(zip(view.keys, view.values["w"]))
        leaves = {}
        for path, info in self.meta["leaves"].items():
            n = int(np.prod(info["shape"])) if info["shape"] else 1
            n_chunks = -(-n // CHUNK_W)
            parts = [by_key[f"{path}#{i}".encode()] for i in range(n_chunks)]
            flat = np.concatenate(parts)[:n] if parts else np.zeros(0, np.float32)
            leaves[path] = flat.reshape(info["shape"]).astype(info["dtype"])
        if like is not None:
            flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
            ordered = [leaves[jax.tree_util.keystr(p)] for p, _ in flat_like]
            if shardings is not None:
                sh_flat = jax.tree_util.tree_leaves(shardings)
                ordered = [jax.device_put(a, s) for a, s in zip(ordered, sh_flat)]
            return jax.tree_util.tree_unflatten(treedef, ordered)
        return leaves

    # -- persistence -------------------------------------------------------------
    def _persist(self) -> None:
        self.store.save(os.path.join(self.root, "store"))
        with open(os.path.join(self.root, "meta.json"), "w") as f:
            json.dump(self.meta, f)

    def _load_existing(self) -> None:
        mp = os.path.join(self.root, "meta.json")
        sp = os.path.join(self.root, "store")
        if os.path.exists(mp) and os.path.exists(sp):
            with open(mp) as f:
                self.meta = json.load(f)
            self.store = VersionedStore.load(sp)

    def stats(self) -> dict:
        self.wait()
        cells = sum(col.log.n_cells for col in self.store.fields.values())
        total_rows = self.store.n_rows
        return {"versions": len(self.meta["steps"]), "rows": total_rows,
                "cells": cells,
                "dedup_ratio": (total_rows * max(len(self.meta['steps']), 1))
                / max(cells, 1)}
