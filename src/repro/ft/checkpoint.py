"""Delta-compressed versioned checkpointing — the paper's technique applied
to training state (DESIGN.md §2): a checkpoint is a *meta-database release*.

Each parameter/optimizer leaf is chunked into fixed-width rows of a
VersionedStore; saving step T is `store.update(ts=T, ...)` — fingerprint
change detection stores only chunks that actually changed, and float chunks
delta-XOR against their previous version on disk (kernels/delta_codec).
Restoring any step is `get_version(T)` — the paper's "run with a specific
meta-database version" requirement, for free.

Async mode: the device->host gather runs on the caller thread, the store
update + disk write on a background thread (off the step critical path).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any

import numpy as np
import jax

from repro.core.store import FieldSchema, VersionedStore

CHUNK_W = 2048


def _leaf_rows(path: str, arr: np.ndarray):
    """Flatten a leaf into (keys, (N, CHUNK_W) f32 rows, pad)."""
    flat = np.asarray(arr, np.float32).reshape(-1)
    pad = (-len(flat)) % CHUNK_W
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    rows = flat.reshape(-1, CHUNK_W)
    keys = [f"{path}#{i}".encode() for i in range(len(rows))]
    return keys, rows, pad


class CheckpointManager:
    def __init__(self, root: str, *, async_save: bool = True,
                 keep_every: int = 1):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.store = VersionedStore("ckpt", [FieldSchema("w", CHUNK_W, "float32")])
        self.meta: dict[str, Any] = {"leaves": {}, "steps": []}
        self.async_save = async_save
        self._worker: threading.Thread | None = None
        self._load_existing()

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state) -> dict:
        """Record `state` (pytree of arrays) as version ts=step."""
        self.wait()
        flat, treedef = jax.tree_util.tree_flatten_with_path(state)
        host = [(jax.tree_util.keystr(p), np.asarray(x)) for p, x in flat]

        def work():
            keys: list[bytes] = []
            rows: list[np.ndarray] = []
            for path, arr in host:
                k, r, _pad = _leaf_rows(path, arr)
                keys.extend(k)
                rows.append(r)
                self.meta["leaves"][path] = {
                    "shape": list(arr.shape), "dtype": str(arr.dtype)}
            table = {"w": np.concatenate(rows) if rows else
                     np.zeros((0, CHUNK_W), np.float32)}
            info = self.store.update(step, keys, table, label=f"step{step}")
            self.meta["steps"].append(step)
            self._persist()
            self._last_info = info

        if self.async_save:
            self._worker = threading.Thread(target=work, daemon=True)
            self._worker.start()
            return {"async": True, "step": step}
        work()
        return {"async": False, "step": step,
                "changed": self._last_info.n_updated + self._last_info.n_new}

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    # -- restore ----------------------------------------------------------------
    def steps(self) -> list[int]:
        self.wait()
        return sorted(self.meta["steps"])

    def restore(self, step: int, like=None, mesh=None, shardings=None):
        """Rebuild the pytree at version `step`. With mesh+shardings, leaves
        are device_put with the given shardings — restoring onto a DIFFERENT
        mesh shape than the one that saved is the elastic-resharding path
        (chunks are mesh-agnostic host rows)."""
        self.wait()
        view = self.store.get_version(step, fields=["w"])
        by_key = dict(zip(view.keys, view.values["w"]))
        leaves = {}
        for path, info in self.meta["leaves"].items():
            n = int(np.prod(info["shape"])) if info["shape"] else 1
            n_chunks = -(-n // CHUNK_W)
            parts = [by_key[f"{path}#{i}".encode()] for i in range(n_chunks)]
            flat = np.concatenate(parts)[:n] if parts else np.zeros(0, np.float32)
            leaves[path] = flat.reshape(info["shape"]).astype(info["dtype"])
        if like is not None:
            flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
            ordered = [leaves[jax.tree_util.keystr(p)] for p, _ in flat_like]
            if shardings is not None:
                sh_flat = jax.tree_util.tree_leaves(shardings)
                ordered = [jax.device_put(a, s) for a, s in zip(ordered, sh_flat)]
            return jax.tree_util.tree_unflatten(treedef, ordered)
        return leaves

    # -- persistence -------------------------------------------------------------
    def _persist(self) -> None:
        self.store.save(os.path.join(self.root, "store"))
        with open(os.path.join(self.root, "meta.json"), "w") as f:
            json.dump(self.meta, f)

    def _load_existing(self) -> None:
        mp = os.path.join(self.root, "meta.json")
        sp = os.path.join(self.root, "store")
        if os.path.exists(mp) and os.path.exists(sp):
            with open(mp) as f:
                self.meta = json.load(f)
            self.store = VersionedStore.load(sp)

    def stats(self) -> dict:
        self.wait()
        cells = sum(col.log.n_cells for col in self.store.fields.values())
        total_rows = self.store.n_rows
        return {"versions": len(self.meta["steps"]), "rows": total_rows,
                "cells": cells,
                "dedup_ratio": (total_rows * max(len(self.meta['steps']), 1))
                / max(cells, 1)}
