"""Straggler detection + mitigation policy.

At pod scale, slow hosts (thermal throttling, failing HBM, network flaps)
stretch every synchronous step. The monitor keeps an EWMA + variance of
per-host step times; a host whose recent mean exceeds
mu + `sigma_threshold` * sigma for `patience` consecutive windows is flagged.
Policy hook: flag -> emit CHECKPOINT_AND_REPLACE so the trainer snapshots
(ft/checkpoint.py, async) and the scheduler can drain/replace the host, then
the job resumes elastically on the survivors (ft/elastic.py).

Host step times come from the trainer's per-step wall clock; in tests they
are synthetic.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque

import numpy as np

OK, WARN, CHECKPOINT_AND_REPLACE = "ok", "warn", "checkpoint_and_replace"


@dataclasses.dataclass
class StragglerConfig:
    window: int = 16
    sigma_threshold: float = 3.0
    patience: int = 3
    min_steps: int = 8


class StragglerMonitor:
    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.times: dict[str, deque] = defaultdict(
            lambda: deque(maxlen=self.cfg.window))
        self.strikes: dict[str, int] = defaultdict(int)

    def record(self, host: str, step_time: float) -> None:
        self.times[host].append(step_time)

    def evaluate(self) -> dict[str, str]:
        """Per-host verdicts. Robust center/scale (median + MAD): a straggler
        inflates the plain mean/std enough to hide itself behind a k-sigma
        gate when the fleet sample is small."""
        means = {h: float(np.mean(t)) for h, t in self.times.items()
                 if len(t) >= self.cfg.min_steps}
        if len(means) < 2:
            return {h: OK for h in self.times}
        vals = np.asarray(list(means.values()))
        med = float(np.median(vals))
        mad = float(np.median(np.abs(vals - med))) * 1.4826  # ~sigma
        out = {}
        for h, m in means.items():
            slow = m > med + self.cfg.sigma_threshold * max(mad, 1e-6) and \
                m > 1.05 * med
            if slow:
                self.strikes[h] += 1
            else:
                self.strikes[h] = 0
            if self.strikes[h] >= self.cfg.patience:
                out[h] = CHECKPOINT_AND_REPLACE
            elif self.strikes[h] > 0:
                out[h] = WARN
            else:
                out[h] = OK
        return out

    def worst(self) -> tuple[str, float] | None:
        means = {h: float(np.mean(t)) for h, t in self.times.items() if t}
        if not means:
            return None
        h = max(means, key=means.get)
        return h, means[h]
