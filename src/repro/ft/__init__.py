"""Fault tolerance: delta checkpoints, elastic resharding, stragglers."""
