"""Elastic scaling: restore a checkpoint onto a different mesh.

Checkpoints are mesh-agnostic (host-row chunks in the versioned store), so
elasticity reduces to (1) choosing a new mesh from the surviving device set,
(2) recomputing shardings from the same logical-axis rules on that mesh,
(3) device_put at restore. Data order is preserved by carrying (step,
dataset version ts) in the train metadata, so a 512->256 shrink replays no
data and loses at most the steps since the last (async, delta-cheap)
checkpoint.
"""
from __future__ import annotations

import jax

from repro.sharding.rules import tree_shardings


def choose_mesh_shape(n_devices: int, prefer_model: int = 16) -> tuple:
    """Largest (data, model) grid for the surviving devices: keep TP width
    if possible (weights layouts unchanged), shrink DP."""
    model = prefer_model
    while model > 1 and (n_devices % model or n_devices // model < 1):
        model //= 2
    return (max(n_devices // model, 1), model)


def remesh(devices=None, prefer_model: int = 16):
    devices = devices if devices is not None else jax.devices()
    data, model = choose_mesh_shape(len(devices), prefer_model)
    import numpy as np
    grid = np.asarray(devices[: data * model]).reshape(data, model)
    from jax.sharding import Mesh
    return Mesh(grid, ("data", "model"))


def restore_elastic(ckpt_manager, step: int, like, spec_tree, mesh):
    """CheckpointManager.restore with shardings recomputed for `mesh`."""
    shardings = tree_shardings(spec_tree, mesh)
    return ckpt_manager.restore(step, like=like, mesh=mesh,
                                shardings=shardings)
