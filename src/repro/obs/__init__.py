"""Unified observability layer: metrics, traces, flight recorder, logs.

One telemetry spine for the whole serving stack (see ARCHITECTURE.md
"Observability"):

  * ``metrics`` — thread-safe counters/gauges/bounded-p50-p99 histograms
    in ``MetricsRegistry`` instances; ``REGISTRY`` is the process-wide
    default, with JSON and Prometheus text exposition.
  * ``trace`` — request/wave trace IDs (minted at ``FrontDoor.submit``)
    and thread-local ``span()`` contexts; ``StageTimer`` carries the old
    per-stage trace-dict contract and feeds spans + registry.
  * ``recorder`` — ``RECORDER``, a bounded ring of structured events
    (rejections, failures, pool churn, spans) dumping to JSON on demand
    or on unhandled failure (``GESTORE_FLIGHT_DUMP``).
  * ``kerneltel`` — per-kernel launch wall/bytes/FLOPs feeding
    ``launch/roofline.py`` fractions (``KERNELS``).
  * ``log`` — the leveled, env-configurable (``GESTORE_LOG``) structured
    logger; quiet by default, the only sanctioned output path for
    library code (ruff bans ``print`` under ``src/``).

``kerneltel`` is imported lazily by its call sites (it pulls in
``launch.roofline``); importing ``repro.obs`` itself stays stdlib+numpy
light so ``core``/``serve`` can depend on it unconditionally.
"""
from .log import configure as configure_logging, get_logger
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      REGISTRY)
from .recorder import RECORDER, FlightRecorder, install_excepthook
from .trace import (Span, StageTimer, current_span, current_trace_id,
                    new_trace_id, span)

__all__ = [
    "Counter", "FlightRecorder", "Gauge", "Histogram", "MetricsRegistry",
    "RECORDER", "REGISTRY", "Span", "StageTimer", "configure_logging",
    "current_span", "current_trace_id", "get_logger", "install_excepthook",
    "new_trace_id", "snapshot_all", "span",
]


def snapshot_all() -> dict:
    """One combined observability snapshot: global registry metrics,
    per-kernel roofline telemetry, and the flight-recorder dump — the
    payload ``benchmarks/table10_observability.py`` writes to
    ``BENCH_metrics.json``."""
    from .kerneltel import KERNELS
    return {"metrics": REGISTRY.snapshot(), "kernels": KERNELS.snapshot(),
            "flight_recorder": RECORDER.dump()}
