"""Process-wide metrics registry: counters, gauges, bounded histograms.

One telemetry spine for the whole stack (the tentpole of the
observability layer): the serving front door, the tiered store pool, the
kernel launch paths, and the segment I/O layer all publish into
``MetricsRegistry`` instances instead of growing private ad-hoc stat
dicts. The module-level ``REGISTRY`` is the process-wide default —
kernel telemetry and pool churn land there — while components whose
stats must stay instance-scoped (e.g. every ``FrontDoor`` owns its
latency histograms, so two doors in one process never alias) construct
their own registry from the same primitives.

All primitives are thread-safe. ``Histogram`` keeps a bounded ring of
the last ``cap`` samples in seconds and snapshots to
``{"n", "p50_ms", "p99_ms"}`` — the exact shape the front door's
``stats()["latency"]`` has always exposed (it migrated here from the
old private ``_Hist``), so dashboards and the serving benchmarks are
unchanged.

Exposition: ``snapshot()`` (plain dict), ``to_json()`` and
``to_prometheus()`` (text format: counters/gauges as bare samples,
histograms as ``_count``/``_p50_ms``/``_p99_ms`` samples).
"""
from __future__ import annotations

import json
import threading

import numpy as np


def _expo_name(name: str) -> str:
    """Sanitize a metric name for Prometheus exposition (dots and any
    other punctuation become underscores)."""
    return "".join(c if c.isalnum() or c == "_" else "_" for c in name)


class Counter:
    """Monotonic thread-safe counter (float-capable for byte totals)."""

    __slots__ = ("_lock", "_v")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._v = 0.0

    def inc(self, delta: float = 1.0) -> None:
        with self._lock:
            self._v += delta

    @property
    def value(self) -> float:
        with self._lock:
            return self._v


class Gauge:
    """Last-write-wins instantaneous value (e.g. queue depth, pressure)."""

    __slots__ = ("_lock", "_v")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._v = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._v = float(value)

    def add(self, delta: float) -> None:
        with self._lock:
            self._v += delta

    @property
    def value(self) -> float:
        with self._lock:
            return self._v


class Histogram:
    """Bounded latency histogram: a ring of the last ``cap`` samples
    (seconds), snapshotting to p50/p99 milliseconds. ``n`` counts every
    sample ever recorded; only the ring is bounded."""

    __slots__ = ("_lock", "_cap", "_buf", "_i", "n")

    def __init__(self, cap: int = 8192):
        self._lock = threading.Lock()
        self._cap = max(int(cap), 1)
        self._buf: list[float] = []
        self._i = 0
        self.n = 0

    def record(self, seconds: float) -> None:
        with self._lock:
            self.n += 1
            if len(self._buf) < self._cap:
                self._buf.append(seconds)
            else:
                self._buf[self._i] = seconds
                self._i = (self._i + 1) % self._cap

    def snapshot(self) -> dict:
        with self._lock:
            if not self._buf:
                return {"n": 0, "p50_ms": 0.0, "p99_ms": 0.0}
            a = np.asarray(self._buf)
            n = self.n
        return {"n": n,
                "p50_ms": float(np.percentile(a, 50) * 1e3),
                "p99_ms": float(np.percentile(a, 99) * 1e3)}


class MetricsRegistry:
    """Get-or-create namespace of counters/gauges/histograms.

    ``counter(name)`` etc. are idempotent: the first call creates the
    metric, later calls return the same object — callers hold no
    references and never coordinate registration. A name is bound to one
    metric kind; reusing it as another kind raises ``TypeError``.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(*args)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                                f"not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, cap: int = 8192) -> Histogram:
        return self._get(name, Histogram, cap)

    def snapshot(self) -> dict:
        """Point-in-time dict: counters/gauges to their value, histograms
        to their ``{"n", "p50_ms", "p99_ms"}`` snapshot."""
        with self._lock:
            items = list(self._metrics.items())
        out: dict[str, object] = {}
        for name, m in items:
            out[name] = (m.snapshot() if isinstance(m, Histogram)
                         else m.value)
        return out

    def to_json(self, **extra) -> str:
        """JSON dump of ``snapshot()`` (plus any ``extra`` top-level
        fields, e.g. a timestamp the caller stamps)."""
        return json.dumps({"metrics": self.snapshot(), **extra}, indent=2,
                          default=str)

    def to_prometheus(self) -> str:
        """Prometheus text exposition: one ``name value`` sample per
        counter/gauge; histograms expand to ``_count``/``_p50_ms``/
        ``_p99_ms`` samples."""
        with self._lock:
            items = list(self._metrics.items())
        lines = []
        for name, m in items:
            pname = _expo_name(name)
            if isinstance(m, Histogram):
                s = m.snapshot()
                lines.append(f"# TYPE {pname} summary")
                lines.append(f"{pname}_count {s['n']}")
                lines.append(f"{pname}_p50_ms {s['p50_ms']}")
                lines.append(f"{pname}_p99_ms {s['p99_ms']}")
            else:
                kind = "counter" if isinstance(m, Counter) else "gauge"
                lines.append(f"# TYPE {pname} {kind}")
                lines.append(f"{pname} {m.value}")
        return "\n".join(lines) + "\n"

    def clear(self) -> None:
        """Drop every metric (test isolation for the global registry)."""
        with self._lock:
            self._metrics.clear()


#: the process-wide default registry: kernel telemetry, pool churn, and
#: stage timings publish here; scrape with ``REGISTRY.to_prometheus()``.
REGISTRY = MetricsRegistry()
