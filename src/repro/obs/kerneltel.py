"""Kernel launch telemetry: wall time + bytes/FLOP roofline accounting.

The hot paths are one kernel family — ``batched_select`` (the fused
superlog scan, serial and stacked), ``shard_route`` (key->shard
hashing), ``delta_codec`` (on-disk chain pack/unpack) — and each has a
single host-facing point where the launch is forced to a host sync.
Those sites wrap themselves in ``launch(name, nbytes=..., flops=...)``:
the context manager times launch-to-sync wall and aggregates per-kernel
``calls / wall_s / bytes / flops`` here, publishing mirrors into the
process-wide registry (``kernel.<name>.calls`` etc.).

Bytes/FLOP figures are *analytic estimates* of the kernel's traffic and
arithmetic (documented at each call site), not HLO measurements — they
are the numerator of the roofline model in ``launch/roofline.py``:
``snapshot()`` derives each kernel's achieved GB/s, GFLOP/s, and
``roofline_fraction`` (roofline-implied minimum time / achieved wall,
against the v5e-class constants), which
``benchmarks/table10_observability.py`` writes into
``BENCH_results.json`` so kernel efficiency regressions gate CI.

Overhead per launch is two ``perf_counter`` reads and one locked dict
update (~1 microsecond) — negligible against any real kernel launch,
and bounded: state is one small dict per kernel name.
"""
from __future__ import annotations

import threading
import time

from repro.launch.roofline import kernel_roofline

from .metrics import REGISTRY


class KernelTelemetry:
    """Per-kernel launch aggregation (see module docstring)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        # name -> [calls, wall_s, bytes, flops]
        self._k: dict[str, list[float]] = {}

    def record(self, name: str, wall_s: float, nbytes: float,
               flops: float) -> None:
        with self._lock:
            row = self._k.get(name)
            if row is None:
                row = self._k[name] = [0, 0.0, 0.0, 0.0]
            row[0] += 1
            row[1] += wall_s
            row[2] += nbytes
            row[3] += flops
        REGISTRY.counter(f"kernel.{name}.calls").inc()
        REGISTRY.counter(f"kernel.{name}.wall_s").inc(wall_s)
        REGISTRY.counter(f"kernel.{name}.bytes").inc(nbytes)
        REGISTRY.counter(f"kernel.{name}.flops").inc(flops)

    def launch(self, name: str, *, nbytes: float, flops: float) -> "_Launch":
        """Context manager timing one launch-to-host-sync region."""
        return _Launch(self, name, nbytes, flops)

    def snapshot(self) -> dict:
        """Per-kernel aggregates + derived roofline terms."""
        with self._lock:
            rows = {n: list(r) for n, r in self._k.items()}
        out = {}
        for name, (calls, wall, nb, fl) in rows.items():
            d = {"calls": int(calls), "wall_s": wall, "bytes": nb,
                 "flops": fl,
                 "us_per_call": (wall / calls * 1e6) if calls else 0.0,
                 "gbytes_per_s": (nb / wall / 1e9) if wall else 0.0,
                 "gflops_per_s": (fl / wall / 1e9) if wall else 0.0}
            d.update(kernel_roofline(fl, nb, wall))
            out[name] = d
        return out

    def clear(self) -> None:
        with self._lock:
            self._k.clear()


class _Launch:
    __slots__ = ("_tel", "_name", "_nbytes", "_flops", "_t0")

    def __init__(self, tel, name, nbytes, flops):
        self._tel, self._name = tel, name
        self._nbytes, self._flops = float(nbytes), float(flops)

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self._tel.record(self._name, time.perf_counter() - self._t0,
                             self._nbytes, self._flops)
        return False


#: the process-wide kernel telemetry the launch sites publish into.
KERNELS = KernelTelemetry()


def launch(name: str, *, nbytes: float, flops: float) -> _Launch:
    """``KERNELS.launch`` shorthand for the instrumented call sites."""
    return KERNELS.launch(name, nbytes=nbytes, flops=flops)
