"""Kernel launch telemetry: wall time + bytes/FLOP roofline accounting.

The hot paths are one kernel family — ``batched_select`` (the fused
superlog scan, serial and stacked), ``shard_route`` (key->shard
hashing), ``delta_codec`` (on-disk chain pack/unpack) — and each has a
single host-facing point where the launch is forced to a host sync.
Those sites wrap themselves in ``launch(name, nbytes=..., flops=...)``:
the context manager times launch-to-sync wall and aggregates per-kernel
``calls / wall_s / bytes / flops`` here, publishing mirrors into the
process-wide registry (``kernel.<name>.calls`` etc.).

Bytes/FLOP figures are *analytic estimates* of the kernel's traffic and
arithmetic (documented at each call site), not HLO measurements — they
are the numerator of the roofline model in ``launch/roofline.py``:
``snapshot()`` derives each kernel's achieved GB/s, GFLOP/s, and
``roofline_fraction`` (roofline-implied minimum time / achieved wall,
against the v5e-class constants), which
``benchmarks/table10_observability.py`` writes into
``BENCH_results.json`` so kernel efficiency regressions gate CI.

Call sites that pad operands (power-of-two cell buckets, tile-multiple
rows) pass the slack separately via ``padded_nbytes``: ``bytes`` stays
the *logical* traffic model while ``padded_bytes`` is what actually
crosses HBM. The roofline terms are derived from the padded figure —
the hardware really moves those bytes — and the logical figure is
reported alongside so compression/bucketing accounting is not
double-counted into efficiency claims.

Overhead per launch is two ``perf_counter`` reads and one locked dict
update (~1 microsecond) — negligible against any real kernel launch,
and bounded: state is one small dict per kernel name.
"""
from __future__ import annotations

import threading
import time

from repro.launch.roofline import kernel_roofline

from .metrics import REGISTRY


class KernelTelemetry:
    """Per-kernel launch aggregation (see module docstring)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        # name -> [calls, wall_s, bytes, flops, padded_bytes]
        self._k: dict[str, list[float]] = {}

    def record(self, name: str, wall_s: float, nbytes: float,
               flops: float, padded_nbytes: float | None = None) -> None:
        padded = nbytes if padded_nbytes is None else padded_nbytes
        with self._lock:
            row = self._k.get(name)
            if row is None:
                row = self._k[name] = [0, 0.0, 0.0, 0.0, 0.0]
            row[0] += 1
            row[1] += wall_s
            row[2] += nbytes
            row[3] += flops
            row[4] += padded
        REGISTRY.counter(f"kernel.{name}.calls").inc()
        REGISTRY.counter(f"kernel.{name}.wall_s").inc(wall_s)
        REGISTRY.counter(f"kernel.{name}.bytes").inc(nbytes)
        REGISTRY.counter(f"kernel.{name}.flops").inc(flops)
        REGISTRY.counter(f"kernel.{name}.padded_bytes").inc(padded)

    def launch(self, name: str, *, nbytes: float, flops: float,
               padded_nbytes: float | None = None) -> "_Launch":
        """Context manager timing one launch-to-host-sync region.
        ``padded_nbytes`` (default: ``nbytes``) is the traffic including
        bucket/tile pad slack — the roofline numerator."""
        return _Launch(self, name, nbytes, flops, padded_nbytes)

    def snapshot(self) -> dict:
        """Per-kernel aggregates + derived roofline terms. ``bytes`` is the
        logical traffic model; ``padded_bytes`` (>= bytes) is what actually
        moved and feeds the roofline/GB/s terms."""
        with self._lock:
            rows = {n: list(r) for n, r in self._k.items()}
        out = {}
        for name, (calls, wall, nb, fl, pb) in rows.items():
            d = {"calls": int(calls), "wall_s": wall, "bytes": nb,
                 "flops": fl, "padded_bytes": pb,
                 "us_per_call": (wall / calls * 1e6) if calls else 0.0,
                 "gbytes_per_s": (pb / wall / 1e9) if wall else 0.0,
                 "logical_gbytes_per_s": (nb / wall / 1e9) if wall else 0.0}
            d["gflops_per_s"] = (fl / wall / 1e9) if wall else 0.0
            d.update(kernel_roofline(fl, pb, wall))
            out[name] = d
        return out

    def clear(self) -> None:
        with self._lock:
            self._k.clear()


class _Launch:
    __slots__ = ("_tel", "_name", "_nbytes", "_flops", "_padded", "_t0")

    def __init__(self, tel, name, nbytes, flops, padded_nbytes=None):
        self._tel, self._name = tel, name
        self._nbytes, self._flops = float(nbytes), float(flops)
        self._padded = None if padded_nbytes is None else float(padded_nbytes)

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self._tel.record(self._name, time.perf_counter() - self._t0,
                             self._nbytes, self._flops, self._padded)
        return False


#: the process-wide kernel telemetry the launch sites publish into.
KERNELS = KernelTelemetry()


def launch(name: str, *, nbytes: float, flops: float,
           padded_nbytes: float | None = None) -> _Launch:
    """``KERNELS.launch`` shorthand for the instrumented call sites."""
    return KERNELS.launch(name, nbytes=nbytes, flops=flops,
                          padded_nbytes=padded_nbytes)
