"""Leveled structured logger for the library (quiet by default).

Library code under ``src/repro`` never calls ``print()`` (ruff T201
enforces this): it logs through ``get_logger(__name__)`` instead. By
default nothing is emitted — the root ``repro`` logger carries only a
``NullHandler`` — so benchmarks, tier-1 test output, and embedding
applications stay clean. Output is opt-in:

  * env: ``GESTORE_LOG=info`` (any standard level name; ``debug``,
    ``warning``, ...) attaches a stderr handler at that level for the
    whole process, or
  * code: CLI entry points call ``configure("info")`` so their
    human-facing progress lines still appear.

The format is one structured line per event:
``<unix-time> <LEVEL> <logger> <message>``.
"""
from __future__ import annotations

import logging
import os
import sys

_ROOT_NAME = "repro"
_FORMAT = "%(created).3f %(levelname)s %(name)s %(message)s"
_configured = False


def get_logger(name: str | None = None) -> logging.Logger:
    """The library logger for ``name`` (dotted module path), rooted under
    the ``repro`` namespace. Safe to call at import time."""
    root = logging.getLogger(_ROOT_NAME)
    if not root.handlers:
        root.addHandler(logging.NullHandler())
    env = os.environ.get("GESTORE_LOG")
    if env and not _configured:
        configure(env)
    if name is None or name == _ROOT_NAME:
        return root
    if name.startswith(_ROOT_NAME + "."):
        return logging.getLogger(name)
    return logging.getLogger(_ROOT_NAME + "." + name)


def configure(level: str | int = "info", *, stream=None) -> logging.Logger:
    """Attach (once) a stream handler to the ``repro`` root at ``level``.

    Idempotent: repeat calls only adjust the level. CLI launchers call
    this so their progress output survives the quiet default; libraries
    never should."""
    global _configured
    root = logging.getLogger(_ROOT_NAME)
    lvl = (logging.getLevelName(level.upper()) if isinstance(level, str)
           else int(level))
    if not isinstance(lvl, int):
        lvl = logging.INFO
    if not _configured:
        h = logging.StreamHandler(stream or sys.stderr)
        h.setFormatter(logging.Formatter(_FORMAT))
        root.addHandler(h)
        _configured = True
    root.setLevel(lvl)
    return root
