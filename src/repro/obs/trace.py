"""Structured trace spans: request/wave IDs propagated end to end.

A request is minted a ``trace_id`` at ``FrontDoor.submit``; the wave it
dispatches in runs inside a ``span()`` whose context is thread-local, so
everything the wave touches on that thread — ``GeStoreService.serve_wave``,
the store scan/gather/materialize stages, ``core/segments.py`` reads —
can attach its timings and failure events to the active trace without
any plumbing through intermediate signatures.

``StageTimer`` is the migration of the old ``core.store._StageTimer``:
it keeps the additive ``trace[stage] += seconds`` contract the serving
layer aggregates (``FrontDoor.stats()`` semantics are unchanged), and
additionally folds each stage's seconds into the enclosing span (where
they appear in the flight-recorder event) and into the process-wide
registry histogram ``stage.<name>``.

Span lifecycle: ``span(name, ...)`` pushes onto the calling thread's
stack (nesting gives ``parent`` links), and on exit records one
``kind="span"`` event — name, trace id, parent id, duration, per-stage
seconds, caller fields — into the flight recorder plus a duration sample
into the ``span.<name>`` registry histogram. IDs are process-monotonic
(``<prefix>-<n>``), deterministic under a single thread, unique across
threads.
"""
from __future__ import annotations

import threading
import time

from .metrics import REGISTRY

_id_lock = threading.Lock()
_id_next = 0

_tls = threading.local()


def new_trace_id(prefix: str = "req") -> str:
    """Mint a process-unique id, e.g. ``req-000017`` / ``wave-000018``."""
    global _id_next
    with _id_lock:
        _id_next += 1
        n = _id_next
    return f"{prefix}-{n:06d}"


class Span:
    """One live span on a thread's stack (use the ``span()`` context
    manager; this class is the handle it yields)."""

    __slots__ = ("name", "trace_id", "parent_id", "fields", "stages", "_t0",
                 "duration_s")

    def __init__(self, name: str, trace_id: str, parent_id: str | None,
                 fields: dict):
        self.name = name
        self.trace_id = trace_id
        self.parent_id = parent_id
        self.fields = fields
        self.stages: dict[str, float] = {}
        self.duration_s = 0.0

    def add_stage(self, stage: str, seconds: float) -> None:
        self.stages[stage] = self.stages.get(stage, 0.0) + seconds


def current_span() -> Span | None:
    """The innermost active span on this thread, or None."""
    stack = getattr(_tls, "stack", None)
    return stack[-1] if stack else None


def current_trace_id() -> str | None:
    """The active trace id on this thread (None outside any span)."""
    s = current_span()
    return s.trace_id if s is not None else None


class span:
    """Context manager opening a span on the calling thread.

    Args:
      name: span name (becomes the ``span.<name>`` histogram).
      trace_id: propagate an existing id (e.g. the one minted at submit);
        None inherits the enclosing span's id, or mints a fresh one at
        the root.
      **fields: structured payload copied into the recorded event.
    """

    __slots__ = ("_name", "_trace_id", "_fields", "_span", "_t0")

    def __init__(self, name: str, *, trace_id: str | None = None, **fields):
        self._name = name
        self._trace_id = trace_id
        self._fields = fields

    def __enter__(self) -> Span:
        parent = current_span()
        tid = self._trace_id
        if tid is None:
            tid = parent.trace_id if parent is not None else new_trace_id()
        s = Span(self._name, tid,
                 parent.trace_id if parent is not None else None,
                 self._fields)
        stack = getattr(_tls, "stack", None)
        if stack is None:
            stack = _tls.stack = []
        stack.append(s)
        self._span = s
        self._t0 = time.perf_counter()
        return s

    def __exit__(self, exc_type, exc, tb):
        s = self._span
        s.duration_s = time.perf_counter() - self._t0
        _tls.stack.pop()
        REGISTRY.histogram(f"span.{s.name}").record(s.duration_s)
        from .recorder import RECORDER
        RECORDER.record(
            "span", name=s.name, trace=s.trace_id, parent=s.parent_id,
            duration_s=s.duration_s,
            **({"stages": dict(s.stages)} if s.stages else {}),
            **({"error": repr(exc)} if exc is not None else {}),
            **s.fields)
        return False


class StageTimer:
    """Accumulate wall seconds into ``trace[stage]`` (no-op when trace is
    None) — the per-stage latency hook the serving layer aggregates into
    p50/p99 histograms. Additive: one trace dict can span a whole wave.
    Each exit also feeds the enclosing span (if any) and the process-wide
    ``stage.<name>`` histogram."""

    __slots__ = ("_trace", "_stage", "_t0")

    def __init__(self, trace: dict | None, stage: str):
        self._trace, self._stage = trace, stage

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        if self._trace is not None:
            self._trace[self._stage] = (self._trace.get(self._stage, 0.0)
                                        + dt)
        s = current_span()
        if s is not None:
            s.add_stage(self._stage, dt)
        REGISTRY.histogram(f"stage.{self._stage}").record(dt)
        return False
