"""Flight recorder: a bounded ring of recent structured events.

When a wave fails three layers down (a segment read raising
``CorruptSegmentError`` inside a batched scan inside a multi-tenant
wave), the stack trace alone does not say *which* request, store, and
spill history led there. The recorder keeps the last ``cap`` structured
events — admission rejections, wave/mutation failures, pool
spill/reload churn, segment read errors, completed spans — each stamped
with a monotonic sequence number and the active trace id, so the dump
reconstructs the failure's context after the fact.

Dump on demand with ``RECORDER.dump()`` / ``dump_json(path)``, or set
``GESTORE_FLIGHT_DUMP=<path>`` to install an excepthook that writes the
dump when the process dies on an unhandled exception.

Events are plain dicts ``{"seq", "t", "kind", ...fields}`` (``t`` is
``time.time()``); the ring drops oldest-first and counts drops, so a
dump always says how much history it lost.
"""
from __future__ import annotations

import json
import os
import sys
import threading
import time
from collections import deque

from .trace import current_trace_id

DEFAULT_CAP = 512


class FlightRecorder:
    """Thread-safe bounded event ring (see module docstring)."""

    def __init__(self, cap: int = DEFAULT_CAP):
        self._lock = threading.Lock()
        self._cap = max(int(cap), 1)
        self._ring: deque[dict] = deque(maxlen=self._cap)
        self._seq = 0
        self._dropped = 0

    @property
    def cap(self) -> int:
        return self._cap

    def record(self, kind: str, **fields) -> None:
        """Append one event; the active trace id is attached automatically
        unless the caller passed an explicit ``trace`` field."""
        if "trace" not in fields:
            tid = current_trace_id()
            if tid is not None:
                fields["trace"] = tid
        with self._lock:
            self._seq += 1
            if len(self._ring) == self._cap:
                self._dropped += 1
            self._ring.append({"seq": self._seq, "t": time.time(),
                               "kind": kind, **fields})

    def events(self, kind: str | None = None) -> list[dict]:
        """Snapshot of the ring, oldest first (optionally one kind)."""
        with self._lock:
            evs = list(self._ring)
        return evs if kind is None else [e for e in evs
                                         if e["kind"] == kind]

    def dump(self) -> dict:
        """The full dump payload: events plus loss accounting."""
        with self._lock:
            return {"cap": self._cap, "recorded": self._seq,
                    "dropped": self._dropped, "events": list(self._ring)}

    def dump_json(self, path: str) -> str:
        """Write ``dump()`` as JSON to ``path``; returns the path."""
        with open(path, "w") as f:
            json.dump(self.dump(), f, indent=2, default=str)
        return path

    def clear(self) -> None:
        """Drop every event and reset counters (test isolation)."""
        with self._lock:
            self._ring.clear()
            self._seq = 0
            self._dropped = 0


def _cap_from_env() -> int:
    try:
        return int(os.environ.get("GESTORE_FLIGHT_CAP", DEFAULT_CAP))
    except ValueError:
        return DEFAULT_CAP


#: the process-wide recorder every layer publishes into.
RECORDER = FlightRecorder(_cap_from_env())


def install_excepthook(path: str | None = None) -> None:
    """Chain an excepthook that dumps the recorder to ``path`` (default
    ``GESTORE_FLIGHT_DUMP``) before the previous hook runs."""
    dest = path or os.environ.get("GESTORE_FLIGHT_DUMP")
    if not dest:
        return
    prev = sys.excepthook

    def _hook(exc_type, exc, tb):
        RECORDER.record("unhandled_exception", error=repr(exc))
        try:
            RECORDER.dump_json(dest)
        except OSError:
            pass
        prev(exc_type, exc, tb)

    sys.excepthook = _hook


if os.environ.get("GESTORE_FLIGHT_DUMP"):
    install_excepthook()
