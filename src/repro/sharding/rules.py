"""Logical-axis -> mesh-axis rules with divisibility fallback chains.

Every parameter/cache dim carries a logical name (see models/*.py specs).
A rule is a priority list of mesh-axis tuples; for each tensor we walk its
dims, assigning the first candidate that (a) exists on the mesh, (b) has not
been used by another dim of the same tensor, and (c) divides the dim size.
This is what lets odd published dims degrade gracefully instead of failing
to lower: whisper's vocab 51865 falls back to replicated, qwen2-0.5b's 14
heads fall through to head_dim sharding, grok's 8 experts fall through to
expert-FFN tensor parallelism.

Parallelism mapping (DP/FSDP/TP/EP/SP):
  batch        -> (pod, data)      pure DP (gradient all-reduce)
  embed        -> data             FSDP / ZeRO-3 parameter+optimizer sharding
  heads/mlp/.. -> model            TP (Megatron-style)
  expert       -> model            EP (falls back to expert_mlp TP)
  kv_seq       -> model            SP for decode caches (sequence-sharded
                                   attention: softmax stats all-reduce)
"""
from __future__ import annotations

import math
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import ParamSpec

Rules = dict[str, list[tuple[str, ...]]]

DEFAULT_RULES: Rules = {
    # parameters
    "vocab": [("model",), ()],
    "embed": [("data",), ()],
    "embed_tbl": [()],        # see models/layers.embed_spec
    "embed_out": [()],
    "heads": [("model",), ()],
    "kv_heads": [("model",), ()],
    # head_dim deliberately unsharded by default: sharding it splits RoPE's
    # rotate-half halves across devices (involuntary full remat in SPMD).
    # Sharding kv_heads' fallback is replication (standard when kv < TP).
    "head_dim": [()],
    "mlp": [("model",), ()],
    "expert": [("model",), ()],
    "expert_in": [()],
    "expert_mlp": [("model",), ()],
    "ssm_inner": [("model",), ()],
    "heads_flat": [("model",), ()],
    "layers": [()],
    None: [()],
    # activations / caches
    "batch": [("pod", "data"), ("data",), ()],
    "seq_sp": [("model",), ()],   # sequence-parallel attention (odd head counts)
    "exp_cap": [("data",), ()],   # MoE capacity dim when expert dim fell back
    "seq": [()],
    "kv_seq": [("model",), ()],
    "act_embed": [()],
}


def _axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def pspec_for(shape: Sequence[int], axes: Sequence[str | None], mesh: Mesh,
              rules: Rules | None = None) -> P:
    rules = rules or DEFAULT_RULES
    used: set[str] = set()
    entries: list[Any] = []
    for dim, name in zip(shape, axes):
        assigned: Any = None
        for cand in rules.get(name, [()]):
            if not cand:
                assigned = None
                break
            if not all(a in mesh.axis_names for a in cand):
                continue
            if any(a in used for a in cand):
                continue
            size = _axis_size(mesh, cand)
            if dim % size == 0 and dim >= size:
                assigned = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        entries.append(assigned)
    return P(*entries)


def current_mesh():
    """The ambient mesh: jax.sharding.set_mesh context if set, else the
    legacy `with mesh:` context, else None."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            return am
    except Exception:
        pass
    try:
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            from jax.interpreters import pxla
            pm = pxla.thread_resources.env.physical_mesh
        if pm is not None and pm.axis_names:
            return pm
    except Exception:
        pass
    return None


def constrain(x, axes: Sequence[str | None], rules: Rules | None = None):
    """with_sharding_constraint by LOGICAL axes, using the ambient mesh.
    No-op outside a mesh context (single-device tests/examples)."""
    try:
        mesh = current_mesh()
        if mesh is None:
            return x
        spec = pspec_for(x.shape, axes, mesh, rules)
        if isinstance(mesh, Mesh):
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def sharding_for(spec: ParamSpec, mesh: Mesh, rules: Rules | None = None) -> NamedSharding:
    return NamedSharding(mesh, pspec_for(spec.shape, spec.axes, mesh, rules))


def tree_shardings(spec_tree, mesh: Mesh, rules: Rules | None = None):
    """ParamSpec tree -> NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda s: sharding_for(s, mesh, rules), spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def abstract_tree(spec_tree, mesh: Mesh, rules: Rules | None = None,
                  dtype_override=None):
    """ParamSpec tree -> ShapeDtypeStruct tree with shardings attached."""
    def mk(s: ParamSpec):
        return jax.ShapeDtypeStruct(
            s.shape, dtype_override or s.dtype,
            sharding=sharding_for(s, mesh, rules))
    return jax.tree_util.tree_map(
        mk, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# input-batch and cache shardings (activation side)
# ---------------------------------------------------------------------------

def batch_pspec(mesh: Mesh) -> Any:
    cand = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return cand if len(cand) > 1 else cand[0]


def shard_batch_specs(specs: Mapping[str, jax.ShapeDtypeStruct], mesh: Mesh,
                      rules: Rules | None = None) -> dict:
    """Attach shardings to model-input ShapeDtypeStructs.

    tokens/labels (B, S): batch over (pod, data). embeds (B, S, d) likewise.
    positions (3, B, S): batch on dim 1. Falls back to replication when the
    batch does not divide (e.g. long_500k batch=1)."""
    out = {}
    bp = batch_pspec(mesh)
    bsz = _axis_size(mesh, bp if isinstance(bp, tuple) else (bp,))
    for name, sds in specs.items():
        dims: list[Any] = [None] * len(sds.shape)
        bdim = 1 if name == "positions" else 0
        if sds.shape[bdim] % bsz == 0:
            dims[bdim] = bp
        elif "data" in mesh.axis_names and sds.shape[bdim] % mesh.shape["data"] == 0:
            dims[bdim] = "data"
        out[name] = jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, P(*dims)))
    return out


def cache_axes(cfg, leaf_path: str, shape: tuple[int, ...]) -> tuple:
    """Logical axes for a decode-cache leaf (stacked (G, B, S, K, hd) etc.)."""
    n = len(shape)
    if n == 5 and "cross" not in leaf_path:        # KV cache (G,B,S,K,hd)
        return ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    if n == 5:                                      # whisper cross (L,B,Se,K,hd)
        return ("layers", "batch", "seq", "kv_heads", "head_dim")
    if n == 4:                                      # ssm h (G,B,di,N)
        return ("layers", "batch", "ssm_inner", None)
    if n == 3:                                      # conv/shift (G,B,di)
        return ("layers", "batch", "ssm_inner")
    return ("layers",) + (None,) * (n - 1)


def shard_decode_state(cfg, state, mesh: Mesh, rules: Rules | None = None):
    """Attach shardings to an abstract DecodeState/WhisperState."""
    rules = rules or DEFAULT_RULES
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    out = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        if leaf.shape == ():
            out.append(jax.ShapeDtypeStruct((), leaf.dtype,
                                            sharding=NamedSharding(mesh, P())))
            continue
        axes: tuple
        if "rwkv" in cfg.family or cfg.rwkv:
            # rwkv state s: (G,B,H,dk,dv); shifts (G,B,d)
            if len(leaf.shape) == 5:
                axes = ("layers", "batch", "heads", None, None)
            elif len(leaf.shape) == 3:
                axes = ("layers", "batch", None)
            else:
                axes = cache_axes(cfg, pstr, leaf.shape)
        else:
            axes = cache_axes(cfg, pstr, leaf.shape)
        pspec = pspec_for(leaf.shape, axes, mesh, rules)
        out.append(jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                        sharding=NamedSharding(mesh, pspec)))
    return jax.tree_util.tree_unflatten(treedef, out)
