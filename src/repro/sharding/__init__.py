"""Sharding rules: logical axes -> mesh axes with divisibility fallbacks."""
from .rules import (DEFAULT_RULES, abstract_tree, batch_pspec, constrain,
                    current_mesh,
                    pspec_for,
                    shard_batch_specs, shard_decode_state, sharding_for,
                    tree_shardings)

__all__ = ["DEFAULT_RULES", "abstract_tree", "batch_pspec", "constrain",
           "current_mesh",
           "pspec_for",
           "shard_batch_specs", "shard_decode_state", "sharding_for",
           "tree_shardings"]
