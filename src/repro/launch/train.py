"""Production training launcher.

    python -m repro.launch.train --arch llama3.2-1b --steps 100 \
        --batch 32 --seq 128 [--smoke] [--mesh single|pod|auto]

On a real TPU fleet each host runs this same entrypoint (jax.distributed
initializes from the TPU environment); on CPU it runs the smoke config on
the local device count. XLA latency-hiding-scheduler flags for
compute/collective overlap are applied here (they are launcher policy, not
library code).
"""
import os

# collective/compute overlap: latency-hiding scheduler + async collectives
_XLA_PERF_FLAGS = " ".join([
    "--xla_tpu_enable_latency_hiding_scheduler=true",
    "--xla_tpu_megacore_fusion_allow_ags=true",
    "--xla_enable_async_all_gather=true",
    "--xla_enable_async_collective_permute=true",
])
if "TPU_NAME" in os.environ or os.environ.get("REPRO_TPU"):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " +
                               _XLA_PERF_FLAGS).strip()

import argparse
import sys

import numpy as np
import jax

from repro.configs.base import RunConfig, get_config, get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.obs.log import configure as configure_logging, get_logger
from repro.train.train_loop import Trainer, TrainerConfig

logger = get_logger("launch.train")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="ckpts")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    configure_logging("info", stream=sys.stdout)  # CLI progress on stdout

    if jax.device_count() > 1 and os.environ.get("REPRO_DISTRIBUTED"):
        jax.distributed.initialize()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    run = RunConfig(optimizer=args.optimizer, learning_rate=args.lr,
                    microbatch=args.microbatch,
                    grad_compress=args.grad_compress,
                    attn_impl="xla" if args.seq <= 2048 else "chunked")
    tcfg = TrainerConfig(total_steps=args.steps,
                         warmup_steps=max(args.steps // 20, 1),
                         ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                         host=f"host{jax.process_index()}")

    rng = np.random.default_rng(args.seed)
    tokens = rng.integers(3, cfg.vocab, size=args.batch * (args.seq + 1) * 64,
                          dtype=np.int64).astype(np.int32)
    pipe = TokenPipeline(tokens, DataConfig(
        seq_len=args.seq, global_batch=args.batch, seed=args.seed,
        host_id=jax.process_index(), n_hosts=jax.process_count()))

    trainer = Trainer(cfg, run, tcfg, seed=args.seed)

    def log(step, m):
        if step % max(args.steps // 10, 1) == 0 or step == 1:
            logger.info("step %5d loss %.4f gnorm %.3f %.2fs", step,
                        m["loss"], m["grad_norm"], m["step_time"])
        verdicts = trainer.monitor.evaluate()
        slow = [h for h, v in verdicts.items() if v != "ok"]
        if slow:
            logger.warning("[straggler] %s", slow)

    hist = trainer.run_loop(iter(pipe), hook=log)
    logger.info("done: %d steps, final loss %.4f", len(hist),
                hist[-1]["loss"])
    return 0


if __name__ == "__main__":
    sys.exit(main())
