"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh):
  compute   = HLO_FLOPs_per_device / peak_FLOPs      [s]
  memory    = HLO_bytes_per_device / HBM_bw          [s]
  collective= collective_bytes_per_device / link_bw  [s]

cost_analysis() of the SPMD-partitioned executable reports per-device
FLOPs/bytes; collective bytes are parsed from the partitioned HLO text with
ring-algorithm traffic factors (all-reduce 2(n-1)/n, all-gather/all-to-all
(n-1)/n on the gathered size, reduce-scatter (n-1) on the scattered size,
permute 1x). Hardware constants: v5e-class chip.
"""
from __future__ import annotations

import dataclasses
import re

# v5e-class constants (from the assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (1 link assumed per hop)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"(?P<op>all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"(?:\()")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device traffic bytes by collective kind (ring factors applied)."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "n_ops": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op").replace("-start", "")
        nbytes = _shape_bytes(m.group("rtype"))
        gm = _GROUP_IOTA_RE.search(line)
        if gm:
            n = int(gm.group(2))
        else:
            gl = _GROUP_LIST_RE.search(line)
            n = len(gl.group(1).split(",")) if gl else 2
        n = max(n, 2)
        if op == "all-reduce":
            traffic = 2.0 * nbytes * (n - 1) / n
        elif op == "all-gather":
            traffic = nbytes * (n - 1) / n          # nbytes = gathered size
        elif op == "reduce-scatter":
            traffic = nbytes * (n - 1)              # nbytes = scattered size
        elif op == "all-to-all":
            traffic = nbytes * (n - 1) / n
        else:
            traffic = float(nbytes)
        out[op] += traffic
        out["n_ops"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective: dict
    model_flops_global: float
    n_devices: int

    @property
    def collective_bytes_total(self) -> float:
        return sum(v for k, v in self.collective.items() if k != "n_ops")

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_total / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / compiled HLO FLOPs (remat/redundancy waste)."""
        hlo_global = self.flops_per_device * self.n_devices
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs utilization if the dominant term were saturated:
        (model flops time) / max(term) — the score we hillclimb."""
        t_model = self.model_flops_global / (self.n_devices * PEAK_FLOPS)
        t_max = max(self.t_compute, self.t_memory, self.t_collective)
        return t_model / t_max if t_max else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes": self.collective_bytes_total,
            "collective_detail": self.collective,
            "model_flops_global": self.model_flops_global,
            "n_devices": self.n_devices,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def kernel_roofline(flops: float, nbytes: float, wall_s: float) -> dict:
    """Single-kernel roofline terms from host-side launch accounting.

    ``flops``/``nbytes`` are the launch path's analytic estimates (see
    ``obs/kerneltel.py`` per-site models), ``wall_s`` the measured
    launch-to-host-sync wall. ``roofline_fraction`` is the fraction of
    the roofline-implied minimum time actually achieved —
    ``max(t_compute, t_memory) / wall`` against the v5e-class constants
    above — the per-kernel score ``benchmarks/table10_observability.py``
    publishes so efficiency regressions are visible in CI.
    """
    t_compute = flops / PEAK_FLOPS
    t_memory = nbytes / HBM_BW
    t_min = max(t_compute, t_memory)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "dominant": "compute" if t_compute >= t_memory else "memory",
        "roofline_fraction": (t_min / wall_s) if wall_s > 0 else 0.0,
    }


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D for a
    forward-only step (+ attention term for long contexts)."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    mult = 6.0 if shape.mode == "train" else 2.0
    base = mult * n * tokens
    # attention FLOPs (QK^T + PV), significant at 32k
    if not cfg.rwkv:
        attn_layers = sum(1 for l in range(cfg.n_layers) if cfg.is_attn_layer(l))
        s = shape.seq_len
        if shape.mode == "decode":
            att = 2 * 2 * cfg.n_heads * cfg.hd * s  # one query over s keys
        else:
            att = 2 * 2 * cfg.n_heads * cfg.hd * s * (s + 1) / 2  # causal
        fb = 3.0 if shape.mode == "train" else 1.0
        base += fb * attn_layers * shape.global_batch * att
    return base
