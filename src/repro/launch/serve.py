"""Serving launcher: bring up the batched engine + scheduler for an
architecture and run a synthetic request stream (or read prompts on stdin).

    python -m repro.launch.serve --arch rwkv6-7b --smoke --requests 16
"""
import argparse
import sys
import time

import numpy as np
import jax

from repro.configs.base import RunConfig, get_config, get_smoke_config
from repro.models import build
from repro.obs.log import configure as configure_logging, get_logger
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.scheduler import Scheduler

log = get_logger("launch.serve")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    configure_logging("info", stream=sys.stdout)  # CLI progress on stdout
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    bundle = build(cfg)
    log.info("initializing %s (%.2fB params)...", cfg.name,
             cfg.param_count() / 1e9)
    params = bundle.init(jax.random.key(args.seed))
    engine = ServeEngine(cfg, params,
                         ServeConfig(max_new_tokens=args.max_new,
                                     temperature=args.temperature),
                         run=RunConfig())
    sched = Scheduler(engine, max_batch=args.max_batch)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        n = int(rng.integers(4, 48))
        sched.submit(f"req{i:04d}", rng.integers(3, cfg.vocab, size=n))
    stats = sched.run_until_drained()
    wall = time.time() - t0
    tput = engine.stats["decode_tokens"] / max(wall, 1e-9)
    log.info("%d requests in %.1fs (%.1f tok/s decode); p50 %.2fs p99 %.2fs",
             stats["n_done"], wall, tput, stats["p50_latency_s"],
             stats["p99_latency_s"])
    return 0


if __name__ == "__main__":
    sys.exit(main())
