"""train_step / prefill_step / serve_step builders shared by the trainer,
the serving engine, and the dry-run."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import build
from repro.models.transformer import FwdOpts
from repro.train.optimizer import OptHyper, apply_updates, clip_by_global_norm


def fwd_opts(run: RunConfig) -> FwdOpts:
    return FwdOpts(attn_impl=run.attn_impl, attn_chunk=run.attn_chunk,
                   remat=run.remat, unroll=run.unroll)


def default_hyper(cfg: ModelConfig, run: RunConfig) -> OptHyper:
    name = run.optimizer
    if cfg.param_count() > 2e11 and name == "adamw":
        # AdamW m+v for >200B params exceeds v5e HBM budgets; see DESIGN.md
        name = "adafactor"
    return OptHyper(name=name, lr=run.learning_rate,
                    weight_decay=run.weight_decay)


def make_train_step(cfg: ModelConfig, run: RunConfig,
                    hyper: OptHyper | None = None):
    bundle = build(cfg)
    hyper = hyper or default_hyper(cfg, run)
    opts = fwd_opts(run)

    def train_step(state, batch):
        params = state["params"]

        def lf(p):
            return bundle.loss(p, batch, opts)

        if run.microbatch and run.microbatch > 1:
            # gradient accumulation: scan over microbatches, mean grads
            mb = run.microbatch

            def split(key_x):
                name, x = key_x
                bdim = 1 if name == "positions" else 0  # positions: (3,B,S)
                assert x.shape[bdim] % mb == 0, (name, x.shape, mb)
                x = jnp.moveaxis(x, bdim, 0)
                x = x.reshape(mb, x.shape[0] // mb, *x.shape[1:])
                return jnp.moveaxis(x, 1, bdim + 1)
            mbatches = {k: split((k, v)) for k, v in dict(batch).items()}

            def acc_body(carry, mbatch):
                g_acc, loss_acc = carry

                def lf_mb(p):
                    return bundle.loss(p, mbatch, opts)
                (loss, _m), g = jax.value_and_grad(lf_mb, has_aux=True)(params)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_body, (zeros, 0.0), mbatches)
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics = {"ce": loss, "z_loss": jnp.zeros(()),
                       "moe_aux": jnp.zeros(()), "tokens": jnp.zeros(())}
        else:
            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
        new_params, new_opt = apply_updates(hyper, params, grads, state["opt"])
        out_metrics = dict(metrics)
        out_metrics.update(loss=loss, grad_norm=gnorm)
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, run: RunConfig):
    bundle = build(cfg)
    opts = fwd_opts(run)

    def prefill_step(params, batch):
        return bundle.prefill(params, batch, opts)

    return prefill_step


def make_serve_step(cfg: ModelConfig, run: RunConfig | None = None):
    bundle = build(cfg)
    opts = fwd_opts(run) if run is not None else None

    def serve_step(params, token, state, positions=None):
        if cfg.family == "encdec":
            return bundle.decode(params, token, state)
        from repro.models import transformer as tf
        return tf.decode_step(params, cfg, token, state, positions, opts)

    return serve_step
