"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is pure
data parallelism whose gradient all-reduce is the only cross-pod collective
(ICI within a pod, DCN across pods).

Defined as functions, not module constants: importing this module never
touches jax device state (device count is locked at first jax init, and the
smoke tests must see 1 CPU device while the dry-run sees 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_shard_mesh(n_shards: int, devices=None):
    """1-D ("shard",) mesh over the first ``n_shards`` devices — the
    layout core/placement.py pins sharded-store superlogs across so the
    scatter-gather batched select runs one shard per device. Returns None
    when fewer than ``n_shards`` devices exist (the placement layer then
    falls back to serial or single-device stacked execution)."""
    devs = list(devices) if devices is not None else jax.devices()
    if n_shards < 1 or len(devs) < n_shards:
        return None
    return jax.make_mesh((n_shards,), ("shard",), devices=devs[:n_shards])


def make_test_mesh(devices: int | None = None):
    """Small mesh for CPU distributed tests (8 host devices -> (2, 4))."""
    n = devices or len(jax.devices())
    if n >= 8:
        return jax.make_mesh((n // 4, 4), ("data", "model"))
    if n >= 2:
        return jax.make_mesh((n // 2, 2), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))
