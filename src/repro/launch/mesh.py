"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is pure
data parallelism whose gradient all-reduce is the only cross-pod collective
(ICI within a pod, DCN across pods).

Defined as functions, not module constants: importing this module never
touches jax device state (device count is locked at first jax init, and the
smoke tests must see 1 CPU device while the dry-run sees 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int | None = None):
    """Small mesh for CPU distributed tests (8 host devices -> (2, 4))."""
    n = devices or len(jax.devices())
    if n >= 8:
        return jax.make_mesh((n // 4, 4), ("data", "model"))
    if n >= 2:
        return jax.make_mesh((n // 2, 2), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))
