"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves (a) the sharding rules produce a coherent SPMD
program on the production meshes (16x16 single-pod, 2x16x16 multi-pod),
(b) memory_analysis() fits, (c) cost_analysis() + HLO collective parsing
yield the roofline terms of EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every cell, subprocess each
  python -m repro.launch.dryrun --all --mesh pod # multi-pod pass
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init;
#   only the module docstring is allowed above these two lines — hence no
#   `from __future__ import annotations` in this module).

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax

from repro.configs.base import (ARCH_IDS, RunConfig, SHAPES, get_config,
                                shapes_for)
from repro.launch import roofline as rl
from repro.obs.log import configure as configure_logging, get_logger
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import default_hyper, make_prefill_step, \
    make_serve_step, make_train_step
from repro.models import abstract_decode_state, batch_specs, build
from repro.sharding import (abstract_tree, shard_batch_specs,
                            shard_decode_state)
from repro.train.optimizer import state_specs

RESULTS_DIR = "experiments/dryrun"

log = get_logger("launch.dryrun")


def abstract_train_state(cfg, run: RunConfig, mesh):
    bundle = build(cfg)
    hyper = default_hyper(cfg, run)
    pspec = bundle.spec
    opt_spec = state_specs(pspec, hyper)
    return {
        "params": abstract_tree(pspec, mesh),
        "opt": abstract_tree(opt_spec, mesh),
    }


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               run: RunConfig | None = None, cfg_override=None):
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    run = run or RunConfig()
    mesh = make_production_mesh(multi_pod=multi_pod)

    with mesh:
        if shape.mode == "train":
            step = make_train_step(cfg, run)
            state = abstract_train_state(cfg, run, mesh)
            batch = shard_batch_specs(batch_specs(cfg, shape), mesh)
            lowered = jax.jit(step, donate_argnums=(0,)).lower(state, batch)
        elif shape.mode == "prefill":
            step = make_prefill_step(cfg, run)
            params = abstract_tree(build(cfg).spec, mesh,
                                   dtype_override="bfloat16")
            batch = shard_batch_specs(batch_specs(cfg, shape), mesh)
            lowered = jax.jit(step).lower(params, batch)
        else:  # decode
            step = make_serve_step(cfg, run)
            params = abstract_tree(build(cfg).spec, mesh,
                                   dtype_override="bfloat16")
            inputs = shard_batch_specs(batch_specs(cfg, shape), mesh)
            state = shard_decode_state(
                cfg, abstract_decode_state(cfg, shape), mesh)
            args = (params, inputs["token"], state)
            if cfg.mrope_sections is not None:
                args = args + (inputs["positions"],)
            lowered = jax.jit(step, donate_argnums=(2,)).lower(*args)
    return lowered, cfg, shape, mesh


def _measure(arch, shape_name, multi_pod, cfg_override=None):
    run = RunConfig(unroll=True)
    lowered, cfg, shape, mesh = lower_cell(arch, shape_name, multi_pod,
                                           run=run, cfg_override=cfg_override)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # pre-0.5 jax returns [dict]
        cost = cost[0] if cost else {}
    coll = rl.collective_bytes(compiled.as_text())
    return compiled, cfg, shape, mesh, {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    """Compile the full-depth cell (pass/fail + memory), then compile 1-group
    and 2-group reduced-depth variants to extrapolate per-layer cost:
    XLA's cost_analysis (and the HLO text) count a while-loop body ONCE, so
    scan-over-layers costs must be scaled by trip count:
      X_total = X(1 group) + (X(2 groups) - X(1 group)) * (n_groups - 1).
    """
    import dataclasses as dc
    from repro.models.transformer import pattern

    t0 = time.time()
    lowered, cfg, shape, mesh = lower_cell(arch, shape_name, multi_pod)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}
    del compiled, lowered

    # per-group cost extrapolation
    p, n_groups = (cfg.n_layers, 1) if cfg.family == "encdec" else pattern(cfg)
    if cfg.family == "encdec":
        p, n_groups = 1, cfg.n_layers
        mk = lambda k: dc.replace(cfg, n_layers=k, encoder_layers=k)
    else:
        mk = lambda k: dc.replace(cfg, n_layers=k * p)
    _, _, _, _, c1 = _measure(arch, shape_name, multi_pod, cfg_override=mk(1))
    _, _, _, _, c2 = _measure(arch, shape_name, multi_pod, cfg_override=mk(2))

    def extrap(key):
        if isinstance(c1[key], dict):
            out = {}
            for k in c1[key]:
                out[k] = c1[key][k] + (c2[key][k] - c1[key][k]) * (n_groups - 1)
            return out
        return c1[key] + (c2[key] - c1[key]) * (n_groups - 1)

    flops = extrap("flops")
    nbytes = extrap("bytes")
    coll = extrap("coll")
    n_dev = mesh.devices.size
    roof = rl.Roofline(
        flops_per_device=flops,
        bytes_per_device=nbytes,
        collective=coll,
        model_flops_global=rl.model_flops(cfg, shape),
        n_devices=n_dev)
    row = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.mode,
        "n_groups": n_groups,
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "memory": mem_info,
        "hlo_ops": {"n_collectives": coll["n_ops"]},
        "roofline": roof.as_dict(),
        "cost_1group": c1, "cost_2group": c2,
    }
    return row


def cell_list(multi_pod: bool, archs=None) -> list[tuple[str, str]]:
    cells = []
    for arch in (archs or ARCH_IDS):
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            cells.append((arch, shape.name))
    return cells


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "pod", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    configure_logging("info", stream=sys.stdout)  # CLI progress on stdout
    os.makedirs(args.out, exist_ok=True)

    meshes = {"single": [False], "pod": [True], "both": [False, True]}[args.mesh]

    if not args.all:
        assert args.arch and args.shape
        ok = True
        for mp in meshes:
            tag = f"{args.arch}_{args.shape}_{'pod' if mp else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                log.info("[skip] %s", tag)
                continue
            try:
                row = run_cell(args.arch, args.shape, mp)
                with open(path, "w") as f:
                    json.dump(row, f, indent=1)
                r = row["roofline"]
                log.info("[ok] %s: compile=%ss dom=%s frac=%.3f", tag,
                         row["t_compile_s"], r["dominant"],
                         r["roofline_fraction"])
            except Exception:
                ok = False
                with open(os.path.join(args.out, tag + ".FAIL"), "w") as f:
                    f.write(traceback.format_exc())
                log.error("[FAIL] %s", tag)
                traceback.print_exc()
        return 0 if ok else 1

    # orchestrate: one subprocess per cell (isolates XLA state + memory)
    failures = []
    for mp in meshes:
        for arch, shape in cell_list(mp):
            tag = f"{arch}_{shape}_{'pod' if mp else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                log.info("[skip] %s", tag)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape,
                   "--mesh", "pod" if mp else "single", "--out", args.out]
            log.info("[run] %s", tag)
            r = subprocess.run(cmd)
            if r.returncode != 0:
                failures.append(tag)
    log.info("done; %d failures: %s", len(failures), failures)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
