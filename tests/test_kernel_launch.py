"""Unified launch helper (kernels/launch.py): tile resolution + autotune
cache, recompile-proof shape bucketing under continuous ingest, and the
byte-equivalence suites pinning the new device paths (in-kernel chain
decode, two-lane 8-byte codec, device compact rewrite) to their host
oracles in kernels/ref.py."""
import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.store import FieldSchema, VersionedStore
from repro.kernels import launch, ops, ref
from repro.kernels.compact_rewrite import compact_rewrite, ref_compact_rewrite
from repro.kernels.delta_codec import (chain_pack, chain_unpack,
                                       delta_pack_wide, delta_unpack_wide)


# ---------------------------------------------------------------------------
# tile resolution + autotune cache
# ---------------------------------------------------------------------------

@pytest.fixture
def tile_cache(tmp_path, monkeypatch):
    """Point the winner cache at a throwaway file and drop the in-memory
    mirror on both sides of the test (the mirror outlives monkeypatch)."""
    path = tmp_path / "tiles.json"
    monkeypatch.setenv(launch.CACHE_ENV, str(path))
    launch.reset_cache()
    yield path
    launch.reset_cache()


def test_pow2_bucket():
    assert launch.pow2_bucket(0) == 1
    assert launch.pow2_bucket(1) == 1
    assert launch.pow2_bucket(5) == 8
    assert launch.pow2_bucket(8) == 8
    assert launch.pow2_bucket(9) == 16
    assert launch.pow2_bucket(3, floor=8) == 8
    assert launch.pow2_bucket(900, floor=512) == 1024


def test_tile_env_override_wins(tile_cache, monkeypatch):
    launch.record_winner("batched_select", 8192, 4096)
    monkeypatch.setenv(launch.ENV_PREFIX + "BATCHED_SELECT", "1024")
    assert launch.tile_for("batched_select", n=5000) == 1024
    # malformed override falls through to the cached winner
    monkeypatch.setenv(launch.ENV_PREFIX + "BATCHED_SELECT", "zero")
    assert launch.tile_for("batched_select", n=5000) == 4096
    monkeypatch.delenv(launch.ENV_PREFIX + "BATCHED_SELECT")
    assert launch.tile_for("batched_select", n=5000) == 4096
    # other buckets still see the built-in default
    assert launch.tile_for("batched_select", n=100) \
        == launch.DEFAULT_TILES["batched_select"]


def test_sweep_records_and_caches(tile_cache):
    calls = []

    def bench(tile):
        calls.append(tile)
        return 1.0 if tile != 256 else 0.5

    res = launch.sweep("shard_route", bench, n=900,
                       candidates=(256, 512, 1024))
    assert res["tile"] == 256 and not res["cached"]
    assert res["bucket"] == 1024
    assert sorted(calls) == [256, 512, 1024]
    # winner persisted to the env-pointed file...
    with open(tile_cache) as f:
        disk = json.load(f)
    assert any(k.startswith("shard_route/") and k.endswith("/b1024")
               for k in disk)
    # ...the serving path resolves it, and a repeat sweep is a cache read
    assert launch.tile_for("shard_route", n=900) == 256
    calls.clear()
    res2 = launch.sweep("shard_route", bench, n=1000)
    assert res2["cached"] and res2["tile"] == 256 and calls == []
    # force=True re-runs even with a winner on disk
    res3 = launch.sweep("shard_route", bench, n=900,
                        candidates=(256, 512), force=True)
    assert not res3["cached"] and calls == [256, 512]


def test_winner_cache_survives_reset(tile_cache):
    launch.record_winner("delta_codec", 2048, 1024)
    launch.reset_cache()  # drop the mirror: must re-read from disk
    assert launch.tile_for("delta_codec", n=1500) == 1024


# ---------------------------------------------------------------------------
# recompile stability under continuous ingest (the table9 stall)
# ---------------------------------------------------------------------------

def _mk_rel(rng, keys):
    return {"a": rng.integers(0, 50, (len(keys), 4)).astype(np.int32),
            "b": rng.normal(size=(len(keys), 2)).astype(np.float32)}


def test_epoch_rolls_bounded_by_buckets(rng):
    """N epoch rolls under continuous ingest must compile at most one scan
    per visited pow2 cell bucket — not one per ingest."""
    before = ops.scan_cache_size()
    if before < 0:
        pytest.skip("jit cache probing unavailable on this jax")
    st = VersionedStore("t", [FieldSchema("a", 4, "int32"),
                              FieldSchema("b", 2, "float32")])
    n_rolls = 12
    buckets = set()
    for v in range(n_rolls):
        keys = [f"K{i:04d}" for i in range((v + 1) * 40)]
        st.update((v + 1) * 10, keys, _mk_rel(rng, keys))
        st.get_versions([(v + 1) * 10, v * 10 + 5], fields=["a"])
        buckets.add(ops.scan_bucket(st._superlog.n_cells))
    grew = ops.scan_cache_size() - before
    # every ingest changes the cell count; without bucketing this is
    # >= n_rolls traces. With it: at most one per (bucket, query-shape)
    assert grew <= len(buckets) + 1, \
        f"{grew} compiles for {n_rolls} rolls over {len(buckets)} buckets"
    assert grew < n_rolls


def test_bucketed_scan_matches_unpadded_ref(rng):
    """Sentinel-padding the cell axis to its pow2 bucket never changes the
    logical columns of the scan."""
    for c in (1, 7, 100, 2047, 2049, 5000):
        ts = np.sort(rng.integers(0, 97, c)).astype(np.int32)
        tq = np.array([-1, 0, 50, 96, 97], np.int32)
        c_pad = ops.scan_bucket(c)
        padded = np.concatenate(
            [ts, np.full(c_pad - c, np.iinfo(np.int32).max, np.int32)])
        got = np.asarray(ops.batched_masked_cumsum(
            jnp.asarray(padded), jnp.asarray(tq), interpret=True))[:, :c]
        want = np.asarray(ref.ref_batched_masked_cumsum(
            jnp.asarray(ts), jnp.asarray(tq)))
        assert np.array_equal(got, want), f"c={c}"


# ---------------------------------------------------------------------------
# in-kernel chain decode == host depth loop
# ---------------------------------------------------------------------------

def _chains(rng, c, w, dtype, lo, hi):
    rows = np.sort(rng.integers(0, max(c // 4, 1), c))
    heads = np.ones(c, bool)
    heads[1:] = rows[1:] != rows[:-1]
    vals = rng.integers(lo, hi, (c, w)).astype(dtype)
    return rows, heads, vals


@pytest.mark.parametrize("dtype", [np.int16, np.int32])
def test_chain_decode_matches_ref(dtype, rng):
    rows, heads, vals = _chains(rng, 500, 3, dtype,
                                np.iinfo(dtype).min, np.iinfo(dtype).max)
    prev = np.roll(vals, 1, axis=0)
    prev[heads] = 0
    with np.errstate(over="ignore"):
        deltas = vals - prev  # stored-dtype wraparound is part of the format
    got = np.asarray(ops.chain_decode(jnp.asarray(deltas),
                                      jnp.asarray(heads)))
    want = ref.ref_chain_decode(deltas, heads)
    assert np.array_equal(got, want)
    # truncation back to the stored dtype recovers the original values
    assert np.array_equal(got.astype(dtype), vals)


def test_chain_decode_xor_lanes(rng):
    rows, heads, _ = _chains(rng, 300, 2, np.int32, -1, 1)
    vals = rng.normal(size=(300, 2)).astype(np.float32)
    prev = np.roll(vals, 1, axis=0)
    prev[heads] = 0
    deltas = vals.view(np.int32) ^ prev.view(np.int32)
    got = np.asarray(ops.chain_decode(jnp.asarray(deltas),
                                      jnp.asarray(heads), xor=True))
    assert np.array_equal(got.view(np.float32).view(np.int32),
                          vals.view(np.int32))


def test_packed_superlog_matches_unpacked(rng, monkeypatch):
    """get_versions over a packed-on-device superlog is byte-identical to
    the unpacked store (GESTORE_PACKED_SUPERLOG=0)."""
    def build():
        st = VersionedStore("t", [FieldSchema("a", 4, "int32"),
                                  FieldSchema("b", 2, "float32")])
        r = np.random.default_rng(7)
        pool = [f"K{i:03d}" for i in range(64)]
        for v in range(5):
            sub = sorted(r.choice(pool, size=r.integers(20, 64),
                                  replace=False))
            st.update((v + 1) * 10, sub, _mk_rel(r, sub))
        return st.get_versions([10, 25, 30, 50, 55], fields=["a", "b"])

    monkeypatch.setenv("GESTORE_PACKED_SUPERLOG", "0")
    plain = build()
    monkeypatch.setenv("GESTORE_PACKED_SUPERLOG", "1")
    packed = build()
    for p, q in zip(plain, packed):
        assert list(p.keys) == list(q.keys)
        for f in ("a", "b"):
            assert np.array_equal(p.values[f], q.values[f])


# ---------------------------------------------------------------------------
# two-lane 8-byte codec == 64-bit host oracle
# ---------------------------------------------------------------------------

def test_wide_codec_int64_roundtrip(rng):
    new = rng.integers(-2**62, 2**62, (257, 3)).astype(np.int64)
    old = rng.integers(-2**62, 2**62, (257, 3)).astype(np.int64)
    # force modular wraparound through the lane arithmetic
    new[0] = np.iinfo(np.int64).min
    old[0] = np.iinfo(np.int64).max
    new[1] = np.iinfo(np.int64).max
    old[1] = -1
    d = delta_pack_wide(new, old, interpret=True)
    assert np.array_equal(d, ref.ref_delta_pack64(new, old))
    back = delta_unpack_wide(d, old, interpret=True)
    assert np.array_equal(back, new)


def test_wide_codec_float64_xor(rng):
    new = rng.normal(size=(100, 2)).astype(np.float64)
    old = rng.normal(size=(100, 2)).astype(np.float64)
    new[0, 0] = np.nan  # bit-exact through XOR, even non-finite
    old[1, 1] = np.inf
    d = delta_pack_wide(new, old, interpret=True)
    assert np.array_equal(d.view(np.int64),
                          ref.ref_delta_pack64(new, old).view(np.int64))
    back = delta_unpack_wide(d, old, interpret=True)
    assert np.array_equal(back.view(np.int64), new.view(np.int64))


@pytest.mark.parametrize("dtype", [np.int64, np.float64])
def test_chain_codec_8byte_roundtrip(dtype, rng):
    """chain_pack/chain_unpack on 8-byte cells round-trips bit-exactly
    through whichever lane path the backend picked."""
    c = 400
    rows = np.sort(rng.integers(0, 60, c)).astype(np.int64)
    if np.issubdtype(np.dtype(dtype), np.floating):
        vals = rng.normal(size=(c, 2)).astype(dtype)
    else:
        vals = rng.integers(-2**62, 2**62, (c, 2)).astype(dtype)
    packed, meta = chain_pack(vals, rows)
    back = chain_unpack(packed, rows, meta, np.dtype(dtype))
    assert back.dtype == np.dtype(dtype)
    assert np.array_equal(back.view(np.int64), vals.view(np.int64))


# ---------------------------------------------------------------------------
# device compact rewrite == numpy oracle
# ---------------------------------------------------------------------------

def _mk_log(rng, n_rows, c, w, dtype=np.int32):
    rows = np.sort(rng.integers(0, n_rows, c)).astype(np.int32)
    tss = rng.integers(0, 1000, c).astype(np.int64)
    order = np.lexsort((tss, rows))
    rows, tss = rows[order], tss[order]
    vals = rng.integers(-50, 50, (c, w)).astype(dtype)
    ptr = np.zeros(n_rows + 1, np.int32)
    np.add.at(ptr, rows + 1, 1)
    return vals, tss, np.cumsum(ptr).astype(np.int32)


@pytest.mark.parametrize("c,horizon", [(1, 0), (7, 500), (513, 500),
                                       (1000, 0), (1000, 2000)])
def test_compact_rewrite_matches_oracle(c, horizon, rng):
    n_rows = 40
    vals, tss, ptr = _mk_log(rng, n_rows, c, 3)
    base_vals = rng.integers(-50, 50, (n_rows, 3)).astype(np.int32)
    base_found = rng.random(n_rows) < 0.7
    want = ref_compact_rewrite(vals, tss, ptr, base_vals, base_found,
                               horizon, n_rows)
    got = compact_rewrite(vals, tss, ptr, base_vals, base_found,
                          horizon, n_rows, interpret=True)
    for g, w in zip(got, want):
        assert np.array_equal(g, w)


def test_store_compact_preserves_history_reads(rng):
    """End-to-end: compacting through the device rewrite keeps every
    still-visible version byte-identical."""
    st = VersionedStore("t", [FieldSchema("a", 4, "int32"),
                              FieldSchema("b", 2, "float32")])
    pool = [f"K{i:03d}" for i in range(48)]
    for v in range(6):
        sub = sorted(rng.choice(pool, size=rng.integers(16, 48),
                                replace=False))
        st.update((v + 1) * 10, sub, _mk_rel(rng, sub))
    qs = [35, 40, 55, 60]
    before = st.get_versions(qs, fields=["a", "b"])
    st.compact(before_ts=30)
    after = st.get_versions(qs, fields=["a", "b"])
    for p, q in zip(before, after):
        assert list(p.keys) == list(q.keys)
        for f in ("a", "b"):
            assert np.array_equal(p.values[f], q.values[f])
