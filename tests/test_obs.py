"""Observability layer: registry correctness under concurrency, histogram
bounds, span nesting/propagation under a seeded thread stress, flight
recorder ring semantics, kernel telemetry, and the front door's trace-id
minting + per-tenant rejection accounting."""
from __future__ import annotations

import json
import random
import threading

import numpy as np
import pytest

from repro.core.store import FieldSchema, VersionedStore
from repro.obs import (FlightRecorder, Histogram, MetricsRegistry, RECORDER,
                       StageTimer, current_span, current_trace_id,
                       new_trace_id, span)
from repro.obs.kerneltel import KernelTelemetry


# -- metrics registry ---------------------------------------------------------

def test_counter_concurrent_increments_are_exact():
    reg = MetricsRegistry()
    n_threads, per_thread = 8, 5_000

    def work():
        c = reg.counter("hits")          # get-or-create races too
        for _ in range(per_thread):
            c.inc()

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("hits").value == n_threads * per_thread


def test_gauge_set_and_add():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    g.set(3)
    g.add(2.5)
    assert g.value == 5.5


def test_histogram_ring_is_bounded_but_n_counts_everything():
    h = Histogram(cap=16)
    for i in range(100):
        h.record(i / 1000)
    s = h.snapshot()
    assert s["n"] == 100
    # only the last 16 samples (84..99 ms) are in the ring
    assert 83.0 <= s["p50_ms"] <= 100.0
    assert s["p99_ms"] <= 99.5


def test_histogram_empty_snapshot():
    assert Histogram(cap=4).snapshot() == {"n": 0, "p50_ms": 0.0,
                                           "p99_ms": 0.0}


def test_registry_kind_collision_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.histogram("x")


def test_registry_snapshot_json_and_prometheus():
    reg = MetricsRegistry()
    reg.counter("reqs").inc(3)
    reg.gauge("pressure").set(0.5)
    reg.histogram("lat").record(0.002)
    snap = reg.snapshot()
    assert snap["reqs"] == 3 and snap["pressure"] == 0.5
    assert snap["lat"]["n"] == 1
    payload = json.loads(reg.to_json(run="r1"))
    assert payload["metrics"]["reqs"] == 3 and payload["run"] == "r1"
    text = reg.to_prometheus()
    assert "# TYPE reqs counter" in text
    assert "lat_count 1" in text and "lat_p50_ms" in text


# -- trace spans --------------------------------------------------------------

def test_trace_ids_are_unique_and_prefixed():
    a, b = new_trace_id(), new_trace_id("wave")
    assert a != b and a.startswith("req-") and b.startswith("wave-")


def test_span_nesting_inherits_trace_and_links_parent():
    assert current_span() is None
    with span("outer", trace_id="req-xyz") as outer:
        assert current_trace_id() == "req-xyz"
        with span("inner") as inner:
            assert inner.trace_id == "req-xyz"       # inherited
            assert inner.parent_id == "req-xyz"
            assert current_span() is inner
        assert current_span() is outer
    assert current_span() is None


def test_span_exit_records_event_and_histogram():
    rec_before = len(RECORDER.events("span"))
    with span("unit_test_span", tenant="t0"):
        with StageTimer(None, "unit_test_stage"):
            pass
    evs = RECORDER.events("span")
    assert len(evs) == rec_before + 1
    e = evs[-1]
    assert e["name"] == "unit_test_span" and e["tenant"] == "t0"
    assert "unit_test_stage" in e["stages"]
    from repro.obs import REGISTRY
    assert REGISTRY.histogram("span.unit_test_span").snapshot()["n"] >= 1


def test_stage_timer_keeps_additive_trace_contract():
    trace: dict[str, float] = {}
    for _ in range(3):
        with StageTimer(trace, "scan"):
            pass
    assert set(trace) == {"scan"} and trace["scan"] > 0


def test_span_stress_seeded_threads_never_cross_traces():
    """N threads each open nested spans around random sleeps; thread-local
    stacks mean no thread ever observes another's trace id."""
    n_threads, per_thread = 8, 40
    errors: list[str] = []

    def work(tid: int):
        rng = random.Random(tid)            # seeded: deterministic schedule
        for i in range(per_thread):
            my = f"t{tid}-{i}"
            with span("stress", trace_id=my):
                if current_trace_id() != my:
                    errors.append(f"outer leak in {my}")
                with span("stress_inner"):
                    if current_trace_id() != my:
                        errors.append(f"inner leak in {my}")
                    if rng.random() < 0.3:
                        threading.Event().wait(0.0005)
            if current_span() is not None:
                errors.append(f"stack not empty after {my}")

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[:5]


# -- flight recorder ----------------------------------------------------------

def test_recorder_ring_bounds_and_drop_accounting():
    rec = FlightRecorder(cap=8)
    for i in range(20):
        rec.record("tick", i=i)
    d = rec.dump()
    assert d["cap"] == 8 and d["recorded"] == 20 and d["dropped"] == 12
    assert [e["i"] for e in d["events"]] == list(range(12, 20))
    assert all(e["kind"] == "tick" for e in d["events"])


def test_recorder_attaches_active_trace():
    rec = FlightRecorder(cap=4)
    with span("ctx", trace_id="req-trace-test"):
        rec.record("inside")
    rec.record("outside")
    inside, outside = rec.events()
    assert inside["trace"] == "req-trace-test"
    assert "trace" not in outside


def test_recorder_dump_json_roundtrip(tmp_path):
    rec = FlightRecorder(cap=4)
    rec.record("boom", error="CorruptSegmentError('x')")
    path = rec.dump_json(str(tmp_path / "flight.json"))
    with open(path) as f:
        d = json.load(f)
    assert d["events"][0]["kind"] == "boom"


# -- kernel telemetry ---------------------------------------------------------

def test_kernel_telemetry_aggregates_and_derives_roofline():
    tel = KernelTelemetry()
    with tel.launch("k", nbytes=1e6, flops=2e6):
        pass
    with tel.launch("k", nbytes=1e6, flops=2e6):
        pass
    snap = tel.snapshot()["k"]
    assert snap["calls"] == 2
    assert snap["bytes"] == 2e6 and snap["flops"] == 4e6
    # analytic-estimate fraction: positive, can exceed 1.0 when the wall
    # of a trivial region undercuts the modeled roofline minimum
    assert snap["roofline_fraction"] > 0.0
    assert snap["dominant"] in ("compute", "memory")


def test_kernel_telemetry_skips_failed_launches():
    tel = KernelTelemetry()
    with pytest.raises(ValueError):
        with tel.launch("k", nbytes=1, flops=1):
            raise ValueError("kernel blew up")
    assert tel.snapshot() == {}


def test_batched_select_launches_are_recorded():
    from repro.obs.kerneltel import KERNELS
    st = VersionedStore("T", [FieldSchema("a", 4, "int32")], capacity=64)
    keys = [f"K{i}" for i in range(32)]
    st.update(10, keys, {"a": np.arange(128, dtype=np.int32).reshape(32, 4)})
    before = KERNELS.snapshot().get("batched_select", {}).get("calls", 0)
    st.get_versions([10, 20, 30], fields=["a"])   # distinct ts: fused scan
    after = KERNELS.snapshot()["batched_select"]["calls"]
    assert after > before


# -- front door integration ---------------------------------------------------

def _mini_door(**cfg_kwargs):
    from repro.serve.frontdoor import FrontDoor, FrontDoorConfig
    st = VersionedStore("S", [FieldSchema("a", 2, "int32")], capacity=64)
    st.update(10, ["K0", "K1"],
              {"a": np.arange(4, dtype=np.int32).reshape(2, 2)})
    return FrontDoor({"S": st}, config=FrontDoorConfig(**cfg_kwargs))


def test_frontdoor_mints_trace_ids_into_dispatch_log():
    fd = _mini_door()
    fut = fd.submit("t0", "S", 10)
    fd.pump()
    fut.result(0)
    assert len(fd.dispatch_log) == 1
    assert fd.dispatch_log[0]["trace"].startswith("req-")


def test_frontdoor_per_tenant_rejection_counters():
    from repro.serve.frontdoor import QueueFull
    fd = _mini_door(max_queue_per_tenant=1)
    fd.submit("t0", "S", 10)
    with pytest.raises(QueueFull):
        fd.submit("t0", "S", 10)
    s = fd.stats()
    assert s["counters"]["rejected_queue_full"] == 1
    assert s["per_tenant"]["t0"]["rejected_queue_full"] == 1
    assert s["per_tenant"]["t0"]["rejected_pressure"] == 0
    rejects = [e for e in RECORDER.events("admission_reject")
               if e.get("tenant") == "t0" and e["reason"] == "queue_full"]
    assert rejects
    fd.pump()


def test_two_frontdoors_do_not_alias_histograms():
    fd1, fd2 = _mini_door(), _mini_door()
    f = fd1.submit("t0", "S", 10)
    fd1.pump()
    f.result(0)
    assert fd1.stats()["latency"]["total"]["n"] == 1
    assert fd2.stats()["latency"]["total"]["n"] == 0
