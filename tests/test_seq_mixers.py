"""Sequence-mixer parity: chunked (TPU-shaped) vs sequential oracles for
Mamba and RWKV6, chunked-vs-full attention, MoE dispatch invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.configs.base import ModelConfig
from repro.models.layers import init_params
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod


def _ssm_cfg(d=64):
    return ModelConfig("t", "hybrid", 2, d, 4, 4, 128, 100,
                       ssm_state=8, ssm_conv=4, ssm_expand=2)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 70), st.integers(0, 10**6))
def test_mamba_chunked_equals_sequential(seqlen, seed):
    cfg = _ssm_cfg()
    p = init_params(ssm_mod.ssm_spec(cfg), jax.random.key(seed % 97))
    x = jax.random.normal(jax.random.key(seed), (2, seqlen, 64), jnp.float32)
    yc, sc = ssm_mod.mamba_forward(p, x, cfg, chunked=True)
    ys, ss = ssm_mod.mamba_forward(p, x, cfg, chunked=False)
    assert float(jnp.max(jnp.abs(yc - ys))) < 2e-4
    assert float(jnp.max(jnp.abs(sc.h - ss.h))) < 2e-4


def test_mamba_stateful_continuation():
    """forward(x) == forward(x[:10]) then forward(x[10:], state)."""
    cfg = _ssm_cfg()
    p = init_params(ssm_mod.ssm_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 24, 64), jnp.float32)
    y_full, _ = ssm_mod.mamba_forward(p, x, cfg)
    y1, s1 = ssm_mod.mamba_forward(p, x[:, :10], cfg)
    y2, _ = ssm_mod.mamba_forward(p, x[:, 10:], cfg, state=s1)
    y_cat = jnp.concatenate([y1, y2], axis=1)
    assert float(jnp.max(jnp.abs(y_cat - y_full))) < 2e-4


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 70), st.integers(0, 10**6))
def test_rwkv_chunked_equals_sequential(seqlen, seed):
    cfg = ModelConfig("t", "ssm", 2, 64, 4, 4, 224, 100, rwkv=True)
    p = init_params(rwkv_mod.rwkv_time_spec(cfg), jax.random.key(seed % 89))
    x = jax.random.normal(jax.random.key(seed), (2, seqlen, 64),
                          jnp.float32) * 0.5
    oc, (sc, _) = rwkv_mod.rwkv_time_mix(p, x, cfg, chunked=True)
    os_, (ss, _) = rwkv_mod.rwkv_time_mix(p, x, cfg, chunked=False)
    assert float(jnp.max(jnp.abs(oc - os_))) < 2e-4
    assert float(jnp.max(jnp.abs(sc - ss))) < 2e-4


@pytest.mark.parametrize("sq,sk,h,kh", [(64, 64, 4, 2), (33, 129, 8, 8),
                                        (128, 128, 2, 1)])
def test_chunked_attention_equals_xla(sq, sk, h, kh):
    rng = np.random.default_rng(0)
    d = 32
    q = jnp.asarray(rng.normal(size=(2, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, sk, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, sk, kh, d)), jnp.float32)
    a = attn.run_attention(q, k, v, causal=True, q_offset=sk - sq, impl="xla")
    b = attn.run_attention(q, k, v, causal=True, q_offset=sk - sq,
                           impl="chunked", chunk=48)
    assert float(jnp.max(jnp.abs(a - b))) < 2e-5


def test_chunked_attention_grad_matches():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 40, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 40, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 40, 2, 16)), jnp.float32)

    def loss(impl):
        return lambda q_: jnp.sum(attn.run_attention(
            q_, k, v, causal=True, impl=impl, chunk=16) ** 2)

    ga = jax.grad(loss("xla"))(q)
    gb = jax.grad(loss("chunked"))(q)
    assert float(jnp.max(jnp.abs(ga - gb))) < 5e-5


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_cfg(e=4, k=2, cf=8.0):
    return ModelConfig("t", "moe", 2, 32, 4, 4, 64, 100, n_experts=e,
                       top_k=k, d_ff_expert=64, capacity_factor=cf)


def test_moe_no_drop_exact_vs_dense():
    """With no_drop, MoE output == explicit per-token expert mixture."""
    cfg = _moe_cfg()
    p = init_params(moe_mod.moe_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 6, 32), jnp.float32)
    y, _aux = moe_mod.apply_moe(p, x, cfg, no_drop=True)
    # dense oracle
    xf = x.reshape(-1, 32)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    outs = []
    for t in range(xf.shape[0]):
        acc = jnp.zeros(32)
        for j in range(cfg.top_k):
            e = int(idx[t, j])
            h = xf[t] @ p["wi"][e]
            g = jax.nn.silu(xf[t] @ p["wg"][e]) * h
            acc = acc + gate[t, j] * (g @ p["wo"][e])
        outs.append(acc)
    want = jnp.stack(outs).reshape(2, 6, 32)
    assert float(jnp.max(jnp.abs(y - want))) < 1e-4


def test_moe_token_permutation_equivariance():
    cfg = _moe_cfg()
    p = init_params(moe_mod.moe_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(2), (1, 8, 32), jnp.float32)
    perm = jnp.asarray([3, 1, 7, 0, 2, 6, 4, 5])
    y1, _ = moe_mod.apply_moe(p, x, cfg, no_drop=True)
    y2, _ = moe_mod.apply_moe(p, x[:, perm], cfg, no_drop=True)
    assert float(jnp.max(jnp.abs(y1[:, perm] - y2))) < 1e-4


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(cf=0.25)
    p = init_params(moe_mod.moe_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(3), (2, 16, 32), jnp.float32)
    y_tight, _ = moe_mod.apply_moe(p, x, cfg)
    y_nodrop, _ = moe_mod.apply_moe(p, x, cfg, no_drop=True)
    # dropped tokens produce zero output rows -> outputs differ
    assert float(jnp.max(jnp.abs(y_tight - y_nodrop))) > 1e-6


def test_moe_aux_loss_balanced_is_lower():
    cfg = _moe_cfg(e=4, k=1)
    p = init_params(moe_mod.moe_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(4), (4, 64, 32), jnp.float32)
    _, aux = moe_mod.apply_moe(p, x, cfg, no_drop=True)
    assert float(aux) >= 1.0 - 1e-3   # E * sum(f*P) >= 1 with equality at uniform
