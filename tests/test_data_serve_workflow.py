"""Data pipeline determinism, versioned corpus increments, serving engine,
scheduler, and the mini-GePan workflow (full vs incremental parity)."""
import numpy as np
import jax

import repro.core as core
from repro.configs.base import get_smoke_config
from repro.core.parsers import FastaParser
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.data.tokenizer import ByteTokenizer
from repro.data.versioned_dataset import VersionedCorpus
from repro.models import build
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.scheduler import Scheduler
from repro.workflow.manager import Tool, WorkflowManager


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "hello GeStore — メタデータ"
    assert tok.decode(tok.encode(s)) == s


def test_pipeline_determinism_and_host_sharding():
    toks = np.arange(10000, dtype=np.int32)
    a = TokenPipeline(toks, DataConfig(seq_len=31, global_batch=8, seed=3))
    b = TokenPipeline(toks, DataConfig(seq_len=31, global_batch=8, seed=3))
    for step in (0, 5, 17):
        ba, bb = a.batch_at(step), b.batch_at(step)
        assert np.array_equal(ba["tokens"], bb["tokens"])
    # host slices partition the global batch
    h0 = TokenPipeline(toks, DataConfig(31, 8, seed=3, host_id=0, n_hosts=2))
    h1 = TokenPipeline(toks, DataConfig(31, 8, seed=3, host_id=1, n_hosts=2))
    full = a.batch_at(2)["tokens"]
    assert np.array_equal(np.concatenate([h0.batch_at(2)["tokens"],
                                          h1.batch_at(2)["tokens"]]), full)
    # labels are next-token shifted
    ba = a.batch_at(0)
    assert np.array_equal(ba["tokens"][:, 1:], ba["labels"][:, :-1])


def test_versioned_corpus_incremental_tokenization():
    c = VersionedCorpus()
    docs = {f"d{i}": f"document number {i} body text" for i in range(20)}
    c.add_release(10, docs)
    n0 = c.tokens_encoded_total
    docs2 = dict(docs)
    docs2["d3"] = "changed!"
    docs2["new"] = "brand new doc"
    del docs2["d7"]
    c.incremental_release(10, 20, docs2)
    assert c.tokens_encoded_total - n0 == 2       # only changed+new re-encoded
    v20 = c.store.get_version(20)
    assert b"d7" not in v20.keys and b"new" in v20.keys
    # pinned old version still intact (reproducibility)
    v10 = c.store.get_version(10)
    assert b"d7" in v10.keys and b"new" not in v10.keys
    # token stream of v20 reflects the edit
    s20 = c.token_stream(20)
    s10 = c.token_stream(10)
    assert len(s20) != len(s10) or not np.array_equal(s20, s10)


def test_serve_engine_greedy_deterministic():
    cfg = get_smoke_config("qwen2-0.5b")
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_new_tokens=6))
    prompts = np.arange(10, dtype=np.int32)[None, :] % cfg.vocab
    a = eng.generate(prompts)
    b = eng.generate(prompts)
    assert np.array_equal(a, b)
    assert a.shape == (1, 6)


def test_serve_engine_eos_stops():
    cfg = get_smoke_config("llama3.2-1b")
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_new_tokens=8, eos_id=-2))
    prompts = np.arange(6, dtype=np.int32)[None, :]
    out = eng.generate(prompts)  # eos never emitted -> all 8 steps
    assert out.shape == (1, 8)


def test_scheduler_buckets_and_drains():
    cfg = get_smoke_config("llama3.2-1b")
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_new_tokens=4))
    sched = Scheduler(eng, max_batch=3)
    for i in range(7):
        sched.submit(f"r{i}", np.arange(4 + 3 * i) % cfg.vocab)
    res = sched.run_until_drained()
    assert res["n_done"] == 7
    assert all(r.output is not None for r in sched.done.values())


# ---------------------------------------------------------------------------
# mini Meta-pipe workflow: full rerun == incremental rerun (paper Table IV)
# ---------------------------------------------------------------------------

def _mk_fasta(n, mut=(), seed=11):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        seq = "".join(rng.choice(list("ACDEFGHIKLMNPQRSTVWY"), 24))
        if i in mut:
            seq = "WWWW" + seq[4:]
        out.append(f">S{i:03d}\n{seq}\n")
    return "".join(out)


def _toy_blast(args):
    """Unmodified 'tool': scores every db entry per query (db size matters
    only through hit count here; e-values synthesized per hit)."""
    path = next(p for k, p in args.items() if k.startswith("store:"))
    text = open(path).read()
    out = []
    for entry in text.split(">")[1:]:
        sid = entry.splitlines()[0].split()[0]
        seq = "".join(entry.splitlines()[1:])
        score = sum(map(ord, seq)) % 97
        out.append(f"q0\t{sid}\t90.0\t24\t0\t0\t1\t24\t1\t24\t"
                   f"{10 ** -(score % 20):.1e}\t{50 + score % 30}.0")
    return "\n".join(out) + "\n"


def test_workflow_incremental_equals_full():
    reg = core.PluginRegistry()
    reg.register_parser(FastaParser(seq_width=64, desc_width=8))
    reg.register_tool(core.ToolPlugin(
        "blast",
        core.FileGenerator(parser="fasta",
                           output_fields=["sequence", "length", "desc"],
                           significant_fields=["sequence", "length"]),
        merger=core.BlastEvalueMerger(),
        params={"max_hits_per_query": 10_000}))
    import tempfile
    with tempfile.TemporaryDirectory() as root:
        gs = core.GeStore(root, reg)
        gs.add_release("up", 1, _mk_fasta(30), parser_name="fasta")
        gs.add_release("up", 2, _mk_fasta(33, mut={2, 9}), parser_name="fasta")
        wf = WorkflowManager(gs, [Tool("blast", _toy_blast, ["store:up"])])

        r1 = wf.run(db_version=1)
        assert r1.mode == "full"
        r2_inc = wf.run(db_version=2, last_version=1)
        assert r2_inc.generated["blast/store:up"] == "increment"

        wf_full = WorkflowManager(gs, [Tool("blast", _toy_blast, ["store:up"])])
        r2_full = wf_full.run(db_version=2)

        def parse(text):
            rows = {}
            for ln in text.strip().splitlines():
                c = ln.split("\t")
                rows[c[1]] = (c[2], c[11])   # pident, bitscore (stable cols)
            return rows

        inc_rows = parse(r2_inc.outputs["blast"])
        full_rows = parse(r2_full.outputs["blast"])
        assert inc_rows == full_rows

        # incremental run touched far fewer db entries
        inc_file = [v for k, v in r2_inc.generated.items()][0]
        assert inc_file == "increment"
