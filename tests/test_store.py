"""VersionedStore invariants (paper §III.B-C), incl. the central property:
get_version(T) == brute-force replay of all updates with ts <= T."""
import tempfile

import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.store import FieldSchema, VersionedStore, KIND_DELETED


def mk_table(rng, n):
    return {"a": rng.integers(0, 50, (n, 4)).astype(np.int32),
            "b": rng.normal(size=(n, 2)).astype(np.float32)}


def brute_force_state(updates, t):
    """Replay updates (ts, {key: row}) -> {key: row} live at t."""
    state, alive = {}, {}
    for ts, rows, full in updates:
        if ts > t:
            break
        seen = set(rows)
        for k, v in rows.items():
            state[k] = v
            alive[k] = True
        if full:
            for k in list(alive):
                if k not in seen:
                    alive[k] = False
    return {k: state[k] for k, v in alive.items() if v}


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 5))
def test_get_version_equals_replay(seed, n_versions):
    rng = np.random.default_rng(seed)
    st_ = VersionedStore("t", [FieldSchema("a", 4, "int32"),
                               FieldSchema("b", 2, "float32")])
    pool = [f"K{i}" for i in range(30)]
    updates = []
    for v in range(n_versions):
        ts = (v + 1) * 10
        keys = sorted(rng.choice(pool, size=rng.integers(5, 25), replace=False))
        tbl = mk_table(rng, len(keys))
        st_.update(ts, keys, tbl)
        updates.append((ts, {k: (tbl["a"][i], tbl["b"][i])
                             for i, k in enumerate(keys)}, True))
    for t in [5, 10, 15, 25, n_versions * 10, n_versions * 10 + 7]:
        want = brute_force_state(updates, t)
        got = st_.get_version(t)
        assert sorted(k.decode() for k in got.keys) == sorted(want)
        for i, k in enumerate(got.keys):
            wa, wb = want[k.decode()]
            assert np.array_equal(got.values["a"][i], wa)
            assert np.array_equal(got.values["b"][i], wb)


def test_increment_plus_base_equals_version(rng):
    """Applying get_increment(t0, t1) onto version(t0) yields version(t1)."""
    st_ = VersionedStore("t", [FieldSchema("a", 3, "int32")])
    keys1 = [f"k{i}" for i in range(20)]
    t1 = {"a": rng.integers(0, 9, (20, 3)).astype(np.int32)}
    st_.update(10, keys1, t1)
    keys2 = keys1[:15] + ["n1", "n2"]
    t2 = {"a": np.concatenate([t1["a"][:15], rng.integers(0, 9, (2, 3)).astype(np.int32)])}
    t2["a"][3] += 1
    t2["a"][7] += 2
    st_.update(20, keys2, t2)

    base = st_.get_version(10)
    inc = st_.get_increment(10, 20)
    merged = {k.decode(): v for k, v in zip(base.keys, base.values["a"])}
    for k, kind, v in zip(inc.keys, inc.kind, inc.values["a"]):
        if kind == KIND_DELETED:
            merged.pop(k.decode())
        else:
            merged[k.decode()] = v
    v2 = st_.get_version(20)
    assert sorted(merged) == sorted(k.decode() for k in v2.keys)
    for i, k in enumerate(v2.keys):
        assert np.array_equal(merged[k.decode()], v2.values["a"][i])


def test_significant_fields_filter(rng):
    st_ = VersionedStore("t", [FieldSchema("seq", 4, "int32"),
                               FieldSchema("annot", 4, "int32")])
    keys = [f"k{i}" for i in range(10)]
    tbl = mk = {"seq": rng.integers(0, 9, (10, 4)).astype(np.int32),
                "annot": rng.integers(0, 9, (10, 4)).astype(np.int32)}
    st_.update(1, keys, tbl)
    tbl2 = {"seq": tbl["seq"].copy(), "annot": tbl["annot"] + 1}
    tbl2["seq"][:2] += 5
    st_.update(2, keys, tbl2)
    inc_seq = st_.get_increment(1, 2, significant_fields=["seq"])
    assert len(inc_seq) == 2            # annotation churn ignored (BLAST case)
    inc_all = st_.get_increment(1, 2)
    assert len(inc_all) == 10


def test_delete_and_tombstones(rng):
    st_ = VersionedStore("t", [FieldSchema("a", 2, "int32")])
    st_.update(1, ["x", "y", "z"], {"a": np.ones((3, 2), np.int32)})
    st_.delete(2, ["y"])
    v = st_.get_version(2)
    assert sorted(k.decode() for k in v.keys) == ["x", "z"]
    v1 = st_.get_version(1)
    assert len(v1) == 3                 # history preserved
    inc = st_.get_increment(1, 2)
    kinds = dict(zip([k.decode() for k in inc.keys], inc.kind))
    assert kinds == {"y": KIND_DELETED}


def test_schema_evolution(rng):
    st_ = VersionedStore("t", [FieldSchema("a", 2, "int32")])
    st_.update(1, ["x"], {"a": np.ones((1, 2), np.int32)})
    st_.update(2, ["x"], {"a": np.ones((1, 2), np.int32),
                          "new_field": np.full((1, 3), 7, np.int32)})
    v = st_.get_version(2)
    assert np.array_equal(v.values["new_field"], [[7, 7, 7]])
    v1 = st_.get_version(1)
    assert np.array_equal(v1.values["new_field"], [[0, 0, 0]])  # absent -> zeros


def test_save_load_roundtrip(rng):
    st_ = VersionedStore("t", [FieldSchema("a", 4, "int32"),
                               FieldSchema("b", 2, "float32")])
    for v in range(3):
        n = 10 + v
        st_.update((v + 1) * 10, [f"k{i}" for i in range(n)], mk_table(rng, n))
    with tempfile.TemporaryDirectory() as d:
        stats = st_.save(d)
        assert stats["packed_bytes"] <= stats["raw_bytes"]
        st2 = VersionedStore.load(d)
        for t in (10, 20, 30):
            a, b = st_.get_version(t), st2.get_version(t)
            assert a.keys == b.keys
            for f in ("a", "b"):
                assert np.array_equal(a.values[f], b.values[f])
        # loaded store accepts further updates
        st2.update(40, ["k0"], {"a": np.zeros((1, 4), np.int32),
                                "b": np.zeros((1, 2), np.float32)},
                   full_release=False)
        assert len(st2.get_version(40)) == len(st_.get_version(30))


def test_patch_with_present_keys(rng):
    st_ = VersionedStore("t", [FieldSchema("a", 2, "int32")])
    st_.update(1, ["x", "y", "z"], {"a": np.ones((3, 2), np.int32)})
    # patch: only x changed, y still present, z gone
    st_.update(2, ["x"], {"a": np.full((1, 2), 9, np.int32)},
               full_release=False, present_keys=[b"x", b"y"])
    v = st_.get_version(2)
    assert sorted(k.decode() for k in v.keys) == ["x", "y"]


def test_key_filter_taxon_use_case(rng):
    st_ = VersionedStore("t", [FieldSchema("a", 2, "int32")])
    st_.update(1, ["tax9606|p1", "tax9606|p2", "tax562|p3"],
               {"a": np.ones((3, 2), np.int32)})
    v = st_.get_version(1, key_filter=r"^tax9606")
    assert len(v) == 2


def test_compaction_preserves_recent_versions(rng):
    st_ = VersionedStore("t", [FieldSchema("a", 3, "int32")])
    keys = [f"k{i}" for i in range(25)]
    tables = {}
    for v in range(1, 6):
        tbl = {"a": rng.integers(0, 9, (25, 3)).astype(np.int32)}
        st_.update(v * 10, keys, tbl)
        tables[v * 10] = tbl
    # also delete a key mid-history
    st_.delete(55, ["k3"])
    before = {t: st_.get_version(t) for t in (30, 40, 50, 55)}
    stats = st_.compact(30)
    assert stats["cells_dropped"] > 0
    for t in (30, 40, 50, 55):
        after = st_.get_version(t)
        assert after.keys == before[t].keys, t
        assert np.array_equal(after.values["a"], before[t].values["a"]), t
    # increments across the compaction point still work for t0 >= before_ts
    inc = st_.get_increment(30, 50)
    assert len(inc) > 0
    # store remains updatable post-compaction (k3 not touched: stays deleted)
    st_.update(60, keys[5:10], {"a": np.zeros((5, 3), np.int32)},
               full_release=False)
    assert len(st_.get_version(60)) == 24  # k3 still deleted


def test_rejected_release_leaves_store_unmutated():
    """A release rejected on its Nth field (value-range cast failure) must
    not leave the earlier fields' cells — or its new rows — behind."""
    st = VersionedStore("r", [FieldSchema("a", 1, "int32"),
                              FieldSchema("b", 1, "int16")])
    st.update(1, ["k"], {"a": np.ones((1, 1), np.int32),
                         "b": np.ones((1, 1), np.int16)})
    epoch = st.log_epoch
    with pytest.raises(ValueError, match="int16 range"):
        st.update(2, ["k", "k2"], {"a": np.full((2, 1), 7, np.int32),
                                   "b": np.full((2, 1), 70000, np.int32)})
    assert st.last_ts == 1 and st.log_epoch == epoch
    assert st.n_rows == 1 and b"k2" not in st.key_to_row
    v = st.get_version(2)
    assert v.keys == [b"k"]
    assert v.values["a"].tolist() == [[1]]  # nothing of ts=2 is visible


def test_rejected_release_registers_no_phantom_fields():
    """Schema evolution must not survive a rejected release: a new field
    in the same update as an invalid one stays unregistered."""
    st = VersionedStore("r2", [FieldSchema("b", 1, "int16")])
    st.update(1, ["k"], {"b": np.ones((1, 1), np.int16)})
    with pytest.raises(ValueError, match="int16 range"):
        st.update(2, ["k"], {"c": np.ones((1, 1), np.int32),
                             "b": np.full((1, 1), 70000, np.int32)})
    assert "c" not in st.fields
    assert "c" not in st.get_version(1).values


def test_unconvertible_key_registers_no_phantom_fields():
    st = VersionedStore("r3", [FieldSchema("b", 1, "int32")])
    with pytest.raises(TypeError):
        st.update(1, ["k", 3.5], {"c": np.ones((2, 1), np.int32),
                                  "b": np.ones((2, 1), np.int32)})
    assert "c" not in st.fields and st.n_rows == 0
