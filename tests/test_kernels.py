"""Per-kernel interpret-mode parity vs the pure-jnp oracles (ref.py),
swept over shapes and dtypes + hypothesis property tests."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels import ops, ref

SHAPES_2D = [(1, 1), (7, 3), (512, 8), (513, 5), (1000, 16), (2048, 1)]


# ---------------------------------------------------------------------------
# fingerprint
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES_2D)
def test_fingerprint_matches_ref(shape, rng):
    x = jnp.asarray(rng.integers(-2**31, 2**31 - 1, shape, dtype=np.int32))
    assert np.array_equal(np.asarray(ops.fingerprint(x, interpret=True)),
                          np.asarray(ref.ref_fingerprint(x)))


def test_fingerprint_collision_resistance(rng):
    """1-element perturbations must change the fingerprint."""
    x = rng.integers(-1000, 1000, (200, 8), dtype=np.int32)
    base = ops.fingerprint_rows(x)
    for i in range(0, 200, 17):
        y = x.copy()
        y[i, i % 8] += 1
        assert not np.array_equal(ops.fingerprint_rows(y)[i], base[i])


@pytest.mark.parametrize("dtype", ["int32", "float32", "int64", "int8", "int16"])
def test_fingerprint_rows_dtypes(dtype, rng):
    x = rng.integers(-100, 100, (64, 4)).astype(dtype)
    fp = ops.fingerprint_rows(x)
    assert fp.shape == (64, 2)
    y = x.copy()
    y[5, 2] += 1
    fp2 = ops.fingerprint_rows(y)
    assert not np.array_equal(fp[5], fp2[5])
    assert np.array_equal(np.delete(fp, 5, 0), np.delete(fp2, 5, 0))


# ---------------------------------------------------------------------------
# masked_cumsum / version_select
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=0, max_size=300),
       st.integers(-5, 60))
def test_masked_cumsum_property(ts_list, t):
    ts = jnp.asarray(sorted(ts_list), jnp.int32)
    got = np.asarray(ops.masked_cumsum(ts, t, interpret=True))
    want = np.cumsum(np.asarray(ts) <= t).astype(np.int32)
    assert np.array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 60), st.integers(0, 4), st.integers(0, 99))
def test_version_select_property(n_rows, max_extra, t):
    rng = np.random.default_rng(n_rows * 7 + max_extra)
    rows, tss, vals = [], [], []
    for r in range(n_rows):
        k = rng.integers(0, max_extra + 2)
        for ts in sorted(rng.integers(0, 100, k)):
            rows.append(r)
            tss.append(ts)
            vals.append(rng.integers(-50, 50, 3))
    rows = np.asarray(rows or [0][:0], np.int32)
    ptr = np.zeros(n_rows + 1, np.int32)
    if len(rows):
        np.add.at(ptr, rows + 1, 1)
    ptr = np.cumsum(ptr).astype(np.int32)
    tss = np.asarray(tss, np.int64)
    vals = (np.stack(vals).astype(np.int32) if vals
            else np.zeros((0, 3), np.int32))
    out, found = ops.version_select(jnp.asarray(vals),
                                    jnp.asarray(tss.astype(np.int32)),
                                    jnp.asarray(ptr), t, interpret=True)
    # brute force oracle
    for r in range(n_rows):
        seg = slice(ptr[r], ptr[r + 1])
        cand = [i for i in range(*seg.indices(len(tss))) if tss[i] <= t]
        if cand:
            assert bool(found[r])
            assert np.array_equal(np.asarray(out)[r], vals[cand[-1]])
        else:
            assert not bool(found[r])


# ---------------------------------------------------------------------------
# delta codec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["int32", "float32", "int8", "int16"])
@pytest.mark.parametrize("shape", [(5, 3), (700, 8), (513, 1)])
def test_delta_roundtrip(dtype, shape, rng):
    a = rng.integers(-1000, 1000, shape).astype(dtype)
    b = rng.integers(-1000, 1000, shape).astype(dtype)
    d, _stat = ops.delta_pack(jnp.asarray(a), jnp.asarray(b), interpret=True)
    assert np.array_equal(np.asarray(d),
                          np.asarray(ref.ref_delta_pack(jnp.asarray(a), jnp.asarray(b))))
    u = ops.delta_unpack(d, jnp.asarray(b), interpret=True)
    assert np.array_equal(np.asarray(u), a)


def test_delta_float_xor_sparsity(rng):
    """Unchanged floats XOR to exact zero (the compressibility win)."""
    a = rng.normal(size=(100, 8)).astype(np.float32)
    b = a.copy()
    b[::5] *= 2.0
    d, nz = ops.delta_pack(jnp.asarray(b), jnp.asarray(a), interpret=True)
    d = np.asarray(d)
    assert np.all(d.view(np.int32)[1::5] == 0)
    assert np.all(d.view(np.int32)[::5] != 0)


def test_narrow_dtype():
    assert ops.narrow_dtype(3) == jnp.int8
    assert ops.narrow_dtype(1000) == jnp.int16
    assert ops.narrow_dtype(10**6) == jnp.int32


# ---------------------------------------------------------------------------
# masked merge
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 600), st.integers(1, 9), st.integers(0, 2**31 - 2))
def test_masked_merge_property(n, w, seed):
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(n, w)).astype(np.float32)
    upd = rng.normal(size=(n, w)).astype(np.float32)
    rm = rng.random(n) < 0.4
    fm = rng.random(w) < 0.7
    tsb = rng.integers(0, 100, n).astype(np.int64)
    got = ops.masked_merge(jnp.asarray(base), jnp.asarray(upd),
                           jnp.asarray(rm), jnp.asarray(fm),
                           jnp.asarray(tsb), 777, interpret=True)
    want = ref.ref_masked_merge(jnp.asarray(base), jnp.asarray(upd),
                                jnp.asarray(rm), jnp.asarray(fm),
                                jnp.asarray(tsb), 777)
    assert np.array_equal(np.asarray(got[0]), np.asarray(want[0]))
    assert np.array_equal(np.asarray(got[1]), np.asarray(want[1]))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,sq,sk,h,kh,d", [
    (2, 256, 256, 8, 4, 64),
    (1, 100, 300, 4, 4, 32),
    (1, 1, 129, 8, 8, 64),
    (1, 37, 37, 2, 1, 128),
    (2, 128, 640, 4, 2, 16),
])
def test_flash_attention_vs_ref(b, sq, sk, h, kh, d, rng):
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, kh, d)), jnp.float32)
    got = ops.flash_attention(q, k, v, interpret=True)
    want = ref.ref_attention(q, k, v)
    assert np.max(np.abs(np.asarray(got) - np.asarray(want))) < 3e-5


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 64, 4, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    got = np.asarray(ops.flash_attention(q, k, v, interpret=True), dtype=np.float32)
    want = np.asarray(ref.ref_attention(q, k, v), dtype=np.float32)
    assert np.max(np.abs(got - want)) < 3e-2
