"""Optimizers, schedules, gradient compression, end-to-end loss descent,
checkpoint/restart, straggler policy."""
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig, get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.ft.straggler import (CHECKPOINT_AND_REPLACE, OK, StragglerConfig,
                                StragglerMonitor)
from repro.train import grad_compress, schedule
from repro.train.optimizer import (OptHyper, apply_updates,
                                   clip_by_global_norm, init_state,
                                   state_specs)
from repro.train.train_loop import Trainer, TrainerConfig
from repro.models.layers import ParamSpec


def test_adamw_matches_reference_math():
    h = OptHyper(name="adamw", lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                 weight_decay=0.0)
    p = {"w": jnp.asarray([[1.0, -2.0]])}
    g = {"w": jnp.asarray([[0.5, 0.5]])}
    s = init_state(p, h)
    new_p, s = apply_updates(h, p, g, s)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mh, vh = m / 0.1, v / 0.01
    want = 1.0 - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    assert abs(float(new_p["w"][0, 0]) - want) < 1e-5


def test_adamw_weight_decay_only_on_matrices():
    h = OptHyper(name="adamw", lr=0.1, weight_decay=0.5)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    s = init_state(p, h)
    new_p, _ = apply_updates(h, p, g, s)
    assert float(new_p["w"][0, 0]) < 1.0      # decayed
    assert float(new_p["b"][0]) == 1.0        # not decayed


def test_adafactor_state_is_factored():
    h = OptHyper(name="adafactor", factored_min=4)
    specs = {"w": ParamSpec((128, 64), ("embed", "mlp")),
             "b": ParamSpec((64,), ("mlp",))}
    st = state_specs(specs, h)
    assert st["vr"]["w"].shape == (128,)
    assert st["vc"]["w"].shape == (64,)
    assert st["vr"]["b"].shape == (64,)       # unfactored fallback
    # factored axes inherit sharding names
    assert st["vr"]["w"].axes == ("embed",)
    assert st["vc"]["w"].axes == ("mlp",)


def test_adafactor_descends_quadratic():
    h = OptHyper(name="adafactor", lr=0.05, weight_decay=0.0, factored_min=2)
    p = {"w": jnp.full((8, 8), 3.0)}
    s = init_state(p, h)
    for _ in range(50):
        g = {"w": 2 * p["w"]}
        p, s = apply_updates(h, p, g, s)
    assert float(jnp.mean(jnp.abs(p["w"]))) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 10.0) < 1e-4
    total = jnp.sqrt(sum(jnp.sum(x ** 2)
                         for x in jax.tree_util.tree_leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-4


def test_warmup_cosine_shape():
    lrs = [float(schedule.warmup_cosine(s, peak_lr=1.0, warmup_steps=10,
                                        total_steps=100)) for s in range(100)]
    assert lrs[0] < lrs[5] < lrs[10]
    assert abs(lrs[10] - 1.0) < 0.01
    assert lrs[99] < 0.2


def test_grad_compress_error_feedback_bounded():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    e = grad_compress.init_error_state(g)
    acc_true = np.zeros((64, 64))
    acc_seen = np.zeros((64, 64))
    for i in range(20):
        g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
        deq, e = grad_compress.compress_grads(g, e)
        acc_true += np.asarray(g["w"])
        acc_seen += np.asarray(deq["w"])
    # error feedback: cumulative error stays bounded by one quantization step
    resid = np.abs(acc_true - acc_seen).max()
    scale = np.abs(acc_true).max() / 127
    assert resid < 8 * scale


def test_trainer_loss_decreases_and_restores():
    cfg = get_smoke_config("llama3.2-1b")
    rng = np.random.default_rng(0)
    toks = rng.integers(3, cfg.vocab, size=5000).astype(np.int32)
    pipe = TokenPipeline(np.tile(toks[:1320], 4), DataConfig(seq_len=32,
                                                             global_batch=4))
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, RunConfig(learning_rate=2e-3, attn_impl="xla"),
                     TrainerConfig(total_steps=14, warmup_steps=2,
                                   ckpt_every=5, ckpt_dir=d))
        hist = tr.run_loop(iter(pipe))
        assert hist[-1]["loss"] < hist[0]["loss"]
        steps = tr.ckpt.steps()
        assert steps == [5, 10]
        p5 = tr.ckpt.restore(5, like=tr.state["params"])
        flat = jax.tree_util.tree_leaves(p5)
        assert all(np.isfinite(np.asarray(x)).all() for x in flat)
        # restore-exactness: saved-at-5 equals what a fresh manager loads
        from repro.ft.checkpoint import CheckpointManager
        cm2 = CheckpointManager(d)
        p5b = cm2.restore(5, like=tr.state["params"])
        same = jax.tree_util.tree_map(
            lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)), p5, p5b)
        assert all(jax.tree_util.tree_leaves(same))


def test_trainer_grad_compress_converges():
    cfg = get_smoke_config("qwen2-0.5b")
    rng = np.random.default_rng(0)
    toks = rng.integers(3, cfg.vocab, size=2000).astype(np.int32)
    pipe = TokenPipeline(np.tile(toks[:660], 4), DataConfig(seq_len=32,
                                                            global_batch=4))
    tr = Trainer(cfg, RunConfig(learning_rate=2e-3, attn_impl="xla",
                                grad_compress=True),
                 TrainerConfig(total_steps=10, warmup_steps=2))
    hist = tr.run_loop(iter(pipe))
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(StragglerConfig(window=8, min_steps=4, patience=2))
    rng = np.random.default_rng(0)
    verdicts = {}
    for step in range(12):
        for h in range(8):
            t = 1.0 + rng.normal() * 0.01 + (3.0 if h == 5 else 0.0)
            mon.record(f"host{h}", t)
        verdicts = mon.evaluate()
    assert verdicts["host5"] == CHECKPOINT_AND_REPLACE
    assert all(v == OK for h, v in verdicts.items() if h != "host5")
    assert mon.worst()[0] == "host5"


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_smoke_config("olmo-1b")
    from repro.launch.steps import make_train_step, default_hyper
    run_full = RunConfig(attn_impl="xla", learning_rate=1e-3)
    run_mb = RunConfig(attn_impl="xla", learning_rate=1e-3, microbatch=2)
    from repro.models import build
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    hyper = default_hyper(cfg, run_full)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
    s1 = {"params": params, "opt": init_state(params, hyper)}
    s2 = jax.tree_util.tree_map(lambda x: x, s1)
    ns1, m1 = jax.jit(make_train_step(cfg, run_full, hyper))(s1, batch)
    ns2, m2 = jax.jit(make_train_step(cfg, run_mb, hyper))(s2, batch)
    # losses agree; grads (hence params) agree to accumulation tolerance
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        ns1["params"], ns2["params"])
    assert max(jax.tree_util.tree_leaves(diff)) < 5e-2
