"""Format-parser roundtrips (paper §IV.B file formats) and streaming-split
equivalence: chunked parses must be byte-identical to whole-file parses at
ANY chunk boundary (the contract core/ingest.py rests on)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.parsers import (BlastTabParser, FastaParser, MgaParser,
                                UniProtParser)

FASTA = """>P00001 subunit alpha
MKTAYIAKQRQISFVKSHFSRQLEERLGLIEVQ
>P00002
ACDEFGHIKLMNPQRSTVWY
"""

UNIPROT = """ID   TEST1_HUMAN             Reviewed;          33 AA.
AC   P00001; Q9999;
DE   RecName: Full=Test protein 1;
GN   Name=TST1;
OS   Homo sapiens (Human).
OX   NCBI_TaxID=9606;
KW   Test; Example.
SQ   SEQUENCE   33 AA;  3707 MW;  DEADBEEF CRC64;
     MKTAYIAKQR QISFVKSHFS RQLEERLGLI EVQ
//
ID   TEST2_ECOLI             Reviewed;          20 AA.
AC   P00002;
OS   Escherichia coli.
OX   NCBI_TaxID=562;
SQ   SEQUENCE   20 AA;  2202 MW;  CAFEBABE CRC64;
     ACDEFGHIKL MNPQRSTVWY
//
"""

BLAST = "q1\tP00001\t98.500\t33\t1\t0\t1\t33\t1\t33\t1.2e-15\t68.2\n" \
        "q1\tP00002\t45.000\t20\t11\t0\t1\t20\t1\t20\t0.001\t32.1\n"

MGA = """# contig001
gene_1\t100\t400\t+\t0\t11\t8.21\t
gene_2\t500\t800\t-\t0\t11\t5.10\t
# contig002
gene_1\t1\t250\t+\t0\t11\t12.00\t
"""


def test_fasta_roundtrip():
    p = FastaParser(seq_width=64, desc_width=32)
    keys, table = p.parse_text(FASTA)
    assert [k.decode() for k in keys] == ["P00001", "P00002"]
    assert table["length"][0, 0] == 33
    out = "".join(p.format_entry(k, {n: table[n][i] for n in table})
                  for i, k in enumerate(keys))
    keys2, table2 = p.parse_text(out)
    assert keys2 == keys
    assert np.array_equal(table2["sequence"], table["sequence"])


def test_uniprot_parse():
    p = UniProtParser(seq_width=64)
    keys, table = p.parse_text(UNIPROT)
    assert [k.decode() for k in keys] == ["P00001", "P00002"]
    assert table["length"][0, 0] == 33
    assert table["taxid"][0, 0] == 9606
    assert table["taxid"][1, 0] == 562
    # annotation captured but separate from sequence (BLAST significance)
    assert table["annotation"][0].any()
    fasta = p.format_entry(keys[0], {n: table[n][0] for n in table})
    assert fasta.startswith(">P00001\n")
    assert "MKTAYIAKQR" in fasta.replace("\n", "")


def test_blast_tab_roundtrip():
    p = BlastTabParser()
    keys, table = p.parse_text(BLAST)
    assert len(keys) == 2
    assert abs(10 ** table["log10_evalue"][0, 0] - 1.2e-15) < 1e-16
    line = p.format_entry(keys[0], {n: table[n][0] for n in table})
    cols = line.strip().split("\t")
    assert cols[0] == "q1" and cols[1] == "P00001"
    keys2, table2 = p.parse_text(line)
    assert keys2[0] == keys[0]
    assert np.allclose(table2["bitscore"], table["bitscore"][:1])


def test_mga_parse():
    p = MgaParser()
    keys, table = p.parse_text(MGA)
    assert [k.decode() for k in keys] == [
        "contig001|gene_1", "contig001|gene_2", "contig002|gene_1"]
    assert np.array_equal(table["coords"][0], [100, 400, 1])
    assert np.array_equal(table["coords"][1], [500, 800, -1])


# -- streaming split equivalence ----------------------------------------------
_STREAM_CASES = [
    (FastaParser(seq_width=64, desc_width=32), FASTA),
    (UniProtParser(seq_width=64), UNIPROT),
    (BlastTabParser(), BLAST),
    (MgaParser(), MGA),
]
_IDS = [type(p).__name__ for p, _ in _STREAM_CASES]


def _split(text: str, size: int) -> list[str]:
    return [text[i:i + size] for i in range(0, len(text), size)]


def _whole(parser, text):
    keys, table = parser.parse_text(text)
    return keys, {n: v.tobytes() for n, v in table.items()}


def _chunked(parser, chunks):
    keys, rows = [], []
    for k, r in parser.iter_records(chunks):
        keys.append(k)
        rows.append(r)
    if not rows:
        return [], {}
    return keys, {n: v.tobytes()
                  for n, v in parser.stack_rows(rows).items()}


@pytest.mark.parametrize("parser,text", _STREAM_CASES, ids=_IDS)
@pytest.mark.parametrize("size", [1, 2, 3, 7, 64, 1000])
def test_chunk_split_byte_identical(parser, text, size):
    """Every chunk size — down to one char, so every record straddles a
    boundary — parses byte-identically to the whole file."""
    assert _chunked(parser, _split(text, size)) == _whole(parser, text)


@pytest.mark.parametrize("parser,text", [c for c in _STREAM_CASES
                                         if not isinstance(c[0],
                                                           BlastTabParser)],
                         ids=[i for i in _IDS if i != "BlastTabParser"])
def test_chunk_split_truncated_record(parser, text):
    """A release cut off mid-record parses identically whole vs chunked —
    the truncated final record is handled the same way in both paths."""
    cut = text[:int(len(text) * 0.8)]
    for size in (1, 5, 37):
        assert _chunked(parser, _split(cut, size)) == _whole(parser, cut)


def test_chunk_split_truncated_line_fails_identically():
    """A tab-per-line record cut mid-line is malformed input: the whole
    and chunked paths must reject it the same way (and the complete
    records before the cut must be recoverable from the stream)."""
    p = BlastTabParser()
    cut = BLAST[:int(len(BLAST) * 0.8)]  # ends inside record 2
    with pytest.raises(ValueError):
        _whole(p, cut)
    for size in (1, 5, 37):
        with pytest.raises(ValueError):
            _chunked(p, _split(cut, size))
        entries = list(p.iter_entries_chunks(_split(cut, size)))
        assert entries == list(p.iter_entries(cut))
        assert entries[0] == BLAST.splitlines(keepends=True)[0]


def test_chunk_split_leading_junk_dropped():
    p = FastaParser(seq_width=64, desc_width=32)
    noisy = "; stray comment\nnot a header\n" + FASTA
    for size in (1, 4, 999):
        assert _chunked(p, _split(noisy, size)) == _whole(p, noisy)


def test_entry_offsets_are_resume_points():
    """``iter_entries_with_offsets`` end offsets: re-feeding the text from
    any entry's end offset yields exactly the remaining entries."""
    p = UniProtParser(seq_width=64)
    pairs = list(p.iter_entries_with_offsets(_split(UNIPROT, 11)))
    entries = [e for e, _ in pairs]
    assert entries == list(p.iter_entries(UNIPROT))
    for i, (_, off) in enumerate(pairs):
        rest = list(p.iter_entries(UNIPROT[off:]))
        assert rest == entries[i + 1:]


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_chunk_split_property_random_boundaries(data):
    """Property: ANY partition of the text into chunks — arbitrary uneven
    sizes, empty chunks interleaved — parses byte-identically."""
    parser, text = _STREAM_CASES[data.draw(
        st.integers(0, len(_STREAM_CASES) - 1), label="case")]
    cuts = sorted(data.draw(
        st.lists(st.integers(0, len(text)), max_size=12), label="cuts"))
    bounds = [0] + cuts + [len(text)]
    chunks = [text[a:b] for a, b in zip(bounds, bounds[1:])]
    assert _chunked(parser, chunks) == _whole(parser, text)
