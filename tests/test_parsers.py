"""Format-parser roundtrips (paper §IV.B file formats)."""
import numpy as np

from repro.core.parsers import (BlastTabParser, FastaParser, MgaParser,
                                UniProtParser)

FASTA = """>P00001 subunit alpha
MKTAYIAKQRQISFVKSHFSRQLEERLGLIEVQ
>P00002
ACDEFGHIKLMNPQRSTVWY
"""

UNIPROT = """ID   TEST1_HUMAN             Reviewed;          33 AA.
AC   P00001; Q9999;
DE   RecName: Full=Test protein 1;
GN   Name=TST1;
OS   Homo sapiens (Human).
OX   NCBI_TaxID=9606;
KW   Test; Example.
SQ   SEQUENCE   33 AA;  3707 MW;  DEADBEEF CRC64;
     MKTAYIAKQR QISFVKSHFS RQLEERLGLI EVQ
//
ID   TEST2_ECOLI             Reviewed;          20 AA.
AC   P00002;
OS   Escherichia coli.
OX   NCBI_TaxID=562;
SQ   SEQUENCE   20 AA;  2202 MW;  CAFEBABE CRC64;
     ACDEFGHIKL MNPQRSTVWY
//
"""

BLAST = "q1\tP00001\t98.500\t33\t1\t0\t1\t33\t1\t33\t1.2e-15\t68.2\n" \
        "q1\tP00002\t45.000\t20\t11\t0\t1\t20\t1\t20\t0.001\t32.1\n"

MGA = """# contig001
gene_1\t100\t400\t+\t0\t11\t8.21\t
gene_2\t500\t800\t-\t0\t11\t5.10\t
# contig002
gene_1\t1\t250\t+\t0\t11\t12.00\t
"""


def test_fasta_roundtrip():
    p = FastaParser(seq_width=64, desc_width=32)
    keys, table = p.parse_text(FASTA)
    assert [k.decode() for k in keys] == ["P00001", "P00002"]
    assert table["length"][0, 0] == 33
    out = "".join(p.format_entry(k, {n: table[n][i] for n in table})
                  for i, k in enumerate(keys))
    keys2, table2 = p.parse_text(out)
    assert keys2 == keys
    assert np.array_equal(table2["sequence"], table["sequence"])


def test_uniprot_parse():
    p = UniProtParser(seq_width=64)
    keys, table = p.parse_text(UNIPROT)
    assert [k.decode() for k in keys] == ["P00001", "P00002"]
    assert table["length"][0, 0] == 33
    assert table["taxid"][0, 0] == 9606
    assert table["taxid"][1, 0] == 562
    # annotation captured but separate from sequence (BLAST significance)
    assert table["annotation"][0].any()
    fasta = p.format_entry(keys[0], {n: table[n][0] for n in table})
    assert fasta.startswith(">P00001\n")
    assert "MKTAYIAKQR" in fasta.replace("\n", "")


def test_blast_tab_roundtrip():
    p = BlastTabParser()
    keys, table = p.parse_text(BLAST)
    assert len(keys) == 2
    assert abs(10 ** table["log10_evalue"][0, 0] - 1.2e-15) < 1e-16
    line = p.format_entry(keys[0], {n: table[n][0] for n in table})
    cols = line.strip().split("\t")
    assert cols[0] == "q1" and cols[1] == "P00001"
    keys2, table2 = p.parse_text(line)
    assert keys2[0] == keys[0]
    assert np.allclose(table2["bitscore"], table["bitscore"][:1])


def test_mga_parse():
    p = MgaParser()
    keys, table = p.parse_text(MGA)
    assert [k.decode() for k in keys] == [
        "contig001|gene_1", "contig001|gene_2", "contig002|gene_1"]
    assert np.array_equal(table["coords"][0], [100, 400, 1])
    assert np.array_equal(table["coords"][1], [500, 800, -1])
