"""Streaming-ingest engine (core/ingest.py): chunked-vs-whole-file byte
identity, crash-resume replay, backpressure, and ingest observability.

The resume test pins the PR's acceptance criterion: kill an ingest at
chunk k, reload the store from disk, re-run the same call — only the
remaining chunks are parsed (journaled ones replay) and the finished
store is byte-identical to an uninterrupted run.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core.ingest import (IngestConfig, IngestResumeError,
                               ingest_release, synth_uniprot_chunks,
                               write_synth_uniprot)
from repro.core.parsers.uniprot import UniProtParser
from repro.core.shard import ShardedStore
from repro.core.store import VersionedStore
from repro.obs import RECORDER, REGISTRY

P = UniProtParser()
N = 260


def _digests(st):
    if isinstance(st, ShardedStore):
        return [st.shard(i)._history_digest for i in range(st.n_shards)]
    return [st._history_digest]


def _sharded(capacity=128):
    return ShardedStore("ing", P.schema(), n_shards=4, capacity=capacity)


def _release_file(tmp_path, n=N, seed=5, churn=0.0):
    path = os.path.join(str(tmp_path), f"rel_{seed}_{churn}.dat")
    write_synth_uniprot(path, n, seed=seed, churn=churn)
    return path


def _reference(path, st, ts=1, label="r"):
    with open(path, encoding="latin-1") as f:
        keys, table = P.parse_text(f.read())
    st.update(ts, keys, table, label=label)
    return st


def test_stream_matches_wholefile_sharded(tmp_path):
    path = _release_file(tmp_path)
    ref = _reference(path, _sharded())
    for cfg in (IngestConfig(chunk_chars=509, batch_entries=48),
                IngestConfig(chunk_chars=1 << 20, batch_entries=64,
                             queue_depth=0),
                IngestConfig(chunk_chars=4096, batch_entries=32,
                             parse_workers=2)):
        st = _sharded()
        rep = ingest_release(st, path, P, 1, label="r", config=cfg)
        assert rep.n_entries == N
        assert _digests(st) == _digests(ref)


def test_stream_matches_wholefile_unsharded(tmp_path):
    path = _release_file(tmp_path)
    ref = _reference(path, VersionedStore("ing", P.schema(), capacity=512))
    st = VersionedStore("ing", P.schema(), capacity=512)
    ingest_release(st, path, P, 1, label="r",
                   config=IngestConfig(chunk_chars=777, batch_entries=50))
    assert _digests(st) == _digests(ref)


def test_stream_second_release_churn(tmp_path):
    """An incremental release (sequence churn) streams identically to the
    whole-file update — exercises the updated-row fingerprint path."""
    p1 = _release_file(tmp_path, seed=5)
    p2 = _release_file(tmp_path, seed=5, churn=0.3)
    ref = _reference(p2, _reference(p1, _sharded()), ts=2, label="r2")
    st = _sharded()
    cfg = IngestConfig(chunk_chars=2048, batch_entries=64)
    ingest_release(st, p1, P, 1, label="r", config=cfg)
    ingest_release(st, p2, P, 2, label="r2", config=cfg)
    assert _digests(st) == _digests(ref)


def test_stream_iterable_source():
    ref = _sharded()
    chunks = list(synth_uniprot_chunks(N, seed=7))
    keys, table = P.parse_text("".join(chunks))
    ref.update(1, keys, table, label="r")
    st = _sharded()
    ingest_release(st, iter(chunks), P, 1, label="r",
                   config=IngestConfig(batch_entries=40))
    assert _digests(st) == _digests(ref)


class _Kill(Exception):
    pass


def _killer_at(k):
    def hook(idx, n_entries, replayed):
        if idx == k:
            raise _Kill
    return hook


def test_resume_replays_only_remaining_chunks(tmp_path):
    """Acceptance pin: kill at chunk k, reload from disk, resume — the
    journaled chunks replay without re-parsing, only the tail is parsed,
    and the store is byte-identical to an uninterrupted run."""
    path = _release_file(tmp_path)
    ref = _reference(path, _sharded())
    sdir = os.path.join(str(tmp_path), "store")
    jdir = os.path.join(str(tmp_path), "journal")
    cfg = IngestConfig(chunk_chars=1 << 20, batch_entries=32)

    st = _sharded()
    st.save(sdir)
    kill_at = 3
    with pytest.raises(_Kill):
        ingest_release(st, path, P, 1, label="r", config=cfg,
                       journal_dir=jdir, store_dir=sdir,
                       on_batch=_killer_at(kill_at))

    st2 = ShardedStore.load(sdir)  # what a restarted process would see
    rep = ingest_release(st2, path, P, 1, label="r", config=cfg,
                         journal_dir=jdir, store_dir=sdir)
    # chunks 0..kill_at were journaled before the kill landed
    assert rep.chunks_replayed == kill_at + 1
    assert rep.entries_replayed == (kill_at + 1) * cfg.batch_entries
    assert rep.entries_parsed == N - rep.entries_replayed
    assert rep.n_entries == N
    assert _digests(st2) == _digests(ref)
    # the journal is consumed and disk holds the finished release
    assert not os.path.exists(os.path.join(jdir, "JOURNAL.json"))
    assert _digests(ShardedStore.load(sdir)) == _digests(ref)


def test_resume_already_committed(tmp_path, monkeypatch):
    """A crash between the final save and journal cleanup must not
    re-apply the release: the resume sees it committed and no-ops."""
    from repro.ft.checkpoint import IngestJournal
    path = _release_file(tmp_path)
    sdir = os.path.join(str(tmp_path), "store")
    jdir = os.path.join(str(tmp_path), "journal")
    st = _sharded()
    st.save(sdir)
    monkeypatch.setattr(IngestJournal, "clear", lambda self: None)
    cfg = IngestConfig(batch_entries=64)
    ingest_release(st, path, P, 1, label="r", config=cfg,
                   journal_dir=jdir, store_dir=sdir)
    monkeypatch.undo()
    before = _digests(st)
    st2 = ShardedStore.load(sdir)
    rep = ingest_release(st2, path, P, 1, label="r", config=cfg,
                         journal_dir=jdir, store_dir=sdir)
    assert rep.already_committed and rep.n_entries == 0
    assert _digests(st2) == before
    assert not os.path.exists(os.path.join(jdir, "JOURNAL.json"))


def test_resume_refuses_dirty_store(tmp_path):
    """Resuming with the killed (half-mutated, in-memory) store instead of
    a fresh reload must refuse: its watermark no longer matches the
    journal's pre-release pin."""
    path = _release_file(tmp_path)
    sdir = os.path.join(str(tmp_path), "store")
    jdir = os.path.join(str(tmp_path), "journal")
    st = _sharded()
    st.save(sdir)
    cfg = IngestConfig(batch_entries=32)
    with pytest.raises(_Kill):
        ingest_release(st, path, P, 1, label="r", config=cfg,
                       journal_dir=jdir, store_dir=sdir,
                       on_batch=_killer_at(2))
    with pytest.raises(IngestResumeError):
        ingest_release(st, path, P, 1, label="r", config=cfg,
                       journal_dir=jdir, store_dir=sdir)


def test_backpressure_pauses_waves(tmp_path):
    path = _release_file(tmp_path)
    level = {"v": 2.0}
    seen = []

    def pressure():
        seen.append(level["v"])
        v, level["v"] = level["v"], 0.0  # high once, then clears
        return v

    st = _sharded()
    rep = ingest_release(
        st, path, P, 1, label="r", pressure_fn=pressure,
        config=IngestConfig(batch_entries=64, max_pressure=1.0,
                            pressure_poll_s=0.001))
    assert rep.backpressure_waits >= 1
    assert rep.backpressure_wait_s > 0
    assert rep.n_entries == N  # paced, not dropped
    assert seen[0] == 2.0


def test_ingest_observability(tmp_path):
    """Counters/histogram advance per run; an aborted ingest leaves a
    flight-recorder event carrying the active trace id."""
    path = _release_file(tmp_path)
    c_chunks = REGISTRY.counter("ingest.chunks_parsed")
    c_entries = REGISTRY.counter("ingest.entries_routed")
    h_wave = REGISTRY.histogram("ingest.wave_wall")
    base = (c_chunks.value, c_entries.value, h_wave.n)
    st = _sharded()
    rep = ingest_release(st, path, P, 1, label="r",
                         config=IngestConfig(batch_entries=64))
    assert c_chunks.value - base[0] == rep.n_chunks
    assert c_entries.value - base[1] == N
    assert h_wave.n - base[2] == rep.n_chunks
    assert h_wave.snapshot()["p99_ms"] >= h_wave.snapshot()["p50_ms"]

    st2 = _sharded()
    with pytest.raises(_Kill):
        ingest_release(st2, path, P, 1, label="r",
                       config=IngestConfig(batch_entries=64),
                       on_batch=_killer_at(1))
    ev = RECORDER.events("ingest_abort")[-1]
    assert ev["store"] == "ing" and ev["chunks_applied"] == 2
    assert ev.get("trace")  # the ingest span's trace id rode along


def test_ingest_journal_checkpoint_counts(tmp_path):
    path = _release_file(tmp_path)
    jdir = os.path.join(str(tmp_path), "journal")
    sdir = os.path.join(str(tmp_path), "store")
    st = _sharded()
    st.save(sdir)
    c_ckpt = REGISTRY.counter("ingest.checkpoint_writes")
    base = c_ckpt.value
    rep = ingest_release(st, path, P, 1, label="r",
                         config=IngestConfig(batch_entries=32),
                         journal_dir=jdir, store_dir=sdir)
    assert rep.checkpoint_writes == rep.n_chunks
    assert c_ckpt.value - base == rep.n_chunks


def test_stress_paced_ingest_with_concurrent_reads(tmp_path):
    """Serving-style stress: a release streams in (forced-threaded waves +
    flapping backpressure) while readers hammer the committed version.
    Readers must only ever see the pre-release snapshot until finish()
    publishes, and the final store must equal the whole-file reference."""
    p1 = _release_file(tmp_path, seed=11)
    p2 = _release_file(tmp_path, seed=11, churn=0.4)
    ref = _reference(p2, _reference(p1, _sharded()), ts=2, label="r2")

    st = _sharded()
    ingest_release(st, p1, P, 1, label="r",
                   config=IngestConfig(batch_entries=64))
    v1 = st.get_versions([1])[0]
    want = v1.values["sequence"].tobytes()

    flap = {"i": 0}

    def pressure():
        flap["i"] += 1
        return 2.0 if flap["i"] % 3 == 1 else 0.0

    errs, stop = [], threading.Event()

    def reader():
        try:
            while not stop.is_set():
                v = st.get_versions([1])[0]
                if v.values["sequence"].tobytes() != want:
                    errs.append("reader saw mutated pre-release view")
                    return
                time.sleep(0.001)
        except Exception as e:  # pragma: no cover - surfaced below
            errs.append(repr(e))

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    try:
        cfg = IngestConfig(batch_entries=48, max_pressure=1.0,
                           pressure_poll_s=0.001)
        rep = ingest_release(st, p2, P, 2, label="r2",
                             pressure_fn=pressure, config=cfg)
        assert rep.backpressure_waits >= 1
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=5)
    assert not errs, errs
    assert _digests(st) == _digests(ref)
    assert np.array_equal(st.get_versions([1])[0].values["sequence"],
                          v1.values["sequence"])
