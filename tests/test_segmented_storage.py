"""Segmented on-disk storage (core/segments.py): incremental save bytes,
lazy load, crash safety, dtype round-trips through compaction, legacy
snapshot migration, and GeStore flush/reopen wiring."""
import json
import os

import numpy as np
import pytest

from repro.core import segments
from repro.core.store import FieldSchema, VersionedStore

SCHEMA = [FieldSchema("a", 4, "int32"), FieldSchema("b", 2, "float32"),
          FieldSchema("c", 3, "int16"), FieldSchema("d", 1, "int8")]


def mk_table(rng, n):
    return {"a": rng.integers(0, 1 << 20, (n, 4)).astype(np.int32),
            "b": rng.normal(size=(n, 2)).astype(np.float32),
            "c": rng.integers(-300, 300, (n, 3)).astype(np.int16),
            "d": rng.integers(-5, 5, (n, 1)).astype(np.int8)}


def mk_store(rng, n_releases=4, n=30):
    st = VersionedStore("t", SCHEMA)
    keys = [f"k{i}" for i in range(n)]
    for v in range(1, n_releases + 1):
        st.update(v * 10, keys, mk_table(rng, n))
    return st


def assert_equal_versions(a: VersionedStore, b: VersionedStore, ts_list):
    for t in ts_list:
        va, vb = a.get_version(t), b.get_version(t)
        assert va.keys == vb.keys, t
        for f in va.values:
            assert np.array_equal(va.values[f], vb.values[f]), (t, f)


def manifest(path):
    with open(os.path.join(path, segments.MANIFEST_NAME)) as f:
        return json.load(f)


def seg_index(path):
    return segments.read_segment_index(path, manifest(path))


# -- incremental save --------------------------------------------------------

def test_incremental_save_bytes_independent_of_history(rng, tmp_path):
    """The acceptance criterion: appending one release and saving writes
    only the new segments — bytes do not grow with history depth."""
    n = 60
    st = VersionedStore("t", SCHEMA)
    keys = [f"k{i}" for i in range(n)]
    st.update(10, keys, mk_table(rng, n))
    d = str(tmp_path / "store")
    first = st.save(d)
    assert first["mode"] == "full"

    inc_bytes = []
    for v in range(2, 26):
        tbl = mk_table(rng, n)   # full churn: every release same size
        st.update(v * 10, keys, tbl)
        stats = st.save(d)
        assert stats["mode"] == "incremental"
        # exactly one new segment per field log (no exists transitions)
        assert stats["segments_written"] == len(SCHEMA)
        inc_bytes.append(stats["bytes_written"])
    # per-release bytes stay flat: the last save is no bigger than the
    # early ones (2x slack for manifest growth / compression jitter)
    assert max(inc_bytes[-3:]) < 2 * max(inc_bytes[:3])
    # and a full rewrite of the 25-release history dwarfs one increment
    full = st.save(str(tmp_path / "rw"), force_full=True)
    assert full["bytes_written"] > 5 * max(inc_bytes)


def test_incremental_save_roundtrip(rng, tmp_path):
    st = mk_store(rng, n_releases=1)
    d = str(tmp_path / "s")
    st.save(d)
    keys = [f"k{i}" for i in range(30)]
    for v in (2, 3, 4):
        st.update(v * 10, keys[: 30 - v], mk_table(rng, 30 - v))  # + deletes
        st.save(d)
    st2 = VersionedStore.load(d)
    assert_equal_versions(st, st2, [10, 20, 30, 40, 45])
    # the reopened store keeps saving incrementally
    st2.update(50, keys[:5], {k: v[:5] for k, v in mk_table(rng, 30).items()},
               full_release=False)
    stats = st2.save(d)
    assert stats["mode"] == "incremental"
    assert_equal_versions(st2, VersionedStore.load(d), [10, 40, 50])


def test_save_to_foreign_dir_is_full_rewrite(rng, tmp_path):
    st = mk_store(rng)
    other = mk_store(rng, n_releases=2)
    d = str(tmp_path / "s")
    other.save(d)
    stats = st.save(d)   # same name but divergent history -> rewrite
    assert stats["mode"] == "full"
    assert_equal_versions(st, VersionedStore.load(d), [10, 20, 30, 40])


# -- lazy load ---------------------------------------------------------------

def test_lazy_load_defers_segment_reads(rng, tmp_path):
    st = mk_store(rng)
    d = str(tmp_path / "s")
    st.save(d)
    st2 = VersionedStore.load(d)   # lazy by default
    pending = {n: len(c.log._pending) for n, c in st2.fields.items()}
    assert all(v == 1 for v in pending.values()), pending
    # a narrow single-version query touches only its own field + EXISTS
    v = st2.get_version(20, fields=["a"])
    assert len(st2.fields["a"].log._pending) == 0
    assert len(st2.fields["b"].log._pending) == 1   # untouched
    want = st.get_version(20, fields=["a"])
    assert v.keys == want.keys
    assert np.array_equal(v.values["a"], want.values["a"])


def test_lazy_load_update_change_detection(rng, tmp_path):
    """Heads rebuild lazily: an identical re-release after a lazy load must
    detect zero churn (fingerprints reconstructed from segments)."""
    st = mk_store(rng, n_releases=2)
    d = str(tmp_path / "s")
    st.save(d)
    st2 = VersionedStore.load(d)
    head = st.get_version(20)
    info = st2.update(30, [k.decode() for k in head.keys],
                      {f: head.values[f] for f in st.fields})
    assert (info.n_new, info.n_updated, info.n_deleted) == (0, 0, 0)


def test_eager_load_matches_lazy(rng, tmp_path):
    st = mk_store(rng)
    d = str(tmp_path / "s")
    st.save(d)
    assert_equal_versions(VersionedStore.load(d, lazy=False),
                          VersionedStore.load(d, lazy=True),
                          [10, 20, 30, 40])


# -- compaction on disk ------------------------------------------------------

def test_compact_on_disk_roundtrip_and_retained_tail(rng, tmp_path):
    st = VersionedStore("t", SCHEMA)
    keys = [f"k{i}" for i in range(25)]
    d = str(tmp_path / "s")
    for v in range(1, 6):
        st.update(v * 10, keys, mk_table(rng, 25))
        st.save(d)                      # one segment per field per release
    st.delete(55, ["k3"])
    st.save(d)
    before = {t: st.get_version(t) for t in (30, 40, 50, 55)}
    stats = st.compact(30, path=d)
    assert stats["cells_dropped"] > 0
    assert stats["segments_retained"] > 0    # tail segments not rewritten
    segs = seg_index(d)
    assert "base" in {s.kind for s in segs}
    assert all(s.ts0 >= 30 for s in segs)
    st2 = VersionedStore.load(d)
    for t in (30, 40, 50, 55):
        after = st2.get_version(t)
        assert after.keys == before[t].keys, t
        for f in after.values:
            assert np.array_equal(after.values[f], before[t].values[f]), (t, f)
    # post-compaction saves are incremental again
    st2.update(60, keys[:4], {k: v[:4] for k, v in mk_table(rng, 25).items()},
               full_release=False)
    assert st2.save(d)["mode"] == "incremental"


# -- crash safety ------------------------------------------------------------

def test_manifest_rejects_truncated_segment(rng, tmp_path):
    st = mk_store(rng)
    d = str(tmp_path / "s")
    st.save(d)
    p = os.path.join(d, seg_index(d)[0].path)
    with open(p, "r+b") as f:
        f.truncate(os.path.getsize(p) // 2)
    with pytest.raises(segments.CorruptSegmentError, match="torn"):
        VersionedStore.load(d)


def test_manifest_rejects_missing_segment(rng, tmp_path):
    st = mk_store(rng)
    d = str(tmp_path / "s")
    st.save(d)
    os.remove(os.path.join(d, seg_index(d)[0].path))
    with pytest.raises(segments.CorruptSegmentError, match="missing"):
        VersionedStore.load(d)


def test_bitflip_rejected_on_first_read(rng, tmp_path):
    st = mk_store(rng)
    d = str(tmp_path / "s")
    st.save(d)
    p = os.path.join(d, seg_index(d)[0].path)
    blob = bytearray(open(p, "rb").read())
    blob[-8] ^= 0xFF
    with open(p, "wb") as f:
        f.write(bytes(blob))
    st2 = VersionedStore.load(d)   # size unchanged: lazy open succeeds
    with pytest.raises(segments.CorruptSegmentError, match="sha256"):
        st2.get_version(40)


def test_uncommitted_index_tail_is_ignored_and_reclaimed(rng, tmp_path,
                                                         monkeypatch):
    """Crash between the index append and the manifest commit: the old
    manifest's byte-offset prefix stays authoritative, and the next save
    truncates the orphan tail before appending."""
    st = mk_store(rng, n_releases=2)
    d = str(tmp_path / "s")
    st.save(d)
    old_versions = [v.ts for v in st.versions]
    keys = [f"k{i}" for i in range(30)]

    # simulate the crash: run the segment+index writes, abort the manifest
    st.update(30, keys, mk_table(rng, 30))
    monkeypatch.setattr(segments, "write_manifest",
                        lambda *a, **k: (_ for _ in ()).throw(OSError("boom")))
    with pytest.raises(OSError):
        st.save(d)
    monkeypatch.undo()

    st2 = VersionedStore.load(d)        # pre-crash state, tail ignored
    assert [v.ts for v in st2.versions] == old_versions
    assert_equal_versions(st, st2, [10, 20])

    stats = st.save(d)                  # retry commits cleanly
    assert stats["mode"] == "incremental"
    assert_equal_versions(st, VersionedStore.load(d), [10, 20, 30])


def test_interrupted_full_rewrite_keeps_previous_state(rng, tmp_path,
                                                       monkeypatch):
    """A crash mid-rewrite never touches the committed generation: the
    previous manifest + index + segments stay fully loadable."""
    st = mk_store(rng)
    d = str(tmp_path / "s")
    st.save(d)
    pre = {t: st.get_version(t) for t in (20, 40)}
    calls = {"n": 0}
    real = segments.write_segment

    def exploding(*a, **k):
        calls["n"] += 1
        if calls["n"] >= 2:
            raise OSError("disk full")
        return real(*a, **k)

    monkeypatch.setattr(segments, "write_segment", exploding)
    with pytest.raises(OSError):
        st.save(d, force_full=True)
    monkeypatch.undo()
    st2 = VersionedStore.load(d)           # previous generation intact
    for t in (20, 40):
        got = st2.get_version(t)
        assert got.keys == pre[t].keys
        for f in got.values:
            assert np.array_equal(got.values[f], pre[t].values[f]), (t, f)


def test_interrupted_compact_keeps_previous_state_loadable(rng, tmp_path,
                                                           monkeypatch):
    """Compaction writes a new index generation and commits via the
    manifest swap: a crash between them must leave the pre-compaction
    store fully loadable."""
    st = VersionedStore("t", SCHEMA)
    keys = [f"k{i}" for i in range(20)]
    d = str(tmp_path / "s")
    for v in range(1, 6):
        st.update(v * 10, keys, mk_table(rng, 20))
        st.save(d)
    pre = {t: st.get_version(t) for t in (20, 50)}
    monkeypatch.setattr(segments, "write_manifest",
                        lambda *a, **k: (_ for _ in ()).throw(OSError("boom")))
    with pytest.raises(OSError):
        st.compact(30, path=d)
    monkeypatch.undo()
    st2 = VersionedStore.load(d)           # previous manifest generation
    for t in (20, 50):
        got = st2.get_version(t)
        assert got.keys == pre[t].keys
        for f in got.values:
            assert np.array_equal(got.values[f], pre[t].values[f]), (t, f)


def test_store_dir_names_never_collide():
    from repro.core.segments import store_dir_name
    assert store_dir_name("a/b") != store_dir_name("a_b")
    assert store_dir_name("plain-name.v2") == "plain-name.v2"


def test_versioned_corpus_incremental_after_lazy_load(rng, tmp_path):
    """Direct head readers (versioned_dataset change detection) must see
    rebuilt heads after a lazy load — unchanged docs are not re-encoded."""
    from repro.data.versioned_dataset import VersionedCorpus
    c = VersionedCorpus()
    docs = {f"d{i}": f"document body {i}" for i in range(12)}
    c.add_release(10, docs)
    d = str(tmp_path / "corpus")
    c.store.save(d)
    c2 = VersionedCorpus()
    c2.store = VersionedStore.load(d)      # lazy: heads stale
    docs2 = dict(docs)
    docs2["d3"] = "changed!"
    c2.incremental_release(10, 20, docs2)
    assert c2.tokens_encoded_total == 1    # only the changed doc


# -- legacy snapshot migration ----------------------------------------------

def test_legacy_snapshot_loads_and_migrates(rng, tmp_path):
    st = mk_store(rng)
    d = str(tmp_path / "legacy")
    segments.write_legacy_snapshot(st, d)
    st2 = VersionedStore.load(d)       # legacy loader path
    assert_equal_versions(st, st2, [10, 20, 30, 40])
    stats = st2.save(d)                # first segmented save migrates
    assert stats["mode"] == "full"
    # the fix under test: no stale cells.npz/meta.json beside the manifest
    assert not os.path.exists(os.path.join(d, "cells.npz"))
    assert not os.path.exists(os.path.join(d, "meta.json"))
    assert os.path.exists(os.path.join(d, segments.MANIFEST_NAME))
    assert_equal_versions(st, VersionedStore.load(d), [10, 20, 30, 40])


# -- GeStore wiring ----------------------------------------------------------

def test_gestore_flush_and_reopen(rng, tmp_path):
    import repro.core as core
    from repro.core.parsers import FastaParser

    def fasta(n, seed):
        r = np.random.default_rng(seed)
        return "".join(
            f">Q{i:03d} d\n" + "".join(r.choice(list("ACDEFGHIK"), 16)) + "\n"
            for i in range(n))

    reg = core.PluginRegistry()
    reg.register_parser(FastaParser(seq_width=32, desc_width=8))
    root = str(tmp_path / "gs")
    gs = core.GeStore(root, reg)
    gs.add_release("up", 1, fasta(20, 1), parser_name="fasta")
    gs.add_release("up", 2, fasta(22, 2), parser_name="fasta")
    assert gs.flush()["up"]["mode"] == "full"
    want = gs.stores["up"].get_version(2)

    gs2 = core.GeStore(root, reg)      # autoload reopens persisted stores
    got = gs2.stores["up"].get_version(2)
    assert got.keys == want.keys
    assert np.array_equal(got.values["sequence"], want.values["sequence"])
    gs2.add_release("up", 3, fasta(23, 3), parser_name="fasta")
    assert gs2.flush("up")["up"]["mode"] == "incremental"
    # cache eviction never touches the persisted store
    gs2.cache.evict(0)
    assert os.path.exists(os.path.join(gs2.store_path("up"),
                                       segments.MANIFEST_NAME))
