"""Segmented on-disk storage (core/segments.py): incremental save bytes,
lazy load, crash safety, dtype round-trips through compaction, legacy
snapshot migration, and GeStore flush/reopen wiring."""
import json
import os

import numpy as np
import pytest

from repro.core import segments
from repro.core.store import FieldSchema, VersionedStore

SCHEMA = [FieldSchema("a", 4, "int32"), FieldSchema("b", 2, "float32"),
          FieldSchema("c", 3, "int16"), FieldSchema("d", 1, "int8")]


def mk_table(rng, n):
    return {"a": rng.integers(0, 1 << 20, (n, 4)).astype(np.int32),
            "b": rng.normal(size=(n, 2)).astype(np.float32),
            "c": rng.integers(-300, 300, (n, 3)).astype(np.int16),
            "d": rng.integers(-5, 5, (n, 1)).astype(np.int8)}


def mk_store(rng, n_releases=4, n=30):
    st = VersionedStore("t", SCHEMA)
    keys = [f"k{i}" for i in range(n)]
    for v in range(1, n_releases + 1):
        st.update(v * 10, keys, mk_table(rng, n))
    return st


def assert_equal_versions(a: VersionedStore, b: VersionedStore, ts_list):
    for t in ts_list:
        va, vb = a.get_version(t), b.get_version(t)
        assert va.keys == vb.keys, t
        for f in va.values:
            assert np.array_equal(va.values[f], vb.values[f]), (t, f)


def manifest(path):
    with open(os.path.join(path, segments.MANIFEST_NAME)) as f:
        return json.load(f)


def seg_index(path):
    return segments.read_segment_index(path, manifest(path))


# -- incremental save --------------------------------------------------------

def test_incremental_save_bytes_independent_of_history(rng, tmp_path):
    """The acceptance criterion: appending one release and saving writes
    only the new segments — bytes do not grow with history depth."""
    n = 60
    st = VersionedStore("t", SCHEMA)
    keys = [f"k{i}" for i in range(n)]
    st.update(10, keys, mk_table(rng, n))
    d = str(tmp_path / "store")
    first = st.save(d)
    assert first["mode"] == "full"

    inc_bytes = []
    for v in range(2, 26):
        tbl = mk_table(rng, n)   # full churn: every release same size
        st.update(v * 10, keys, tbl)
        stats = st.save(d)
        assert stats["mode"] == "incremental"
        # exactly one new segment per field log (no exists transitions)
        assert stats["segments_written"] == len(SCHEMA)
        inc_bytes.append(stats["bytes_written"])
    # per-release bytes stay flat: the last save is no bigger than the
    # early ones (2x slack for manifest growth / compression jitter)
    assert max(inc_bytes[-3:]) < 2 * max(inc_bytes[:3])
    # and a full rewrite of the 25-release history dwarfs one increment
    full = st.save(str(tmp_path / "rw"), force_full=True)
    assert full["bytes_written"] > 5 * max(inc_bytes)


def test_incremental_save_roundtrip(rng, tmp_path):
    st = mk_store(rng, n_releases=1)
    d = str(tmp_path / "s")
    st.save(d)
    keys = [f"k{i}" for i in range(30)]
    for v in (2, 3, 4):
        st.update(v * 10, keys[: 30 - v], mk_table(rng, 30 - v))  # + deletes
        st.save(d)
    st2 = VersionedStore.load(d)
    assert_equal_versions(st, st2, [10, 20, 30, 40, 45])
    # the reopened store keeps saving incrementally
    st2.update(50, keys[:5], {k: v[:5] for k, v in mk_table(rng, 30).items()},
               full_release=False)
    stats = st2.save(d)
    assert stats["mode"] == "incremental"
    assert_equal_versions(st2, VersionedStore.load(d), [10, 40, 50])


def test_save_to_foreign_dir_is_full_rewrite(rng, tmp_path):
    st = mk_store(rng)
    other = mk_store(rng, n_releases=2)
    d = str(tmp_path / "s")
    other.save(d)
    stats = st.save(d)   # same name but divergent history -> rewrite
    assert stats["mode"] == "full"
    assert_equal_versions(st, VersionedStore.load(d), [10, 20, 30, 40])


# -- lazy load ---------------------------------------------------------------

def test_lazy_load_defers_segment_reads(rng, tmp_path):
    st = mk_store(rng)
    d = str(tmp_path / "s")
    st.save(d)
    st2 = VersionedStore.load(d)   # lazy by default
    pending = {n: len(c.log._pending) for n, c in st2.fields.items()}
    assert all(v == 1 for v in pending.values()), pending
    # a narrow single-version query touches only its own field + EXISTS
    v = st2.get_version(20, fields=["a"])
    assert len(st2.fields["a"].log._pending) == 0
    assert len(st2.fields["b"].log._pending) == 1   # untouched
    want = st.get_version(20, fields=["a"])
    assert v.keys == want.keys
    assert np.array_equal(v.values["a"], want.values["a"])


def test_lazy_load_update_change_detection(rng, tmp_path):
    """Heads rebuild lazily: an identical re-release after a lazy load must
    detect zero churn (fingerprints reconstructed from segments)."""
    st = mk_store(rng, n_releases=2)
    d = str(tmp_path / "s")
    st.save(d)
    st2 = VersionedStore.load(d)
    head = st.get_version(20)
    info = st2.update(30, [k.decode() for k in head.keys],
                      {f: head.values[f] for f in st.fields})
    assert (info.n_new, info.n_updated, info.n_deleted) == (0, 0, 0)


def test_eager_load_matches_lazy(rng, tmp_path):
    st = mk_store(rng)
    d = str(tmp_path / "s")
    st.save(d)
    assert_equal_versions(VersionedStore.load(d, lazy=False),
                          VersionedStore.load(d, lazy=True),
                          [10, 20, 30, 40])


# -- compaction on disk ------------------------------------------------------

def test_compact_on_disk_roundtrip_and_retained_tail(rng, tmp_path):
    st = VersionedStore("t", SCHEMA)
    keys = [f"k{i}" for i in range(25)]
    d = str(tmp_path / "s")
    for v in range(1, 6):
        st.update(v * 10, keys, mk_table(rng, 25))
        st.save(d)                      # one segment per field per release
    st.delete(55, ["k3"])
    st.save(d)
    before = {t: st.get_version(t) for t in (30, 40, 50, 55)}
    stats = st.compact(30, path=d)
    assert stats["cells_dropped"] > 0
    assert stats["segments_retained"] > 0    # tail segments not rewritten
    segs = seg_index(d)
    assert "base" in {s.kind for s in segs}
    assert all(s.ts0 >= 30 for s in segs)
    st2 = VersionedStore.load(d)
    for t in (30, 40, 50, 55):
        after = st2.get_version(t)
        assert after.keys == before[t].keys, t
        for f in after.values:
            assert np.array_equal(after.values[f], before[t].values[f]), (t, f)
    # post-compaction saves are incremental again
    st2.update(60, keys[:4], {k: v[:4] for k, v in mk_table(rng, 25).items()},
               full_release=False)
    assert st2.save(d)["mode"] == "incremental"


# -- crash safety ------------------------------------------------------------

def test_manifest_rejects_truncated_segment(rng, tmp_path):
    st = mk_store(rng)
    d = str(tmp_path / "s")
    st.save(d)
    p = os.path.join(d, seg_index(d)[0].path)
    with open(p, "r+b") as f:
        f.truncate(os.path.getsize(p) // 2)
    with pytest.raises(segments.CorruptSegmentError, match="torn"):
        VersionedStore.load(d)


def test_manifest_rejects_missing_segment(rng, tmp_path):
    st = mk_store(rng)
    d = str(tmp_path / "s")
    st.save(d)
    os.remove(os.path.join(d, seg_index(d)[0].path))
    with pytest.raises(segments.CorruptSegmentError, match="missing"):
        VersionedStore.load(d)


def test_bitflip_rejected_on_first_read(rng, tmp_path):
    st = mk_store(rng)
    d = str(tmp_path / "s")
    st.save(d)
    p = os.path.join(d, seg_index(d)[0].path)
    blob = bytearray(open(p, "rb").read())
    blob[-8] ^= 0xFF
    with open(p, "wb") as f:
        f.write(bytes(blob))
    st2 = VersionedStore.load(d)   # size unchanged: lazy open succeeds
    with pytest.raises(segments.CorruptSegmentError, match="sha256"):
        st2.get_version(40)


def test_uncommitted_index_tail_is_ignored_and_reclaimed(rng, tmp_path,
                                                         monkeypatch):
    """Crash between the index append and the manifest commit: the old
    manifest's byte-offset prefix stays authoritative, and the next save
    truncates the orphan tail before appending."""
    st = mk_store(rng, n_releases=2)
    d = str(tmp_path / "s")
    st.save(d)
    old_versions = [v.ts for v in st.versions]
    keys = [f"k{i}" for i in range(30)]

    # simulate the crash: run the segment+index writes, abort the manifest
    st.update(30, keys, mk_table(rng, 30))
    monkeypatch.setattr(segments, "write_manifest",
                        lambda *a, **k: (_ for _ in ()).throw(OSError("boom")))
    with pytest.raises(OSError):
        st.save(d)
    monkeypatch.undo()

    st2 = VersionedStore.load(d)        # pre-crash state, tail ignored
    assert [v.ts for v in st2.versions] == old_versions
    assert_equal_versions(st, st2, [10, 20])

    stats = st.save(d)                  # retry commits cleanly
    assert stats["mode"] == "incremental"
    assert_equal_versions(st, VersionedStore.load(d), [10, 20, 30])


def test_interrupted_full_rewrite_keeps_previous_state(rng, tmp_path,
                                                       monkeypatch):
    """A crash mid-rewrite never touches the committed generation: the
    previous manifest + index + segments stay fully loadable."""
    st = mk_store(rng)
    d = str(tmp_path / "s")
    st.save(d)
    pre = {t: st.get_version(t) for t in (20, 40)}
    calls = {"n": 0}
    real = segments.write_segment

    def exploding(*a, **k):
        calls["n"] += 1
        if calls["n"] >= 2:
            raise OSError("disk full")
        return real(*a, **k)

    monkeypatch.setattr(segments, "write_segment", exploding)
    with pytest.raises(OSError):
        st.save(d, force_full=True)
    monkeypatch.undo()
    st2 = VersionedStore.load(d)           # previous generation intact
    for t in (20, 40):
        got = st2.get_version(t)
        assert got.keys == pre[t].keys
        for f in got.values:
            assert np.array_equal(got.values[f], pre[t].values[f]), (t, f)


def test_interrupted_compact_keeps_previous_state_loadable(rng, tmp_path,
                                                           monkeypatch):
    """Compaction writes a new index generation and commits via the
    manifest swap: a crash between them must leave the pre-compaction
    store fully loadable."""
    st = VersionedStore("t", SCHEMA)
    keys = [f"k{i}" for i in range(20)]
    d = str(tmp_path / "s")
    for v in range(1, 6):
        st.update(v * 10, keys, mk_table(rng, 20))
        st.save(d)
    pre = {t: st.get_version(t) for t in (20, 50)}
    monkeypatch.setattr(segments, "write_manifest",
                        lambda *a, **k: (_ for _ in ()).throw(OSError("boom")))
    with pytest.raises(OSError):
        st.compact(30, path=d)
    monkeypatch.undo()
    st2 = VersionedStore.load(d)           # previous manifest generation
    for t in (20, 50):
        got = st2.get_version(t)
        assert got.keys == pre[t].keys
        for f in got.values:
            assert np.array_equal(got.values[f], pre[t].values[f]), (t, f)


def test_store_dir_names_never_collide():
    from repro.core.segments import store_dir_name
    assert store_dir_name("a/b") != store_dir_name("a_b")
    assert store_dir_name("plain-name.v2") == "plain-name.v2"


def test_versioned_corpus_incremental_after_lazy_load(rng, tmp_path):
    """Direct head readers (versioned_dataset change detection) must see
    rebuilt heads after a lazy load — unchanged docs are not re-encoded."""
    from repro.data.versioned_dataset import VersionedCorpus
    c = VersionedCorpus()
    docs = {f"d{i}": f"document body {i}" for i in range(12)}
    c.add_release(10, docs)
    d = str(tmp_path / "corpus")
    c.store.save(d)
    c2 = VersionedCorpus()
    c2.store = VersionedStore.load(d)      # lazy: heads stale
    docs2 = dict(docs)
    docs2["d3"] = "changed!"
    c2.incremental_release(10, 20, docs2)
    assert c2.tokens_encoded_total == 1    # only the changed doc


# -- legacy snapshot migration ----------------------------------------------

def test_legacy_snapshot_loads_and_migrates(rng, tmp_path):
    st = mk_store(rng)
    d = str(tmp_path / "legacy")
    segments.write_legacy_snapshot(st, d)
    st2 = VersionedStore.load(d)       # legacy loader path
    assert_equal_versions(st, st2, [10, 20, 30, 40])
    stats = st2.save(d)                # first segmented save migrates
    assert stats["mode"] == "full"
    # the fix under test: no stale cells.npz/meta.json beside the manifest
    assert not os.path.exists(os.path.join(d, "cells.npz"))
    assert not os.path.exists(os.path.join(d, "meta.json"))
    assert os.path.exists(os.path.join(d, segments.MANIFEST_NAME))
    assert_equal_versions(st, VersionedStore.load(d), [10, 20, 30, 40])


# -- GeStore wiring ----------------------------------------------------------

def test_gestore_flush_and_reopen(rng, tmp_path):
    import repro.core as core
    from repro.core.parsers import FastaParser

    def fasta(n, seed):
        r = np.random.default_rng(seed)
        return "".join(
            f">Q{i:03d} d\n" + "".join(r.choice(list("ACDEFGHIK"), 16)) + "\n"
            for i in range(n))

    reg = core.PluginRegistry()
    reg.register_parser(FastaParser(seq_width=32, desc_width=8))
    root = str(tmp_path / "gs")
    gs = core.GeStore(root, reg)
    gs.add_release("up", 1, fasta(20, 1), parser_name="fasta")
    gs.add_release("up", 2, fasta(22, 2), parser_name="fasta")
    assert gs.flush()["up"]["mode"] == "full"
    want = gs.stores["up"].get_version(2)

    gs2 = core.GeStore(root, reg)      # autoload reopens persisted stores
    got = gs2.stores["up"].get_version(2)
    assert got.keys == want.keys
    assert np.array_equal(got.values["sequence"], want.values["sequence"])
    gs2.add_release("up", 3, fasta(23, 3), parser_name="fasta")
    assert gs2.flush("up")["up"]["mode"] == "incremental"
    # cache eviction never touches the persisted store
    gs2.cache.evict(0)
    assert os.path.exists(os.path.join(gs2.store_path("up"),
                                       segments.MANIFEST_NAME))


# -- wide dtypes and divergent-history compaction ----------------------------

def test_chain_codec_8byte_dtypes_beyond_32bit(rng):
    """The on-disk chain codec must round-trip 8-byte dtypes with values
    outside the 32-bit range (the jax delta kernels run 32-bit with x64
    disabled, so chain_pack deltas these on host)."""
    from repro.kernels.delta_codec import chain_pack, chain_unpack

    vals = np.array([[2**40], [2**40 + 5], [7], [-2**45]], np.int64)
    rows = np.array([0, 0, 1, 2], np.int32)
    packed, meta = chain_pack(vals, rows)
    got = chain_unpack(packed, rows, meta, np.dtype(np.int64))
    assert np.array_equal(got, vals)

    fv = rng.normal(scale=1e300, size=(6, 3)).astype(np.float64)
    frows = np.array([0, 0, 0, 1, 2, 2], np.int32)
    packed, meta = chain_pack(fv, frows)
    got = chain_unpack(packed, frows, meta, np.dtype(np.float64))
    assert np.array_equal(got, fv)

    # extreme deltas (wraparound territory) still round-trip unnarrowed
    iv = np.array([[2**62], [-(2**62)], [0]], np.int64)
    irows = np.array([0, 0, 0], np.int32)
    packed, meta = chain_pack(iv, irows)
    assert meta.get("narrow") is None
    got = chain_unpack(packed, irows, meta, np.dtype(np.int64))
    assert np.array_equal(got, iv)


def test_store_rejects_8byte_field_dtypes():
    """The 32-bit query engine cannot materialize int64/float64 cells
    losslessly; schema registration must fail loudly, not corrupt later."""
    for dt in ("int64", "float64"):
        with pytest.raises(ValueError, match="wider than 32 bits"):
            VersionedStore("wide", [FieldSchema("x", 2, dt)])


def test_compact_refuses_divergent_directory(rng, tmp_path):
    """compact(path=) against a directory written by a DIFFERENT store with
    the same name/keys/timestamps must full-rewrite, never splice the
    foreign store's retained tail segments into its own manifest."""
    keys = [f"k{i}" for i in range(25)]

    def mk(seed):
        st = VersionedStore("t", SCHEMA)
        r = np.random.default_rng(seed)
        for v in range(1, 6):
            st.update(v * 10, keys, mk_table(r, 25))
        return st

    a, b = mk(1), mk(2)
    d = str(tmp_path / "s")
    a.save(d)                          # directory belongs to store A
    want = {t: b.get_version(t) for t in (30, 40, 50)}
    stats = b.compact(30, path=d)      # divergent: must not retain A's tail
    assert stats.get("segments_retained", 0) == 0
    re = VersionedStore.load(d)
    for t in (30, 40, 50):
        got = re.get_version(t)
        assert got.keys == want[t].keys, t
        for f in got.values:
            assert np.array_equal(got.values[f], want[t].values[f]), (t, f)


def test_field_segment_dirs_never_collide(rng, tmp_path):
    """Field names that sanitize identically ('a/b' vs 'a_b') must get
    distinct segment directories, or the second field's segment file
    overwrites the first's and the store becomes unloadable."""
    schema = [FieldSchema("a/b", 2, "int32"), FieldSchema("a_b", 2, "int32")]
    st = VersionedStore("t", schema)
    keys = [f"k{i}" for i in range(8)]
    tab = {"a/b": rng.integers(0, 99, (8, 2)).astype(np.int32),
           "a_b": rng.integers(100, 199, (8, 2)).astype(np.int32)}
    st.update(10, keys, tab)
    d = str(tmp_path / "s")
    st.save(d)
    re = VersionedStore.load(d)
    got = re.get_version(10)
    for f in ("a/b", "a_b"):
        assert np.array_equal(got.values[f], tab[f]), f


def test_reserved_exists_field_name_rejected():
    """'__exists__' is the on-disk sentinel for the tombstone log; a user
    field under that name would collide with it in the segment layout."""
    with pytest.raises(ValueError, match="reserved"):
        VersionedStore("t", [FieldSchema("__exists__", 1, "int8")])


def test_gestore_flush_spilled_store_by_name(rng, tmp_path):
    """flush(name) must reopen a store the tiered pool spilled out of the
    shared dict instead of raising KeyError."""
    import repro.core as core
    from repro.core.parsers import FastaParser
    from repro.serve import GeStoreService
    from repro.serve.gestore_service import VersionRequest

    reg = core.PluginRegistry()
    reg.register_parser(FastaParser(seq_width=16, desc_width=4))
    gs = core.GeStore(str(tmp_path / "gs"), reg)
    gs.add_release("up", 1, ">A x\nACDE\n", parser_name="fasta")
    svc = GeStoreService(gs, memory_budget_bytes=1)
    svc.materialize([VersionRequest("up", 1)])     # flush -> enforce -> spill
    assert "up" not in gs.stores
    stats = gs.flush("up")                         # was KeyError pre-fix
    assert stats["up"]["mode"] in ("incremental", "full")


def test_schema_inference_narrows_platform_default_dtypes():
    """update() with plain Python lists (np.asarray infers int64/float64
    on 64-bit platforms) must narrow to the engine's 32-bit lanes when
    lossless instead of tripping the wide-dtype rejection."""
    st = VersionedStore("t", [])
    st.update(10, ["k0"], {"x": [[1, 2]], "y": [[1.5, 2.5]]})
    assert st.schema["x"].dtype == "int32"
    assert st.schema["y"].dtype == "float32"
    got = st.get_version(10)
    assert got.values["x"].tolist() == [[1, 2]]
    # values that genuinely need 64 bits still fail loudly at ingestion
    st2 = VersionedStore("t2", [])
    with pytest.raises(ValueError, match="wider than 32 bits"):
        st2.update(10, ["k0"], {"x": [[2**40]]})
    # int64-min must not slip past the bounds check via abs() wraparound
    with pytest.raises(ValueError, match="wider than 32 bits"):
        VersionedStore("t3", []).update(10, ["k0"], {"x": [[-2**63, 5]]})
    # -2**31 is representable in int32 and narrows
    st4 = VersionedStore("t4", [])
    st4.update(10, ["k0"], {"x": [[-2**31]]})
    assert st4.schema["x"].dtype == "int32"
    # float magnitudes outside float32 range fail loudly, not inf/0
    for v in (1e300, 1e-300):
        with pytest.raises(ValueError, match="wider than 32 bits"):
            VersionedStore("t5", []).update(10, ["k0"], {"x": [[v]]})


def test_gestore_autoload_skips_unloadable_store(rng, tmp_path):
    """One corrupt store directory must not brick GeStore autoload for
    every other store under the root."""
    import repro.core as core
    from repro.core.parsers import FastaParser

    reg = core.PluginRegistry()
    reg.register_parser(FastaParser(seq_width=16, desc_width=4))
    root = str(tmp_path / "gs")
    gs = core.GeStore(root, reg)
    gs.add_release("good", 1, ">A x\nACDE\n", parser_name="fasta")
    gs.add_release("bad", 1, ">B y\nACDF\n", parser_name="fasta")
    gs.flush()
    seg = seg_index(gs.store_path("bad"))[0]
    with open(os.path.join(gs.store_path("bad"), seg.path), "r+b") as f:
        f.truncate(4)                                   # corrupt one store

    gs2 = core.GeStore(root, reg)                       # must not raise
    assert "good" in gs2.stores
    assert "bad" not in gs2.stores
    assert list(gs2.load_errors)                        # recorded, not lost
    with pytest.raises(segments.CorruptSegmentError):
        gs2.open_store("bad")                           # surfaces on access


def test_chain_pack_int64_min_delta_among_small_deltas():
    """A single int64-min delta must block narrowing even when every other
    delta is tiny (np.abs wraps int64-min negative, hiding it from a
    max-of-abs bound)."""
    from repro.kernels.delta_codec import chain_pack, chain_unpack

    vals = np.array([[5], [-2**63 + 5]], np.int64)   # chain delta = -2**63
    rows = np.array([0, 0], np.int32)
    packed, meta = chain_pack(vals, rows)
    assert meta.get("narrow") is None, meta
    got = chain_unpack(packed, rows, meta, np.dtype(np.int64))
    assert np.array_equal(got, vals)


def test_update_existing_field_rejects_out_of_range_values():
    """Out-of-range values fail loudly on EVERY update, not only at schema
    inference — a later int64 block must not wrap into an int32 field."""
    st = VersionedStore("t", [])
    st.update(10, ["k0"], {"x": [[1]], "y": [[1.5]]})
    with pytest.raises(ValueError, match="exceed the int32 range"):
        st.update(20, ["k0"], {"x": np.array([[2**40]], np.int64)})
    with pytest.raises(ValueError, match="exceed the float32 range"):
        st.update(20, ["k0"], {"y": np.array([[1e300]], np.float64)})
    st.update(30, ["k0"], {"x": [[7]], "y": [[0.25]]})   # in-range still fine
    assert st.get_version(30).values["x"].tolist() == [[7]]


def test_load_narrows_legacy_float64_schema(rng, tmp_path):
    """A manifest persisted with a float64 field (pre-rejection) must still
    load — narrowed to float32, which is the precision the 32-bit engine
    always materialized — and migrate on the next save."""
    st = VersionedStore("t", [FieldSchema("f", 2, "float32")])
    vals = rng.normal(size=(6, 2)).astype(np.float32)
    st.update(10, [f"k{i}" for i in range(6)], {"f": vals})
    d = str(tmp_path / "s")
    st.save(d)
    m = manifest(d)
    assert m["schema"][0]["dtype"] == "float32"
    m["schema"][0]["dtype"] = "float64"          # as an old store would say
    with open(os.path.join(d, segments.MANIFEST_NAME), "w") as f:
        json.dump(m, f)
    re = VersionedStore.load(d)
    assert re.schema["f"].dtype == "float32"
    assert np.array_equal(re.get_version(10).values["f"], vals)
    assert re.save(d)["mode"] == "full"          # schema mismatch -> migrate
    assert manifest(d)["schema"][0]["dtype"] == "float32"
