"""Neural-BLAST: incremental update + merge must EXACTLY equal full
recompute (top-k, scores, and the e-value normalizer Z)."""
import numpy as np
from _hyp import given, settings, st  # optional-hypothesis shim

import repro.core as core
from repro.core.store import FieldSchema, VersionedStore


def build_store(rng, n, w=16):
    store = VersionedStore("c", [FieldSchema("sequence", w, "int32")])
    store.update(100, [f"d{i}" for i in range(n)],
                 {"sequence": rng.integers(0, 20, (n, w)).astype(np.int32)})
    return store


def mutate(store, rng, t0, t1, n_mut, n_new, n_del, w=16):
    view = store.get_version(t0)
    keys = [k.decode() for k in view.keys]
    tbl = view.values["sequence"].copy()
    mut = rng.choice(len(keys), size=min(n_mut, len(keys)), replace=False)
    tbl[mut] = rng.integers(0, 20, (len(mut), w))
    drop = set(rng.choice(len(keys), size=min(n_del, len(keys) - 1),
                          replace=False).tolist()) - set(mut.tolist())
    keep = [i for i in range(len(keys)) if i not in drop]
    new_keys = [f"n{t1}_{i}" for i in range(n_new)]
    all_keys = [keys[i] for i in keep] + new_keys
    all_tbl = np.concatenate([tbl[keep],
                              rng.integers(0, 20, (n_new, w)).astype(np.int32)])
    store.update(t1, all_keys, {"sequence": all_tbl})


def encoder(rng_seed=0, w=16, d=8):
    proj = np.random.default_rng(rng_seed).normal(size=(w, d)).astype(np.float32)
    return lambda toks: (toks.astype(np.float32) @ proj) / 4.0


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10**6), st.integers(0, 6), st.integers(0, 5),
       st.integers(0, 3))
def test_incremental_equals_full(seed, n_mut, n_new, n_del):
    rng = np.random.default_rng(seed)
    store = build_store(rng, 40)
    mutate(store, rng, 100, 200, n_mut, n_new, n_del)
    enc = encoder()
    q = rng.integers(0, 20, (3, 16)).astype(np.int32)
    qids = [b"q0", b"q1", b"q2"]

    db = core.EmbeddingSearchDB(store, enc, seg_size=8)
    db.refresh(100)
    r1 = db.query(qids, q, ts=100, k=5)
    r2 = db.incremental_query(r1, qids, q, t_last=100, ts=200, k=5)

    full = core.EmbeddingSearchDB(store, enc, seg_size=8)
    full.refresh(200)
    rf = full.query(qids, q, ts=200, k=5)

    assert np.array_equal(r2.topk_idx, rf.topk_idx)
    assert np.allclose(r2.topk_score, rf.topk_score, atol=1e-5)
    assert np.allclose(r2.z, rf.z, atol=1e-4)


def test_incremental_work_is_proportional():
    rng = np.random.default_rng(1)
    store = build_store(rng, 200)
    mutate(store, rng, 100, 200, n_mut=4, n_new=2, n_del=0)
    db = core.EmbeddingSearchDB(store, encoder(), seg_size=16)
    db.refresh(100)
    assert db.n_embedded_total == 200
    r1 = db.query([b"q"], rng.integers(0, 20, (1, 16)).astype(np.int32), ts=100)
    r2 = db.incremental_query(r1, [b"q"],
                              rng.integers(0, 20, (1, 16)).astype(np.int32),
                              t_last=100, ts=200)
    assert db.n_embedded_total <= 200 + 6      # only the increment re-embedded


def test_evalue_normalization():
    rng = np.random.default_rng(2)
    store = build_store(rng, 32)
    db = core.EmbeddingSearchDB(store, encoder(), seg_size=8)
    db.refresh(100)
    q = rng.integers(0, 20, (2, 16)).astype(np.int32)
    r = db.query([b"a", b"b"], q, ts=100, k=32)
    ev = r.evalue()
    sums = ev.sum(axis=1)
    assert np.all(sums <= 1.0 + 1e-5)          # p = exp(s - Z) over full corpus
    assert np.all(sums > 0.95)                 # k = corpus size -> sums to 1
