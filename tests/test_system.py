"""End-to-end system behaviour: the paper's workflow lifecycle on top of
the full stack (store -> plugins -> incremental search -> versioned
checkpoints), mirroring the GeStore evaluation narrative."""
import tempfile

import numpy as np
import jax

import repro.core as core
from repro.configs.base import RunConfig, get_smoke_config
from repro.core.parsers import FastaParser, UniProtParser
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.data.versioned_dataset import VersionedCorpus
from repro.train.train_loop import Trainer, TrainerConfig


def _fasta(n, mut=(), seed=5):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        seq = "".join(rng.choice(list("ACDEFGHIKLMNPQRSTVWY"), 28))
        if i in mut:
            seq = seq[:4] + "YYYY" + seq[8:]
        out.append(f">P{i:04d} protein {i}\n{seq}\n")
    return "".join(out)


def test_full_gestore_lifecycle():
    """add release -> update release -> full gen -> increment gen -> cached;
    operation set of paper Tables I-II."""
    reg = core.PluginRegistry()
    reg.register_parser(FastaParser(seq_width=64, desc_width=16))
    reg.register_tool(core.ToolPlugin(
        "blastp",
        core.FileGenerator(parser="fasta",
                           output_fields=["sequence", "length", "desc"],
                           significant_fields=["sequence", "length"]),
        merger=core.BlastEvalueMerger()))
    with tempfile.TemporaryDirectory() as root:
        gs = core.GeStore(root, reg)
        i1 = gs.add_release("up", 100, _fasta(60), parser_name="fasta")
        assert i1.n_new == 60
        i2 = gs.add_release("up", 200, _fasta(66, mut={1, 2, 3}),
                            parser_name="fasta")
        assert i2.n_new == 6 and i2.n_updated == 3
        full = gs.generate_files("blastp", "up", t_version=200)
        inc = gs.generate_files("blastp", "up", t_version=200, t_last=100)
        assert full.n_entries == 66 and inc.n_entries == 9
        cached = gs.generate_files("blastp", "up", t_version=200)
        assert cached.mode == "cached"
        # updates table recorded both releases
        ups = gs.tables.updates_for("up")
        assert [u.ts for u in ups] == [100, 200]


def test_incremental_reanalysis_speedup_model():
    """The Table-IV story: incremental work / full work ~= churn rate."""
    rng = np.random.default_rng(0)
    store = core.VersionedStore("c", [core.FieldSchema("sequence", 16, "int32")])
    n = 400
    store.update(1, [f"d{i}" for i in range(n)],
                 {"sequence": rng.integers(0, 20, (n, 16)).astype(np.int32)})
    view = store.get_version(1)
    tbl = view.values["sequence"].copy()
    tbl[:12] = rng.integers(0, 20, (12, 16))     # 3% churn
    store.update(2, [k.decode() for k in view.keys], {"sequence": tbl})

    proj = rng.normal(size=(16, 8)).astype(np.float32)
    enc = lambda t: (t.astype(np.float32) @ proj) / 4.0
    db = core.EmbeddingSearchDB(store, enc, seg_size=16)
    db.refresh(1)
    full_cost = db.n_embedded_total
    q = rng.integers(0, 20, (4, 16)).astype(np.int32)
    r1 = db.query([b"a", b"b", b"c", b"d"], q, ts=1, k=5)
    r2 = db.incremental_query(r1, [b"a", b"b", b"c", b"d"], q, t_last=1, ts=2,
                              k=5)
    inc_cost = db.n_embedded_total - full_cost
    speedup = full_cost / max(inc_cost, 1)
    assert speedup >= 13, speedup                 # paper: 13x for 1-month delta
    # and results are exact
    db2 = core.EmbeddingSearchDB(store, enc, seg_size=16)
    db2.refresh(2)
    rf = db2.query([b"a", b"b", b"c", b"d"], q, ts=2, k=5)
    assert np.array_equal(r2.topk_idx, rf.topk_idx)


def test_versioned_training_pipeline():
    """Training consumes a pinned corpus version; checkpoint versions are
    delta-compressed releases; restart reproduces the run."""
    corpus = VersionedCorpus()
    docs = {f"d{i}": f"sample training text number {i} " * 6 for i in range(30)}
    corpus.add_release(1, docs)
    cfg = get_smoke_config("olmo-1b")
    toks = corpus.token_stream(1) % cfg.vocab
    pipe = TokenPipeline(toks, DataConfig(seq_len=24, global_batch=4, seed=1))
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, RunConfig(attn_impl="xla", learning_rate=1e-3),
                     TrainerConfig(total_steps=8, warmup_steps=1,
                                   ckpt_every=4, ckpt_dir=d))
        tr.run_loop(iter(pipe))
        assert tr.ckpt.stats()["versions"] == 2
        # crash-restart from step 4
        tr2 = Trainer(cfg, RunConfig(attn_impl="xla", learning_rate=1e-3),
                      TrainerConfig(total_steps=8, warmup_steps=1,
                                    ckpt_every=0, ckpt_dir=d))
        tr2.state["params"] = tr.ckpt.restore(4, like=tr2.state["params"])
        flat = jax.tree_util.tree_leaves(tr2.state["params"])
        assert all(np.isfinite(np.asarray(x)).all() for x in flat)


def test_uniprot_blast_significance_end_to_end():
    """Annotation-only release churn must produce an EMPTY BLAST increment
    (the paper's central motivating example)."""
    up_v1 = """ID   A_TEST   Reviewed;   10 AA.
AC   A0001;
DE   RecName: Full=Old name;
OX   NCBI_TaxID=9606;
SQ   SEQUENCE   10 AA;  1111 MW;  AAAA CRC64;
     MKTAYIAKQR
//
"""
    up_v2 = up_v1.replace("Old name", "Shiny new annotation")
    reg = core.PluginRegistry()
    reg.register_parser(UniProtParser(seq_width=32))
    reg.register_tool(core.ToolPlugin(
        "blastp",
        core.FileGenerator(parser="uniprot_dat",
                           output_fields=["sequence", "length", "annotation",
                                          "taxid"],
                           significant_fields=["sequence", "length"])))
    with tempfile.TemporaryDirectory() as root:
        gs = core.GeStore(root, reg)
        gs.add_release("uniprot", 1, up_v1, parser_name="uniprot_dat")
        info = gs.add_release("uniprot", 2, up_v2, parser_name="uniprot_dat")
        assert info.n_updated == 1                    # annotation cell written
        inc = gs.generate_files("blastp", "uniprot", t_version=2, t_last=1)
        assert inc.n_entries == 0                     # but BLAST sees nothing
