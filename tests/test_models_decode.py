"""Serving-path correctness: prefill + one decode step must equal the full
forward over the extended sequence, for every architecture family."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models import build
from repro.models.transformer import FwdOpts

# parity tests pin the xla attention impl so they isolate cache/state logic
# from chunked-vs-full attention precision (bf16 compact prefill logits)
XLA = FwdOpts(attn_impl="xla")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = get_smoke_config(arch)
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    B, S = 2, 12
    rng = np.random.default_rng(0)
    if cfg.family == "encdec":
        enc = jnp.asarray(rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)),
                          jnp.float32).astype(jnp.bfloat16)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
        _, state = bundle.prefill(params, {"enc_embeds": enc,
                                           "tokens": toks[:, :S]}, XLA, pad_to=S + 4)
        logits_d, state2 = bundle.decode(params, toks[:, S:S + 1], state)
        logits_ref, _ = bundle.prefill(params, {"enc_embeds": enc,
                                                "tokens": toks}, XLA)
        assert int(state2.pos) == S + 1
    elif cfg.input_mode == "embeddings":
        emb = jnp.asarray(rng.normal(size=(B, S + 1, cfg.d_model)),
                          jnp.float32).astype(jnp.bfloat16)
        pos = jnp.asarray(np.tile(np.arange(S + 1), (3, B, 1)), jnp.int32)
        _, state = bundle.prefill(params, {"embeds": emb[:, :S],
                                           "positions": pos[:, :, :S]},
                                  XLA, pad_to=S + 4)
        logits_d, _ = bundle.decode(params, emb[:, S:S + 1], state,
                                    positions=pos[:, :, S:S + 1])
        logits_ref, _ = bundle.prefill(params, {"embeds": emb, "positions": pos}, XLA)
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
        _, state = bundle.prefill(params, {"tokens": toks[:, :S]}, XLA, pad_to=S + 4)
        logits_d, _ = bundle.decode(params, toks[:, S:S + 1], state)
        logits_ref, _ = bundle.prefill(params, {"tokens": toks}, XLA)
    err = float(jnp.max(jnp.abs(logits_d - logits_ref)))
    # jamba's 8-deep hybrid smoke accumulates bf16 drift near the generic
    # gate (and CPU oneDNN reduction order jitters run-to-run); its
    # correctness is pinned by the exact-seq-mixer tests, so the logits
    # tolerance is family-scaled here.
    tol = 0.5 if cfg.family == "hybrid" else 0.2
    assert err < tol, (arch, err)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-7b", "jamba-v0.1-52b"])
def test_multi_step_decode(arch):
    """8 sequential decode steps equal one long prefill."""
    cfg = get_smoke_config(arch)
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    B, S, N = 2, 8, 8
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + N)), jnp.int32)
    _, state = bundle.prefill(params, {"tokens": toks[:, :S]}, XLA, pad_to=S + N)
    last = None
    for t in range(N):
        last, state = bundle.decode(params, toks[:, S + t:S + t + 1], state)
    ref, _ = bundle.prefill(params, {"tokens": toks}, XLA)
    err = float(jnp.max(jnp.abs(last - ref)))
    tol = 0.5 if cfg.family == "hybrid" else 0.25   # see parity-test note
    assert err < tol, (arch, err)


def test_decode_ring_at_capacity():
    """When the cache is full, decode still runs (sliding-window ring)."""
    cfg = get_smoke_config("llama3.2-1b")
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    toks = jnp.asarray(np.arange(10)[None, :] % cfg.vocab, jnp.int32)
    _, state = bundle.prefill(params, {"tokens": toks}, XLA)  # capacity == 10
    for _ in range(4):
        logits, state = bundle.decode(params, toks[:, :1], state)
        assert np.isfinite(np.asarray(logits)).all()
