"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see exactly
one CPU device (the 512-device override belongs ONLY to launch/dryrun.py;
multi-device tests spawn subprocesses that set their own flags)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # for the _hyp shim


@pytest.fixture
def rng():
    return np.random.default_rng(0)
