"""Sharding-rule unit tests: fallback chains against the published dims
(these run with a FAKE mesh shape object — no devices needed)."""

from repro.sharding.rules import pspec_for
from jax.sharding import PartitionSpec as P


class FakeMesh:
    """Duck-typed mesh: pspec_for only reads axis_names and shape."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


SINGLE = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_basic_tp_fsdp():
    # llama wq: (2048, 32, 64) (embed, heads, head_dim)
    ps = pspec_for((2048, 32, 64), ("embed", "heads", "head_dim"), SINGLE)
    assert ps == P("data", "model", None)


def test_kv_heads_fallback_replicates():
    # grok wk: kv=8 not divisible by 16 -> replicated (NOT head_dim sharding)
    ps = pspec_for((6144, 8, 128), ("embed", "kv_heads", "head_dim"), SINGLE)
    assert ps == P("data", None, None)


def test_qwen_odd_heads_fallback():
    # qwen2-0.5b: 14 heads -> replicated attention
    ps = pspec_for((896, 14, 64), ("embed", "heads", "head_dim"), SINGLE)
    assert ps == P("data", None, None)


def test_whisper_vocab_fallback():
    # 51865 % 16 != 0 -> replicated vocab
    ps = pspec_for((51865, 1024), ("vocab", "embed_tbl"), SINGLE)
    assert ps == P(None, None)


def test_kimi_expert_parallelism():
    # kimi wi: (384, 7168, 2048): experts 384/16 -> EP on model
    ps = pspec_for((384, 7168, 2048), ("expert", "embed", "expert_mlp"), SINGLE)
    assert ps == P("model", "data", None)


def test_grok_expert_fallback_to_tp():
    # grok wi: (8, 6144, 32768): 8 experts < 16 -> expert-FFN TP instead
    ps = pspec_for((8, 6144, 32768), ("expert", "embed", "expert_mlp"), SINGLE)
    assert ps == P(None, "data", "model")


def test_axis_used_once_per_tensor():
    # both dims want "model": second falls through
    ps = pspec_for((64, 64), ("heads", "mlp"), FakeMesh({"model": 16}))
    assert ps == P("model", None)


def test_batch_multi_pod():
    ps = pspec_for((256, 4096), ("batch", "seq"), POD)
    assert ps == P(("pod", "data"), None)


def test_batch_fallback_single_axis():
    # batch 8 doesn't divide pod*data=32 but divides data? no (16) ->
    # falls to replicated via the chain
    ps = pspec_for((8, 128), ("batch", "seq"), POD)
    assert ps == P(None, None)


def test_decode_cache_sequence_sharding():
    # (G, B, S, K, hd) decode cache: kv_seq -> model
    ps = pspec_for((64, 128, 32768, 8, 128),
                   ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), POD)
    assert ps == P(None, ("pod", "data"), "model", None, None)


def test_rwkv_projection_sharding():
    ps = pspec_for((4096, 4096), ("embed", "heads_flat"), SINGLE)
    assert ps == P("data", "model")
