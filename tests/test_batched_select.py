"""Batched multi-version materialization: kernel parity vs the per-timestamp
reference, fused-superlog store APIs (get_versions / get_increments), the
single-scan guarantee, and the GeStoreService batching/plan-cache path."""
import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.core.store import FieldSchema, VersionedStore, TS_MAX, KIND_DELETED
from repro.serve.gestore_service import GeStoreService, VersionRequest


def mk_csr_log(rng, n_rows, n_cells, width=3, ts_hi=100):
    """Random CSR cell log sorted by (row, ts), as _CellLog builds it."""
    rows = rng.integers(0, n_rows, n_cells).astype(np.int32)
    tss = rng.integers(0, ts_hi, n_cells).astype(np.int32)
    order = np.lexsort((tss, rows))
    rows, tss = rows[order], tss[order]
    vals = rng.integers(-50, 50, (n_cells, width)).astype(np.int32)
    ptr = np.zeros(n_rows + 1, np.int32)
    np.add.at(ptr, rows + 1, 1)
    return vals, tss, np.cumsum(ptr).astype(np.int32)


def mk_store(rng, n_versions=4, pool=24):
    st = VersionedStore("t", [FieldSchema("a", 4, "int32"),
                              FieldSchema("b", 2, "float32")])
    keys = [f"K{i:02d}" for i in range(pool)]
    for v in range(n_versions):
        sub = sorted(rng.choice(keys, size=rng.integers(8, pool), replace=False))
        st.update((v + 1) * 10, sub,
                  {"a": rng.integers(0, 50, (len(sub), 4)).astype(np.int32),
                   "b": rng.normal(size=(len(sub), 2)).astype(np.float32)})
    return st


# ---------------------------------------------------------------------------
# kernel layer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_cells", [1, 7, 2047, 2048, 2049, 5001])
def test_batched_cumsum_matches_per_ts(n_cells, rng):
    ts = np.sort(rng.integers(0, 97, n_cells)).astype(np.int32)
    tq = np.array([-1, 0, 13, 96, 97, TS_MAX], np.int32)
    got = np.asarray(ops.batched_masked_cumsum(
        jnp.asarray(ts), jnp.asarray(tq), interpret=True))
    for i, t in enumerate(tq):
        want = np.asarray(ops.masked_cumsum(jnp.asarray(ts), t, interpret=True))
        assert np.array_equal(got[i], want), t
    # dispatch default (ref path on CPU) agrees with the kernel
    assert np.array_equal(
        got, np.asarray(ops.batched_masked_cumsum(jnp.asarray(ts),
                                                  jnp.asarray(tq))))


def test_batched_select_matches_per_ts_ref(rng):
    vals, tss, ptr = mk_csr_log(rng, n_rows=41, n_cells=300)
    tq = np.array([0, 5, 50, 99, 100, TS_MAX], np.int32)
    out, found = ops.batched_version_select(
        jnp.asarray(vals), jnp.asarray(tss), jnp.asarray(ptr),
        jnp.asarray(tq), interpret=True)
    for i, t in enumerate(tq):
        o1, f1 = ref.ref_version_select(jnp.asarray(vals), jnp.asarray(tss),
                                        jnp.asarray(ptr), t)
        assert np.array_equal(np.asarray(out)[i], np.asarray(o1))
        assert np.array_equal(np.asarray(found)[i], np.asarray(f1))


def test_batched_select_empty_log():
    vals = jnp.zeros((0, 3), jnp.int32)
    tss = jnp.zeros((0,), jnp.int32)
    ptr = jnp.zeros((8,), jnp.int32)
    out, found = ops.batched_version_select(vals, tss, ptr,
                                            jnp.asarray([1, 2, TS_MAX]))
    assert out.shape == (3, 7, 3) and not np.asarray(found).any()
    assert not np.asarray(out).any()


def test_batched_cumsum_clamp_edge(rng):
    """Padding must never count, even for queries at the TS_MAX clamp."""
    for n_cells in (2047, 2049):  # force padding on both sides of a tile
        ts = np.full(n_cells, TS_MAX, np.int32)
        got = np.asarray(ops.batched_masked_cumsum(
            jnp.asarray(ts), jnp.asarray([TS_MAX, TS_MAX - 1], np.int32),
            interpret=True))
        assert got[0, -1] == n_cells and got[1, -1] == 0


# ---------------------------------------------------------------------------
# store layer
# ---------------------------------------------------------------------------

def test_get_versions_matches_get_version(rng):
    st = mk_store(rng)
    st.delete(45, [st.get_version(40).keys[0]])
    qs = [5, 10, 15, 25, 40, 45, 47, TS_MAX, TS_MAX + 10]
    views = st.get_versions(qs)
    assert len(views) == len(qs)
    for t, v in zip(qs, views):
        w = st.get_version(t)
        assert v.ts == t and v.keys == w.keys
        assert np.array_equal(v.row_idx, w.row_idx)
        for f in ("a", "b"):
            assert np.array_equal(v.values[f], w.values[f]), (t, f)


def test_get_versions_filters_and_deleted(rng):
    st = mk_store(rng)
    st.delete(45, [st.get_version(40).keys[0]])
    for kw in (dict(include_deleted=True), dict(key_filter=r"^K0"),
               dict(fields=["a"])):
        v = st.get_versions([45, 47], **kw)
        for t, got in zip([45, 47], v):
            want = st.get_version(t, **kw)
            assert got.keys == want.keys
            for f in got.values:
                assert np.array_equal(got.values[f], want.values[f])


def test_get_versions_empty_store_and_empty_batch():
    st = VersionedStore("t", [FieldSchema("a", 2, "int32")])
    assert st.get_versions([]) == []
    v = st.get_versions([1, TS_MAX])
    assert [len(x) for x in v] == [0, 0]


def test_get_versions_all_deleted(rng):
    st = VersionedStore("t", [FieldSchema("a", 2, "int32")])
    st.update(1, ["x", "y"], {"a": np.ones((2, 2), np.int32)})
    st.delete(2, ["x", "y"])
    v1, v2 = st.get_versions([1, 2])
    assert len(v1) == 2 and len(v2) == 0
    ever = st.get_versions([2], include_deleted=True)[0]
    assert sorted(k.decode() for k in ever.keys) == ["x", "y"]


def test_get_versions_single_scan(rng, monkeypatch):
    """8 versions x F fields on a 4-release store = ONE batched scan."""
    st = mk_store(rng, n_versions=4)
    st.superlog()  # warm the lazy build
    calls = []
    orig = ops.batched_masked_cumsum

    def counted(ts, tq, **kw):
        calls.append(np.asarray(tq).shape)
        return orig(ts, tq, **kw)

    monkeypatch.setattr("repro.core.store.kops.batched_masked_cumsum", counted)
    views = st.get_versions([10, 20, 30, 40, 15, 25, 35, TS_MAX])
    assert len(views) == 8
    assert calls == [(8,)]


def test_superlog_epoch_invalidation(rng):
    st = mk_store(rng, n_versions=2)
    sl1 = st.superlog()
    assert st.superlog() is sl1          # stable while the log is unchanged
    st.update(100, ["K00"], {"a": np.zeros((1, 4), np.int32),
                             "b": np.zeros((1, 2), np.float32)},
              full_release=False)
    sl2 = st.superlog()
    assert sl2 is not sl1 and sl2.epoch > sl1.epoch


def test_get_increments_matches_get_increment(rng):
    st = mk_store(rng)
    st.delete(45, [st.get_version(40).keys[0]])
    pairs = [(10, 20), (10, 40), (20, 45), (-1, 10), (40, 45)]
    incs = st.get_increments(pairs, significant_fields=["a"])
    for (t0, t1), inc in zip(pairs, incs):
        one = st.get_increment(t0, t1, significant_fields=["a"])
        assert (inc.t0, inc.t1) == (one.t0, one.t1)
        assert inc.keys == one.keys
        assert np.array_equal(inc.kind, one.kind)
        for f in ("a", "b"):
            assert np.array_equal(inc.values[f], one.values[f])
        # deleted rows carry zeroed values
        assert not inc.values["a"][inc.kind == KIND_DELETED].any()


# ---------------------------------------------------------------------------
# service layer
# ---------------------------------------------------------------------------

def test_service_concurrent_submit_matches_store(rng):
    st = mk_store(rng)
    svc = GeStoreService({"t": st}, max_batch=4)
    futs = {}

    def worker(t):
        futs[t] = svc.submit("t", t, fields=["a"])

    threads = [threading.Thread(target=worker, args=(t,))
               for t in (10, 20, 30, 40, 15, 25)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert svc.flush() == 6
    for t, fut in futs.items():
        want = st.get_version(t, fields=["a"])
        got = fut.result(timeout=1)
        assert got.keys == want.keys
        assert np.array_equal(got.values["a"], want.values["a"])


def test_service_plan_cache_and_epoch(rng):
    st = mk_store(rng)
    svc = GeStoreService({"t": st}, plan_cache_size=2)
    v1 = svc.materialize([VersionRequest("t", 20, fields=("a",))])[0]
    assert svc.stats["plan_misses"] == 1
    v2 = svc.materialize([VersionRequest("t", 20, fields=("a",))])[0]
    assert svc.stats["plan_hits"] == 1 and v2 is v1   # memoized plan
    # mutation bumps the epoch -> the plan is stale and re-materialized
    st.update(90, ["K00"], {"a": np.full((1, 4), 7, np.int32),
                            "b": np.zeros((1, 2), np.float32)},
              full_release=False)
    v3 = svc.materialize([VersionRequest("t", 20, fields=("a",))])[0]
    assert v3 is not v1 and v3.keys == v1.keys
    # duplicate requests in one flush dedupe into a single materialization
    misses = svc.stats["plan_misses"]
    a, b = svc.materialize([VersionRequest("t", 30), VersionRequest("t", 30)])
    assert a is b and svc.stats["plan_misses"] == misses + 1


def test_generate_files_batch_matches_single(tmp_path, rng):
    import repro.core as core
    from repro.core.parsers import FastaParser

    reg = core.PluginRegistry()
    reg.register_parser(FastaParser(seq_width=32, desc_width=8))
    reg.register_tool(core.ToolPlugin(
        "blastp",
        core.FileGenerator(parser="fasta",
                           output_fields=["sequence", "length", "desc"],
                           significant_fields=["sequence", "length"])))
    gs = core.GeStore(str(tmp_path / "a"), reg)
    gs2 = core.GeStore(str(tmp_path / "b"), reg)
    fa1 = "".join(f">S{i:03d} d\n{'ACDE' * 6}\n" for i in range(8))
    fa2 = "".join(f">S{i:03d} d\n{'ACDE' * 6 if i % 3 else 'WWWW' * 6}\n"
                  for i in range(10))
    for g in (gs, gs2):
        g.add_release("up", 100, fa1, parser_name="fasta")
        g.add_release("up", 200, fa2, parser_name="fasta")

    reqs = [{"tool": "blastp", "store": "up", "t_version": 100},
            {"tool": "blastp", "store": "up", "t_version": 200},
            {"tool": "blastp", "store": "up", "t_version": 200, "t_last": 100},
            {"tool": "blastp", "store": "up", "t_version": 100}]  # dup -> cached
    batch = gs.generate_files_batch(reqs)
    singles = [gs2.generate_files(r["tool"], r["store"],
                                  t_version=r["t_version"],
                                  t_last=r.get("t_last")) for r in reqs]
    for got, want in zip(batch, singles):
        assert got.n_entries == want.n_entries
        assert open(got.path).read() == open(want.path).read()
        for k in ("deleted_keys", "updated_keys", "new_keys",
                  "db_size_old", "db_size_new"):
            assert got.context.get(k) == want.context.get(k), k
    assert batch[3].mode == "cached" and batch[3].path == batch[0].path
