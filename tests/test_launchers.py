"""Launcher entrypoints (train/serve/dryrun CLIs) + assigned-shape policy."""
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
ENV = dict(os.environ, PYTHONPATH=SRC)


def run_cli(args, timeout=420):
    r = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                       text=True, env=ENV, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-2000:]}"
    return r.stdout


def test_train_launcher_smoke(tmp_path):
    out = run_cli(["repro.launch.train", "--arch", "olmo-1b", "--smoke",
                   "--steps", "4", "--batch", "2", "--seq", "16",
                   "--ckpt-every", "2", "--ckpt-dir", str(tmp_path)])
    assert "done: 4 steps" in out
    assert (tmp_path / "meta.json").exists()  # delta checkpoints written


def test_train_launcher_adafactor_grad_compress(tmp_path):
    out = run_cli(["repro.launch.train", "--arch", "qwen2-0.5b", "--smoke",
                   "--steps", "3", "--batch", "2", "--seq", "16",
                   "--optimizer", "adafactor", "--grad-compress",
                   "--ckpt-every", "0", "--ckpt-dir", str(tmp_path)])
    assert "done: 3 steps" in out


def test_serve_launcher_smoke():
    out = run_cli(["repro.launch.serve", "--arch", "rwkv6-7b", "--smoke",
                   "--requests", "3", "--max-new", "4", "--max-batch", "2"])
    assert "3 requests" in out


def test_assigned_shape_policy():
    """long_500k only for sub-quadratic archs; decode for everyone (whisper
    decodes through its decoder); 32 single-mesh cells total."""
    from repro.configs.base import ARCH_IDS, get_config, shapes_for
    cells = {(a, s.name) for a in ARCH_IDS for s in shapes_for(get_config(a))}
    assert len(cells) == 32
    long_archs = {a for (a, s) in cells if s == "long_500k"}
    assert long_archs == {"jamba-v0.1-52b", "rwkv6-7b"}
    assert all((a, "decode_32k") in cells for a in ARCH_IDS)


def test_dryrun_results_complete():
    """The committed dry-run artifacts cover every cell on both meshes."""
    import glob
    import json
    base = os.path.join(os.path.dirname(__file__), "..",
                        "experiments", "dryrun_final")
    files = glob.glob(os.path.join(base, "*.json"))
    if len(files) < 64:
        pytest.skip("final sweep artifacts not present")
    from repro.configs.base import ARCH_IDS, get_config, shapes_for
    have = {os.path.basename(p)[:-5] for p in files}
    for mesh in ("single", "pod"):
        for a in ARCH_IDS:
            for s in shapes_for(get_config(a)):
                assert f"{a}_{s.name}_{mesh}" in have
    # and every roofline row is sane
    for p in files:
        r = json.load(open(p))["roofline"]
        assert r["flops_per_device"] > 0
        assert r["t_memory_s"] > 0
        assert 0 <= r["roofline_fraction"] <= 1
