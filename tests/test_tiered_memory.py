"""Tiered memory manager (serve/gestore_service.TieredStorePool):
device->host->disk eviction under a byte budget, transparent lazy reload,
and the log_epoch safety floor for the plan cache."""
import numpy as np

from repro.core.store import FieldSchema, VersionedStore
from repro.serve import GeStoreService, TieredStorePool
from repro.serve.gestore_service import VersionRequest


def mk_store(name, rng, n=120, releases=3):
    st = VersionedStore(name, [FieldSchema("a", 8, "int32")])
    keys = [f"{name}-k{i}" for i in range(n)]
    for v in range(1, releases + 1):
        st.update(v * 10, keys,
                  {"a": rng.integers(0, 99, (n, 8)).astype(np.int32)})
    return st


def test_eviction_then_query_identical(rng, tmp_path):
    stores = {"A": mk_store("A", rng), "B": mk_store("B", rng)}
    want_a = stores["A"].get_version(20, fields=["a"])
    want_b = stores["B"].get_version(30, fields=["a"])

    svc = GeStoreService(stores, memory_budget_bytes=1,
                         spill_root=str(tmp_path))
    got_a = svc.materialize([VersionRequest("A", 20, ("a",))])[0]
    got_b = svc.materialize([VersionRequest("B", 30, ("a",))])[0]
    assert svc.pool.stats["spills"] >= 1
    got_a2 = svc.materialize([VersionRequest("A", 20, ("a",))])[0]  # reload
    assert svc.pool.stats["reloads"] >= 1
    for got, want in ((got_a, want_a), (got_b, want_b), (got_a2, want_a)):
        assert got.keys == want.keys
        assert np.array_equal(got.values["a"], want.values["a"])


def test_device_to_host_demotion(rng):
    st = mk_store("C", rng)
    want = st.get_version(20, fields=["a"])
    svc = GeStoreService({"C": st}, memory_budget_bytes=1)  # no spill root
    # multi-ts batch builds the fused superlog -> device-resident bytes
    svc.materialize([VersionRequest("C", 20, ("a",)),
                     VersionRequest("C", 30, ("a",))])
    assert svc.pool.stats["demotions"] >= 1
    assert st._superlog is None           # demoted, store still in memory
    got = svc.materialize([VersionRequest("C", 20, ("a",))])[0]
    assert np.array_equal(got.values["a"], want.values["a"])


def test_no_budget_means_no_eviction(rng):
    st = mk_store("D", rng)
    svc = GeStoreService({"D": st})
    assert svc.pool is None               # seed behavior preserved
    svc.materialize([VersionRequest("D", 20, ("a",)),
                     VersionRequest("D", 30, ("a",))])
    assert st._superlog is not None


def test_epoch_floor_survives_spill(rng, tmp_path):
    pool = TieredStorePool({"E": mk_store("E", rng)}, budget_bytes=1,
                           spill_root=str(tmp_path))
    pre = pool["E"].log_epoch
    assert pool.enforce() >= 1
    assert "E" in pool and len(pool) == 1
    post = pool["E"].log_epoch            # transparent reload
    assert post > pre                     # (store, epoch) keys never alias


def test_pool_accounting_and_add(rng, tmp_path):
    pool = TieredStorePool({}, budget_bytes=None, spill_root=str(tmp_path))
    assert pool.resident_bytes() == 0
    st = mk_store("F", rng)
    pool.add("F", st)
    assert pool.resident_bytes() == sum(st.nbytes().values())
    assert pool.enforce() == 0            # budget None: never evicts
    assert set(pool.keys()) == {"F"}


def test_gestore_facade_spill_then_mutate_serves_fresh_data(rng, tmp_path):
    """The pool shares the facade's live dict: a spill removes the store
    from GeStore.stores too, add_release reopens it from disk, and the
    service serves the post-mutation value (never a stale spilled copy)."""
    import repro.core as core
    from repro.core.parsers import FastaParser

    reg = core.PluginRegistry()
    reg.register_parser(FastaParser(seq_width=16, desc_width=4))
    gs = core.GeStore(str(tmp_path / "gs"), reg)
    gs.add_release("up", 1, ">A x\nACDE\n>B y\nACDF\n", parser_name="fasta")
    svc = GeStoreService(gs, memory_budget_bytes=1)   # facade-supplied paths
    v1 = svc.materialize([VersionRequest("up", 1)])[0]
    assert svc.pool.stats["spills"] >= 1
    assert "up" not in gs.stores                      # live dict shared
    gs.add_release("up", 2, ">A x\nACDE\n>C z\nGGGG\n", parser_name="fasta")
    v2 = svc.materialize([VersionRequest("up", 2)])[0]
    assert sorted(v2.keys) == [b"A", b"C"]            # fresh, not stale
    assert sorted(v1.keys) == [b"A", b"B"]


def test_pool_add_replacing_name_advances_epoch_floor(rng):
    st1 = mk_store("H", rng)
    pool = TieredStorePool({"H": st1})
    high = pool["H"].log_epoch
    st2 = mk_store("H", rng, releases=1)              # fresh, lower epoch
    assert st2.log_epoch < high
    pool.add("H", st2)
    assert pool["H"].log_epoch > high                 # no (name, epoch) alias


def test_service_over_initially_empty_mapping_still_uses_pool(rng, tmp_path):
    """An empty pool is falsy (it defines __len__): the service must still
    route requests through it, or a store spilled out of the shared dict
    raises KeyError on the next request instead of lazily reloading."""
    stores = {}
    svc = GeStoreService(stores, memory_budget_bytes=1,
                         spill_root=str(tmp_path))
    assert svc._stores is svc.pool
    svc.pool.add("Z", mk_store("Z", rng))
    v1 = svc.materialize([VersionRequest("Z", 20, ("a",))])[0]
    assert svc.pool.stats["spills"] >= 1   # flush() enforced the budget
    v2 = svc.materialize([VersionRequest("Z", 20, ("a",))])[0]
    assert v2.keys == v1.keys
    assert np.array_equal(v2.values["a"], v1.values["a"])


def test_spill_paths_never_collide_for_sanitized_names(rng, tmp_path):
    """'a/b' and 'a_b' sanitize to the same filesystem name; their spill
    directories must differ or the second spill destroys the first."""
    stores = {"a/b": mk_store("a/b", rng), "a_b": mk_store("a_b", rng)}
    wants = {n: st.get_version(20, fields=["a"]) for n, st in stores.items()}
    pool = TieredStorePool(stores, budget_bytes=1, spill_root=str(tmp_path))
    assert pool.enforce() >= 2             # both stores spill to disk
    for name, want in wants.items():
        got = pool[name].get_version(20, fields=["a"])
        assert got.keys == want.keys       # keys embed the store name
        assert np.array_equal(got.values["a"], want.values["a"])


def test_failed_reload_keeps_spill_record(rng, tmp_path):
    """A reload that raises (corrupt segments) must keep the spill record:
    every access re-raises the corruption, never a masking KeyError."""
    import glob
    import pytest
    from repro.core.segments import CorruptSegmentError

    pool = TieredStorePool({"K": mk_store("K", rng)}, budget_bytes=1,
                           spill_root=str(tmp_path))
    assert pool.enforce() >= 1
    seg = glob.glob(str(tmp_path / "**" / "segments" / "**" / "*.npz"),
                    recursive=True)[0]
    with open(seg, "r+b") as f:            # torn write: truncate a segment
        f.truncate(8)
    for _ in range(2):                     # second access must not KeyError
        with pytest.raises(CorruptSegmentError):
            pool["K"]
    assert "K" in pool


def test_store_nbytes_tracks_superlog(rng):
    st = mk_store("G", rng)
    host_only = st.nbytes()
    assert host_only["device"] == 0
    st.get_versions([10, 20], fields=["a"])   # builds + uploads superlog
    with_dev = st.nbytes()
    assert with_dev["device"] > 0
    st.drop_superlog()
    assert st.nbytes()["device"] == 0


# -- fault injection: segment reads failing mid-wave --------------------------

import pytest

from repro.core.segments import CorruptSegmentError, store_dir_name


@pytest.fixture
def chaos(monkeypatch):
    """Arm per-store segment-read fault injection. Set ``state["target"]``
    to a store name and ``state["exc"]`` to the instance to raise; every
    segment read under that store's directory then fails. Reset
    ``target`` to None to heal."""
    import repro.core.segments as segments

    state = {"target": None, "exc": CorruptSegmentError("injected"),
             "hits": 0}
    real = segments.read_segment

    def wrapped(root, *args, **kwargs):
        t = state["target"]
        if t is not None and store_dir_name(t) in str(root):
            state["hits"] += 1
            raise state["exc"]
        return real(root, *args, **kwargs)

    monkeypatch.setattr(segments, "read_segment", wrapped)
    return state


@pytest.mark.parametrize("exc", [CorruptSegmentError("injected bit rot"),
                                 OSError("injected disk failure")])
def test_chaos_wave_fails_only_affected_group(rng, tmp_path, chaos, exc):
    """A segment read failing mid-wave fails exactly the requests touching
    that store; the rest of the wave is served and the pool stays
    consistent (the spill record survives, so the error keeps surfacing
    instead of decaying into a KeyError)."""
    from concurrent.futures import Future

    stores = {"A": mk_store("A", rng), "B": mk_store("B", rng)}
    want_a = stores["A"].get_version(20, fields=["a"])
    want_b = stores["B"].get_version(20, fields=["a"])
    svc = GeStoreService(stores, memory_budget_bytes=1,
                         spill_root=str(tmp_path))
    assert svc.pool.enforce() >= 2        # both stores fully on disk

    from repro.obs import RECORDER
    RECORDER.clear()                      # isolate this test's events

    chaos["target"], chaos["exc"] = "A", exc
    items = [(VersionRequest("A", 20, ("a",)), Future()),
             (VersionRequest("B", 20, ("a",)), Future())]
    svc.serve_wave(items)
    with pytest.raises(type(exc)):
        items[0][1].result(0)
    assert chaos["hits"] >= 1

    # the injected failure is reconstructable from the flight recorder:
    # the segment-read error (with the segment path) AND the wave-level
    # failure (store + error + blast radius) are both in the dump
    dump = RECORDER.dump()
    seg_errs = [e for e in dump["events"] if e["kind"] == "segment_read_error"]
    assert seg_errs and "injected" in seg_errs[0]["error"]
    assert store_dir_name("A") in seg_errs[0]["root"]
    wave_errs = [e for e in dump["events"] if e["kind"] == "wave_error"]
    assert wave_errs and wave_errs[0]["store"] == "A"
    assert "injected" in wave_errs[0]["error"]
    got_b = items[1][1].result(0)         # other group served in-wave
    assert np.array_equal(got_b.values["a"], want_b.values["a"])
    assert "A" in svc.pool                # consistent: still addressable

    chaos["target"] = None                # heal the disk
    got_a = svc.materialize([VersionRequest("A", 20, ("a",))])[0]
    assert got_a.keys == want_a.keys
    assert np.array_equal(got_a.values["a"], want_a.values["a"])


def test_chaos_frontdoor_keeps_serving_other_tenants(rng, tmp_path, chaos):
    """Through the front door: one tenant's store going bad fails that
    tenant's requests with the real error while other tenants keep being
    served; after healing, the store serves byte-identical data."""
    from repro.serve import FrontDoor

    stores = {"A": mk_store("A", rng), "B": mk_store("B", rng)}
    want_a = stores["A"].get_version(30, fields=["a"])
    fd = FrontDoor(stores, memory_budget_bytes=1, spill_root=str(tmp_path))
    assert fd.service.pool.enforce() >= 2

    from repro.obs import RECORDER
    RECORDER.clear()

    chaos["target"] = "A"
    doomed = fd.submit("tenant-a", "A", 30)
    fine = fd.submit("tenant-b", "B", 30)
    fd.pump()
    with pytest.raises(CorruptSegmentError):
        doomed.result(0)

    # end-to-end trace: the segment failure carries the trace id minted
    # for tenant-a's request at submit (the wave span propagated it), so
    # the dump alone answers "whose request died, and where"
    events = RECORDER.dump()["events"]
    seg_errs = [e for e in events if e["kind"] == "segment_read_error"]
    assert seg_errs and seg_errs[0].get("trace", "").startswith("req-")
    wave_errs = [e for e in events if e["kind"] == "wave_error"]
    assert wave_errs and wave_errs[0]["store"] == "A"
    assert wave_errs[0]["trace"] == seg_errs[0]["trace"]
    doomed_spans = [e for e in events if e["kind"] == "span"
                    and e["name"] == "read_wave"
                    and e["trace"] == seg_errs[0]["trace"]]
    assert doomed_spans and doomed_spans[0]["tenant"] == "tenant-a"
    assert len(fine.result(0).keys) == 120
    s = fd.stats()
    assert s["counters"]["failed"] == 1
    assert s["per_tenant"]["tenant-a"]["failed"] == 1
    assert s["per_tenant"]["tenant-b"]["completed"] == 1

    chaos["target"] = None
    healed = fd.submit("tenant-a", "A", 30)
    fd.pump()
    got = healed.result(0)
    assert got.keys == want_a.keys
    assert np.array_equal(got.values["a"], want_a.values["a"])
    assert fd.stats()["per_tenant"]["tenant-a"]["completed"] == 1
