"""Multi-device CPU tests (8 host devices via subprocess isolation — the
main pytest process must keep seeing exactly 1 device)."""
import os
import subprocess
import sys
import textwrap


SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_with_devices(body: str, n: int = 8) -> str:
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={n}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_8dev():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import get_smoke_config, RunConfig
        from repro.launch.steps import make_train_step, default_hyper
        from repro.launch.mesh import make_test_mesh
        from repro.models import build
        from repro.sharding import abstract_tree, shard_batch_specs, tree_shardings
        from repro.train.optimizer import state_specs, init_state
        from repro.models import batch_specs
        from repro.configs.base import ShapeConfig

        cfg = get_smoke_config('llama3.2-1b')
        run = RunConfig(attn_impl='xla')
        mesh = make_test_mesh()
        bundle = build(cfg)
        hyper = default_hyper(cfg, run)
        with mesh:
            params = bundle.init(jax.random.key(0))
            pshard = tree_shardings(bundle.spec, mesh)
            params = jax.device_put(params, pshard)
            opt = init_state(params, hyper)
            oshard = tree_shardings(state_specs(bundle.spec, hyper), mesh)
            opt = jax.device_put(opt, oshard)
            state = {'params': params, 'opt': opt}
            rng = np.random.default_rng(0)
            batch = {'tokens': jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
                     'labels': jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
            step = jax.jit(make_train_step(cfg, run, hyper), donate_argnums=(0,))
            state, m = step(state, batch)
            l1 = float(m['loss'])
            state, m = step(state, batch)
            l2 = float(m['loss'])
        assert np.isfinite(l1) and np.isfinite(l2) and l2 < l1, (l1, l2)
        # compare with single-logical-device result
        print('SHARDED_OK', l1)
    """)
    assert "SHARDED_OK" in out


def test_sharded_matches_unsharded_loss():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import get_smoke_config
        from repro.launch.mesh import make_test_mesh
        from repro.models import build
        from repro.sharding import tree_shardings

        cfg = get_smoke_config('qwen2-0.5b')
        bundle = build(cfg)
        params = bundle.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        batch = {'tokens': jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
                 'labels': jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
        l_un, _ = jax.jit(lambda p, b: bundle.loss(p, b))(params, batch)
        mesh = make_test_mesh()
        with mesh:
            ps = jax.device_put(params, tree_shardings(bundle.spec, mesh))
            l_sh, _ = jax.jit(lambda p, b: bundle.loss(p, b))(ps, batch)
        d = abs(float(l_un) - float(l_sh))
        assert d < 1e-2, d
        print('PARITY_OK', d)
    """)
    assert "PARITY_OK" in out


def test_grad_compress_cross_pod_psum():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.train import grad_compress

        devs = np.asarray(jax.devices()).reshape(2, 4)
        mesh = Mesh(devs, ('pod', 'data'))
        g = jax.random.normal(jax.random.key(0), (2, 64))  # per-pod grads

        def body(g_local, e_local):
            deq, e = grad_compress.compress_grads({'w': g_local}, {'w': e_local})
            out = grad_compress.podwise_mean(deq, 'pod')
            return out['w'], e['w']

        f = shard_map(body, mesh=mesh, in_specs=(P('pod'), P('pod')),
                      out_specs=(P('pod'), P('pod')))
        e0 = jnp.zeros((2, 64))
        out, e = f(g, e0)
        want = jnp.mean(g, axis=0)
        err = float(jnp.max(jnp.abs(out[0] - want)))
        scale = float(jnp.max(jnp.abs(g))) / 127
        assert err < 2.1 * scale, (err, scale)   # int8 quantization bound
        print('PSUM_OK', err)
    """)
    assert "PSUM_OK" in out


def test_dryrun_cell_small_mesh():
    """End-to-end dry-run machinery on an 8-device mesh with a smoke config
    (the 512-device production run is exercised by launch/dryrun.py)."""
    out = run_with_devices("""
        import jax, numpy as np
        from repro.configs.base import get_smoke_config, RunConfig, ShapeConfig
        from repro.launch.steps import make_train_step, default_hyper
        from repro.launch.mesh import make_test_mesh
        from repro.models import build, batch_specs
        from repro.sharding import abstract_tree, shard_batch_specs
        from repro.train.optimizer import state_specs
        from repro.launch import roofline as rl

        cfg = get_smoke_config('jamba-v0.1-52b')
        shape = ShapeConfig('t', 64, 8, 'train')
        run = RunConfig(attn_impl='xla')
        mesh = make_test_mesh()
        bundle = build(cfg)
        hyper = default_hyper(cfg, run)
        with mesh:
            state = {'params': abstract_tree(bundle.spec, mesh),
                     'opt': abstract_tree(state_specs(bundle.spec, hyper), mesh)}
            batch = shard_batch_specs(batch_specs(cfg, shape), mesh)
            step = make_train_step(cfg, run, hyper)
            lowered = jax.jit(step, donate_argnums=(0,)).lower(state, batch)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            coll = rl.collective_bytes(compiled.as_text())
        if isinstance(cost, (list, tuple)):   # pre-0.5 jax returns [dict]
            cost = cost[0] if cost else {}
        assert cost.get('flops', 0) > 0
        print('DRYRUN_OK', int(cost['flops']), coll['n_ops'])
    """)
    assert "DRYRUN_OK" in out


def test_elastic_restore_across_mesh_sizes():
    out = run_with_devices("""
        import tempfile, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs.base import get_smoke_config
        from repro.ft.checkpoint import CheckpointManager
        from repro.ft.elastic import choose_mesh_shape, restore_elastic
        from repro.models import build
        from repro.sharding import tree_shardings

        cfg = get_smoke_config('olmo-1b')
        bundle = build(cfg)
        params = bundle.init(jax.random.key(0))
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, async_save=False)
            # save while sharded on an 8-device mesh
            devs = np.asarray(jax.devices()).reshape(2, 4)
            mesh8 = Mesh(devs, ('data', 'model'))
            p8 = jax.device_put(params, tree_shardings(bundle.spec, mesh8))
            cm.save(1, p8)
            # restore onto a 4-device mesh (elastic shrink)
            assert choose_mesh_shape(4, prefer_model=4) == (1, 4)
            devs4 = np.asarray(jax.devices()[:4]).reshape(1, 4)
            mesh4 = Mesh(devs4, ('data', 'model'))
            p4 = restore_elastic(cm, 1, params, bundle.spec, mesh4)
            same = jax.tree_util.tree_map(
                lambda a, b: np.allclose(np.asarray(a), np.asarray(b)),
                params, p4)
            assert all(jax.tree_util.tree_leaves(same))
        print('ELASTIC_OK')
    """)
    assert "ELASTIC_OK" in out
