"""Front door (serve/frontdoor.py): admission control, priority/deadline
scheduling, rider batching, fairness, backpressure, and the concurrency
stress suite vs a serial oracle replay.

Determinism: scheduling tests drive the door with ``pump()`` (no thread)
and an injected fake clock, so wave formation is a pure function of the
submission sequence. The stress test uses the background dispatcher with
seeded per-thread workloads and checks results against a serial replay.
"""
import threading

import numpy as np
import pytest

from _hyp import given, settings, st  # optional-hypothesis shim
from repro.core.store import FieldSchema, VersionedStore
from repro.serve import (DeadlineExceeded, FrontDoor, FrontDoorConfig,
                         Overloaded, QueueFull)

SEED = 20260808


def mk_store(name, seed, n=24, releases=3, width=4):
    rng = np.random.default_rng(seed)
    st_ = VersionedStore(name, [FieldSchema("a", width, "int32")])
    keys = [f"{name}-k{i}" for i in range(n)]
    for v in range(1, releases + 1):
        st_.update(v * 10, keys,
                   {"a": rng.integers(0, 99, (n, width)).astype(np.int32)})
    return st_


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


# -- batching + scheduling (deterministic, caller-pumped) ---------------------

def test_riders_share_one_wave_and_one_view():
    fd = FrontDoor({"G": mk_store("G", SEED)})
    f1 = fd.submit("alice", "G", 20)
    f2 = fd.submit("bob", "G", 20)
    f3 = fd.submit("carol", "G", 30)    # same group, different plan key
    assert fd.pump() == 1               # one wave serves all three
    assert f1.result(0) is f2.result(0)  # memoized view shared
    assert len(f3.result(0).keys) == 24
    log = fd.dispatch_log
    assert len(log) == 1 and sorted(log[0]["members"]) == [1, 2, 3]
    assert sorted(log[0]["riders"]) == [2, 3]
    assert fd.counters["riders"] == 2 and fd.counters["waves"] == 1


def test_priority_orders_dispatch_within_tenant():
    stores = {n: mk_store(n, SEED + i) for i, n in enumerate("ABC")}
    fd = FrontDoor(stores)
    fd.submit("t", "A", 10, priority=0)
    fd.submit("t", "B", 10, priority=9)
    fd.submit("t", "C", 10, priority=4)
    fd.pump()
    order = [d["store"] for d in fd.dispatch_log]
    assert order == ["B", "C", "A"]


def test_same_priority_is_fifo_and_mutations_dispatch_alone():
    fd = FrontDoor({"G": mk_store("G", SEED)})
    keys = [f"G-k{i}" for i in range(4)]
    tbl = {"a": np.ones((4, 4), np.int32)}
    f1 = fd.submit_update("w", "G", 40, keys, tbl, full_release=False)
    f2 = fd.submit_update("w", "G", 50, keys, tbl, full_release=False)
    f3 = fd.submit("w", "G", 50)
    fd.pump()
    # same priority = pure FIFO by submit order; mutations run alone
    assert [d["kind"] for d in fd.dispatch_log] == [
        "update", "update", "get_versions"]
    assert [len(d["members"]) for d in fd.dispatch_log] == [1, 1, 1]
    assert f1.result(0).ts == 40 and f2.result(0).ts == 50
    assert len(f3.result(0).keys) == 24


def test_read_your_writes():
    fd = FrontDoor({"G": mk_store("G", SEED)})
    keys = [f"G-k{i}" for i in range(24)]
    fut = fd.submit_update("w", "G", 40, keys,
                           {"a": np.full((24, 4), 7, np.int32)})
    fd.pump()
    fut.result(0)                      # mutation visible once resolved
    got = fd.submit("r", "G", 40)
    fd.pump()
    assert (got.result(0).values["a"] == 7).all()


def test_fairness_bounded_interleave():
    stores = {"A": mk_store("A", SEED), "B": mk_store("B", SEED + 1)}
    # max_wave=1: no riders, every request is its own wave
    fd = FrontDoor(stores, config=FrontDoorConfig(max_wave=1))
    for _ in range(10):
        fd.submit("big", "A", 20)
    for _ in range(3):
        fd.submit("small", "B", 20)
    fd.pump()
    tenants = [d["tenant"] for d in fd.dispatch_log]
    assert len(tenants) == 13
    # round-robin: while both are pending, no tenant waits more than
    # n_tenants waves between dispatches
    small_waves = [i for i, t in enumerate(tenants) if t == "small"]
    assert small_waves[0] <= 2
    for a, b in zip(small_waves, small_waves[1:]):
        assert b - a <= 2, f"small starved between waves {a} and {b}"


def test_max_wave_caps_batch():
    fd = FrontDoor({"G": mk_store("G", SEED)},
                   config=FrontDoorConfig(max_wave=2))
    futs = [fd.submit("t", "G", 20) for _ in range(5)]
    fd.pump()
    assert fd.counters["waves"] == 3
    assert all(len(d["members"]) <= 2 for d in fd.dispatch_log)
    for f in futs:
        f.result(0)


# -- admission policy ---------------------------------------------------------

def test_queue_full_rejects_at_submit():
    fd = FrontDoor({"G": mk_store("G", SEED)},
                   config=FrontDoorConfig(max_queue_per_tenant=2))
    fd.submit("t", "G", 10)
    fd.submit("t", "G", 20)
    with pytest.raises(QueueFull):
        fd.submit("t", "G", 30)
    # bound is per tenant: another tenant still admitted
    fd.submit("u", "G", 10)
    assert fd.counters["rejected_queue_full"] == 1
    assert fd.pump() >= 1 and fd.queued() == 0
    fd.submit("t", "G", 30)            # drained queue admits again


def test_deadline_shed_via_future():
    clk = FakeClock()
    fd = FrontDoor({"G": mk_store("G", SEED)},
                   config=FrontDoorConfig(clock=clk))
    doomed = fd.submit("t", "G", 20, timeout=1.0)
    alive = fd.submit("t", "G", 20)    # no deadline
    clk.t = 5.0
    fd.pump()
    with pytest.raises(DeadlineExceeded):
        doomed.result(0)
    assert len(alive.result(0).keys) == 24
    assert fd.counters["shed_deadline"] == 1
    assert fd.stats()["per_tenant"]["t"]["shed_deadline"] == 1


def test_pressure_sheds_reads_but_never_mutations(tmp_path):
    fd = FrontDoor({"G": mk_store("G", SEED)},
                   memory_budget_bytes=1 << 30, spill_root=str(tmp_path))
    fd.service.pool._thrash = 99.0     # force pressure >= shed_pressure
    assert fd.service.pool_pressure() >= fd.config.shed_pressure
    with pytest.raises(Overloaded):
        fd.submit("t", "G", 20)
    keys = [f"G-k{i}" for i in range(4)]
    fut = fd.submit_update("t", "G", 40, keys,
                           {"a": np.ones((4, 4), np.int32)},
                           full_release=False)     # ingest never shed
    fd.pump()
    assert fut.result(0).ts == 40
    assert fd.counters["rejected_pressure"] == 1


def test_pressure_degrades_wave_to_serial(tmp_path):
    # spill_root alone: a pool with no byte budget, so enforce() never
    # decays the injected pressure mid-test
    fd = FrontDoor({"G": mk_store("G", SEED)}, spill_root=str(tmp_path))
    cfg = fd.config
    # between serial_pressure and shed_pressure: admit, but don't batch
    fd.service.pool._thrash = cfg.serial_pressure * 4.0
    assert (cfg.serial_pressure <= fd.service.pool_pressure()
            < cfg.shed_pressure)
    f1 = fd.submit("a", "G", 20)
    f2 = fd.submit("b", "G", 20)       # would ride when calm
    fd.pump()
    assert fd.counters["serial_degrades"] == 2
    assert all(d["degraded"] and len(d["members"]) == 1
               for d in fd.dispatch_log)
    assert f1.result(0) is f2.result(0)   # plan cache still dedupes


def test_failed_mutation_isolated():
    fd = FrontDoor({"G": mk_store("G", SEED)})
    keys = [f"G-k{i}" for i in range(4)]
    bad = fd.submit_update("w", "G", 5, keys,       # 5 <= last_ts: rejected
                          {"a": np.ones((4, 4), np.int32)})
    ok = fd.submit("r", "G", 20)
    fd.pump()
    with pytest.raises(ValueError, match="monotonic"):
        bad.result(0)
    assert len(ok.result(0).keys) == 24
    assert fd.counters["failed"] == 1 and fd.counters["completed"] == 1


def test_cancelled_before_dispatch_skips_work():
    fd = FrontDoor({"G": mk_store("G", SEED)})
    fut = fd.submit("t", "G", 20)
    assert fut.cancel()
    fd.pump()
    assert fut.cancelled()
    assert fd.counters["cancelled"] == 1 and fd.counters["completed"] == 0


# -- stats --------------------------------------------------------------------

def test_stats_histograms_and_counters():
    fd = FrontDoor({"G": mk_store("G", SEED)})
    for i in range(4):
        fd.submit("t", "G", 20 + 10 * (i % 2))
    fd.pump()
    s = fd.stats()
    lat = s["latency"]
    for stage in ("queue", "batch", "scan", "gather", "materialize",
                  "exec", "total"):
        assert stage in lat and {"n", "p50_ms", "p99_ms"} <= set(lat[stage])
    assert lat["total"]["n"] == 4 and lat["total"]["p99_ms"] >= 0.0
    assert lat["scan"]["n"] >= 1       # cold wave really hit the scan stage
    assert s["counters"]["completed"] == 4
    assert s["per_tenant"]["t"]["completed"] == 4
    assert s["queued"] == {"t": 0}
    assert "pool_pressure" in s and s["service"]["requests"] == 0


# -- concurrency stress vs serial oracle --------------------------------------

N_READERS, READS_EACH, RELEASES = 4, 20, 5


def _writer(fd, store, tenant, seed, published, applied, lock, errors):
    wrng = np.random.default_rng(seed)
    keys = [f"{store}-k{i}" for i in range(24)]
    try:
        for r in range(RELEASES):
            ts = 40 + r * 10
            table = {"a": wrng.integers(0, 99, (24, 4)).astype(np.int32)}
            fd.submit_update(tenant, store, ts, keys, table).result(60)
            with lock:
                applied[store].append((ts, keys, table))
                published[store].append(ts)
            if r == RELEASES // 2:
                # mixed traffic includes compaction; before_ts at the
                # oldest release keeps every published ts byte-stable
                # (compact contract: get_version(t>=before_ts) unchanged)
                fd.submit_compact(tenant, store, 10).result(60)
    except Exception as e:  # noqa: BLE001 — surfaced by the main thread
        errors.append(("writer", store, e))


def _reader(fd, idx, published, lock, reads, errors):
    rrng = np.random.default_rng(SEED + 100 + idx)
    tenant = f"reader-{idx}"
    try:
        for _ in range(READS_EACH):
            store_name = ("S1", "S2")[int(rrng.integers(0, 2))]
            with lock:
                opts = published[store_name]
                ts = opts[int(rrng.integers(0, len(opts)))]
            fut = fd.submit(tenant, store_name, int(ts),
                            priority=int(rrng.integers(0, 3)))
            reads.append((store_name, int(ts), fut))
    except Exception as e:  # noqa: BLE001
        errors.append(("reader", idx, e))


def test_stress_concurrent_matches_serial_oracle():
    stores = {"S1": mk_store("S1", SEED + 1), "S2": mk_store("S2", SEED + 2)}
    fd = FrontDoor(stores, config=FrontDoorConfig(max_queue_per_tenant=4096))
    published = {"S1": [10, 20, 30], "S2": [10, 20, 30]}
    applied = {"S1": [], "S2": []}
    lock = threading.Lock()
    errors, reads = [], []

    threads = [threading.Thread(target=_writer, args=(
        fd, s, f"writer-{s}", SEED + 10 + i, published, applied, lock,
        errors)) for i, s in enumerate(("S1", "S2"))]
    threads += [threading.Thread(target=_reader, args=(
        fd, i, published, lock, reads, errors))
        for i in range(N_READERS)]

    with fd:                           # background dispatcher
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
    assert not any(t.is_alive() for t in threads), "deadlock: thread stuck"
    assert not errors, errors

    # serial oracle: fresh stores, same seeds, mutations replayed in the
    # order their futures resolved (per store = writer submission order)
    oracle = {"S1": mk_store("S1", SEED + 1), "S2": mk_store("S2", SEED + 2)}
    for name, muts in applied.items():
        for ts, keys, table in muts:
            oracle[name].update(ts, keys, table)
    assert all(len(m) == RELEASES for m in applied.values())

    assert len(reads) == N_READERS * READS_EACH
    for store_name, ts, fut in reads:
        got = fut.result(60)
        want = oracle[store_name].get_version(ts, fields=["a"])
        assert [bytes(k) for k in got.keys] == [bytes(k) for k in want.keys]
        assert np.array_equal(got.values["a"], want.values["a"]), \
            f"{store_name}@{ts}: concurrent result diverged from oracle"

    s = fd.stats()
    assert s["counters"]["failed"] == 0
    assert s["counters"]["completed"] == (
        len(reads) + 2 * RELEASES + 2)  # reads + updates + compacts
    # every tenant that submitted got served
    assert len(s["per_tenant"]) == N_READERS + 2


# -- property test: admission + ordering policy (optional hypothesis) ---------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2),                    # tenant
                          st.integers(0, 2),                    # store
                          st.integers(-2, 5),                   # priority
                          st.one_of(st.none(),
                                    st.integers(-5, 5))),       # deadline
                min_size=1, max_size=32))
def test_property_shed_policy_and_priority_order(stream):
    stores = {f"P{i}": mk_store(f"P{i}", SEED + i, n=4, releases=1)
              for i in range(3)}
    clk = FakeClock(0.0)
    fd = FrontDoor(stores, config=FrontDoorConfig(
        clock=clk, max_queue_per_tenant=4096))
    tickets = {}
    for seq0, (tenant, store, prio, dl) in enumerate(stream):
        fut = fd.submit(f"t{tenant}", f"P{store}", 10, priority=prio,
                        timeout=None if dl is None else float(dl))
        tickets[seq0 + 1] = (f"t{tenant}", f"P{store}", prio,
                             None if dl is None else float(dl), fut)
    clk.t = 1.0
    fd.pump()

    for seq, (tenant, store, prio, dl, fut) in tickets.items():
        assert fut.done(), f"request {seq} neither served nor shed"
        # documented admission policy: the ONLY asynchronous shed is a
        # deadline in the past when the scheduler considered the request
        if dl is not None and dl < clk.t:
            assert isinstance(fut.exception(), DeadlineExceeded), seq
        else:
            assert fut.exception() is None, fut.exception()

    # per tenant, wave initiators follow (-priority, deadline, seq):
    # removals (riders, sheds) never reorder the remaining queue
    by_tenant = {}
    for d in fd.dispatch_log:
        by_tenant.setdefault(d["tenant"], []).append(d["initiator"])
    for tenant, seqs in by_tenant.items():
        keys = []
        for seq in seqs:
            _, _, prio, dl, _ = tickets[seq]
            keys.append((-prio, dl if dl is not None else float("inf"), seq))
        assert keys == sorted(keys), f"{tenant}: initiators out of order"

    # riders only ever join a wave for their own group
    for d in fd.dispatch_log:
        stores_in_wave = {tickets[m][1] for m in d["members"]}
        assert len(stores_in_wave) == 1
