"""Sharded meta-database engine (core/shard.py + kernels/shard_route.py):
routing stability, byte-identical scatter-gather equivalence with the
unsharded store, per-shard persistence, and tiered-memory integration."""
import numpy as np
import pytest

from _hyp import given, settings, st as hst

from repro.core.shard import ShardedStore, open_any_store
from repro.core.store import FieldSchema, VersionedStore
from repro.kernels import ref
from repro.kernels.shard_route import (key_lanes, merge_shard_rows,
                                       route_keys, shard_route)

SCHEMA = [FieldSchema("seq", 6, "int32"), FieldSchema("len", 1, "int32")]
SHARD_COUNTS = (1, 2, 5)


# -- routing ------------------------------------------------------------------

def test_route_width_stable():
    """The same key routes identically no matter how wide its batch was
    padded — the property that makes the hash a persistent partitioner."""
    keys = [b"", b"a", b"a\x00\x00\x00\x00", b"P12345",
            b"a-much-longer-key-with-\x00-bytes-inside-it"]
    batch = route_keys(keys, 7)
    solo = np.array([route_keys([k], 7)[0] for k in keys])
    assert np.array_equal(batch, solo)


def test_route_kernel_matches_ref():
    keys = [f"K{i:06d}".encode() for i in range(1500)] + [b"", b"\x00\x00"]
    lanes, lens = key_lanes(keys)
    import jax.numpy as jnp
    got = shard_route(jnp.asarray(lanes), jnp.asarray(lens), 5,
                      interpret=True)
    want = ref.ref_shard_route(jnp.asarray(lanes), jnp.asarray(lens), 5)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(want).min() >= 0 and np.asarray(want).max() < 5


def test_route_reasonably_balanced():
    r = route_keys([f"P{i:08d}".encode() for i in range(5000)], 4)
    counts = np.bincount(r, minlength=4)
    assert counts.min() > 5000 / 4 * 0.7  # no pathological skew


def test_route_rejects_bad_shard_count():
    with pytest.raises(ValueError):
        route_keys([b"k"], 0)


def test_merge_shard_rows_reproduces_global_order():
    parts = [np.array([0, 3, 9]), np.array([], np.int64), np.array([1, 4])]
    rows, order = merge_shard_rows(parts)
    assert rows.tolist() == [0, 1, 3, 4, 9]
    assert np.concatenate(parts)[order].tolist() == rows.tolist()


# -- equivalence --------------------------------------------------------------

def assert_view_equal(a, b):
    assert a.ts == b.ts and a.keys == b.keys
    assert np.array_equal(a.row_idx, b.row_idx)
    assert a.row_idx.dtype == b.row_idx.dtype
    assert set(a.values) == set(b.values)
    for f in a.values:
        assert a.values[f].dtype == b.values[f].dtype, f
        assert np.array_equal(a.values[f], b.values[f]), f


def assert_inc_equal(a, b):
    assert (a.t0, a.t1, a.keys) == (b.t0, b.t1, b.keys)
    assert np.array_equal(a.row_idx, b.row_idx)
    assert np.array_equal(a.kind, b.kind)
    for f in a.values:
        assert np.array_equal(a.values[f], b.values[f]), f


def scripted_history(store, rng):
    """Releases exercising new/updated/deleted rows, schema evolution with
    int64 narrowing, patch semantics, and explicit deletes."""
    keys = [f"K{i:04d}" for i in range(30)]
    t1 = {"seq": rng.integers(0, 9, (30, 6)).astype(np.int32),
          "len": rng.integers(1, 9, (30, 1)).astype(np.int32)}
    infos = [store.update(10, keys, t1)]
    keys2 = keys[:24] + ["N0", "N1", "N2"]
    t2 = {"seq": np.concatenate(
              [t1["seq"][:24], rng.integers(0, 9, (3, 6))]).astype(np.int32),
          "len": np.concatenate(
              [t1["len"][:24], rng.integers(1, 9, (3, 1))]).astype(np.int32),
          "ann": np.arange(27 * 2).reshape(27, 2)}  # int64 -> int32 narrowing
    t2["seq"][5] += 1
    infos.append(store.update(20, keys2, t2))
    infos.append(store.delete(25, ["K0003", "N1"]))
    infos.append(store.update(
        30, ["K0001", "Z9"],
        {"seq": rng.integers(0, 9, (2, 6)).astype(np.int32),
         "len": np.ones((2, 1), np.int32),
         "ann": np.zeros((2, 2), np.int32)},
        full_release=False))
    return infos


def mk_pair(n_shards):
    a = VersionedStore("up", SCHEMA)
    b = ShardedStore("up", SCHEMA, n_shards=n_shards)
    ia = scripted_history(a, np.random.default_rng(7))
    ib = scripted_history(b, np.random.default_rng(7))
    return a, b, ia, ib


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_scatter_gather_equivalence(n_shards):
    a, b, ia, ib = mk_pair(n_shards)
    assert ia == ib  # VersionInfo counts aggregate exactly
    ts = [10, 20, 25, 30]
    for va, vb in zip(a.get_versions(ts), b.get_versions(ts)):
        assert_view_equal(va, vb)
    for va, vb in zip(a.get_versions(ts, include_deleted=True),
                      b.get_versions(ts, include_deleted=True)):
        assert_view_equal(va, vb)
    pairs = [(10, 20), (20, 25), (10, 30), (25, 30), (10, 20)]
    for xa, xb in zip(a.get_increments(pairs, significant_fields=["seq"]),
                      b.get_increments(pairs, significant_fields=["seq"])):
        assert_inc_equal(xa, xb)


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_equivalence_with_filter_and_fields(n_shards):
    a, b, _, _ = mk_pair(n_shards)
    va = a.get_versions([20, 30], fields=["seq"], key_filter=b"K000")
    vb = b.get_versions([20, 30], fields=["seq"], key_filter=b"K000")
    for x, y in zip(va, vb):
        assert_view_equal(x, y)
    xa = a.get_increment(10, 30, fields=[])
    xb = b.get_increment(10, 30, fields=[])
    assert_inc_equal(xa, xb)


def test_save_load_round_trip_equivalence(tmp_path):
    a, b, _, _ = mk_pair(3)
    b.save(str(tmp_path / "up"))
    b2 = ShardedStore.load(str(tmp_path / "up"))
    for t in (10, 20, 25, 30):
        assert_view_equal(a.get_version(t), b2.get_version(t))
    assert_inc_equal(a.get_increment(10, 30), b2.get_increment(10, 30))
    # incremental per-shard save after reload-and-mutate
    b2.update(40, ["K0001"], {"seq": np.ones((1, 6), np.int32),
                              "len": np.ones((1, 1), np.int32),
                              "ann": np.ones((1, 2), np.int32)},
              full_release=False)
    stats = b2.save(str(tmp_path / "up"))
    assert stats["mode"] == "incremental"
    a.update(40, ["K0001"], {"seq": np.ones((1, 6), np.int32),
                             "len": np.ones((1, 1), np.int32),
                             "ann": np.ones((1, 2), np.int32)},
             full_release=False)
    b3 = open_any_store(str(tmp_path / "up"))
    assert isinstance(b3, ShardedStore)
    assert_view_equal(a.get_version(40), b3.get_version(40))


def test_compact_equivalence(tmp_path):
    a, b, _, _ = mk_pair(2)
    b.save(str(tmp_path / "up"))
    sa = a.compact(22)
    sb = b.compact(22, path=str(tmp_path / "up"))
    assert sa["cells_dropped"] == sb["cells_dropped"]
    assert sa["versions_kept"] == sb["versions_kept"]
    assert [v.ts for v in a.versions] == [v.ts for v in b.versions]
    assert a.versions[0].n_entries == b.versions[0].n_entries
    for t in (25, 30):
        assert_view_equal(a.get_version(t), b.get_version(t))
    b2 = ShardedStore.load(str(tmp_path / "up"))
    for t in (25, 30):
        assert_view_equal(a.get_version(t), b2.get_version(t))


def test_monotonic_ts_and_unknown_key_guards():
    _, b, _, _ = mk_pair(2)
    with pytest.raises(ValueError):
        b.update(30, ["X"], {"seq": np.zeros((1, 6), np.int32),
                             "len": np.zeros((1, 1), np.int32)})
    epoch = b.log_epoch
    with pytest.raises(KeyError):
        b.delete(50, ["NEVER-SEEN"])
    assert b.log_epoch == epoch  # guard fired before any shard mutated


def test_load_rejects_foreign_routing(tmp_path):
    import json, os
    _, b, _, _ = mk_pair(2)
    b.save(str(tmp_path / "up"))
    p = os.path.join(str(tmp_path / "up"), "SHARD_MANIFEST.json")
    man = json.load(open(p))
    man["routing"] = "some-other-hash-v9"
    json.dump(man, open(p, "w"))
    with pytest.raises(ValueError, match="routing"):
        ShardedStore.load(str(tmp_path / "up"))


# -- epoch contract + tiered memory ------------------------------------------

def test_epoch_monotone_and_floorable():
    _, b, _, _ = mk_pair(2)
    e0 = b.log_epoch
    b.update(50, ["K0000"], {"seq": np.zeros((1, 6), np.int32),
                             "len": np.zeros((1, 1), np.int32),
                             "ann": np.zeros((1, 2), np.int32)},
             full_release=False)
    e1 = b.log_epoch
    assert e1 > e0
    b._log_epoch = e1 + 100           # the pool's floor assignment
    assert b.log_epoch == e1 + 100


def test_shard_spill_partial_residency(tmp_path):
    a, b, _, _ = mk_pair(3)
    b.save(str(tmp_path / "up"))
    e0 = b.log_epoch
    freed = b.spill_shard()
    assert freed and freed > 0
    assert len(b.resident_shard_ids()) == 2
    assert b.nbytes()["host"] > 0
    assert b.log_epoch >= e0            # spilled shard's epoch is frozen in
    assert_view_equal(a.get_version(20), b.get_version(20))  # lazy reload
    assert len(b.resident_shard_ids()) == 3
    while b.spill_shard() is not None:
        pass
    assert b.resident_shard_ids() == []
    assert b.nbytes() == {"host": 0, "device": 0}
    assert_view_equal(a.get_version(30), b.get_version(30))


def test_pool_spills_sharded_store_shard_by_shard(tmp_path):
    from repro.serve import TieredStorePool
    a, b, _, _ = mk_pair(3)
    want = a.get_version(20)
    pool = TieredStorePool({"up": b},
                           budget_bytes=sum(b.nbytes().values()) - 1,
                           spill_root=str(tmp_path))
    assert pool.enforce() >= 1
    assert pool.stats["shard_spills"] >= 1
    assert pool.stats["spills"] == 0          # facade stays admitted
    assert len(b.resident_shard_ids()) < 3    # partial residency
    assert_view_equal(want, pool["up"].get_version(20))


def test_service_over_sharded_store(tmp_path):
    from repro.serve import GeStoreService
    from repro.serve.gestore_service import VersionRequest
    a, b, _, _ = mk_pair(2)
    svc = GeStoreService({"up": b}, memory_budget_bytes=1,
                         spill_root=str(tmp_path))
    got = svc.materialize([VersionRequest("up", 20, ("seq",)),
                           VersionRequest("up", 30, ("seq",))])
    want = a.get_versions([20, 30], fields=["seq"])
    for w, g in zip(want, got):
        assert w.keys == g.keys
        assert np.array_equal(w.values["seq"], g.values["seq"])
    assert svc.pool.stats["shard_spills"] >= 1
    got2 = svc.materialize([VersionRequest("up", 20, ("seq",))])[0]
    assert got2.keys == want[0].keys          # post-spill reload serves same


def test_spill_keeps_directory_loadable(tmp_path):
    """A per-shard spill must commit a manifest consistent with every
    shard directory: a fresh process opening the store right after the
    spill sees the post-mutation state, never a bricked or stale one."""
    _, b, _, _ = mk_pair(3)
    b.save(str(tmp_path / "up"))
    b.update(50, ["NEWKEY"], {"seq": np.ones((1, 6), np.int32),
                              "len": np.ones((1, 1), np.int32),
                              "ann": np.ones((1, 2), np.int32)},
             full_release=False)                   # not flushed yet
    assert b.spill_shard() is not None
    b2 = ShardedStore.load(str(tmp_path / "up"))   # "fresh process"
    assert b"NEWKEY" in b2.key_to_row
    assert_view_equal(b.get_version(50), b2.get_version(50))


def test_rejected_release_registers_no_phantom_fields_sharded():
    _, b, _, _ = mk_pair(2)
    with pytest.raises(ValueError, match="int32 range"):
        b.update(99, ["K0000"],
                 {"newf": np.ones((1, 1), np.int32),
                  "len": np.full((1, 1), 2**40, np.int64)},
                 full_release=False)
    with pytest.raises(TypeError):                 # unconvertible key
        b.update(99, ["K0000", 3.5],
                 {"newf": np.ones((2, 1), np.int32),
                  "len": np.ones((2, 1), np.int32),
                  "seq": np.ones((2, 6), np.int32),
                  "ann": np.ones((2, 2), np.int32)},
                 full_release=False)
    assert "newf" not in b.schema
    for s in range(b.n_shards):
        assert "newf" not in b.shard(s).fields


def test_save_to_new_dir_with_spilled_shards(tmp_path):
    """Saving a partially spilled store to a DIFFERENT directory must
    write every shard there (reloading spilled ones), and spilling into a
    new root must not skip the save that makes the shard reloadable."""
    a, b, _, _ = mk_pair(3)
    b.save(str(tmp_path / "A"))
    assert b.spill_shard() is not None            # shard 0 lives in A only
    b.save(str(tmp_path / "B"))                   # "backup" to a new dir
    b2 = ShardedStore.load(str(tmp_path / "B"))
    assert_view_equal(a.get_version(30), b2.get_version(30))
    # clean store, spill retargeted to a fresh root: must save there first
    c = ShardedStore.load(str(tmp_path / "B"))
    assert c.spill_shard(root=str(tmp_path / "C")) is not None
    assert_view_equal(a.get_version(30), c.get_version(30))


def test_monotonic_floor_sees_spilled_shards(tmp_path):
    """A crash-skewed shard that is currently spilled must still raise
    the monotonicity error BEFORE the facade allocates rows or mutates
    other shards (the floor is computed after residency is forced)."""
    _, b, _, _ = mk_pair(2)
    b.save(str(tmp_path / "up"))
    b._shards[0].update(77, [], {}, full_release=False)   # simulated skew
    b.spill_shard(0)
    rows_before = list(b.row_keys)
    with pytest.raises(ValueError, match="monotonic"):
        b.update(77, ["BRANDNEW"], {"seq": np.ones((1, 6), np.int32),
                                    "len": np.ones((1, 1), np.int32),
                                    "ann": np.ones((1, 2), np.int32)},
                 full_release=False)
    assert b.row_keys == rows_before                      # no phantom rows
    for s in range(b.n_shards):
        assert b.shard(s).last_ts != 77 or s == 0         # shard 1 untouched


def test_torn_save_recovers_on_load(tmp_path):
    """save() commits shard dirs first, shard manifest last: a crash in
    between (simulated by restoring the pre-release manifest) must leave
    the store loadable, with the torn release's committed keys adopted."""
    import shutil
    _, b, _, _ = mk_pair(2)
    b.save(str(tmp_path / "up"))
    man = str(tmp_path / "up" / "SHARD_MANIFEST.json")
    shutil.copy(man, str(tmp_path / "man.bak"))
    b.update(60, ["TORNKEY"], {"seq": np.ones((1, 6), np.int32),
                               "len": np.ones((1, 1), np.int32),
                               "ann": np.ones((1, 2), np.int32)},
             full_release=False)
    b.save(str(tmp_path / "up"))
    shutil.copy(str(tmp_path / "man.bak"), man)   # crash before manifest
    b2 = ShardedStore.load(str(tmp_path / "up"))
    assert b"TORNKEY" in b2.key_to_row            # adopted, not bricked
    v = b2.get_version(60)
    assert b"TORNKEY" in v.keys
    # recovered facade is save-dirty: the next spill re-commits the manifest
    assert b2.spill_shard() is not None
    b3 = ShardedStore.load(str(tmp_path / "up"))
    assert b"TORNKEY" in b3.key_to_row


def test_pool_drops_fully_spilled_facade(tmp_path):
    """Once every shard is on disk the facade itself leaves the pool (its
    key index is host memory too) and reloads transparently."""
    from repro.serve import TieredStorePool
    a, b, _, _ = mk_pair(2)
    want = a.get_version(30)
    pool = TieredStorePool({"up": b}, budget_bytes=1,
                           spill_root=str(tmp_path))
    assert pool.enforce() >= 2
    assert "up" not in pool._stores and "up" in pool
    re = pool["up"]                               # sharded reload
    assert isinstance(re, ShardedStore)
    assert_view_equal(want, re.get_version(30))


def test_corrupt_shard_fails_before_any_mutation(tmp_path):
    """A shard whose reload raises (corrupt segment) must abort update()
    BEFORE any other shard ingests the release — otherwise the facade's
    global row order and the shard histories desync for good."""
    import glob
    from repro.core.segments import CorruptSegmentError
    _, b, _, _ = mk_pair(3)
    b.save(str(tmp_path / "up"))
    while b.spill_shard() is not None:
        pass
    seg = sorted(glob.glob(str(tmp_path / "up" / "shard-00001" / "segments"
                               / "**" / "*.npz"), recursive=True))[0]
    with open(seg, "r+b") as f:
        f.truncate(8)                              # torn write
    versions_before = list(b.versions)
    with pytest.raises(CorruptSegmentError):
        b.update(99, ["K0000"], {"seq": np.ones((1, 6), np.int32),
                                 "len": np.ones((1, 1), np.int32),
                                 "ann": np.ones((1, 2), np.int32)},
                 full_release=False)
    assert b.versions == versions_before
    for s in b.resident_shard_ids():               # no shard saw ts=99
        assert b._shards[s].last_ts < 99


# -- device-parallel placement (core/placement.py) ----------------------------

def test_plan_placement_modes():
    """Auto plan: serial below 2 shards or with too few devices (the
    graceful fallback); force='parallel' degrades to single-device
    stacked execution instead of failing."""
    from repro.core.placement import plan_placement
    import jax
    n_dev = len(jax.devices())          # 1 in the tier-1 process
    assert plan_placement(1).mode == "serial"
    assert plan_placement(5).mode == ("mesh" if n_dev >= 5 else "serial")
    assert plan_placement(5, force="parallel").mode == (
        "mesh" if n_dev >= 5 else "stacked")
    assert plan_placement(5, force="serial").mode == "serial"
    assert plan_placement(n_dev, force="parallel").mode == (
        "mesh" if n_dev >= 2 else "serial")


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_parallel_path_byte_identical(n_shards):
    """Forced-parallel (stacked on one device) facade queries are
    byte-identical to the serial per-shard loop — the placement is pure
    execution strategy. Covers include_deleted, field subsets, filters."""
    from repro.core.placement import plan_placement
    a, b, _, _ = mk_pair(n_shards)
    a.placement = plan_placement(n_shards, force="serial")
    b.placement = plan_placement(n_shards, force="parallel")
    ts = [10, 20, 25, 30, 20]
    for va, vb in zip(a.get_versions(ts), b.get_versions(ts)):
        assert_view_equal(va, vb)
    for va, vb in zip(a.get_versions(ts, include_deleted=True),
                      b.get_versions(ts, include_deleted=True)):
        assert_view_equal(va, vb)
    for va, vb in zip(a.get_versions([20], fields=["seq"], key_filter=b"K00"),
                      b.get_versions([20], fields=["seq"], key_filter=b"K00")):
        assert_view_equal(va, vb)
    pairs = [(10, 20), (20, 25), (10, 30), (25, 30), (10, 20)]
    for xa, xb in zip(a.get_increments(pairs, significant_fields=["seq"]),
                      b.get_increments(pairs, significant_fields=["seq"])):
        assert_inc_equal(xa, xb)
    for xa, xb in zip(a.get_increments(pairs[:1], fields=[]),
                      b.get_increments(pairs[:1], fields=[])):
        assert_inc_equal(xa, xb)


def test_parallel_path_survives_spill_midsequence(tmp_path):
    """Shard eviction between parallel queries: the stacked cache is keyed
    on the per-shard epoch tuple, which spill freezes and reload floors —
    results must stay byte-identical to serial with no restack skew."""
    from repro.core.placement import plan_placement
    a, b, _, _ = mk_pair(3)
    b.placement = plan_placement(3, force="parallel")
    b.save(str(tmp_path / "up"))
    for va, vb in zip(a.get_versions([10, 20]), b.get_versions([10, 20])):
        assert_view_equal(va, vb)
    assert b.spill_shard() is not None            # evict mid-sequence
    for va, vb in zip(a.get_versions([20, 30]), b.get_versions([20, 30])):
        assert_view_equal(va, vb)
    assert_inc_equal(a.get_increment(10, 30), b.get_increment(10, 30))


def test_parallel_placed_cache_in_tiered_accounting(tmp_path):
    """The stacked cross-shard superlog counts as device state: nbytes
    reports it and drop_superlog releases it (the pool's device->host
    demotion tier must actually reclaim the memory)."""
    from repro.core.placement import plan_placement
    _, b, _, _ = mk_pair(2)
    b.placement = plan_placement(2, force="parallel")
    b.get_versions([10, 20])
    assert b._placed is not None
    assert b.has_device_state()
    assert b.nbytes()["device"] > 0
    b.drop_superlog()
    assert b._placed is None and not b.has_device_state()
    # epoch tuple unchanged after a plain rebuild => cache reused
    b.get_versions([10, 20])
    placed = b._placed
    b.get_versions([25, 30])
    assert b._placed is placed
    # a mutation moves a shard epoch => restack (multi-ts query: a single
    # cold timestamp takes the lazy per-field path, by design)
    b.update(99, ["K0000"], {"seq": np.ones((1, 6), np.int32),
                             "len": np.ones((1, 1), np.int32),
                             "ann": np.ones((1, 2), np.int32)},
             full_release=False)
    b.get_versions([99, 10])
    assert b._placed is not placed


def test_pool_pins_placement_across_spill_reload(tmp_path):
    """TieredStorePool(shard_placement=...) applies the policy to admitted
    stores AND to spill reloads — a reload must not silently re-plan."""
    from repro.serve import TieredStorePool
    a, b, _, _ = mk_pair(2)
    want = a.get_version(30)
    pool = TieredStorePool({"up": b}, budget_bytes=1,
                           spill_root=str(tmp_path),
                           shard_placement="parallel")
    assert b.placement.parallel
    assert pool.enforce() >= 2                    # fully spill the facade
    re = pool["up"]
    assert isinstance(re, ShardedStore) and re.placement.parallel
    assert_view_equal(want, re.get_version(30))


def test_service_routes_through_parallel_placement():
    """GeStoreService(shard_placement='parallel') serves byte-identical
    views through the stacked path (no memory budget needed)."""
    from repro.serve import GeStoreService
    from repro.serve.gestore_service import VersionRequest
    a, b, _, _ = mk_pair(2)
    svc = GeStoreService({"up": b}, shard_placement="parallel")
    assert b.placement.parallel
    got = svc.materialize([VersionRequest("up", 20, None),
                           VersionRequest("up", 30, None)])
    for w, g in zip(a.get_versions([20, 30]), got):
        assert_view_equal(w, g)


# -- GeStore wiring -----------------------------------------------------------

def test_gestore_creates_flushes_and_reopens_sharded(tmp_path):
    import repro.core as core
    from repro.core.parsers import FastaParser
    reg = core.PluginRegistry()
    reg.register_parser(FastaParser(seq_width=8, desc_width=2))
    gs = core.GeStore(str(tmp_path / "gs"), reg)
    gs.add_release("up", 1, ">A x\nACDE\n>B y\nACDF\n", parser_name="fasta",
                   shards=2)
    assert isinstance(gs.stores["up"], ShardedStore)
    gs.add_release("up", 2, ">A x\nACDE\n>C z\nGGGG\n", parser_name="fasta")
    stats = gs.flush()
    assert stats["up"]["n_shards"] == 2
    gs2 = core.GeStore(str(tmp_path / "gs"), reg)     # autoload
    st = gs2.open_store("up")
    assert isinstance(st, ShardedStore)
    assert sorted(st.get_version(2).keys) == [b"A", b"C"]
    inc = st.get_increment(1, 2)
    assert set(inc.keys) == {b"B", b"C"}
    with pytest.raises(ValueError):
        gs2.create_store("up", [], shards=3)          # name collision


# -- satellite: bounded VersionCache ------------------------------------------

def test_version_cache_byte_budget(tmp_path):
    from repro.core.cache import VersionCache
    cache = VersionCache(str(tmp_path / "c"), max_bytes=64)

    def put(i):
        return cache.put(f"file-{i}|0|1", lambda p: open(p, "w").write("x" * 40),
                         suffix=".txt")
    import os
    p0 = put(0)
    assert os.path.exists(p0)          # within budget
    p1 = put(1)
    assert os.path.exists(p1)          # the just-put file is protected...
    assert not os.path.exists(p0)      # ...the LRU one was evicted
    assert cache.get("file-0|0|1") is None


def test_gestore_cache_budget_wired(tmp_path):
    import repro.core as core
    from repro.core.parsers import FastaParser
    reg = core.PluginRegistry()
    reg.register_parser(FastaParser(seq_width=8, desc_width=2))
    gs = core.GeStore(str(tmp_path / "gs"), reg, cache_max_bytes=123)
    assert gs.cache.max_bytes == 123


# -- device matrix: serial == parallel across real device counts --------------
# Subprocess isolation: the device count is locked at first jax init, and
# the main pytest process must keep seeing exactly one CPU device.

def _run_with_devices(body, n):
    import subprocess, sys, textwrap
    src = __import__("os").path.abspath(
        __import__("os").path.join(__import__("os").path.dirname(__file__),
                                   "..", "src"))
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={n}'\n"
            + textwrap.dedent(body))
    import os
    env = dict(os.environ, PYTHONPATH=src)
    env.pop("GESTORE_PARALLEL", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


_DEVICE_MATRIX_BODY = """
    import numpy as np, jax, tempfile
    from repro.core.shard import ShardedStore
    from repro.core.store import FieldSchema, VersionedStore
    from repro.core.placement import plan_placement

    SCHEMA = [FieldSchema("seq", 6, "int32"), FieldSchema("len", 1, "int32")]

    def history(store, rng):
        keys = [f"K{i:04d}" for i in range(40)]
        store.update(10, keys, {"seq": rng.integers(0, 9, (40, 6)).astype(np.int32),
                                "len": rng.integers(1, 9, (40, 1)).astype(np.int32)})
        keys2 = keys[:30] + ["N0", "N1"]
        store.update(20, keys2, {"seq": rng.integers(0, 9, (32, 6)).astype(np.int32),
                                 "len": rng.integers(1, 9, (32, 1)).astype(np.int32)})
        store.delete(25, ["K0003", "N1"])
        return store

    def check(a, b):
        ts = [10, 20, 25, 20]
        for va, vb in zip(a.get_versions(ts), b.get_versions(ts)):
            assert va.keys == vb.keys
            assert np.array_equal(va.row_idx, vb.row_idx)
            for f in va.values:
                assert va.values[f].tobytes() == vb.values[f].tobytes(), f
        for xa, xb in zip(a.get_increments([(10, 20), (20, 25), (10, 25)]),
                          b.get_increments([(10, 20), (20, 25), (10, 25)])):
            assert xa.keys == xb.keys
            assert np.array_equal(xa.kind, xb.kind)
            for f in xa.values:
                assert xa.values[f].tobytes() == xb.values[f].tobytes(), f

    n_dev = len(jax.devices())
    for n_shards in (1, 2, 5):
        a = history(ShardedStore("up", SCHEMA, n_shards=n_shards),
                    np.random.default_rng(7))
        b = history(ShardedStore("up", SCHEMA, n_shards=n_shards),
                    np.random.default_rng(7))
        a.placement = plan_placement(n_shards, force="serial")
        b.placement = plan_placement(n_shards, force="parallel")
        want = ("mesh" if n_dev >= n_shards >= 2
                else "stacked" if n_shards >= 2 else "serial")
        assert b.placement.mode == want, (b.placement.mode, want)
        check(a, b)
        if n_shards >= 2:                    # spill mid-sequence, re-check
            with tempfile.TemporaryDirectory() as d:
                b.save(d + "/up")
                assert b.spill_shard() is not None
                check(a, b)
        print(f"DEV{n_dev}_S{n_shards}_{b.placement.mode}_OK")
"""


@pytest.mark.parametrize("n_devices", (1, 2, 8))
def test_device_matrix_serial_parallel_equivalence(n_devices):
    """devices x shards equivalence matrix: with d devices forced via
    XLA_FLAGS, every shard count in {1,2,5} returns byte-identical
    results under serial and device-parallel placement (mesh when d >=
    shards >= 2, stacked otherwise), including after spill_shard evicts
    a shard between queries."""
    out = _run_with_devices(_DEVICE_MATRIX_BODY, n_devices)
    for n_shards in (1, 2, 5):
        assert f"DEV{n_devices}_S{n_shards}_" in out


# -- property test: random histories (runs when hypothesis is installed) ------

@settings(max_examples=12, deadline=None)
@given(hst.data())
def test_shard_equivalence_property(data):
    """ShardedStore with N in {1,2,5} returns byte-identical
    get_versions/get_increments to an unsharded store over random
    update/delete histories, including after a save/load round trip."""
    import tempfile
    key_pool = [f"K{i:02d}".encode() for i in range(18)]
    n_rel = data.draw(hst.integers(2, 5), label="n_releases")
    history = []
    ts = 0
    for _ in range(n_rel):
        ts += data.draw(hst.integers(1, 5), label="dt")
        op = data.draw(hst.sampled_from(["full", "patch", "delete"]),
                       label="op")
        ks = data.draw(
            hst.lists(hst.sampled_from(key_pool), min_size=0, max_size=12,
                      unique=True),
            label="keys")
        vals = data.draw(
            hst.lists(hst.integers(-5, 5), min_size=len(ks) * 3,
                      max_size=len(ks) * 3),
            label="vals")
        history.append((op, ts, ks, vals))

    def build(store):
        seen = set()
        for op, t, ks, vals in history:
            if op == "delete":
                known = [k for k in ks if k in seen]
                store.delete(t, known) if known else None
                if not known:
                    store.update(t, [], {}, full_release=False)
                continue
            table = {"f": np.asarray(vals, np.int32).reshape(len(ks), 3)}
            store.update(t, ks, table, full_release=(op == "full"))
            if op == "full":
                seen -= {k for k in seen if k not in ks}
            seen |= set(ks)
        return store

    a = build(VersionedStore("p", [FieldSchema("f", 3, "int32")]))
    all_ts = [t for _, t, _, _ in history]
    pairs = [(t0, t1) for t0 in all_ts for t1 in all_ts if t0 < t1]
    for n in SHARD_COUNTS:
        b = build(ShardedStore("p", [FieldSchema("f", 3, "int32")],
                               n_shards=n))
        for va, vb in zip(a.get_versions(all_ts), b.get_versions(all_ts)):
            assert_view_equal(va, vb)
        for xa, xb in zip(a.get_increments(pairs), b.get_increments(pairs)):
            assert_inc_equal(xa, xb)
        with tempfile.TemporaryDirectory() as d:
            b.save(d + "/s")
            b2 = ShardedStore.load(d + "/s")
            for va, vb in zip(a.get_versions(all_ts),
                              b2.get_versions(all_ts)):
                assert_view_equal(va, vb)
