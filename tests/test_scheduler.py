"""serve/scheduler.py unit tests (stub engine — no model build, no jit)
plus the ServeConfig default-instance regression (serve/engine.py)."""
import inspect

import numpy as np

from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.scheduler import Request, Scheduler, _bucket


class StubEngine:
    """Duck-types the two things Scheduler touches: scfg.pad_id and
    generate(). Echoes the batch shape so tests can audit padding."""

    def __init__(self, pad_id=0, new_tokens=4):
        self.scfg = ServeConfig(pad_id=pad_id)
        self.new_tokens = new_tokens
        self.calls: list[np.ndarray] = []

    def generate(self, batch, *, seed=0):
        self.calls.append(np.array(batch))
        b = batch.shape[0]
        return np.tile(np.arange(self.new_tokens, dtype=np.int32), (b, 1))


def test_bucket_rounds_to_pow2_with_floor():
    assert _bucket(1) == 16
    assert _bucket(16) == 16
    assert _bucket(17) == 32
    assert _bucket(100) == 128


def test_submit_routes_by_bucket_and_pads():
    eng = StubEngine(pad_id=-7)
    sched = Scheduler(eng, max_batch=8)
    sched.submit("a", np.arange(5))
    sched.submit("b", np.arange(20))
    assert sorted(sched.queues) == [16, 32]
    res = sched.run_until_drained()
    assert res["n_done"] == 2
    # one batch per bucket, padded to the bucket width with pad_id
    shapes = sorted(c.shape for c in eng.calls)
    assert shapes == [(1, 16), (1, 32)]
    short = next(c for c in eng.calls if c.shape == (1, 16))
    assert np.array_equal(short[0, :5], np.arange(5))
    assert (short[0, 5:] == -7).all()


def test_max_batch_splits_full_buckets():
    eng = StubEngine()
    sched = Scheduler(eng, max_batch=2)
    for i in range(5):
        sched.submit(f"r{i}", np.arange(8))
    res = sched.run_until_drained()
    assert res["n_done"] == 5
    assert [c.shape[0] for c in eng.calls] == [2, 2, 1]
    assert set(sched.done) == {f"r{i}" for i in range(5)}
    assert all(isinstance(r, Request) and r.output is not None
               for r in sched.done.values())
    assert res["p50_latency_s"] >= 0.0 and res["p99_latency_s"] >= 0.0


def test_drain_empty_queue_reports_zero():
    sched = Scheduler(StubEngine())
    res = sched.run_until_drained()
    assert res == {"n_done": 0, "p50_latency_s": 0.0, "p99_latency_s": 0.0}


def test_serve_config_default_not_shared():
    # regression: `scfg: ServeConfig = ServeConfig()` handed every engine
    # the same instance, so one caller's knob tweak leaked into all
    sig = inspect.signature(ServeEngine.__init__)
    assert sig.parameters["scfg"].default is None
