"""Optional-hypothesis shim: property tests run when `hypothesis` is
installed and collect-but-skip on minimal environments, so tier-1
(`PYTHONPATH=src python -m pytest -x -q`) never fails at import time.

Usage in a test module:  ``from _hyp import given, settings, st``
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Placeholder: strategy objects are only consumed at decoration
        time, and the decorated tests are skipped anyway."""
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

strategies = st
