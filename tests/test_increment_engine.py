"""GeStore facade: generate/merge around unmodified tools, cache behaviour,
and the BLAST e-value merger correction (paper §III.A, §IV.B)."""
import math

import numpy as np
import pytest

import repro.core as core
from repro.core.parsers import FastaParser


def mk_fasta(n, mut=(), drop=(), rng_seed=7):
    rng = np.random.default_rng(rng_seed)
    out = []
    for i in range(n):
        # draw BEFORE the drop check: entry i's sequence must not depend on
        # which other entries are dropped
        seq = "".join(rng.choice(list("ACDEFGHIKLMNPQRSTVWY"), 30))
        if i in drop:
            continue
        if i in mut:
            seq = seq[:5] + "WWWWW" + seq[10:]
        out.append(f">SEQ{i:04d} desc {i}\n{seq}\n")
    return "".join(out)


@pytest.fixture
def gestore(tmp_path):
    reg = core.PluginRegistry()
    reg.register_parser(FastaParser(seq_width=64, desc_width=16))
    reg.register_tool(core.ToolPlugin(
        "blastp",
        core.FileGenerator(parser="fasta",
                           output_fields=["sequence", "length", "desc"],
                           significant_fields=["sequence", "length"]),
        merger=core.BlastEvalueMerger()))
    gs = core.GeStore(str(tmp_path), reg)
    gs.add_release("up", 100, mk_fasta(50), parser_name="fasta")
    gs.add_release("up", 200, mk_fasta(55, mut={3, 7}, drop={11}),
                   parser_name="fasta")
    return gs


def test_full_and_incremental_generation(gestore):
    full = gestore.generate_files("blastp", "up", t_version=100)
    assert full.mode == "full" and full.n_entries == 50
    inc = gestore.generate_files("blastp", "up", t_version=200, t_last=100)
    # 6 new (50..54, minus the dropped 11 which existed) + 2 mutated
    assert inc.mode == "increment"
    assert inc.n_entries == 5 + 2
    assert len(inc.context["deleted_keys"]) == 1
    assert len(inc.context["updated_keys"]) == 2
    assert inc.context["db_size_new"] > 0


def test_cache_hit_and_eviction(gestore):
    a = gestore.generate_files("blastp", "up", t_version=100)
    b = gestore.generate_files("blastp", "up", t_version=100)
    assert b.mode == "cached" and b.path == a.path
    assert gestore.cache.hits >= 1
    n = gestore.cache.evict(0)
    assert n >= 1
    c = gestore.generate_files("blastp", "up", t_version=100)
    assert c.mode == "full"              # regenerated after eviction


def test_pinned_version_reproducibility(gestore):
    v1a = gestore.generate_files("blastp", "up", t_version=100)
    gestore.add_release("up", 300, mk_fasta(60, mut={1}), parser_name="fasta")
    v1b = gestore.generate_files("blastp", "up", t_version=100)
    assert open(v1a.path).read() == open(v1b.path).read()


def test_taxon_filter(gestore):
    f = gestore.generate_files("blastp", "up", t_version=100,
                               key_filter=r"SEQ000")
    assert f.n_entries == 10


def test_evalue_merger_rescaling():
    m = core.BlastEvalueMerger()
    prev = "q1\tS1\t90.0\t30\t3\t0\t1\t30\t1\t30\t1.0e-10\t50.0\n" \
           "q1\tS2\t80.0\t30\t6\t0\t1\t30\t1\t30\t1.0e-05\t40.0\n"
    partial = "q1\tS3\t95.0\t30\t1\t0\t1\t30\t1\t30\t2.0e-12\t60.0\n"
    merged = m.merge(prev, partial, context={
        "db_size_old": 1000, "db_size_new": 2000,
        "deleted_keys": [b"S2"], "updated_keys": [], "max_hits_per_query": 10})
    lines = merged.strip().splitlines()
    subjects = [l.split("\t")[1] for l in lines]
    assert "S2" not in subjects             # deleted subject dropped
    assert set(subjects) == {"S1", "S3"}
    ev = {l.split("\t")[1]: float(l.split("\t")[10]) for l in lines}
    assert math.isclose(ev["S1"], 2.0e-10, rel_tol=0.05)   # rescaled 2x
    assert math.isclose(ev["S3"], 2.0e-12, rel_tol=0.05)   # fresh: untouched
    # best hit first per query
    assert subjects[0] == "S3"


def test_run_tool_provenance(gestore):
    def tool(path):
        n = open(path).read().count(">")
        return f"q1\tS1\t90.0\t{n}\t0\t0\t1\t30\t1\t30\t1e-10\t50.0\n"

    out, gen = gestore.run_tool("blastp", "up", tool, t_version=100)
    assert "q1" in out
    runs = list(gestore.tables.runs.values())
    assert any(r.tool == "blastp" and r.status == "done" for r in runs)
