"""Per-arch REDUCED-config smoke tests (assignment requirement): one
forward/train step on CPU asserting output shapes + no NaNs, for every
assigned architecture family."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, RunConfig, get_config, get_smoke_config
from repro.launch.steps import make_train_step
from repro.models import build
from repro.train.optimizer import init_state
from repro.launch.steps import default_hyper


def smoke_batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.family == "encdec":
        return {"enc_embeds": jnp.asarray(
                    rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)),
                    jnp.bfloat16),
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)),
                                      jnp.bfloat16)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                      jnp.int32)
    if cfg.mrope_sections is not None:
        batch["positions"] = jnp.asarray(np.tile(np.arange(s), (3, b, 1)),
                                         jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_finite(arch):
    cfg = get_smoke_config(arch)
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    loss, metrics = jax.jit(lambda p, b: bundle.loss(p, b))(
        params, smoke_batch(cfg))
    assert np.isfinite(float(loss)), arch
    assert 2.0 < float(loss) < 12.0, (arch, float(loss))
    assert np.isfinite(float(metrics["ce"]))


@pytest.mark.parametrize("arch", ["llama3.2-1b", "jamba-v0.1-52b", "rwkv6-7b",
                                  "kimi-k2-1t-a32b", "whisper-medium"])
def test_train_step_updates_params(arch):
    """Full train step (grad + clip + optimizer) moves params, no NaNs."""
    cfg = get_smoke_config(arch)
    run = RunConfig(attn_impl="xla", learning_rate=1e-3)
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    hyper = default_hyper(cfg, run)
    state = {"params": params, "opt": init_state(params, hyper)}
    step = jax.jit(make_train_step(cfg, run, hyper))
    new_state, metrics = step(state, smoke_batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # at least one leaf moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        params, new_state["params"])
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_dims_match_assignment(arch):
    """The FULL configs carry the exact published dims (never instantiated
    on CPU — dims only)."""
    cfg = get_config(arch)
    expect = {
        "grok-1-314b": (64, 6144, 48, 8, 131072),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 163840),
        "llama3.2-1b": (16, 2048, 32, 8, 128256),
        "qwen2-0.5b": (24, 896, 14, 2, 151936),
        "qwen1.5-4b": (40, 2560, 20, 20, 151936),
        "olmo-1b": (16, 2048, 16, 16, 50304),
        "qwen2-vl-72b": (80, 8192, 64, 8, 152064),
        "whisper-medium": (24, 1024, 16, 16, 51865),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 65536),
        "rwkv6-7b": (32, 4096, 64, 64, 65536),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.vocab)
    assert got == expect


def test_param_counts_match_published():
    for arch, lo, hi in [("grok-1-314b", 300e9, 330e9),
                         ("kimi-k2-1t-a32b", 0.95e12, 1.1e12),
                         ("jamba-v0.1-52b", 48e9, 55e9),
                         ("rwkv6-7b", 6.5e9, 8e9),
                         ("llama3.2-1b", 1.1e9, 1.4e9),
                         ("qwen2-0.5b", 0.4e9, 0.65e9)]:
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
    # active params for the MoEs
    assert 25e9 <= get_config("kimi-k2-1t-a32b").active_param_count() <= 40e9
    assert 75e9 <= get_config("grok-1-314b").active_param_count() <= 95e9
