"""Paper Table IV: application benchmark — Meta-pipe with GeStore.

Paper numbers: full workflow 833 min; with GeStore 965 min (first run,
overhead); cached DB 859 min; 1-month incremental update 61 min (13x).

Our application is the neural-BLAST workflow (embed corpus + score
queries): the dominant cost is per-entry embedding+scoring FLOPs, exactly
as BLAST's per-entry alignment. We measure wall time AND the work counter
(entries embedded), reporting the achieved incremental speedup at the
paper's churn rate.
"""
from __future__ import annotations

import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.search import EmbeddingSearchDB
from repro.core.store import FieldSchema, VersionedStore
from repro.configs.metapipe import ENCODER
from repro.models import build

from ._util import timeit

N = int(os.environ.get("BENCH_APP_N", 6000))
SEQ_W = 32
CHURN = 0.031


def _encoder():
    bundle = build(ENCODER)
    params = bundle.init(jax.random.key(0))

    @jax.jit
    def fwd(tokens):
        from repro.models.transformer import forward_train, FwdOpts
        x, _ = forward_train(params, ENCODER,
                             {"tokens": tokens % ENCODER.vocab},
                             FwdOpts(attn_impl="xla", remat="none"))
        return x.mean(axis=1)  # mean-pooled sequence embedding

    def enc(tokens: np.ndarray) -> np.ndarray:
        out = []
        bs = 256
        for i in range(0, len(tokens), bs):
            chunk = tokens[i:i + bs]
            pad = bs - len(chunk)
            if pad:
                chunk = np.concatenate([chunk, np.zeros((pad, chunk.shape[1]),
                                                        chunk.dtype)])
            out.append(np.asarray(fwd(jnp.asarray(chunk)))[: bs - pad])
        return np.concatenate(out) if out else np.zeros((0, ENCODER.d_model),
                                                        np.float32)
    return enc


def run() -> list[tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)
    store = VersionedStore("c", [FieldSchema("sequence", SEQ_W, "int32")],
                           capacity=N + 64)
    store.update(1, [f"d{i}" for i in range(N)],
                 {"sequence": rng.integers(0, 25, (N, SEQ_W)).astype(np.int32)})
    view = store.get_version(1)
    tbl = view.values["sequence"].copy()
    n_mut = int(CHURN * N)
    tbl[rng.choice(N, n_mut, replace=False)] = \
        rng.integers(0, 25, (n_mut, SEQ_W))
    store.update(2, [k.decode() for k in view.keys], {"sequence": tbl})

    enc = _encoder()
    q = rng.integers(0, 25, (8, SEQ_W)).astype(np.int32)
    qids = [f"q{i}".encode() for i in range(8)]

    db = EmbeddingSearchDB(store, enc, seg_size=64)

    def full_run():
        db.refresh(1)
        return db.query(qids, q, ts=1, k=10)

    t_full, _ = timeit(full_run, reps=1)
    r1 = db.query(qids, q, ts=1, k=10)
    work_full = db.n_embedded_total
    rows.append(("table4.full_workflow", t_full * 1e6 / N,
                 f"wall_s={t_full:.2f};entries={N};paper=833min"))

    def incremental_run():
        return db.incremental_query(r1, qids, q, t_last=1, ts=2, k=10)

    t_inc, _ = timeit(incremental_run, reps=1)
    work_inc = db.n_embedded_total - work_full
    speed_wall = t_full / max(t_inc, 1e-9)
    speed_work = work_full / max(work_inc, 1)
    rows.append(("table4.incremental_update", t_inc * 1e6 / max(work_inc, 1),
                 f"wall_s={t_inc:.2f};entries={work_inc};paper=61min"))
    rows.append(("table4.incremental_speedup_wall", speed_wall,
                 f"paper=13.6x(833/61)"))
    rows.append(("table4.incremental_speedup_work", speed_work,
                 f"churn={CHURN};embedded {work_inc}/{work_full}"))

    # exactness guard (the merge must not trade correctness for speed)
    db2 = EmbeddingSearchDB(store, enc, seg_size=64)
    db2.refresh(2)
    rf = db2.query(qids, q, ts=2, k=10)
    r2 = incremental_run()
    exact = bool(np.array_equal(r2.topk_idx, rf.topk_idx) and
                 np.allclose(r2.z, rf.z, atol=1e-4))
    rows.append(("table4.incremental_exact", 1.0 if exact else 0.0,
                 "merged==full" if exact else "MISMATCH"))
    return rows
