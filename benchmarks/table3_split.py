"""Paper Table III: create-and-split meta-database. The paper's lesson: a
single-reducer writer takes 55 min while 20 split writers take 9 min (5x).
We reproduce the structure: materialize one monolithic output file vs R
per-shard files (row-range splits, no single-writer concat)."""
from __future__ import annotations

import os
import tempfile


from repro.core.store import FieldSchema, VersionedStore

from ._util import synth_release, timeit

N = int(os.environ.get("BENCH_N", 200_000))
R = 20


def run() -> list[tuple[str, float, str]]:
    rows = []
    keys, tbl = synth_release(N, seed=3)
    st = VersionedStore("fa", [FieldSchema("sequence", 64, "int32")],
                        capacity=N)
    st.update(1, keys, {"sequence": tbl["sequence"]})
    view = st.get_version(1)

    with tempfile.TemporaryDirectory() as d:
        def single_writer():
            # gather + one serial write (the paper's formatdb/NFS path)
            buf = view.values["sequence"]
            with open(os.path.join(d, "mono.bin"), "wb") as f:
                for i in range(0, len(buf), 4096):   # serialized chunks
                    f.write(buf[i:i + 4096].tobytes())

        def split_writers():
            # R independent row-range writers (HDFS-split analogue)
            buf = view.values["sequence"]
            per = -(-len(buf) // R)
            for r in range(R):
                buf[r * per:(r + 1) * per].tofile(
                    os.path.join(d, f"part-{r:05d}.bin"))

        t_mono, _ = timeit(single_writer, reps=2)
        t_split, _ = timeit(split_writers, reps=2)
        rows.append(("table3.single_writer", t_mono * 1e6 / N,
                     f"wall_s={t_mono:.2f};paper=55min"))
        rows.append(("table3.split_writers", t_split * 1e6 / N,
                     f"wall_s={t_split:.2f};R={R};paper=9min"))
        rows.append(("table3.split_speedup", t_mono / max(t_split, 1e-9),
                     "paper=5x(55/9+no-copy)"))
    return rows
