"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Set BENCH_N / BENCH_APP_N to scale
(defaults sized for a single CPU core; the operations are row-parallel, see
DESIGN.md §8 for the pod-scale throughput argument).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import (fig1_growth, roofline_table, table1_lifecycle,
                            table2_incremental, table3_split,
                            table4_application)
    print("name,us_per_call,derived")
    failures = 0
    for mod in (table1_lifecycle, table2_incremental, table3_split,
                table4_application, fig1_growth, roofline_table):
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.3f},{derived}")
        except Exception:
            failures += 1
            print(f"{mod.__name__},NaN,FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
