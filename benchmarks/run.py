"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and merges the same rows into
``BENCH_results.json`` (the CI artifact) *per table*: a run replaces only
the tables it attempted, so a partial or BENCH_TABLES-filtered run no
longer clobbers earlier results. Set BENCH_N / BENCH_APP_N / BENCH_BATCH_N
/ BENCH_STORE_N / BENCH_SHARD_N / BENCH_SHARDS / BENCH_SERVE_* /
BENCH_INGEST_* to scale
(defaults sized
for a single CPU core; the operations are row-parallel, see DESIGN.md §8
for the pod-scale throughput argument), and BENCH_TABLES to a
comma-separated list of table keys (e.g. ``table5,table7``) to run a
subset.
"""
from __future__ import annotations

import json
import os
import sys
import traceback

# BENCH_DEVICES=N forces N host CPU devices (the device-parallel sharded
# rows in table7 need a real mesh). The count is locked at first jax init,
# so this must run at module top — before the benchmark modules import.
_DEV = os.environ.get("BENCH_DEVICES", "").strip()
if _DEV and _DEV != "0":
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={int(_DEV)}").strip()

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_RESULTS = os.path.join(_ROOT, "BENCH_results.json")


def _table_key(mod) -> str:
    """'benchmarks.table5_batched' -> 'table5' (matches its row prefixes)."""
    return mod.__name__.split(".")[-1].split("_")[0]


def _merge(path: str, attempted: set[str], results: list[dict],
           failures: list[str]) -> dict:
    """Per-table merge: rows and failures of tables NOT attempted by this
    run survive; attempted tables are replaced wholesale."""
    old_results: list[dict] = []
    old_failures: list[str] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            old_results = old.get("results", [])
            old_failures = old.get("failures", [])
        except (json.JSONDecodeError, OSError):
            pass  # unreadable history: start fresh
    keep = [r for r in old_results
            if r.get("name", "").split(".")[0] not in attempted]
    keep_fail = [f for f in old_failures
                 if f.split(".")[-1].split("_")[0] not in attempted]
    return {"results": keep + results, "failures": keep_fail + failures}


def main() -> None:
    # robust to both `python benchmarks/run.py` and `python -m benchmarks.run`
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    sys.path.insert(0, _ROOT)
    from benchmarks import (fig1_growth, roofline_table, table1_lifecycle,
                            table2_incremental, table3_split,
                            table4_application, table5_batched,
                            table6_storage, table7_sharding, table9_serving,
                            table10_observability, table11_kernels,
                            table12_ingest)
    mods = [table1_lifecycle, table2_incremental, table3_split,
            table4_application, table5_batched, table6_storage,
            table7_sharding, table9_serving, table10_observability,
            table11_kernels, table12_ingest, fig1_growth, roofline_table]
    only = {w.strip() for w in os.environ.get("BENCH_TABLES", "").split(",")
            if w.strip()}
    if only:
        unknown = only - {_table_key(m) for m in mods}
        if unknown:
            print(f"BENCH_TABLES names unknown tables: {sorted(unknown)}",
                  file=sys.stderr)
            sys.exit(2)
        mods = [m for m in mods if _table_key(m) in only]
    print("name,us_per_call,derived")
    results = []
    failures = []
    for mod in mods:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.3f},{derived}")
                results.append({"name": name, "us_per_call": us,
                                "derived": derived})
        except Exception:
            failures.append(mod.__name__)
            print(f"{mod.__name__},NaN,FAILED", file=sys.stderr)
            traceback.print_exc()
    merged = _merge(_RESULTS, {_table_key(m) for m in mods}, results,
                    failures)
    with open(_RESULTS, "w") as f:
        json.dump(merged, f, indent=2)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
