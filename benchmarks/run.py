"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes the same rows to
``BENCH_results.json`` (the CI artifact). Set BENCH_N / BENCH_APP_N /
BENCH_BATCH_N to scale (defaults sized for a single CPU core; the
operations are row-parallel, see DESIGN.md §8 for the pod-scale throughput
argument).
"""
from __future__ import annotations

import json
import os
import sys
import traceback

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    # robust to both `python benchmarks/run.py` and `python -m benchmarks.run`
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    sys.path.insert(0, _ROOT)
    from benchmarks import (fig1_growth, roofline_table, table1_lifecycle,
                            table2_incremental, table3_split,
                            table4_application, table5_batched,
                            table6_storage)
    print("name,us_per_call,derived")
    results = []
    failures = []
    for mod in (table1_lifecycle, table2_incremental, table3_split,
                table4_application, table5_batched, table6_storage,
                fig1_growth, roofline_table):
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.3f},{derived}")
                results.append({"name": name, "us_per_call": us,
                                "derived": derived})
        except Exception:
            failures.append(mod.__name__)
            print(f"{mod.__name__},NaN,FAILED", file=sys.stderr)
            traceback.print_exc()
    with open(os.path.join(_ROOT, "BENCH_results.json"), "w") as f:
        json.dump({"results": results, "failures": failures}, f, indent=2)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
