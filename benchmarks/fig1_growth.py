"""Paper Fig. 1: UniProtKB growth — the motivation for versioned storage.
We model a release series (3%/release entry growth, 26% churn) and measure
what the MVCC store pays per release: cells written vs full-copy bytes
(the delta-compression win that makes many-release retention viable)."""
from __future__ import annotations

import os
import tempfile

from repro.core.store import FieldSchema, VersionedStore

from ._util import synth_release

N0 = int(os.environ.get("BENCH_FIG1_N", 50_000))
RELEASES = 6


def run() -> list[tuple[str, float, str]]:
    st = VersionedStore("up", [FieldSchema("sequence", 64, "int32"),
                               FieldSchema("length", 1, "int32"),
                               FieldSchema("annotation", 8, "int32")],
                        capacity=int(N0 * 1.5))
    keys, tbl = synth_release(N0, seed=1)
    st.update(1, keys, tbl)
    full_copy_bytes = 0
    for r in range(2, RELEASES + 1):
        keys, tbl = synth_release(0, base=(keys, tbl), frac_updated=0.26,
                                  n_new=int(len(keys) * 0.03), seed=r)
        st.update(r, keys, tbl)
        full_copy_bytes += sum(v.nbytes for v in tbl.values())
    cells = sum(col.log.csr(st.n_rows)[0].nbytes
                for col in st.fields.values())
    with tempfile.TemporaryDirectory() as d:
        stats = st.save(d)
    ratio_mvcc = full_copy_bytes / max(cells, 1)
    ratio_disk = full_copy_bytes / max(stats["disk_bytes"], 1)
    return [
        ("fig1.releases_stored", float(RELEASES), f"entries_final={st.n_rows}"),
        ("fig1.mvcc_vs_fullcopy", ratio_mvcc,
         f"cell_bytes={cells};fullcopy_bytes={full_copy_bytes}"),
        ("fig1.disk_vs_fullcopy", ratio_disk,
         f"disk_bytes={stats['disk_bytes']}(delta-packed npz)"),
    ]
