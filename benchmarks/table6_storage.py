"""Table VI (new): segmented on-disk storage — incremental save vs full
rewrite, and cold-load latency.

The paper's second pillar (§III.B/§IV) is storing many 240 GB-class
releases cheaply. The seed's monolithic snapshot rewrote every cell per
save and inflated the full history on load; the segmented layout
(core/segments.py) writes only segments newer than the manifest watermark
and opens lazily. This table quantifies both, at BENCH_RELEASES (default
32) releases:

  * incremental_save — bytes/latency to persist ONE new release on top of
    the full history (should be independent of history depth).
  * full_rewrite    — bytes/latency of a from-scratch segmented rewrite.
  * legacy_rewrite  — the seed's monolithic cells.npz writer (baseline).
  * cold_load_lazy  — open + materialize one pinned version, lazy load.
  * cold_load_eager — open with everything inflated (seed behavior).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time


from repro.core import segments
from repro.core.store import FieldSchema, VersionedStore

from ._util import synth_release, timeit

N = int(os.environ.get("BENCH_STORE_N", 4_000))
RELEASES = int(os.environ.get("BENCH_RELEASES", 32))


def _mk_store() -> tuple[VersionedStore, tuple]:
    st = VersionedStore("up", [FieldSchema("sequence", 64, "int32"),
                               FieldSchema("length", 1, "int32"),
                               FieldSchema("annotation", 8, "int32")],
                        capacity=N + N // 4)
    rel = synth_release(N, seed=1)
    st.update(10, *rel)
    for v in range(1, RELEASES):
        rel = synth_release(0, base=rel, frac_updated=0.02, n_new=N // 200,
                            seed=v + 1)
        st.update((v + 1) * 10, *rel)
    return st, rel


def run() -> list[tuple[str, float, str]]:
    st, rel = _mk_store()
    rows: list[tuple[str, float, str]] = []
    work = tempfile.mkdtemp(prefix="table6_")
    try:
        main_dir = os.path.join(work, "main")
        st.save(main_dir)   # first save: full (also warms the pack kernels)

        # append + incrementally persist two releases; the first amortizes
        # jit compilation, the second is the timed, reported one (saves are
        # destructive-once, so timeit reps would measure a no-op rewrite)
        for extra in (1, 2):
            rel = synth_release(0, base=rel, frac_updated=0.02,
                                n_new=N // 200, seed=RELEASES + extra)
            st.update((RELEASES + extra) * 10, *rel)
            t0 = time.perf_counter()
            inc_stats = st.save(main_dir)
            t_inc = time.perf_counter() - t0
            assert inc_stats["mode"] == "incremental", inc_stats["mode"]
        inc_bytes = max(inc_stats["bytes_written"], 1)

        def full_rewrite():
            d = os.path.join(work, "rw")
            shutil.rmtree(d, ignore_errors=True)
            return st.save(d, force_full=True)

        t_full, _ = timeit(full_rewrite, reps=1, warmup=1)
        full_rw = full_rewrite()

        def legacy_rewrite():
            d = os.path.join(work, "legacy")
            shutil.rmtree(d, ignore_errors=True)
            return segments.write_legacy_snapshot(st, d)

        t_leg, _ = timeit(legacy_rewrite, reps=1, warmup=1)
        leg = legacy_rewrite()

        ratio = full_rw["bytes_written"] / inc_bytes
        rows.append(("table6.incremental_save", t_inc * 1e6,
                     f"bytes={inc_bytes};vs_full={ratio:.1f}x_smaller"))
        rows.append(("table6.full_rewrite", t_full * 1e6,
                     f"bytes={full_rw['bytes_written']}"))
        rows.append(("table6.legacy_rewrite", t_leg * 1e6,
                     f"bytes={leg['bytes_written']}"))

        last_ts = st.last_ts

        def cold_lazy():
            s = VersionedStore.load(main_dir, lazy=True)
            return s.get_version(last_ts, fields=["length"])

        def cold_eager():
            s = VersionedStore.load(main_dir, lazy=False)
            return s.get_version(last_ts, fields=["length"])

        t_lazy, _ = timeit(cold_lazy, reps=1, warmup=1)
        t_eager, _ = timeit(cold_eager, reps=1, warmup=1)
        rows.append(("table6.cold_load_lazy", t_lazy * 1e6,
                     f"releases={RELEASES + 2};entries={N}"))
        rows.append(("table6.cold_load_eager", t_eager * 1e6,
                     f"speedup_lazy={t_eager / max(t_lazy, 1e-9):.2f}x"))
    finally:
        shutil.rmtree(work, ignore_errors=True)
    return rows
