"""Table X (new): observability layer — kernel rooflines + overhead.

Two row families:

  * ``table10.roofline_<kernel>`` — drive each instrumented kernel launch
    path (``batched_select`` via a batched ``get_versions`` wave,
    ``shard_route`` via ``route_keys``, ``delta_codec`` via
    ``chain_pack``/``chain_unpack``) and report the per-kernel telemetry
    ``KernelTelemetry`` aggregated: wall us/launch plus the derived
    roofline fraction and achieved GB/s against the v5e-class constants
    in ``launch/roofline.py``. A collapsing fraction (or exploding
    us/launch) gates CI via tools/bench_compare.py.
  * ``table10.<primitive>`` — the cost of one observability primitive
    (counter inc, histogram record, span open/close, flight-recorder
    append): the instrumentation-overhead budget. These are the numbers
    that keep the "≲5% serving overhead" claim honest.

Also dumps the combined ``repro.obs.snapshot_all()`` payload (registry
metrics, kernel telemetry, flight-recorder ring) to ``BENCH_metrics.json``
at the repo root — uploaded as a CI artifact next to BENCH_results.json.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro import obs
from repro.core.store import FieldSchema, VersionedStore
from repro.kernels.delta_codec import chain_pack, chain_unpack
from repro.kernels.shard_route import route_keys
from repro.obs import FlightRecorder, MetricsRegistry, span
from repro.obs.kerneltel import KERNELS

from ._util import synth_release, timeit

N = int(os.environ.get("BENCH_OBS_N",
                       os.environ.get("BENCH_BATCH_N", 8_000)))
PROBE_REPS = 10_000
# roofline rows sample SEVERAL warm launches (calls > 1), so a single
# stray compile can't dominate the per-call wall numbers
ROOFLINE_REPS = int(os.environ.get("BENCH_OBS_REPS", 5))
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
METRICS_OUT = os.path.join(_ROOT, "BENCH_metrics.json")


def _probe_rows() -> list[tuple[str, float, str]]:
    """Single-primitive overhead: us per counter inc / histogram record /
    span open+close / recorder append, on private instances so the probe
    does not pollute the process-wide registry or flight-recorder ring."""
    reg = MetricsRegistry()
    c = reg.counter("probe")
    h = reg.histogram("probe_h", 4096)
    rec = FlightRecorder(cap=512)

    def counters():
        for _ in range(PROBE_REPS):
            c.inc()

    def hists():
        for _ in range(PROBE_REPS):
            h.record(1e-3)

    def spans():
        for _ in range(PROBE_REPS // 10):
            with span("probe"):
                pass

    def records():
        for _ in range(PROBE_REPS):
            rec.record("probe", i=1)

    rows = []
    for name, fn, calls in (("counter_inc", counters, PROBE_REPS),
                            ("histogram_record", hists, PROBE_REPS),
                            ("span", spans, PROBE_REPS // 10),
                            ("recorder_record", records, PROBE_REPS)):
        t, _ = timeit(fn, reps=2, warmup=1)
        rows.append((f"table10.{name}", t * 1e6 / calls, "per_call"))
    return rows


def _build_state():
    """Build the bench state ONCE, outside the sampled region — store
    construction (ingest) must not ride along in the roofline rows."""
    st = VersionedStore("obs", [FieldSchema("sequence", 16, "int32"),
                                FieldSchema("length", 1, "int32")],
                        capacity=N + N // 4)
    rel = synth_release(N, seq_w=16, seed=7)
    st.update(10, *rel)
    for v in range(1, 4):
        rel = synth_release(0, base=rel, frac_updated=0.05, n_new=N // 100,
                            seed=v + 7)
        st.update((v + 1) * 10, *rel)
    ts_list = [((i % 4) + 1) * 10 for i in range(32)]
    keys = [f"P{i:08d}".encode() for i in range(N)]
    rng = np.random.default_rng(11)
    rows = np.sort(rng.integers(0, max(N // 4, 1), size=N)).astype(np.int64)
    vals = rng.integers(0, 100, size=(N, 16)).astype(np.int32)
    return st, ts_list, keys, rows, vals


def _drive_kernels(state, reps: int = 1) -> None:
    """Exercise every instrumented launch site at bench scale."""
    st, ts_list, keys, rows, vals = state
    for _ in range(reps):
        # batched_select: a 32-version fused batch over the 4-release store
        st.get_versions(ts_list, fields=["sequence"])
        # shard_route: hash the whole keyspace across 8 shards
        route_keys(keys, 8)
        # delta_codec: pack + unpack one (row, ts)-sorted chain run
        packed, meta = chain_pack(vals, rows)
        chain_unpack(packed, rows, meta, np.dtype(np.int32))


def run() -> list[tuple[str, float, str]]:
    rows = _probe_rows()
    state = _build_state()
    _drive_kernels(state)    # warmup: compile/trace cost stays out of the
    KERNELS.clear()          # telemetry attributed to the timed drive
    _drive_kernels(state, reps=ROOFLINE_REPS)   # warm steady-state sample
    snap = KERNELS.snapshot()
    for kernel in ("batched_select", "shard_route", "delta_codec"):
        k = snap.get(kernel)
        if k is None:        # a kernel path went dark — that IS the signal
            rows.append((f"table10.roofline_{kernel}", float("nan"),
                         "missing=1"))
            continue
        rows.append((
            f"table10.roofline_{kernel}", k["us_per_call"],
            f"roofline_frac={k['roofline_fraction']:.4f};"
            f"gbytes_per_s={k['gbytes_per_s']:.2f};"
            f"dominant={k['dominant']};calls={k['calls']}"))
    with open(METRICS_OUT, "w") as f:
        json.dump(obs.snapshot_all(), f, indent=2, default=str)
    return rows
