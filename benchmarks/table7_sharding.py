"""Table VII (new): sharded-store scatter-gather throughput vs shard count.

The paper spreads meta-database rows across HBase region servers so version
generation parallelizes with the data (§II.B/§V); core/shard.py is that
scale-out axis here. This table ingests the same synthetic release history
into a ShardedStore at several shard counts (1 = the unsharded baseline
path wrapped in the facade) and measures update (scatter) throughput and
batched get_versions (scatter-gather materialization) throughput.

Each shard count reports the serial per-shard loop AND the device-parallel
placement (core/placement.py) side by side: with BENCH_DEVICES=N (run.py
forces N host CPU devices before jax initializes) the parallel rows run one
shard per device over a ("shard",) mesh; with fewer devices than shards they
fall back to one stacked launch, which still amortizes per-shard launch
overhead. Every get_versions row's derived field records ``devices=`` and
``mode=`` so results across device counts never get conflated, and the
parallel rows carry ``vs_serial=`` — the speedup over the serial loop on
the identical store. BENCH_SHARDS picks the shard counts (comma-separated),
e.g. the CI smoke sets ``BENCH_SHARDS=1,2`` to exercise the scatter-gather
path cheaply.
"""
from __future__ import annotations

import os

import jax

from repro.core.placement import plan_placement
from repro.core.shard import ShardedStore
from repro.core.store import FieldSchema

from ._util import synth_release, timeit

N = int(os.environ.get("BENCH_SHARD_N", 12_000))
SHARDS = [int(s) for s in
          os.environ.get("BENCH_SHARDS", "1,2,4").split(",") if s.strip()]
Q = 32  # concurrent pinned versions per materialization wave
FIELDS = ["sequence", "length"]


def _schema() -> list[FieldSchema]:
    return [FieldSchema("sequence", 64, "int32"),
            FieldSchema("length", 1, "int32"),
            FieldSchema("annotation", 8, "int32")]


def _releases():
    rels = [synth_release(N, seed=1)]
    for v in range(1, 4):
        rels.append(synth_release(0, base=rels[-1], frac_updated=0.03,
                                  n_new=N // 100, seed=v + 1))
    return rels


def run() -> list[tuple[str, float, str]]:
    rels = _releases()
    rows = []
    n_dev = len(jax.devices())
    base_update = base_query = base_par = None
    # the relative column is named for the shard count it is relative to:
    # BENCH_SHARDS need not include 1
    rel_label = f"rel_s{SHARDS[0]}"
    for s in SHARDS:
        st = ShardedStore("up", _schema(), n_shards=s, capacity=N + N // 8)
        for v, rel in enumerate(rels[:-1]):
            st.update((v + 1) * 10, *rel)
        last_ts = len(rels) * 10

        def ingest():
            st.update(last_ts, *rels[-1])

        # one timed ingest of the final release (reps=1: updates are
        # monotonic, a release cannot be replayed into the same store)
        t_upd, _ = timeit(ingest, reps=1, warmup=0)
        ts_list = [((i % len(rels)) + 1) * 10 for i in range(Q)]

        def wave():
            return st.get_versions(ts_list, fields=FIELDS)

        # serial vs device-parallel on the SAME ingested store (the two
        # paths are byte-identical; only the execution strategy differs)
        st.placement = plan_placement(s, force="serial")
        t_q, _ = timeit(wave, reps=2, warmup=1)
        st.placement = plan_placement(s, force="parallel")
        mode = st.placement.mode
        t_p, _ = timeit(wave, reps=2, warmup=1)
        if base_update is None:
            base_update, base_query, base_par = t_upd, t_q, t_p
        rows.append((f"table7.update_s{s}", t_upd * 1e6 / len(rels[-1][0]),
                     f"entries_per_s={len(rels[-1][0]) / t_upd:.0f};"
                     f"{rel_label}={base_update / t_upd:.2f}x"))
        rows.append((f"table7.get_versions_s{s}_q{Q}", t_q * 1e6 / Q,
                     f"versions_per_s={Q / t_q:.1f};"
                     f"{rel_label}={base_query / t_q:.2f}x;"
                     f"rows_per_shard={st.n_rows // s};"
                     f"devices={n_dev};mode=serial"))
        rows.append((f"table7.get_versions_par_s{s}_q{Q}", t_p * 1e6 / Q,
                     f"versions_per_s={Q / t_p:.1f};"
                     f"{rel_label}={base_par / t_p:.2f}x;"
                     f"vs_serial={t_q / t_p:.2f}x;"
                     f"devices={n_dev};mode={mode}"))
    return rows
