"""Inject the §Dry-run and §Roofline tables into EXPERIMENTS.md from the
final sweep JSON (between the <!-- DRYRUN_TABLE --> / <!-- ROOFLINE_TABLE -->
markers). Run after `launch/dryrun.py --all --mesh both --out
experiments/dryrun_final`."""
from __future__ import annotations

import glob
import json
import os

DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun_final")


def cells(mesh):
    out = []
    for p in sorted(glob.glob(os.path.join(DIR, f"*_{mesh}.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_bytes(b):
    if b is None:
        return "n/a"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table():
    singles = {(c["arch"], c["shape"]): c for c in cells("single")}
    pods = {(c["arch"], c["shape"]): c for c in cells("pod")}
    lines = ["| arch | shape | mode | 16x16 compile | 2x16x16 compile | "
             "args/device (pod) | collectives |",
             "|---|---|---|---|---|---|---|"]
    for key in sorted(singles):
        s = singles[key]
        p = pods.get(key)
        args_b = (p or s)["memory"].get("argument_bytes")
        lines.append(
            f"| {key[0]} | {key[1]} | {s['mode']} | {s['t_compile_s']}s | "
            f"{(str(p['t_compile_s']) + 's') if p else 'n/a'} | "
            f"{fmt_bytes(args_b)} | {s['hlo_ops']['n_collectives']} |")
    n_s, n_p = len(singles), len(pods)
    head = (f"\n**{n_s} single-pod + {n_p} multi-pod cells compiled, "
            f"0 failures.**\n\n")
    return head + "\n".join(lines) + "\n"


def roofline_table():
    lines = ["| arch | shape | t_compute | t_memory | t_collective | "
             "dominant | useful | frac | frac(pod) |",
             "|---|---|---|---|---|---|---|---|---|"]
    pods = {(c["arch"], c["shape"]): c for c in cells("pod")}
    for c in sorted(cells("single"), key=lambda c: (c["arch"], c["shape"])):
        r = c["roofline"]
        p = pods.get((c["arch"], c["shape"]))
        pf = f"{p['roofline']['roofline_fraction']:.3f}" if p else "n/a"
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['t_compute_s']:.3g}s | "
            f"{r['t_memory_s']:.3g}s | {r['t_collective_s']:.3g}s | "
            f"{r['dominant']} | {r['useful_flops_fraction']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {pf} |")
    return "\n" + "\n".join(lines) + "\n"


def main():
    path = "EXPERIMENTS.md"
    text = open(path).read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_table())
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table())
    open(path, "w").write(text)
    print("EXPERIMENTS.md tables injected "
          f"({len(cells('single'))} single, {len(cells('pod'))} pod cells)")


if __name__ == "__main__":
    main()
