"""Benchmark helpers: timing + synthetic UniProt-like releases."""
from __future__ import annotations

import time

import numpy as np


def timeit(fn, *, reps: int = 3, warmup: int = 0):
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)), float(np.std(ts))


def synth_release(n_entries: int, seq_w: int = 64, *, seed: int = 0,
                  base=None, frac_updated: float = 0.0, n_new: int = 0,
                  n_deleted: int = 0):
    """Synthetic parsed UniProtKB-like release: (keys, table).

    With `base`, derives the next release: `frac_updated` of entries get new
    sequences (significant churn), everyone gets fresh annotation (the
    annotation-churn regime of real UniProt releases), `n_new` appended,
    `n_deleted` dropped."""
    rng = np.random.default_rng(seed)
    if base is None:
        keys = [f"P{i:08d}" for i in range(n_entries)]
        table = {
            "sequence": rng.integers(0, 25, (n_entries, seq_w)).astype(np.int32),
            "length": rng.integers(50, 400, (n_entries, 1)).astype(np.int32),
            "annotation": rng.integers(0, 100, (n_entries, 8)).astype(np.int32),
        }
        return keys, table
    keys0, tbl0 = base
    keep = len(keys0) - n_deleted
    keys = list(keys0[:keep])
    table = {k: v[:keep].copy() for k, v in tbl0.items()}
    n_upd = int(frac_updated * keep)
    upd = rng.choice(keep, size=n_upd, replace=False)
    table["sequence"][upd] = rng.integers(0, 25, (n_upd, table["sequence"].shape[1]))
    table["annotation"] = rng.integers(0, 100, table["annotation"].shape).astype(np.int32)
    start = int(keys0[-1][1:]) + 1
    for i in range(n_new):
        keys.append(f"P{start + i:08d}")
    if n_new:
        rngn = np.random.default_rng(seed + 1)
        for name, v in list(table.items()):
            roww = v.shape[1]
            newv = (rngn.integers(0, 25, (n_new, roww)).astype(np.int32)
                    if name != "length" else
                    rngn.integers(50, 400, (n_new, 1)).astype(np.int32))
            table[name] = np.concatenate([v, newv])
    return keys, table
