"""Table V (new): batched multi-version materialization throughput.

The paper's runtime-generation promise (§III.C) under the production
workload the seed couldn't serve: many analyses pinned to different
meta-database versions materializing concurrently. Compares a single-ts
get_version loop against the fused-superlog get_versions batch at 1/8/64
concurrent versions on a 4-release store; the batch issues ONE batched
scan per call instead of Q scans."""
from __future__ import annotations

import os

from repro.core.store import FieldSchema, VersionedStore

from ._util import synth_release, timeit

N = int(os.environ.get("BENCH_BATCH_N", 20_000))
FIELDS = ["sequence", "length"]


def _mk_store() -> VersionedStore:
    st = VersionedStore("up", [FieldSchema("sequence", 64, "int32"),
                               FieldSchema("length", 1, "int32"),
                               FieldSchema("annotation", 8, "int32")],
                        capacity=N + N // 8)
    rel = synth_release(N, seed=1)
    st.update(10, *rel)
    for v in range(1, 4):
        rel = synth_release(0, base=rel, frac_updated=0.03, n_new=N // 100,
                            seed=v + 1)
        st.update((v + 1) * 10, *rel)
    return st


def run() -> list[tuple[str, float, str]]:
    st = _mk_store()
    rows = []
    for q in (1, 8, 64):
        ts_list = [((i % 4) + 1) * 10 for i in range(q)]

        def single():
            return [st.get_version(t, fields=FIELDS) for t in ts_list]

        def batched():
            return st.get_versions(ts_list, fields=FIELDS)

        t_single, _ = timeit(single, reps=2, warmup=1)
        t_batch, _ = timeit(batched, reps=2, warmup=1)
        speedup = t_single / max(t_batch, 1e-9)
        rows.append((f"table5.single_loop_q{q}", t_single * 1e6 / q,
                     f"versions_per_s={q / t_single:.1f}"))
        rows.append((f"table5.batched_q{q}", t_batch * 1e6 / q,
                     f"versions_per_s={q / t_batch:.1f};"
                     f"speedup={speedup:.2f}x"))
    return rows
