"""Paper Table II: generate an incremental meta-database (9 min vs 80 min
full; cached increment 26 s). Measures get_increment + significant-field
filtering at the paper's churn rate (~3% sequence churn month-to-month)."""
from __future__ import annotations

import os
import tempfile

from repro.core.store import FieldSchema, VersionedStore
from repro.core.cache import VersionCache, descriptor
from repro.core.tables import SystemTables

from ._util import synth_release, timeit

N = int(os.environ.get("BENCH_N", 200_000))


def run() -> list[tuple[str, float, str]]:
    rows = []
    keys1, tbl1 = synth_release(N, seed=1)
    # 3% sequence churn + annotation churn everywhere (the BLAST trap)
    keys2, tbl2 = synth_release(0, base=(keys1, tbl1), frac_updated=0.031,
                                n_new=N // 100, seed=2)
    st = VersionedStore("up", [FieldSchema("sequence", 64, "int32"),
                               FieldSchema("length", 1, "int32"),
                               FieldSchema("annotation", 8, "int32")],
                        capacity=N + N // 16)
    st.update(1, keys1, tbl1)
    st.update(2, keys2, tbl2)

    def gen_inc():
        inc = st.get_increment(1, 2, significant_fields=["sequence", "length"],
                               fields=["sequence", "length"])
        assert 0 < len(inc) < 0.06 * N
        return inc

    t_inc, _ = timeit(gen_inc, reps=2)
    inc = gen_inc()
    rows.append(("table2.get_increment", t_inc * 1e6 / N,
                 f"wall_s={t_inc:.2f};entries={len(inc)};paper=9min@89M"))

    # full-version generation for the ratio (paper: 9 min vs 80 min)
    t_full, _ = timeit(lambda: st.get_version(2, fields=["sequence", "length"]),
                       reps=2)
    rows.append(("table2.inc_vs_full_ratio", t_full / max(t_inc, 1e-9),
                 f"full_s={t_full:.2f};inc_s={t_inc:.2f};paper=80/9=8.9x"))

    with tempfile.TemporaryDirectory() as d:
        cache = VersionCache(d, SystemTables())
        desc = descriptor("up", 1, 2, plugin="blastp")
        cache.put(desc, lambda p: inc.values["sequence"].tofile(p))

        def cached():
            assert cache.get(desc) is not None

        t_c, _ = timeit(cached, reps=5)
        rows.append(("table2.cached_increment", t_c * 1e6,
                     f"wall_s={t_c:.5f};paper=26s(io-bound)"))
    return rows
