"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md §Roofline
table + CSV rows for benchmarks.run."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def markdown_table(mesh: str = "single") -> str:
    cells = load_cells(mesh)
    hdr = ("| arch | shape | t_compute | t_memory | t_collective | dominant | "
           "MODEL_FLOPS | useful-FLOPs frac | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for c in cells:
        r = c["roofline"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['t_compute_s']:.3g}s | "
            f"{r['t_memory_s']:.3g}s | {r['t_collective_s']:.3g}s | "
            f"**{r['dominant']}** | {r['model_flops_global']:.3g} | "
            f"{r['useful_flops_fraction']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return hdr + "\n".join(lines) + "\n"


def run() -> list[tuple[str, float, str]]:
    rows = []
    for mesh in ("single", "pod"):
        cells = load_cells(mesh)
        if not cells:
            continue
        n_ok = len(cells)
        worst = min(cells, key=lambda c: c["roofline"]["roofline_fraction"])
        best = max(cells, key=lambda c: c["roofline"]["roofline_fraction"])
        rows.append((f"roofline.{mesh}.cells_compiled", float(n_ok),
                     "all (arch x shape) cells lower+compile"))
        rows.append((f"roofline.{mesh}.best_fraction",
                     best["roofline"]["roofline_fraction"],
                     f"{best['arch']}/{best['shape']}"))
        rows.append((f"roofline.{mesh}.worst_fraction",
                     worst["roofline"]["roofline_fraction"],
                     f"{worst['arch']}/{worst['shape']}"))
    return rows


if __name__ == "__main__":
    print(markdown_table("single"))
