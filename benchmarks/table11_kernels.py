"""Table 11: kernel launch tuning — tile sweeps + steady-state rooflines.

Two row families, both built on the unified launch helper
(``src/repro/kernels/launch.py``):

  * ``table11.sweep_<kernel>`` — run the explicit autotune sweep for each
    kernel at bench scale and report the winning tile's us/call. The
    derived column records ``tile``/``bucket``/``cached`` (``cached=1``
    means the on-disk winner cache answered and no sweep ran — which is
    exactly what the CI ``actions/cache`` restore of
    ``GESTORE_TILE_CACHE`` buys). The winner is persisted per
    (kernel, platform, pow2 shape bucket), so serving picks it up with no
    env knobs set.
  * ``table11.steady_<kernel>`` — WARM steady-state launches only: the
    drive runs once to compile, telemetry is cleared, then ``REPS`` more
    launches are sampled. The derived column carries the padded-byte
    roofline fraction plus both achieved bandwidths (padded = what moved,
    logical = the useful fraction of it); a collapsing ``roofline_frac``
    or a padded/logical ratio drifting far from 1 gates CI via
    tools/bench_compare.py.

Scale with ``BENCH_KERNEL_N`` (falls back to ``BENCH_BATCH_N``); widen
the sweep with ``GESTORE_TILE_<KERNEL>`` unset (an env override bypasses
the cache entirely, by design).
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from repro.core.store import FieldSchema, VersionedStore
from repro.kernels import launch
from repro.kernels.batched_select import batched_masked_cumsum
from repro.kernels.delta_codec import chain_pack, chain_unpack, delta_pack
from repro.kernels.shard_route import key_lanes, route_keys, shard_route
from repro.obs.kerneltel import KERNELS

from ._util import synth_release, timeit

N = int(os.environ.get("BENCH_KERNEL_N",
                       os.environ.get("BENCH_BATCH_N", 8_000)))
REPS = int(os.environ.get("BENCH_KERNEL_REPS", 5))
SWEEP_KERNELS = ("batched_select", "shard_route", "delta_codec")


def _benches() -> dict:
    """bench(tile) -> wall seconds, one closure per swept kernel. Each
    closure launches the device entry point with an explicit static tile
    (tile=None would re-resolve and hide the candidate under test)."""
    rng = np.random.default_rng(3)
    ts = jnp.asarray(rng.integers(0, 10_000, N).astype(np.int32))
    tq = jnp.asarray(np.linspace(0, 10_000, 32).astype(np.int32))
    lanes, lens = key_lanes([f"P{i:08d}".encode() for i in range(N)])
    lanes, lens = jnp.asarray(lanes), jnp.asarray(lens)
    a = jnp.asarray(rng.integers(-500, 500, (N, 16)).astype(np.int32))
    b = jnp.asarray(rng.integers(-500, 500, (N, 16)).astype(np.int32))

    def bench_select(tile):
        def go():
            batched_masked_cumsum(ts, tq, tile=tile).block_until_ready()
        t, _ = timeit(go, reps=3, warmup=1)
        return t

    def bench_route(tile):
        def go():
            shard_route(lanes, lens, 8, tile=tile).block_until_ready()
        t, _ = timeit(go, reps=3, warmup=1)
        return t

    def bench_codec(tile):
        def go():
            d, _stat = delta_pack(a, b, tile=tile)
            d.block_until_ready()
        t, _ = timeit(go, reps=3, warmup=1)
        return t

    return {"batched_select": bench_select, "shard_route": bench_route,
            "delta_codec": bench_codec}


def _sweep_rows() -> list[tuple[str, float, str]]:
    rows = []
    benches = _benches()
    for kernel in SWEEP_KERNELS:
        bench = benches[kernel]
        res = launch.sweep(kernel, bench, n=N)
        # cached winners skipped the sweep; still time the winner once so
        # the row value stays comparable across cached/uncached runs
        wall = res["walls"].get(res["tile"]) or bench(res["tile"])
        rows.append((
            f"table11.sweep_{kernel}", wall * 1e6,
            f"tile={res['tile']};bucket={res['bucket']};"
            f"cached={int(res['cached'])};n={N}"))
    return rows


def _steady_state() -> list[tuple[str, float, str]]:
    """Warm per-launch telemetry through the real instrumented call sites
    (the store's fused scan, route_keys, the chain codec)."""
    st = VersionedStore("t11", [FieldSchema("sequence", 16, "int32"),
                                FieldSchema("length", 1, "int32")],
                        capacity=N + N // 4)
    rel = synth_release(N, seq_w=16, seed=5)
    st.update(10, *rel)
    for v in range(1, 4):
        rel = synth_release(0, base=rel, frac_updated=0.05, n_new=N // 100,
                            seed=v + 5)
        st.update((v + 1) * 10, *rel)
    ts_list = [((i % 4) + 1) * 10 for i in range(32)]
    keys = [f"P{i:08d}".encode() for i in range(N)]
    rng = np.random.default_rng(13)
    crows = np.sort(rng.integers(0, max(N // 4, 1), size=N)).astype(np.int64)
    cvals = rng.integers(0, 100, size=(N, 16)).astype(np.int32)

    def drive():
        st.get_versions(ts_list, fields=["sequence"])
        route_keys(keys, 8)
        packed, meta = chain_pack(cvals, crows)
        chain_unpack(packed, crows, meta, np.dtype(np.int32))

    drive()                  # compile/trace + autotune-cache read
    KERNELS.clear()          # telemetry now sees only warm launches
    for _ in range(REPS):
        drive()
    snap = KERNELS.snapshot()
    rows = []
    for kernel in SWEEP_KERNELS:
        k = snap.get(kernel)
        if k is None:        # an instrumented path went dark: that IS the row
            rows.append((f"table11.steady_{kernel}", float("nan"),
                         "missing=1"))
            continue
        rows.append((
            f"table11.steady_{kernel}", k["us_per_call"],
            f"roofline_frac={k['roofline_fraction']:.4f};"
            f"gbytes_per_s={k['gbytes_per_s']:.2f};"
            f"logical_gbytes_per_s={k['logical_gbytes_per_s']:.2f};"
            f"calls={k['calls']}"))
    return rows


def run() -> list[tuple[str, float, str]]:
    return _sweep_rows() + _steady_state()
