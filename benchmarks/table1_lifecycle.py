"""Paper Table I: add / update / retrieve / cached-retrieve meta-database.

The paper's absolute numbers (191/144/80/12 min) are for 89M entries on a
10-node Hadoop cluster; here we measure the same OPERATIONS on the JAX
store at N entries on one CPU core and report both the measured wall time
and the per-entry rate (the scale-free comparison; the ops are row-parallel
so pod-scale throughput multiplies by aggregate chip bandwidth — DESIGN §8).
"""
from __future__ import annotations

import os
import tempfile

from repro.core.store import FieldSchema, VersionedStore
from repro.core.cache import VersionCache, descriptor
from repro.core.tables import SystemTables

from ._util import synth_release, timeit

N = int(os.environ.get("BENCH_N", 200_000))


def run() -> list[tuple[str, float, str]]:
    rows = []
    keys1, tbl1 = synth_release(N, seed=1)
    keys2, tbl2 = synth_release(0, base=(keys1, tbl1), frac_updated=0.26,
                                n_new=N // 33, n_deleted=N // 100, seed=2)

    # --- add (paper: 191 min / 89M) ---
    store_holder = {}

    def add():
        st = VersionedStore("up", [FieldSchema("sequence", 64, "int32"),
                                   FieldSchema("length", 1, "int32"),
                                   FieldSchema("annotation", 8, "int32")],
                            capacity=N + N // 16)
        st.update(1, keys1, tbl1)
        store_holder["st"] = st

    t_add, _ = timeit(add, reps=1)
    rows.append(("table1.add", t_add * 1e6 / N,
                 f"N={N};wall_s={t_add:.2f};paper=191min@89M"))

    # --- update to next release (paper: 144 min; 26% churn + 3% new) ---
    st = store_holder["st"]
    t_upd, _ = timeit(lambda: st.update(2, keys2, tbl2), reps=1)
    info = st.versions[-1]
    rows.append(("table1.update", t_upd * 1e6 / N,
                 f"wall_s={t_upd:.2f};updated={info.n_updated};"
                 f"new={info.n_new};deleted={info.n_deleted};paper=144min"))

    # --- retrieve a pinned version + format (paper: 80 min) ---
    with tempfile.TemporaryDirectory() as d:
        tables = SystemTables()
        cache = VersionCache(d, tables)

        def retrieve():
            view = st.get_version(2, fields=["sequence", "length"])
            desc = descriptor("up", -1, 2, plugin="blastp")
            cache.put(desc, lambda p: view.values["sequence"].tofile(p),
                      plugin="blastp")

        t_ret, _ = timeit(retrieve, reps=1)
        rows.append(("table1.retrieve", t_ret * 1e6 / N,
                     f"wall_s={t_ret:.2f};paper=80min"))

        # --- cached retrieve (paper: 12 min, pure copy) ---
        def cached():
            desc = descriptor("up", -1, 2, plugin="blastp")
            assert cache.get(desc) is not None

        t_c, _ = timeit(cached, reps=5)
        rows.append(("table1.retrieve_cached", t_c * 1e6,
                     f"wall_s={t_c:.4f};paper=12min(io-bound)"))

    return rows
