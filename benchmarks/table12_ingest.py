"""Table 12: streaming ingest — chunked shard-parallel waves vs the serial
whole-file path (paper Tables 1/3: release-update cost dominates GeStore).

Rows (value = us per ingested entry; throughput in the derived column):

  * ``table12.ingest_wholefile`` — baseline: read + ``parse_text`` the
    whole release in memory, then one ``ShardedStore.update`` (serial
    per-shard loop).
  * ``table12.ingest_stream`` — the core/ingest.py pipeline: chunked
    parse on a producer thread overlapping shard-parallel update waves.
    ``speedup`` in derived is the acceptance number (target >= 1.5x at
    4 shards on a multi-core host). On a single-CPU host the engine
    auto-degrades to its inline mode (no reader thread, serial waves) —
    there the pipeline cannot overlap anything and the speedup reduces
    to its algorithmic component (direct batch assembly + hoisted
    fingerprints, ~1.0-1.15x); ``cpus`` in derived records which regime
    the number came from.
  * ``table12.ingest_host_bytes`` — transient staging footprint of each
    path: tracemalloc ``peak - end`` (memory allocated during ingest and
    released after — release text, entry strings, stacked batches), which
    excludes the store's resident cells since both paths end in the same
    store state. Value = streaming transient MB; ``ratio`` in derived is
    whole-file/streaming (target >= 4x: the stream is bounded by chunk
    size, the baseline by release size).
  * ``table12.ingest_resume`` — journaled ingest killed at half the
    chunks, then resumed on a fresh store load: value = resume us/entry;
    derived records the replayed/parsed split and that the resumed digest
    matches an uninterrupted run.

Scale with ``BENCH_INGEST_N`` (entries), ``BENCH_INGEST_CHUNK`` (reader
chunk chars), ``BENCH_INGEST_BATCH`` (entries per wave),
``BENCH_INGEST_SHARDS``, ``BENCH_INGEST_REPS`` (best-of timing reps).
"""
from __future__ import annotations

import os
import tempfile
import tracemalloc

from repro.core.ingest import (IngestConfig, _cpu_count, ingest_release,
                               write_synth_uniprot)
from repro.core.parsers.uniprot import UniProtParser
from repro.core.shard import ShardedStore

N = int(os.environ.get("BENCH_INGEST_N", 6_000))
CHUNK = int(os.environ.get("BENCH_INGEST_CHUNK", 1 << 17))
BATCH = int(os.environ.get("BENCH_INGEST_BATCH", 1536))
SHARDS = int(os.environ.get("BENCH_INGEST_SHARDS", 4))
REPS = int(os.environ.get("BENCH_INGEST_REPS", 3))

_P = UniProtParser()


def _cfg() -> IngestConfig:
    return IngestConfig(chunk_chars=CHUNK, batch_entries=BATCH)


def _store() -> ShardedStore:
    return ShardedStore("t12", _P.schema(), n_shards=SHARDS,
                        capacity=max(N // SHARDS + N // 8, 64))


def _wholefile(path: str, st: ShardedStore) -> None:
    with open(path, encoding="latin-1") as f:
        text = f.read()
    keys, table = _P.parse_text(text)
    st.update(1, keys, table, label="bench")


def _stream(path: str, st: ShardedStore, **kw) -> object:
    return ingest_release(st, path, _P, 1, label="bench", config=_cfg(),
                          **kw)


def _best_wall(fn, path):
    """Best-of-REPS wall seconds, a fresh store per rep (ingest mutates),
    last rep's store returned for the identity check."""
    import time
    best, st = float("inf"), None
    for _ in range(REPS):
        st = _store()
        t0 = time.perf_counter()
        out = fn(path, st)
        best = min(best, time.perf_counter() - t0)
    return best, st, out


def _transient(fn, path):
    """tracemalloc peak minus the end watermark — staging memory the path
    allocated and freed (release text, entry strings, batch arrays); the
    store's resident cells cancel out since both paths end identically."""
    was_tracing = tracemalloc.is_tracing()
    if not was_tracing:
        tracemalloc.start()
    tracemalloc.reset_peak()
    st = _store()          # alive past the end-watermark read, so the
    fn(path, st)           # store's resident cells cancel out of peak-end
    end, peak = tracemalloc.get_traced_memory()
    del st
    if not was_tracing:
        tracemalloc.stop()
    return max(peak - end, 1)


def run() -> list[tuple[str, float, str]]:
    tmp = tempfile.mkdtemp(prefix="t12_")
    path = os.path.join(tmp, "release.dat")
    nbytes = write_synth_uniprot(path, N, seed=12)

    # warm JAX (route/fingerprint kernels) outside the timed windows, on
    # BOTH paths' shapes — whole-file updates trace at release size, the
    # stream at wave size
    warm = _store()
    _stream(path, warm)
    del warm
    warm = _store()
    _wholefile(path, warm)
    del warm

    wall_a, st_a, _ = _best_wall(_wholefile, path)
    wall_b, st_b, rep = _best_wall(_stream, path)
    bytes_a = _transient(_wholefile, path)
    bytes_b = _transient(_stream, path)

    dig = lambda s: [s.shard(i)._history_digest for i in range(s.n_shards)]
    identical = int(dig(st_a) == dig(st_b))
    eps_a, eps_b = N / wall_a, N / wall_b
    rows = [
        ("table12.ingest_wholefile", wall_a / N * 1e6,
         f"entries_per_s={eps_a:.0f};n={N};shards={SHARDS};"
         f"release_mb={nbytes / 1e6:.1f}"),
        ("table12.ingest_stream", wall_b / N * 1e6,
         f"entries_per_s={eps_b:.0f};speedup={eps_b / eps_a:.2f};"
         f"chunks={rep.n_chunks};identical={identical};n={N};"
         f"shards={SHARDS};cpus={_cpu_count()}"),
        ("table12.ingest_host_bytes", bytes_b / 1e6,
         f"wholefile_mb={bytes_a / 1e6:.2f};stream_mb={bytes_b / 1e6:.2f};"
         f"ratio={bytes_a / bytes_b:.1f};chunk_kb={CHUNK // 1024}"),
    ]

    # resume: journaled ingest killed halfway, resumed on a fresh load
    sdir, jdir = os.path.join(tmp, "store"), os.path.join(tmp, "journal")
    st_c = _store()
    st_c.save(sdir)
    kill_at = max(rep.n_chunks // 2, 1)

    class _Kill(Exception):
        pass

    def killer(i, n, replayed):
        if i == kill_at:
            raise _Kill

    try:
        _stream(path, st_c, journal_dir=jdir, store_dir=sdir,
                on_batch=killer)
    except _Kill:
        pass
    st_d = ShardedStore.load(sdir)
    import time
    t0 = time.perf_counter()
    rep2 = _stream(path, st_d, journal_dir=jdir, store_dir=sdir)
    wall_r = time.perf_counter() - t0
    rows.append((
        "table12.ingest_resume", wall_r / N * 1e6,
        f"replayed={rep2.chunks_replayed};parsed={rep2.entries_parsed};"
        f"entries={rep2.n_entries};"
        f"identical={int(dig(st_d) == dig(st_a))}"))
    return rows
