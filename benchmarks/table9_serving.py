"""Table IX (new): multi-tenant serving latency/throughput through the
front door (serve/frontdoor.py).

The paper's platform serves pinned meta-database versions to many
concurrent analysis jobs; this table drives the closed system end to end
— admission, per-tenant queues, wave batching, dispatch through the plan
cache — with mixed read/update traffic paced at a target QPS (open-loop,
so queueing delay is measured honestly instead of being absorbed by a
stalled load generator). Reads come from BENCH_SERVE_TENANTS reader
tenants round-robin over two stores at pinned released timestamps; every
``UPDATE_EVERY``-th request is a release ingest from a dedicated writer
tenant, so plan-cache epochs roll over mid-run like production.

Rows report the p50 end-to-end latency as ``us_per_call`` (the gated
column) with p99 / achieved-vs-target QPS / wave + rider counts in
``derived``. Scale knobs: BENCH_SERVE_N (rows per store),
BENCH_SERVE_QPS (target request rate), BENCH_SERVE_SECS (duration),
BENCH_SERVE_TENANTS (reader tenants, >= 2 per the acceptance bar).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.store import FieldSchema, VersionedStore
from repro.serve import FrontDoor, FrontDoorConfig

N = int(os.environ.get("BENCH_SERVE_N", 8_000))
QPS = float(os.environ.get("BENCH_SERVE_QPS", 300))
SECS = float(os.environ.get("BENCH_SERVE_SECS", 3.0))
TENANTS = max(2, int(os.environ.get("BENCH_SERVE_TENANTS", 4)))
UPDATE_EVERY = 50          # 1 ingest per 50 requests ~ "mixed" read/update
READ_TS = (10, 20, 30)     # pinned released versions the readers target
STORES = ("uniprot", "refseq")


def _mk_store(name: str, seed: int) -> VersionedStore:
    rng = np.random.default_rng(seed)
    st = VersionedStore(name, [FieldSchema("sequence", 32, "int32"),
                               FieldSchema("length", 1, "int32")])
    keys = [f"{name}-k{i}" for i in range(N)]
    for ts in READ_TS:
        st.update(ts, keys,
                  {"sequence": rng.integers(0, 99, (N, 32)).astype(np.int32),
                   "length": rng.integers(1, 33, (N, 1)).astype(np.int32)})
    return st


def run() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(9)
    stores = {s: _mk_store(s, 9 + i) for i, s in enumerate(STORES)}
    # warm the jit caches for the initial-epoch shapes before pacing
    # starts, or every request queues behind the first wave's compile;
    # post-update epochs still recompile mid-run, as in production
    for st in stores.values():
        st.get_versions(list(READ_TS), fields=["sequence", "length"])
    upd_keys = {s: [f"{s}-k{i}" for i in range(N // 100 or 1)] for s in STORES}
    fd = FrontDoor(stores, config=FrontDoorConfig(max_queue_per_tenant=65536))

    total = max(1, int(QPS * SECS))
    futs = []
    next_ts = dict.fromkeys(STORES, 40)
    with fd:                                        # background dispatcher
        t0 = time.perf_counter()
        for i in range(total):
            pace = t0 + i / QPS                     # open-loop pacing
            while time.perf_counter() < pace:
                time.sleep(0)
            store = STORES[i % len(STORES)]
            if i and i % UPDATE_EVERY == 0:
                nk = len(upd_keys[store])
                table = {"sequence": rng.integers(
                             0, 99, (nk, 32)).astype(np.int32),
                         "length": rng.integers(
                             1, 33, (nk, 1)).astype(np.int32)}
                futs.append(fd.submit_update(
                    "ingest", store, next_ts[store], upd_keys[store], table,
                    full_release=False))
                next_ts[store] += 10
            else:
                tenant = f"reader-{i % TENANTS}"
                ts = READ_TS[int(rng.integers(0, len(READ_TS)))]
                futs.append(fd.submit(tenant, store, ts))
        submit_span = time.perf_counter() - t0
        for f in futs:
            f.result(120)
        span = time.perf_counter() - t0
    s = fd.stats()
    lat, c = s["latency"], s["counters"]
    achieved = c["completed"] / span
    derived_common = (f"target_qps={QPS:.0f};achieved_qps={achieved:.0f};"
                      f"tenants={TENANTS};n={total}")
    rows = [
        ("table9.serve_total", lat["total"]["p50_ms"] * 1e3,
         f"p99_ms={lat['total']['p99_ms']:.2f};{derived_common};"
         f"waves={c['waves']};riders={c['riders']};"
         f"shed={c['shed_deadline'] + c['rejected_pressure']}"),
        ("table9.serve_exec", lat["exec"]["p50_ms"] * 1e3,
         f"p99_ms={lat['exec']['p99_ms']:.2f};"
         f"scan_p50_ms={lat['scan']['p50_ms']:.2f};"
         f"gather_p50_ms={lat['gather']['p50_ms']:.2f};"
         f"materialize_p50_ms={lat['materialize']['p50_ms']:.2f}"),
        ("table9.serve_throughput", 1e6 / achieved,
         f"{derived_common};"
         f"submit_span_s={submit_span:.2f};drain_span_s={span:.2f}"),
    ]
    return rows
